"""L2 correctness: TinyMLLM prefill/decode/encoder semantics.

The key contracts the Rust runtime relies on:
  * prefill(padded prompt) == prefill(exact prompt) for the real rows
    (padding invariance);
  * the prefill->decode KV-cache path reproduces no-cache greedy generation
    token-for-token;
  * batched decode with padded slots matches single-request decode;
  * encoder output is deterministic and shaped [P, D].
"""

import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

P = M.init_params()


def _embed(ids):
    return jnp.take(P["tok_embed"], jnp.asarray(ids, dtype=jnp.int32), axis=0)


def _pad(emb, L):
    return jnp.pad(emb, ((0, L - emb.shape[0]), (0, 0)))


def _kv_len(kv, length):
    """The rows of kv that are semantically meaningful."""
    return np.asarray(kv)[:, :, :, :length, :]


class TestPrefill:
    def test_padding_invariance(self):
        rng = np.random.default_rng(0)
        ids = rng.integers(0, M.VOCAB, size=20)
        emb = _embed(ids)
        lg32, kv32 = M.prefill_fn(P, _pad(emb, 32), jnp.int32(20))
        lg64, kv64 = M.prefill_fn(P, _pad(emb, 64), jnp.int32(20))
        np.testing.assert_allclose(np.asarray(lg32), np.asarray(lg64),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(_kv_len(kv32, 20), _kv_len(kv64, 20),
                                   rtol=1e-5, atol=1e-5)

    def test_kv_shape_padded_to_max_seq(self):
        emb = _embed([1, 2, 3])
        _, kv = M.prefill_fn(P, _pad(emb, 32), jnp.int32(3))
        assert kv.shape == (M.N_LAYERS, 2, M.N_HEADS, M.MAX_SEQ, M.HEAD_DIM)
        # rows >= bucket L are zero (jnp.pad)
        assert np.all(np.asarray(kv)[:, :, :, 32:, :] == 0.0)

    def test_logits_at_true_length(self):
        """Changing pad content must not change the logits."""
        rng = np.random.default_rng(1)
        ids = rng.integers(0, M.VOCAB, size=10)
        emb = _pad(_embed(ids), 32)
        noisy = emb.at[10:].set(
            jnp.asarray(rng.standard_normal((22, M.D_MODEL)), jnp.float32))
        lg_a, _ = M.prefill_fn(P, emb, jnp.int32(10))
        lg_b, _ = M.prefill_fn(P, noisy, jnp.int32(10))
        np.testing.assert_allclose(np.asarray(lg_a), np.asarray(lg_b),
                                   rtol=1e-5, atol=1e-5)


class TestDecode:
    def _prefill(self, ids, bucket=32):
        emb = _embed(ids)
        logits, kv = M.prefill_fn(P, _pad(emb, bucket), jnp.int32(len(ids)))
        return logits, kv

    def test_matches_nocache_reference(self):
        rng = np.random.default_rng(2)
        ids = rng.integers(0, M.VOCAB, size=9)
        ref = M.reference_generate(_embed(ids), 5)
        logits, kv = self._prefill(ids)
        toks = [int(jnp.argmax(logits))]
        kvb = kv[None]
        lengths = jnp.array([len(ids)], jnp.int32)
        for _ in range(4):
            lg, kvb = M.decode_fn(
                P, jnp.array([toks[-1]], jnp.int32), kvb, lengths)
            toks.append(int(jnp.argmax(lg[0])))
            lengths = lengths + 1
        assert toks == ref

    def test_batch_padding_slots_inert(self):
        """A padded batch slot must not perturb real slots."""
        rng = np.random.default_rng(3)
        ids = rng.integers(0, M.VOCAB, size=7)
        _, kv = self._prefill(ids)
        tok = jnp.array([5], jnp.int32)
        lg1, _ = M.decode_fn(P, tok, kv[None],
                             jnp.array([7], jnp.int32))
        # same request in a 4-slot batch with garbage in the pad slots
        kv4 = jnp.stack([kv,
                         jnp.ones_like(kv) * 9.0,
                         jnp.zeros_like(kv),
                         jnp.ones_like(kv) * -3.0])
        lg4, _ = M.decode_fn(P, jnp.array([5, 1, 2, 3], jnp.int32), kv4,
                             jnp.array([7, 0, 0, 0], jnp.int32))
        np.testing.assert_allclose(np.asarray(lg1[0]), np.asarray(lg4[0]),
                                   rtol=1e-5, atol=1e-5)

    def test_two_concurrent_requests(self):
        """Batched decode == each request decoded alone."""
        rng = np.random.default_rng(4)
        ids_a = rng.integers(0, M.VOCAB, size=6)
        ids_b = rng.integers(0, M.VOCAB, size=11)
        lg_a, kv_a = self._prefill(ids_a)
        lg_b, kv_b = self._prefill(ids_b)
        t_a, t_b = int(jnp.argmax(lg_a)), int(jnp.argmax(lg_b))

        solo_a, _ = M.decode_fn(P, jnp.array([t_a], jnp.int32), kv_a[None],
                                jnp.array([6], jnp.int32))
        solo_b, _ = M.decode_fn(P, jnp.array([t_b], jnp.int32), kv_b[None],
                                jnp.array([11], jnp.int32))
        both, _ = M.decode_fn(P, jnp.array([t_a, t_b], jnp.int32),
                              jnp.stack([kv_a, kv_b]),
                              jnp.array([6, 11], jnp.int32))
        np.testing.assert_allclose(np.asarray(both[0]), np.asarray(solo_a[0]),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(both[1]), np.asarray(solo_b[0]),
                                   rtol=1e-5, atol=1e-5)

    def test_decode_updates_cache_in_place(self):
        rng = np.random.default_rng(5)
        ids = rng.integers(0, M.VOCAB, size=4)
        _, kv = self._prefill(ids)
        _, kv2 = M.decode_fn(P, jnp.array([7], jnp.int32), kv[None],
                             jnp.array([4], jnp.int32))
        kv2 = np.asarray(kv2[0])
        kv = np.asarray(kv)
        # rows < 4 unchanged, row 4 written, rows > 4 unchanged
        np.testing.assert_allclose(kv2[:, :, :, :4], kv[:, :, :, :4],
                                   rtol=1e-6, atol=1e-6)
        assert np.any(kv2[:, :, :, 4] != kv[:, :, :, 4])
        np.testing.assert_allclose(kv2[:, :, :, 5:], kv[:, :, :, 5:],
                                   rtol=1e-6, atol=1e-6)


class TestEncoder:
    @pytest.mark.parametrize("n_patches", list(M.ENCODER_BUCKETS))
    def test_shapes(self, n_patches):
        rng = np.random.default_rng(6)
        px = jnp.asarray(rng.standard_normal((n_patches, M.PATCH_DIM)),
                         jnp.float32)
        out = M.encoder_fn(P, px)[0]
        assert out.shape == (n_patches, M.D_MODEL)
        assert np.all(np.isfinite(np.asarray(out)))

    def test_deterministic(self):
        rng = np.random.default_rng(7)
        px = jnp.asarray(rng.standard_normal((16, M.PATCH_DIM)), jnp.float32)
        a = np.asarray(M.encoder_fn(P, px)[0])
        b = np.asarray(M.encoder_fn(P, px)[0])
        np.testing.assert_array_equal(a, b)

    def test_patch_permutation_changes_output(self):
        """Positions are real: permuting patches must change embeddings."""
        rng = np.random.default_rng(8)
        px = jnp.asarray(rng.standard_normal((16, M.PATCH_DIM)), jnp.float32)
        a = np.asarray(M.encoder_fn(P, px)[0])
        b = np.asarray(M.encoder_fn(P, px[::-1])[0])
        assert not np.allclose(a, b[::-1])


class TestEmbed:
    def test_embed_rows(self):
        ids = jnp.array([0, 5, M.VOCAB - 1], jnp.int32)
        out = M.embed_fn(P, ids)[0]
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(P["tok_embed"])[np.asarray(ids)])
