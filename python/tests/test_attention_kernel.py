"""L1 correctness: the Pallas flash-attention kernel vs the pure-jnp oracle.

This is the CORE correctness signal for the kernel layer: hypothesis sweeps
shapes/dtypes/causality and asserts allclose against kernels.ref.
"""

import hypothesis
import hypothesis.strategies as st
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.attention import (flash_attention, pick_block,
                                       vmem_footprint_bytes)
from compile.kernels.ref import attention_ref

hypothesis.settings.register_profile(
    "kernel", deadline=None, max_examples=40,
    suppress_health_check=[hypothesis.HealthCheck.too_slow])
hypothesis.settings.load_profile("kernel")


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


def _check(h, sq, sk, d, causal, dtype=jnp.float32, seed=0, **blocks):
    rng = np.random.default_rng(seed)
    q = _rand(rng, (h, sq, d), dtype)
    k = _rand(rng, (h, sk, d), dtype)
    v = _rand(rng, (h, sk, d), dtype)
    out = flash_attention(q, k, v, causal=causal, **blocks)
    ref = attention_ref(q, k, v, causal=causal)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=tol, atol=tol)


# ----------------------------------------------------------------------
# Deterministic cases: exact tile boundaries, chunked-prefill offsets,
# single-row queries (decode-like), MXU-sized tiles.
# ----------------------------------------------------------------------
@pytest.mark.parametrize("h,sq,sk,d,causal", [
    (1, 1, 1, 8, True),        # degenerate single element
    (1, 1, 64, 32, True),      # decode-shaped: one query over a long cache
    (2, 16, 16, 8, True),      # single tile
    (4, 128, 128, 64, True),   # exact MXU tile
    (2, 256, 256, 32, True),   # multiple tiles both dims
    (1, 8, 32, 16, True),      # chunked prefill: q is trailing chunk
    (1, 32, 96, 16, True),     # chunk offset not tile-aligned
    (2, 7, 21, 8, False),      # ragged, bidirectional (vision encoder)
    (3, 48, 48, 48, False),    # PATCH_DIM-sized head, encoder shape
])
def test_matches_ref(h, sq, sk, d, causal):
    _check(h, sq, sk, d, causal)


@pytest.mark.parametrize("bq,bk", [(8, 8), (16, 32), (128, 128), (64, 16)])
def test_block_shape_invariance(bq, bk):
    """Output must be identical regardless of tiling (pure optimization)."""
    _check(2, 128, 128, 32, True, block_q=bq, block_k=bk)


def test_bfloat16_inputs():
    _check(2, 32, 32, 16, True, dtype=jnp.bfloat16)


def test_large_logit_stability():
    """Online softmax must not overflow for large logits."""
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.standard_normal((1, 32, 16)).astype(np.float32) * 30)
    k = jnp.asarray(rng.standard_normal((1, 32, 16)).astype(np.float32) * 30)
    v = jnp.asarray(rng.standard_normal((1, 32, 16)).astype(np.float32))
    out = flash_attention(q, k, v, causal=True)
    assert np.all(np.isfinite(np.asarray(out)))
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-4, atol=1e-4)


# ----------------------------------------------------------------------
# Hypothesis sweep: arbitrary shapes within CPU-feasible bounds.
# ----------------------------------------------------------------------
@hypothesis.given(
    h=st.integers(1, 4),
    sq=st.integers(1, 96),
    extra_k=st.integers(0, 64),
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_shapes(h, sq, extra_k, d, causal, seed):
    sk = sq + extra_k  # seq_k >= seq_q: the chunked-prefill contract
    _check(h, sq, sk, d, causal, seed=seed)


@hypothesis.given(n=st.integers(1, 4096), pref=st.sampled_from([8, 64, 128]))
def test_pick_block_divides(n, pref):
    b = pick_block(n, pref)
    assert 1 <= b <= min(n, pref)
    assert n % b == 0


def test_vmem_footprint_within_budget():
    """DESIGN.md §Perf: default tiles must fit comfortably in 16 MB VMEM."""
    assert vmem_footprint_bytes(128, 128, 128) < 16 * 1024 * 1024
    # and leave room for double buffering at the largest head_dim we use
    assert vmem_footprint_bytes(128, 128, 256) < 16 * 1024 * 1024
