"""AOT path: manifest/weights/HLO-text contract the Rust runtime parses.

These tests exercise compile.aot without re-lowering every bucket (slow-ish
in CI): they lower the smallest bucket of each entry point and validate the
interchange invariants (entry parameter order = weights then inputs, HLO
text parses structurally, weights.bin layout matches the manifest).
"""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile import model as M


def test_flat_params_order_is_sorted():
    names = [n for n, _ in aot.flat_params()]
    assert names == sorted(names)
    assert len(names) == len(set(names))


def test_flat_params_covers_everything():
    total = sum(a.size for _, a in aot.flat_params())
    leaves = jax.tree_util.tree_leaves(M.init_params())
    assert total == sum(int(np.prod(l.shape)) for l in leaves)


def test_artifact_specs_cover_all_buckets():
    names = {n for n, _, _ in aot.artifact_specs()}
    for L in M.PREFILL_BUCKETS:
        assert f"prefill_{L}" in names and f"embed_{L}" in names
    for B in M.DECODE_BUCKETS:
        assert f"decode_{B}" in names
    for Pn in M.ENCODER_BUCKETS:
        assert f"encoder_{Pn}" in names


@pytest.mark.parametrize("name", ["embed_32", "prefill_32", "encoder_16",
                                  "decode_1"])
def test_hlo_text_entry_signature(name):
    spec = {n: (f, a) for n, f, a in aot.artifact_specs()}[name]
    fn, example_args = spec
    params = M.init_params()
    lowered = jax.jit(fn, keep_unused=True).lower(params, *example_args)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text and "ENTRY" in text
    # entry params = weight leaves + example inputs, in that order
    entry = text[text.index("ENTRY"):]
    n_params = entry.count(" parameter(")
    assert n_params == len(aot.flat_params()) + len(example_args)
    # weights come first: parameter(0) must have the first leaf's shape
    first_shape = aot.flat_params()[0][1].shape
    dims = ",".join(map(str, first_shape))
    assert f"f32[{dims}]" in entry.split("parameter(0)")[0].rsplit("=", 1)[1]


def test_weights_bin_roundtrip(tmp_path):
    manifest = []
    n = aot.dump_weights(str(tmp_path), manifest)
    raw = np.fromfile(tmp_path / "weights.bin", dtype="<f4")
    assert raw.size == n
    # manifest offsets slice back to the exact leaves
    entries = [l.split() for l in manifest if l.startswith("weight ")]
    flat = dict(aot.flat_params())
    for _, name, shape_s, off_s, size_s in entries:
        off, size = int(off_s), int(size_s)
        arr = flat[name]
        np.testing.assert_array_equal(raw[off:off + size],
                                      arr.ravel().astype("<f4"))


def test_built_artifacts_if_present():
    """When `make artifacts` has run, validate the on-disk output."""
    art = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
    manifest = os.path.join(art, "manifest.txt")
    if not os.path.exists(manifest):
        pytest.skip("artifacts not built")
    lines = open(manifest).read().splitlines()
    arts = [l.split() for l in lines if l.startswith("artifact ")]
    assert len(arts) >= 2 * len(M.PREFILL_BUCKETS) + len(M.DECODE_BUCKETS) \
        + len(M.ENCODER_BUCKETS)
    for _, name, fname, _digest in arts:
        path = os.path.join(art, fname)
        assert os.path.exists(path), name
        head = open(path).read(64)
        assert head.startswith("HloModule"), name
