"""L2: the TinyMLLM — a small but real multimodal LLM in JAX.

Architecture mirrors the paper's Table-1 template (vision encoder → LLM
backend) at toy scale so the whole stack executes on the CPU PJRT plugin:

  vision encoder : patch-embed → 2 pre-norm transformer blocks (bidirectional
                   attention via the L1 Pallas kernel) → projection into the
                   LLM embedding space (the "multimodal projector").
  LLM backend    : token embedding + learned positions → 2 pre-norm causal
                   transformer blocks (prefill attention = L1 Pallas kernel,
                   decode attention = masked jnp matvec over the KV cache) →
                   RMSNorm → tied-ish LM head.

Weights are generated deterministically from MODEL_SEED and passed to every
entry point as an explicit pytree: aot.py dumps them once to
artifacts/weights.bin in pytree-flatten order (sorted dict keys) and records
each leaf's name/shape/offset in the manifest, so the Rust runtime loads
them once and prepends them to every execute() call.

Shape contract with the Rust runtime (static buckets, see aot.py):
  embed   : ids i32[L]                                  -> f32[L, D]
  encoder : pixels f32[P, PATCH_DIM]                    -> f32[P, D]
  prefill : embeds f32[L, D], length i32[]              ->
              (logits f32[VOCAB], kv f32[LAYERS, 2, HEADS, MAX_SEQ, HEAD_DIM])
  decode  : ids i32[B], kv f32[B, LAYERS, 2, HEADS, MAX_SEQ, HEAD_DIM],
            lengths i32[B]                              ->
              (logits f32[B, VOCAB], kv (updated, same shape))

Padding semantics: prompts are padded *at the end* to the enclosing L
bucket. Causal masking means real rows never attend pad rows, and the KV
rows past `length` are ignored by decode's explicit `k_pos < length` mask,
so padding never affects the numbers (tests assert this).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import flash_attention
from .kernels.ref import rmsnorm_ref

# ---------------------------------------------------------------------------
# Model hyperparameters (one place; aot.py and the Rust manifest read these).
# ---------------------------------------------------------------------------
MODEL_SEED = 20260710
VOCAB = 512
D_MODEL = 128
N_LAYERS = 2
N_HEADS = 4
HEAD_DIM = D_MODEL // N_HEADS
FFN_DIM = 256
MAX_SEQ = 640          # prefill bucket max (512) + decode budget (128)
PATCH_DIM = 48         # 4x4 RGB patches
VIS_LAYERS = 2
VIS_D = 128

PREFILL_BUCKETS = (32, 64, 128, 256, 512)
DECODE_BUCKETS = (1, 2, 4, 8)
ENCODER_BUCKETS = (16, 64, 256)


def _init(rng: np.random.Generator, *shape, scale=None):
    scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
    return jnp.asarray(rng.standard_normal(shape, dtype=np.float32) * scale)


@functools.lru_cache(maxsize=1)
def init_params():
    """Deterministic toy weights. Cached: tracing repeatedly is common."""
    rng = np.random.default_rng(MODEL_SEED)
    p = {}
    p["tok_embed"] = _init(rng, VOCAB, D_MODEL, scale=0.02)
    p["pos_embed"] = _init(rng, MAX_SEQ, D_MODEL, scale=0.02)
    for i in range(N_LAYERS):
        L = {}
        L["ln1"] = jnp.ones((D_MODEL,), jnp.float32)
        L["wq"] = _init(rng, D_MODEL, D_MODEL)
        L["wk"] = _init(rng, D_MODEL, D_MODEL)
        L["wv"] = _init(rng, D_MODEL, D_MODEL)
        L["wo"] = _init(rng, D_MODEL, D_MODEL)
        L["ln2"] = jnp.ones((D_MODEL,), jnp.float32)
        L["w_up"] = _init(rng, D_MODEL, FFN_DIM)
        L["w_down"] = _init(rng, FFN_DIM, D_MODEL)
        p[f"layer_{i}"] = L
    p["ln_f"] = jnp.ones((D_MODEL,), jnp.float32)
    p["lm_head"] = _init(rng, D_MODEL, VOCAB)
    # Vision tower.
    p["patch_proj_w"] = _init(rng, PATCH_DIM, VIS_D)
    p["patch_proj_b"] = jnp.zeros((VIS_D,), jnp.float32)
    p["vis_pos"] = _init(rng, 1024, VIS_D, scale=0.02)
    for i in range(VIS_LAYERS):
        L = {}
        L["ln1"] = jnp.ones((VIS_D,), jnp.float32)
        L["wq"] = _init(rng, VIS_D, VIS_D)
        L["wk"] = _init(rng, VIS_D, VIS_D)
        L["wv"] = _init(rng, VIS_D, VIS_D)
        L["wo"] = _init(rng, VIS_D, VIS_D)
        L["ln2"] = jnp.ones((VIS_D,), jnp.float32)
        L["w_up"] = _init(rng, VIS_D, FFN_DIM)
        L["w_down"] = _init(rng, FFN_DIM, VIS_D)
        p[f"vis_layer_{i}"] = L
    p["vis_ln_f"] = jnp.ones((VIS_D,), jnp.float32)
    p["mm_proj"] = _init(rng, VIS_D, D_MODEL)
    return p


# ---------------------------------------------------------------------------
# Building blocks
# ---------------------------------------------------------------------------
def _split_heads(x):  # [L, D] -> [H, L, hd]
    L = x.shape[0]
    return x.reshape(L, N_HEADS, HEAD_DIM).transpose(1, 0, 2)


def _merge_heads(x):  # [H, L, hd] -> [L, D]
    return x.transpose(1, 0, 2).reshape(x.shape[1], D_MODEL)


def _block(L, x, *, causal):
    """Pre-norm transformer block; attention runs on the L1 Pallas kernel.

    Returns (x_out, k, v) with k/v shaped [H, L, hd] for KV caching.
    """
    h = rmsnorm_ref(x, L["ln1"])
    q = _split_heads(h @ L["wq"])
    k = _split_heads(h @ L["wk"])
    v = _split_heads(h @ L["wv"])
    attn = flash_attention(q, k, v, causal=causal)
    x = x + _merge_heads(attn) @ L["wo"]
    h = rmsnorm_ref(x, L["ln2"])
    x = x + jax.nn.gelu(h @ L["w_up"]) @ L["w_down"]
    return x, k, v


def _decode_block(L, x, k_cache, v_cache, pos, lengths):
    """Single-token block for a batch: x [B, D], caches [B, H, M, hd].

    pos = lengths (the new token's position). Attention is a masked matvec
    over the cache: k_pos <= pos AND k_pos < length+1 (i.e. the cache rows
    written so far plus the new token's own row, which we fold in directly).
    """
    B = x.shape[0]
    h = rmsnorm_ref(x, L["ln1"])
    q = (h @ L["wq"]).reshape(B, N_HEADS, HEAD_DIM)
    k_new = (h @ L["wk"]).reshape(B, N_HEADS, HEAD_DIM)
    v_new = (h @ L["wv"]).reshape(B, N_HEADS, HEAD_DIM)

    # Write the new row into the cache at position `pos` per batch element.
    onehot = (jnp.arange(MAX_SEQ)[None, :] == pos[:, None]).astype(jnp.float32)
    k_cache = k_cache * (1.0 - onehot[:, None, :, None]) + \
        k_new[:, :, None, :] * onehot[:, None, :, None]
    v_cache = v_cache * (1.0 - onehot[:, None, :, None]) + \
        v_new[:, :, None, :] * onehot[:, None, :, None]

    scale = 1.0 / jnp.sqrt(jnp.float32(HEAD_DIM))
    logits = jnp.einsum("bhd,bhmd->bhm", q, k_cache) * scale
    valid = jnp.arange(MAX_SEQ)[None, :] <= pos[:, None]      # [B, M]
    logits = jnp.where(valid[:, None, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    p = jnp.exp(logits - m)
    p = p / jnp.sum(p, axis=-1, keepdims=True)
    attn = jnp.einsum("bhm,bhmd->bhd", p, v_cache).reshape(B, D_MODEL)

    x = x + attn @ L["wo"]
    h = rmsnorm_ref(x, L["ln2"])
    x = x + jax.nn.gelu(h @ L["w_up"]) @ L["w_down"]
    return x, k_cache, v_cache


# ---------------------------------------------------------------------------
# Exported entry points (one AOT artifact per bucket each)
# ---------------------------------------------------------------------------
def embed_fn(p, ids):
    """Token ids -> embeddings (positions added in prefill, not here)."""
    return (jnp.take(p["tok_embed"], ids, axis=0),)


def encoder_fn(p, pixels):
    """Vision tower: flattened patches -> LLM-space embeddings [P, D]."""
    n = pixels.shape[0]
    x = pixels @ p["patch_proj_w"] + p["patch_proj_b"]
    x = x + p["vis_pos"][:n]
    for i in range(VIS_LAYERS):
        x, _, _ = _block(p[f"vis_layer_{i}"], x, causal=False)
    x = rmsnorm_ref(x, p["vis_ln_f"])
    return (x @ p["mm_proj"],)


def prefill_fn(p, embeds, length):
    """Full-prompt prefill over a padded [L, D] embedding buffer.

    Returns last-real-token logits and the KV cache padded to MAX_SEQ
    (rows >= L are zero; rows in [length, L) are garbage-but-ignored, see
    module docstring).
    """
    L = embeds.shape[0]
    x = embeds + p["pos_embed"][:L]
    ks, vs = [], []
    for i in range(N_LAYERS):
        x, k, v = _block(p[f"layer_{i}"], x, causal=True)
        ks.append(k)
        vs.append(v)
    x = rmsnorm_ref(x, p["ln_f"])
    logits = jnp.take(x, length - 1, axis=0) @ p["lm_head"]
    kv = jnp.stack([jnp.stack([k, v]) for k, v in zip(ks, vs)])  # [Ly,2,H,L,hd]
    kv = jnp.pad(kv, ((0, 0), (0, 0), (0, 0), (0, MAX_SEQ - L), (0, 0)))
    return (logits, kv)


def decode_fn(p, ids, kv, lengths):
    """One decode step for a padded batch.

    ids i32[B]; kv f32[B, Ly, 2, H, M, hd]; lengths i32[B] = tokens cached
    so far (the new token lands at position lengths[b]). Inactive batch
    slots (lengths == 0 works: they attend only their own row) are padding.
    """
    B = ids.shape[0]
    x = jnp.take(p["tok_embed"], ids, axis=0)
    x = x + jnp.take(p["pos_embed"], jnp.minimum(lengths, MAX_SEQ - 1), axis=0)
    new_kv = []
    for i in range(N_LAYERS):
        k_cache = kv[:, i, 0]
        v_cache = kv[:, i, 1]
        x, k_cache, v_cache = _decode_block(
            p[f"layer_{i}"], x, k_cache, v_cache,
            jnp.minimum(lengths, MAX_SEQ - 1), lengths)
        new_kv.append(jnp.stack([k_cache, v_cache], axis=1))  # [B,2,H,M,hd]
    x = rmsnorm_ref(x, p["ln_f"])
    logits = x @ p["lm_head"]
    kv_out = jnp.stack(new_kv, axis=1)  # [B, Ly, 2, H, M, hd]
    return (logits, kv_out)


# ---------------------------------------------------------------------------
# Pure-python reference driver (used by tests to cross-check prefill+decode)
# ---------------------------------------------------------------------------
def reference_generate(prompt_embeds, n_new_tokens):
    """Greedy generation without KV caching: re-run full attention each step.

    Ground truth for the prefill->decode KV-cache path.
    """
    p = init_params()
    embeds = prompt_embeds
    out_tokens = []
    for _ in range(n_new_tokens):
        L = embeds.shape[0]
        x = embeds + p["pos_embed"][:L]
        for i in range(N_LAYERS):
            x, _, _ = _block(p[f"layer_{i}"], x, causal=True)
        x = rmsnorm_ref(x, p["ln_f"])
        logits = x[-1] @ p["lm_head"]
        tok = int(jnp.argmax(logits))
        out_tokens.append(tok)
        embeds = jnp.concatenate(
            [embeds, p["tok_embed"][tok][None, :]], axis=0)
    return out_tokens
