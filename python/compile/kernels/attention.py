"""L1 Pallas kernel: tiled online-softmax (flash-attention-style) attention.

This is the prefill hot spot of the MLLM: for multimodal requests the prompt
holds 10^2–10^5 vision tokens, so prefill attention is O(L^2) and dominates
GPU time (paper §2.2, Fig 6). The CUDA formulation tiles Q across
threadblocks and streams K/V through shared memory; the TPU/Pallas rethink
(DESIGN.md §2) is:

  * grid = (heads, q_tiles, kv_tiles) with the KV dimension innermost, so a
    Q tile's online-softmax state stays resident in VMEM scratch while KV
    tiles stream HBM→VMEM via the BlockSpec index maps (the role
    shared-memory double buffering plays on GPUs — Pallas' pipeline emitter
    overlaps the next tile's copy with the current tile's compute);
  * tile shapes are multiples of the MXU systolic array (128) where the
    problem size allows, so both q·kᵀ and p·v land on the MXU;
  * accumulators (m, l, acc) live in VMEM scratch at f32 regardless of
    input dtype — the standard numerically-stable online-softmax recurrence.

`interpret=True` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, so the kernel lowers to plain HLO for this repo's runtime.
Real-TPU efficiency is *estimated* from tile shapes in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # finite stand-in for -inf inside the kernel (avoids NaNs)


def _attention_kernel(q_ref, k_ref, v_ref, o_ref, m_scratch, l_scratch,
                      acc_scratch, *, kv_offset, scale, causal, block_q,
                      block_k):
    """One (head, q_tile, kv_tile) grid step of online-softmax attention.

    Refs arrive pre-tiled by the BlockSpecs: q_ref [1, block_q, d],
    k_ref/v_ref [1, block_k, d], o_ref [1, block_q, d]. Scratch persists
    across the innermost (kv) grid dimension.
    """
    kv_idx = pl.program_id(2)

    # Reset the running softmax state at the first KV tile of each Q tile.
    @pl.when(kv_idx == 0)
    def _init():
        m_scratch[...] = jnp.full_like(m_scratch, NEG_INF)
        l_scratch[...] = jnp.zeros_like(l_scratch)
        acc_scratch[...] = jnp.zeros_like(acc_scratch)

    q = q_ref[0].astype(jnp.float32)  # [bq, d]
    k = k_ref[0].astype(jnp.float32)  # [bk, d]
    v = v_ref[0].astype(jnp.float32)  # [bk, d]

    # MXU-shaped contraction: [bq, d] x [d, bk] -> [bq, bk].
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    if causal:
        # Absolute positions: q rows are the *trailing* chunk of the key
        # sequence (chunked prefill); kv_offset = seq_k - seq_q.
        q_idx = pl.program_id(1)
        q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0) + kv_offset
        k_pos = kv_idx * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(k_pos <= q_pos, s, NEG_INF)

    m_prev = m_scratch[...]          # [bq, 1]
    l_prev = l_scratch[...]          # [bq, 1]
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)

    p = jnp.exp(s - m_new)           # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)  # rescale factor for the old state
    l_new = alpha * l_prev + jnp.sum(p, axis=-1, keepdims=True)

    # [bq, bk] x [bk, d] -> [bq, d], second MXU contraction.
    pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)
    acc_scratch[...] = acc_scratch[...] * alpha + pv
    m_scratch[...] = m_new
    l_scratch[...] = l_new

    # Finalize on the last KV tile.
    @pl.when(kv_idx == pl.num_programs(2) - 1)
    def _finalize():
        o_ref[0] = (acc_scratch[...] /
                    jnp.maximum(l_scratch[...], 1e-30)).astype(o_ref.dtype)


def pick_block(n: int, preferred: int) -> int:
    """Largest divisor of n that is <= preferred (tiles must divide evenly)."""
    b = max(1, min(n, preferred))
    while n % b != 0:
        b -= 1
    return b


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = 128, block_k: int = 128):
    """Tiled attention via pallas_call. Shapes: q [h, sq, d], k/v [h, sk, d].

    Matches kernels.ref.attention_ref to f32 tolerance. Block sizes default
    to the MXU-friendly 128 and are shrunk to the nearest divisor for small
    problem sizes.
    """
    heads, seq_q, head_dim = q.shape
    seq_k = k.shape[1]
    bq = pick_block(seq_q, block_q)
    bk = pick_block(seq_k, block_k)
    scale = 1.0 / (head_dim ** 0.5)
    kv_offset = seq_k - seq_q

    grid = (heads, seq_q // bq, seq_k // bk)
    kernel = functools.partial(
        _attention_kernel, kv_offset=kv_offset, scale=scale, causal=causal,
        block_q=bq, block_k=bk)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, head_dim), lambda h, qi, ki: (h, qi, 0)),
            pl.BlockSpec((1, bk, head_dim), lambda h, qi, ki: (h, ki, 0)),
            pl.BlockSpec((1, bk, head_dim), lambda h, qi, ki: (h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, head_dim), lambda h, qi, ki: (h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((heads, seq_q, head_dim), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),         # running max m
            pltpu.VMEM((bq, 1), jnp.float32),         # running denom l
            pltpu.VMEM((bq, head_dim), jnp.float32),  # output accumulator
        ],
        interpret=True,
    )(q, k, v)


def vmem_footprint_bytes(block_q: int, block_k: int, head_dim: int) -> int:
    """Estimated per-step VMEM residency of the kernel (DESIGN.md §Perf).

    Counts double-buffered input tiles (Pallas pipelines the next HBM→VMEM
    copy during compute), the output tile, and the f32 scratch accumulators.
    """
    f32 = 4
    tiles_in = 2 * (block_q * head_dim + 2 * block_k * head_dim) * f32
    tile_out = block_q * head_dim * f32
    scratch = (block_q * 1 * 2 + block_q * head_dim) * f32
    logits = block_q * block_k * f32  # s/p intermediate
    return tiles_in + tile_out + scratch + logits
