"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package must match its oracle here to float32 tolerance.
The oracles are intentionally naive: materialize the full attention matrix,
use stable softmax, no tiling — they define *what* the kernels compute,
while the kernels define *how* (VMEM tiling, online softmax, MXU-shaped
matmuls).
"""

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, scale: float | None = None):
    """Naive scaled-dot-product attention.

    Args:
      q: [heads, seq_q, head_dim]
      k: [heads, seq_k, head_dim]
      v: [heads, seq_k, head_dim]
      causal: apply a causal mask (seq_q aligned to the *end* of seq_k, the
        convention used for chunked prefill where q is the trailing chunk of
        the full key sequence).
      scale: softmax temperature; defaults to 1/sqrt(head_dim).

    Returns:
      [heads, seq_q, head_dim] float32
    """
    q = q.astype(jnp.float32)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    head_dim = q.shape[-1]
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.float32(head_dim))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    if causal:
        seq_q, seq_k = q.shape[1], k.shape[1]
        # Row i of the chunk corresponds to absolute position seq_k - seq_q + i.
        offset = seq_k - seq_q
        qi = jnp.arange(seq_q)[:, None] + offset
        kj = jnp.arange(seq_k)[None, :]
        mask = kj <= qi
        logits = jnp.where(mask[None, :, :], logits, -jnp.inf)
    m = jnp.max(logits, axis=-1, keepdims=True)
    # Guard fully-masked rows (can only happen with empty chunks).
    m = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.exp(logits - m)
    denom = jnp.sum(p, axis=-1, keepdims=True)
    return jnp.einsum("hqk,hkd->hqd", p, v) / jnp.maximum(denom, 1e-30)


def rmsnorm_ref(x, w, eps: float = 1e-6):
    """RMSNorm over the last dim. x: [..., d], w: [d]."""
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(var + eps)) * w


def patch_embed_ref(pixels, w, b):
    """Vision patch embedding: flatten non-overlapping patches + linear proj.

    pixels: [n_patches, patch_dim]  (preprocessing already flattened patches)
    w: [patch_dim, embed_dim], b: [embed_dim]
    """
    return pixels.astype(jnp.float32) @ w + b
