#!/usr/bin/env python3
"""Validate a Chrome/Perfetto trace_event JSON file emitted by
`tcm-serve simulate --trace-out` (rust/src/obs/trace.rs).

Checks the subset of the trace_event format the exporter uses:

  * top level is an object with a "traceEvents" list;
  * every event is an object with ph in {X, C, M};
  * X (complete) events carry finite ts >= 0, dur >= 0, pid, tid, name;
  * within each (pid, tid), X events are sorted by ts and do not
    overlap (next.ts >= prev.ts + prev.dur, with a 1e-6 us tolerance
    for float rendering);
  * C (counter) events carry finite ts, an args object of finite
    numbers, and per (pid, name) non-decreasing ts;
  * M (metadata) events are thread_name records with a string name in
    args;
  * the trace is non-vacuous: at least one X and one C event.

Exit status 0 on success, 1 on any violation (all violations are
printed, not just the first). stdlib only — no third-party imports.
"""

import json
import math
import sys
from collections import defaultdict

TOL = 1e-6  # us; trace.rs renders timestamps with {:.3}


def is_finite_number(x):
    return isinstance(x, (int, float)) and not isinstance(x, bool) and math.isfinite(x)


def check(path):
    errors = []

    def err(msg):
        errors.append(msg)

    try:
        with open(path, encoding="utf-8") as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        return [f"{path}: unreadable or invalid JSON: {e}"]

    if not isinstance(doc, dict) or not isinstance(doc.get("traceEvents"), list):
        return [f"{path}: top level must be an object with a traceEvents list"]

    events = doc["traceEvents"]
    complete = defaultdict(list)  # (pid, tid) -> [(ts, dur, idx)]
    counters = defaultdict(list)  # (pid, name) -> [(ts, idx)]
    n_x = n_c = 0

    for i, ev in enumerate(events):
        if not isinstance(ev, dict):
            err(f"event[{i}]: not an object")
            continue
        ph = ev.get("ph")
        if ph not in ("X", "C", "M"):
            err(f"event[{i}]: unexpected ph {ph!r} (exporter only emits X/C/M)")
            continue

        if ph == "M":
            if ev.get("name") != "thread_name":
                err(f"event[{i}]: M event must be a thread_name record, got {ev.get('name')!r}")
            args = ev.get("args")
            if not isinstance(args, dict) or not isinstance(args.get("name"), str):
                err(f"event[{i}]: M event needs args.name string")
            continue

        ts = ev.get("ts")
        if not is_finite_number(ts) or ts < 0:
            err(f"event[{i}] ({ph}): ts must be a finite number >= 0, got {ts!r}")
            continue
        if "pid" not in ev or not isinstance(ev.get("name"), str) or not ev["name"]:
            err(f"event[{i}] ({ph}): missing pid or name")
            continue

        if ph == "X":
            n_x += 1
            dur = ev.get("dur")
            if not is_finite_number(dur) or dur < 0:
                err(f"event[{i}] (X): dur must be a finite number >= 0, got {dur!r}")
                continue
            if "tid" not in ev:
                err(f"event[{i}] (X): missing tid")
                continue
            complete[(ev["pid"], ev["tid"])].append((ts, dur, i))
        else:  # C
            n_c += 1
            args = ev.get("args")
            if not isinstance(args, dict) or not args:
                err(f"event[{i}] (C): counter needs a non-empty args object")
                continue
            for k, v in args.items():
                if not is_finite_number(v):
                    err(f"event[{i}] (C): args[{k!r}] must be a finite number, got {v!r}")
            counters[(ev["pid"], ev["name"])].append((ts, i))

    for (pid, tid), slices in complete.items():
        prev_end, prev_idx = None, None
        for ts, dur, idx in slices:
            if prev_end is not None and ts < prev_end - TOL:
                err(
                    f"event[{idx}] (X): lane pid={pid} tid={tid} overlaps/regresses: "
                    f"ts={ts} < previous end {prev_end} (event[{prev_idx}])"
                )
            prev_end, prev_idx = ts + dur, idx

    for (pid, name), samples in counters.items():
        prev_ts, prev_idx = None, None
        for ts, idx in samples:
            if prev_ts is not None and ts < prev_ts - TOL:
                err(
                    f"event[{idx}] (C): counter pid={pid} name={name!r} time regressed: "
                    f"ts={ts} < {prev_ts} (event[{prev_idx}])"
                )
            prev_ts, prev_idx = ts, idx

    if n_x == 0:
        err(f"{path}: vacuous trace — no X (complete) events")
    if n_c == 0:
        err(f"{path}: vacuous trace — no C (counter) events")

    if not errors:
        lanes = len(complete)
        print(f"{path}: OK — {n_x} slices across {lanes} lanes, {n_c} counter samples")
    return errors


def main(argv):
    if len(argv) < 2:
        print(f"usage: {argv[0]} TRACE.json [TRACE.json ...]", file=sys.stderr)
        return 2
    failures = 0
    for path in argv[1:]:
        for msg in check(path):
            print(f"FAIL {msg}", file=sys.stderr)
            failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
