#!/usr/bin/env python3
"""Bench-regression gate for CI.

Merges the JSONL sink emitted by the Rust bench harness (one object per
line: name, median_ns, throughput, hot) into a single machine-readable
results file, then compares hot-path entries against a checked-in
baseline and fails when any median regresses beyond the threshold.

Usage:
  python3 tools/bench_compare.py \
      --results BENCH_PR3.jsonl --baseline BENCH_baseline.json \
      --out BENCH_PR3.json --max-regress 0.25

Baseline entries with "median_ns": null are placeholders ("no baseline
recorded yet") and are skipped; refresh the baseline by copying a CI
run's BENCH_PR3.json artifact over BENCH_baseline.json (see
rust/README.md).
"""

import argparse
import json
import sys


def load_results(path):
    benches = []
    with open(path) as f:
        for lineno, line in enumerate(f, 1):
            line = line.strip()
            if not line:
                continue
            try:
                benches.append(json.loads(line))
            except json.JSONDecodeError as e:
                sys.exit(f"{path}:{lineno}: malformed bench record: {e}")
    if not benches:
        sys.exit(f"{path}: no bench records — did the bench run emit BENCH_JSON?")
    return benches


def load_baseline(path):
    with open(path) as f:
        doc = json.load(f)
    entries = doc["benches"] if isinstance(doc, dict) else doc
    return {b["name"]: b for b in entries}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", required=True, help="JSONL sink from the bench run")
    ap.add_argument("--baseline", required=True, help="checked-in BENCH_baseline.json")
    ap.add_argument("--out", required=True, help="merged JSON results to write/upload")
    ap.add_argument(
        "--max-regress",
        type=float,
        default=0.25,
        help="fail when a hot-path median exceeds baseline by this fraction",
    )
    args = ap.parse_args()

    benches = load_results(args.results)
    with open(args.out, "w") as f:
        json.dump({"benches": benches}, f, indent=2)
        f.write("\n")
    print(f"wrote {len(benches)} bench records to {args.out}")

    baseline = load_baseline(args.baseline)
    failures = []
    # a hot baseline entry with no matching result means the gate for
    # that bench was silently disabled (renamed bench, emission bug) —
    # that must fail, not pass quietly
    result_names = {b["name"] for b in benches}
    for name, b in sorted(baseline.items()):
        if b.get("hot") and b.get("median_ns") is not None and name not in result_names:
            failures.append((name, b["median_ns"], float("nan"), float("inf")))
            print(f"  [hot ] {name:<40} MISSING from results (baseline has it)")
    for b in benches:
        name, median = b["name"], b["median_ns"]
        tag = "hot " if b.get("hot") else "info"
        base = baseline.get(name, {}).get("median_ns")
        if base is None:
            print(f"  [{tag}] {name:<40} {median:>14.1f} ns  (no baseline, skipped)")
            continue
        ratio = median / base if base > 0 else float("inf")
        verdict = f"{(ratio - 1):+.1%} vs baseline {base:.1f} ns"
        print(f"  [{tag}] {name:<40} {median:>14.1f} ns  {verdict}")
        if b.get("hot") and ratio > 1.0 + args.max_regress:
            failures.append((name, base, median, ratio))

    if failures:
        print(f"\nFAIL: {len(failures)} hot-path gate violation(s):")
        for name, base, median, ratio in failures:
            if median != median:  # NaN sentinel: bench missing from results
                print(f"  {name}: baseline {base:.1f} ns but no result was emitted")
            else:
                print(f"  {name}: {base:.1f} ns -> {median:.1f} ns ({ratio - 1:+.1%})")
        sys.exit(1)
    print("\nbench gate passed")


if __name__ == "__main__":
    main()
