//! Cluster serving: four engine replicas behind the three router
//! policies on the same arrival trace, plus the encode/prefill-overlap
//! knob — the fleet-level version of the quickstart.
//!
//! Run: `cargo run --release --example cluster_serving`

use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::experiments::run_cluster;
use tcm_serve::report;
use tcm_serve::request::Modality;

fn main() {
    let mut cfg = ServeConfig::default(); // llava-7b, MH, SLO 5x
    cfg.policy = "fcfs".into();
    cfg.rate = 6.0; // 1.5 req/s per replica
    cfg.num_requests = tcm_serve::util::example_requests(600);
    cfg.seed = 42;
    cfg.cluster.replicas = 4;

    println!(
        "cluster: {} replicas, mix {}, {:.1} req/s total, model {}",
        cfg.cluster.replicas, cfg.mix, cfg.rate, cfg.model
    );

    for router in ROUTERS {
        let mut c = cfg.clone();
        c.cluster.router = router.into();
        let cr = run_cluster(&c);
        report::header(&format!("router = {router}"));
        report::modality_rows(router, &cr.report);
        for rs in &cr.per_replica {
            println!(
                "  replica {} routed={:<5} busy={:>8.1}s util={:>5.1}% preempt={}",
                rs.replica,
                rs.routed,
                rs.busy_time_s,
                cr.utilization(rs.replica) * 100.0,
                rs.preemptions
            );
        }
        println!(
            "  makespan={:.1}s imbalance={:.2} slo_attainment={:.1}%",
            cr.makespan,
            cr.imbalance(),
            cr.report.slo_attainment() * 100.0
        );
    }

    report::header("encode/prefill overlap (modality-partition router)");
    for overlap in [false, true] {
        let mut c = cfg.clone();
        c.cluster.router = "modality-partition".into();
        c.cluster.encode_overlap = overlap;
        let cr = run_cluster(&c);
        let img = cr.report.by_modality(Modality::Image);
        let vid = cr.report.by_modality(Modality::Video);
        println!(
            "overlap={overlap:<5} image ttft avg={:.3}s  video ttft avg={:.3}s  makespan={:.1}s",
            img.avg_ttft, vid.avg_ttft, cr.makespan
        );
    }

    println!("\nExpected shape: round-robin lets videos land on every replica, so text");
    println!("p99 TTFT inherits rock head-of-line blocking; the rocks/pebbles/sand");
    println!("partition isolates sand replicas (text p99 drops by orders of magnitude)");
    println!("while idle-borrowing keeps rock replicas from starving the fleet.");
    println!("Encode-overlap hides the vision encoder behind prefill/decode and");
    println!("strictly lowers multimodal TTFT on the same seed.");
}
