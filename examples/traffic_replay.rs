//! Trace persistence + A/B policy comparison: generate a workload trace,
//! save it, reload it, and replay the identical arrival sequence through
//! every scheduling policy — driving the scheduler through its *online*
//! stepping API (`inject` / `step` / `advance_to`), exactly as the server
//! leader does, with requests injected only once virtual time reaches
//! their arrival.
//!
//! The stepped replay is checked bit-identical against the batch
//! `Scheduler::run` wrapper on every policy, so this example doubles as a
//! live demonstration that online stepping and batch simulation agree.
//!
//! This is how external traces (e.g. ServeGen-style production
//! characterizations, converted to the trace line format) plug into the
//! system: `cargo run --release --example traffic_replay -- my.trace`

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::{make_trace, run_sim_with_trace};
use tcm_serve::metrics::Report;
use tcm_serve::policies::build_policy;
use tcm_serve::report;
use tcm_serve::request::Request;
use tcm_serve::workload::{load_trace, save_trace};

/// Replay a trace through the stepping API in virtual time: hold each
/// request outside the scheduler until its arrival, step between
/// injections, and count the events streamed along the way.
fn replay_stepped(cfg: &ServeConfig, trace: &[Request]) -> (Report, u64, u64) {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(cfg, &profile);
    let mut sched = Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&profile)));

    let mut pending = trace.to_vec();
    pending.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let mut iter = pending.into_iter();
    let mut next = iter.next();
    let mut first_tokens = 0u64;
    let mut preemptions = 0u64;

    loop {
        // online injection: only hand over requests that have "arrived"
        while next.as_ref().is_some_and(|r| r.arrival <= sched.now()) {
            sched.inject(next.take().unwrap());
            next = iter.next();
        }
        let outcome = sched.step();
        for ev in sched.take_events() {
            match ev {
                RequestEvent::FirstToken { .. } => first_tokens += 1,
                RequestEvent::Preempted { .. } => preemptions += 1,
                _ => {}
            }
        }
        // jump virtual time to whatever comes first: the scheduler's next
        // internal event or the next external arrival
        let external = next.as_ref().map(|r| r.arrival);
        match outcome {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => {
                sched.advance_to(external.map_or(next_event, |a| next_event.min(a)));
            }
            StepOutcome::Blocked { next_event: Some(t) } => {
                sched.advance_to(external.map_or(t, |a| t.min(a)));
            }
            StepOutcome::Blocked { next_event: None } => match external {
                Some(a) => sched.advance_to(a),
                None => sched.drop_blocked(),
            },
            StepOutcome::Drained => match external {
                Some(a) => sched.advance_to(a),
                None => break,
            },
        }
    }
    (sched.report(), first_tokens, preemptions)
}

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.num_requests = tcm_serve::util::example_requests(300);
    cfg.seed = 77;

    let trace = match std::env::args().nth(1) {
        Some(path) => {
            let trace = load_trace(std::path::Path::new(&path)).expect("load trace");
            println!("replaying external trace {path} ({} requests)", trace.len());
            trace
        }
        None => {
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);
            let path = std::env::temp_dir().join("tcm_demo.trace");
            save_trace(&path, &trace).expect("save trace");
            let reloaded = load_trace(&path).expect("reload");
            assert_eq!(trace.len(), reloaded.len());
            println!(
                "generated + persisted {} requests to {} (round-trip verified)",
                trace.len(),
                path.display()
            );
            reloaded
        }
    };

    report::header("identical trace through every policy (MH, llava-7b) — stepped replay");
    for policy in ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"] {
        let mut c = cfg.clone();
        c.policy = policy.into();

        let (stepped, first_tokens, preemptions) = replay_stepped(&c, &trace);
        let batch = run_sim_with_trace(&c, trace.clone());

        // online stepping and the batch wrapper must agree exactly
        assert_eq!(stepped.outcomes.len(), batch.report.outcomes.len(), "{policy}: outcomes");
        assert_eq!(stepped.failed.len(), batch.report.failed.len(), "{policy}: drops");
        for (a, b) in stepped.outcomes.iter().zip(&batch.report.outcomes) {
            assert_eq!(a.id, b.id, "{policy}: outcome order");
            assert_eq!(
                a.first_token.to_bits(),
                b.first_token.to_bits(),
                "{policy}: ttft diverged for req {}",
                a.id
            );
        }

        report::summary_row(policy, &stepped.overall());
        println!(
            "    streamed: {first_tokens} first-token events, {preemptions} preemption \
             events, {} drops (batch-identical ✓)",
            stepped.failed.len()
        );
    }
}
