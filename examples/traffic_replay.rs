//! Trace persistence + A/B policy comparison: generate a workload trace,
//! save it, reload it, and replay the identical arrival sequence through
//! every scheduling policy.
//!
//! This is how external traces (e.g. ServeGen-style production
//! characterizations, converted to the trace line format) plug into the
//! system: `cargo run --release --example traffic_replay -- my.trace`

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{make_trace, run_sim_with_trace};
use tcm_serve::report;
use tcm_serve::workload::{load_trace, save_trace};

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.num_requests = 300;
    cfg.seed = 77;

    let trace = match std::env::args().nth(1) {
        Some(path) => {
            let trace = load_trace(std::path::Path::new(&path)).expect("load trace");
            println!("replaying external trace {path} ({} requests)", trace.len());
            trace
        }
        None => {
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);
            let path = std::env::temp_dir().join("tcm_demo.trace");
            save_trace(&path, &trace).expect("save trace");
            let reloaded = load_trace(&path).expect("reload");
            assert_eq!(trace.len(), reloaded.len());
            println!(
                "generated + persisted {} requests to {} (round-trip verified)",
                trace.len(),
                path.display()
            );
            reloaded
        }
    };

    report::header("identical trace through every policy (MH, llava-7b)");
    for policy in ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"] {
        let mut c = cfg.clone();
        c.policy = policy.into();
        let r = run_sim_with_trace(&c, trace.clone());
        report::summary_row(policy, &r.report.overall());
    }
}
