//! The client-population workload engine end-to-end: ServeGen-grade
//! traffic (bursty MMPP chat clients, closed-loop agents, best-effort
//! batch, multi-turn sessions with growing context) with a mid-run
//! video-heavy → text-heavy mix flip, replayed through fcfs and tcm.
//!
//! Run with a smaller population via the CI knob:
//!   TCM_EXAMPLE_REQUESTS=40 cargo run --release --example servegen

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{make_trace, run_serve_with_trace};
use tcm_serve::request::Modality;
use tcm_serve::workload::{scale_trace, Category, Mix, PopulationGen, WorkloadSpec};

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.mix = "VH".into();
    cfg.rate = 3.0;
    cfg.num_requests = tcm_serve::util::example_requests(200);
    cfg.seed = 23;
    cfg.workload.engine = "population".into();
    cfg.workload.mix_flip_at_s = 40.0;
    cfg.workload.mix_flip_to = "ML".into();

    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let n = cfg.num_requests;

    // --------------------------------------------------------------
    // who is sending what: categories, sessions, turns
    // --------------------------------------------------------------
    let spec = WorkloadSpec::from_config(&cfg.workload, Mix::by_name(&cfg.mix).unwrap(), cfg.rate);
    let (reqs, meta) = PopulationGen::new(&profile, spec, cfg.seed).generate_with_meta(n);
    println!("population: {} requests from {} clients", reqs.len(), cfg.workload.clients);
    for cat in Category::ALL {
        let idx: Vec<usize> =
            meta.iter().enumerate().filter(|(_, m)| m.category == cat).map(|(i, _)| i).collect();
        let sessions: std::collections::BTreeSet<(u32, u32)> =
            idx.iter().map(|&i| (meta[i].client, meta[i].session)).collect();
        let turns = idx.iter().map(|&i| meta[i].turn + 1).max().unwrap_or(0);
        println!(
            "  {:<6} {:>4} requests in {:>3} sessions (deepest turn {turns}), slo={}",
            cat.name(),
            idx.len(),
            sessions.len(),
            idx.first().and_then(|&i| reqs[i].slo_class).map(|c| c.name()).unwrap_or("-")
        );
    }

    // context growth: the deepest session, turn by turn
    if let Some((client, session)) =
        meta.iter().max_by_key(|m| m.turn).map(|m| (m.client, m.session))
    {
        let mut turns: Vec<(u32, u32)> = meta
            .iter()
            .zip(&reqs)
            .filter(|(m, _)| m.client == client && m.session == session)
            .map(|(m, r)| (m.turn, r.text_tokens))
            .collect();
        turns.sort_unstable();
        let shape: Vec<String> = turns.iter().map(|(t, tok)| format!("t{t}:{tok}")).collect();
        println!("  deepest session (client {client}): context {}", shape.join(" → "));
    }

    // the flip, visible in the modality composition
    let frac_video = |lo: f64, hi: f64| {
        let w: Vec<_> = reqs.iter().filter(|r| r.arrival >= lo && r.arrival < hi).collect();
        100.0 * w.iter().filter(|r| r.modality == Modality::Video).count() as f64
            / w.len().max(1) as f64
    };
    let last = reqs.last().map(|r| r.arrival).unwrap_or(0.0);
    println!(
        "mix flip @ 40s: video share {:.0}% before → {:.0}% after",
        frac_video(0.0, 40.0),
        frac_video(60.0, last + 1.0)
    );

    // --------------------------------------------------------------
    // the same trace through fcfs and tcm
    // --------------------------------------------------------------
    let trace = make_trace(&cfg, &profile);
    println!("\npolicy comparison on the population trace (sand = text requests):");
    for policy in ["fcfs", "tcm"] {
        let mut c = cfg.clone();
        c.policy = policy.into();
        let r = run_serve_with_trace(&c, trace.clone());
        let s = r.by_modality(Modality::Text);
        println!(
            "  {:<5} sand mean-ttft={:>7.3}s p99={:>8.3}s slo={:>5.1}%",
            policy,
            s.avg_ttft,
            s.p99_ttft,
            r.slo_attainment() * 100.0
        );
    }

    // --------------------------------------------------------------
    // k×-scaled replay of the same trace
    // --------------------------------------------------------------
    let scaled = scale_trace(&trace, 3);
    println!(
        "\nscale-x3 replay: {} → {} requests, same shape compressed 3x \
         (ids stable per copy)",
        trace.len(),
        scaled.len()
    );
    let mut c = cfg.clone();
    c.cluster.replicas = 2;
    c.cluster.router = "least-work".into();
    let r = run_serve_with_trace(&c, scaled);
    println!(
        "  2 replicas, tcm: {} finished, slo={:.1}%",
        r.outcomes.len(),
        r.slo_attainment() * 100.0
    );
}
