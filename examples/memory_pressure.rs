//! Memory-pressure study (paper §2.4 + §4.3.2): progressively halve the
//! KV-cache capacity and watch FCFS collapse while TCM-Serve keeps
//! motorcycles responsive.
//!
//! Run: `cargo run --release --example memory_pressure`

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{make_trace, run_sim_with_trace};
use tcm_serve::report;
use tcm_serve::request::Class;

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.num_requests = tcm_serve::util::example_requests(300);
    cfg.seed = 1234;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);

    for policy in ["fcfs", "tcm"] {
        report::header(&format!("{policy} under shrinking KV cache (MH, llava-7b)"));
        for frac in [1.0, 0.5, 0.25, 0.125] {
            let mut c = cfg.clone();
            c.policy = policy.into();
            c.memory_frac = frac;
            let r = run_sim_with_trace(&c, trace.clone());
            let o = r.report.overall();
            let m = r.report.by_class(Class::Motorcycle);
            println!(
                "mem {:>5.1}%  overall: viol={:>5.1}% sev={:>6.2}s  | motorcycles: \
                 ttft={:>6.3}s viol={:>5.1}%  | preemptions={} dropped={}",
                frac * 100.0,
                o.slo_violation_rate * 100.0,
                o.violation_severity,
                m.avg_ttft,
                m.slo_violation_rate * 100.0,
                r.stats.preemptions,
                r.stats.dropped
            );
        }
    }
    println!("\nExpected shape (Fig 4 vs Fig 14): FCFS violations surge toward 90% as");
    println!("memory shrinks; TCM keeps motorcycle TTFT < 1 s even at 25% capacity.");
}
