//! Quickstart: serve a heavy multimodal mix (MH) on the LLaVA-7B cost
//! model with TCM-Serve, and compare against the vLLM FCFS baseline on the
//! *same* arrival trace.
//!
//! Run: `cargo run --release --example quickstart`

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{make_trace, run_sim_with_trace};
use tcm_serve::report;

fn main() {
    let mut cfg = ServeConfig::default(); // llava-7b, MH, 2 req/s, SLO 5x
    cfg.num_requests = tcm_serve::util::example_requests(400);
    cfg.seed = 42;

    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    println!(
        "workload: {} requests, mix {}, {:.1} req/s, model {}",
        trace.len(),
        cfg.mix,
        cfg.rate,
        cfg.model
    );

    for policy in ["fcfs", "tcm"] {
        let mut c = cfg.clone();
        c.policy = policy.into();
        let r = run_sim_with_trace(&c, trace.clone());
        report::header(&format!(
            "{policy} — norm latency / TTFT / SLO by class (M=motorcycle C=car T=truck)"
        ));
        report::mcto_rows(policy, &r.report);
        println!(
            "iterations={} preemptions={} makespan={:.1}s engine-busy={:.1}s",
            r.stats.iterations, r.stats.preemptions, r.makespan, r.stats.busy_time_s
        );
    }

    println!("\nExpected shape (paper Fig 3/10): under FCFS, lightweight text requests");
    println!("(motorcycles) wait tens of seconds behind video prefills; TCM-Serve");
    println!("drops their TTFT to ~0.1-0.2 s while trucks still finish.");
}
