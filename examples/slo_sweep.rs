//! SLO-scale sensitivity + goodput (paper §4.3.3 / Fig 15): sweep the SLO
//! multiplier and report violation rate, severity, and the goodput (max
//! sustainable rate at 90% SLO attainment, DistServe-style).
//!
//! Run: `cargo run --release --example slo_sweep`

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{goodput, run_sim};
use tcm_serve::report;
use tcm_serve::request::Class;

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.num_requests = tcm_serve::util::example_requests(250);
    cfg.policy = "tcm".into();
    cfg.seed = 99;

    report::header("TCM-Serve under varying SLO scales (MH, llava-7b, 2 req/s)");
    for scale in [1.25, 2.5, 5.0, 10.0, 20.0] {
        let mut c = cfg.clone();
        c.slo_scale = scale;
        let r = run_sim(&c);
        print!("slo x{scale:<5}");
        for class in Class::ALL {
            let s = r.report.by_class(class);
            print!(
                "  {}: viol={:>5.1}% sev={:>5.1}s",
                class.short(),
                s.slo_violation_rate * 100.0,
                s.violation_severity
            );
        }
        println!();
    }

    report::header("goodput (max req/s at 90% SLO attainment)");
    for scale in [2.5, 5.0, 10.0] {
        let mut c = cfg.clone();
        c.slo_scale = scale;
        let g = goodput(&c, 0.9, tcm_serve::util::example_requests(150));
        println!("slo x{scale:<5} goodput ≈ {g:.2} req/s");
    }
    println!("\nExpected shape (Fig 15): violations/severity fall and goodput rises");
    println!("monotonically as the SLO relaxes; motorcycles stay best throughout.");
}
