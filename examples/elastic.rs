//! The elastic control plane end-to-end: a text flood flips video-heavy
//! mid-run, and the controller re-partitions the sand/pebble/rock
//! replica groups (drain-then-reassign) and grows the encoder pool —
//! then the same trace replayed with the controller off shows the
//! static split it replaced.
//!
//! Run with a smaller trace via the CI knob:
//!   TCM_EXAMPLE_REQUESTS=40 cargo run --release --example elastic

use tcm_serve::cluster::Cluster;
use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::make_trace;
use tcm_serve::request::Modality;

fn main() {
    let mut cfg = ServeConfig::default();
    cfg.policy = "fcfs".into();
    cfg.mix = "T0".into();
    cfg.rate = 8.0;
    cfg.num_requests = tcm_serve::util::example_requests(300);
    cfg.seed = 23;
    cfg.cluster.replicas = 4;
    cfg.cluster.router = "modality-partition".into();
    cfg.workload.engine = "population".into();
    cfg.workload.mix_flip_at_s = 20.0;
    cfg.workload.mix_flip_to = "VH".into();
    cfg.pool.enabled = true;
    cfg.pool.slots = 1;
    cfg.elastic.enabled = true;
    cfg.elastic.epoch_s = 1.0;
    cfg.elastic.cooldown_epochs = 0;
    cfg.elastic.slots_max = 4;
    cfg.validate().unwrap();

    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    println!(
        "elastic control plane: {} requests, T0 -> VH flip @ {}s, 4 replicas, pool 1..4 slots",
        trace.len(),
        cfg.workload.mix_flip_at_s
    );

    // --------------------------------------------------------------
    // controller on: watch the partition and the pool adapt
    // --------------------------------------------------------------
    let mut cluster = Cluster::new(&cfg);
    let cr = cluster.run(trace.clone());
    let sand = cr.report.by_modality(Modality::Text);
    let e = cr.elastic.as_ref().expect("controller attached");
    let p = cr.pool.as_ref().expect("pool enabled");
    println!("\nwith the controller (epoch {}s):", cfg.elastic.epoch_s);
    println!(
        "  decisions: {} epochs, {} drains, {} repartitions, pool +{}/-{} slot resizes",
        e.stats.epochs,
        e.stats.drains_started,
        e.stats.repartitions,
        e.stats.slot_grows,
        e.stats.slot_shrinks
    );
    println!(
        "  final groups: sand {:?} pebble {:?} rock {:?} | pool peak {} slots",
        e.sand, e.pebble, e.rock, p.max_concurrent_slots
    );
    println!(
        "  every flip waited for an empty replica: max active at flip = {}, KV blocks = {}",
        e.stats.max_active_at_flip, e.stats.max_kv_at_flip
    );
    println!("  sand mean-ttft={:.3}s p99={:.3}s", sand.avg_ttft, sand.p99_ttft);

    // --------------------------------------------------------------
    // controller off: the static 1/1/2 split on the same trace
    // --------------------------------------------------------------
    let mut off = cfg.clone();
    off.elastic.enabled = false;
    let cr_off = Cluster::new(&off).run(trace);
    let sand_off = cr_off.report.by_modality(Modality::Text);
    println!("\nwithout the controller (static split):");
    println!(
        "  sand mean-ttft={:.3}s p99={:.3}s (pool fixed at {} slot)",
        sand_off.avg_ttft,
        sand_off.p99_ttft,
        cr_off.pool.as_ref().map(|p| p.slots).unwrap_or(0)
    );
    println!("\nthe text flood wants sand replicas, the video phase wants rocks and encoder");
    println!("slots; the controller moves both while the static split serves one regime.");
}
