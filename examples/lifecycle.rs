//! The request-lifecycle API end-to-end: submit with a deadline, cancel
//! mid-stream, and get fast rejections under overload — against the same
//! `ServerHandle` whether the backend is one scheduler or a cluster
//! (`Server::spawn_sim` builds whatever the config describes).
//!
//! Run: `cargo run --release --example lifecycle`

use tcm_serve::config::ServeConfig;
use tcm_serve::request::{Modality, Request, SloClass};
use tcm_serve::server::{ResponseEvent, Server, SubmitOptions};

fn text(id: u64, text_tokens: u32, output_tokens: u32) -> Request {
    Request { id, text_tokens, output_tokens, ..Request::default() }
}

fn main() {
    let n = tcm_serve::util::example_requests(24);

    // ---------------------------------------------------------------
    // 1. submit with a deadline + SLO class
    // ---------------------------------------------------------------
    let mut cfg = ServeConfig::default();
    cfg.policy = "tcm".into();
    println!("== deadlines: a critical request with an explicit 2 s budget ==");
    let server = Server::spawn_sim(cfg.clone());
    let h = server.handle();
    let opts = SubmitOptions { deadline_s: Some(2.0), slo_class: Some(SloClass::Critical) };
    let rx = h.submit_with(text(0, 128, 16), opts).expect("server up");
    for i in 0..(n as u64 / 2) {
        // background traffic the critical request competes with
        let mut req = text(1_000 + i, 2_000, 32);
        req.modality = Modality::Image;
        req.mm_tokens = 729;
        let _ = h.submit(req);
    }
    for ev in rx.iter() {
        println!("  critical req 0 → {ev:?}");
    }
    let report = server.finish();
    let o = report.outcomes.iter().find(|o| o.id == 0).expect("critical outcome");
    println!(
        "  slo_latency={}s (the submitted deadline), e2e={:.3}s, met={}",
        o.slo_latency,
        o.e2e(),
        !o.violates_slo()
    );

    // ---------------------------------------------------------------
    // 2. cancel mid-stream
    // ---------------------------------------------------------------
    println!("\n== cancellation: abandon a giant request while it runs ==");
    let server = Server::spawn_sim(cfg.clone());
    let h = server.handle();
    let rx_giant = h.submit(text(0, 200_000, 5_000)).expect("server up");
    let rx_small = h.submit(text(1, 64, 8)).expect("server up");
    // wait for the small one to finish — the giant is mid-prefill
    let _ = rx_small.iter().count();
    h.cancel(0).expect("server up");
    for ev in rx_giant.iter() {
        println!("  giant req 0 → {ev:?}");
    }
    let report = server.finish();
    println!(
        "  finished={} cancelled={} (finished + cancelled == submitted: {})",
        report.outcomes.len(),
        report.cancelled.len(),
        report.total() == 2
    );

    // ---------------------------------------------------------------
    // 3. admission backpressure under overload
    // ---------------------------------------------------------------
    println!("\n== backpressure: admission_limit=4, {n} concurrent submissions ==");
    let mut over = cfg.clone();
    over.cluster.replicas = 2; // same API against a cluster backend
    over.server.admission_limit = 4;
    let server = Server::spawn_sim(over);
    let h = server.handle();
    let mut streams = Vec::new();
    for id in 0..n as u64 {
        streams.push((id, h.submit(text(id, 50_000, 500)).expect("server up")));
    }
    let mut rejected = 0;
    for (id, rx) in &streams {
        if let Some(ResponseEvent::Rejected { .. }) = rx.iter().next() {
            println!("  req {id} rejected immediately (fleet saturated)");
            rejected += 1;
        }
    }
    // the accepted requests are heavyweight; cancel them instead of
    // waiting out their decodes
    for (id, _) in &streams {
        let _ = h.cancel(*id);
    }
    let report = server.finish();
    println!(
        "  accepted={} rejected={} (server saw {} submissions)",
        report.total(),
        report.rejected,
        report.total() as u64 + report.rejected
    );
    assert_eq!(report.rejected, rejected as u64);

    println!("\nThe same ServerHandle drives every backend: deadlines ride the EDF/SLO");
    println!("path, cancels free KV and encoder slots wherever the request sits, and");
    println!("over-limit submissions fail fast instead of queueing forever.");
}
