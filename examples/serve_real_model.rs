//! End-to-end validation driver (DESIGN.md: the mandated real-workload
//! example): load the TinyMLLM AOT artifacts, serve a batched multimodal
//! request stream through the *real* PJRT execution engine with the TCM
//! coordinator, and report latency/throughput measured in wall time.
//!
//! All three layers compose here: the Pallas flash-attention kernel (L1)
//! is inside the prefill HLO (L2), loaded and executed by the Rust
//! coordinator (L3). Requires `make artifacts` and a build with
//! `RUSTFLAGS="--cfg pjrt_runtime"` (the PJRT path needs the external
//! xla + anyhow crates; see rust/README.md).
//!
//! Run: `cargo run --release --example serve_real_model [-- <n_requests>]`

#[cfg(pjrt_runtime)]
mod real {
    use tcm_serve::config::ServeConfig;
    use tcm_serve::coordinator::Scheduler;
    use tcm_serve::engine::real::RealEngine;
    use tcm_serve::experiments::make_trace;
    use tcm_serve::policies::build_policy;
    use tcm_serve::report;
    use tcm_serve::request::Modality;
    use tcm_serve::runtime::Runtime;

    pub fn run() {
        let n: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(48);
        let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.txt").exists() {
            eprintln!("artifacts missing — run `make artifacts` first");
            std::process::exit(1);
        }

        println!("loading + compiling artifacts from {} ...", dir.display());
        let t0 = std::time::Instant::now();
        let rt = Runtime::load(&dir).expect("runtime load");
        println!(
            "compiled {} executables in {:.1}s",
            rt.artifact_names().len(),
            t0.elapsed().as_secs_f64()
        );

        let mut cfg = ServeConfig::default();
        cfg.model = "tiny-mllm".into();
        cfg.policy = "tcm".into();
        cfg.mix = "MH".into();
        cfg.rate = 30.0;
        cfg.num_requests = n;
        cfg.seed = 7;
        cfg.scheduler.atomic_prefill = true; // whole-prompt prefill buckets
        cfg.scheduler.max_running = 8;

        let profile = tcm_serve::model::by_name("tiny-mllm").unwrap();
        let trace = make_trace(&cfg, &profile);
        let by = |m: Modality| trace.iter().filter(|r| r.modality == m).count();
        println!(
            "serving {} requests (text {}, image {}, video {}) at {:.0} req/s (simulated arrivals)",
            n,
            by(Modality::Text),
            by(Modality::Image),
            by(Modality::Video),
            cfg.rate
        );

        let policy = build_policy(&cfg, &profile);
        let engine = Box::new(RealEngine::new(rt));
        let mut sched = Scheduler::new(cfg, policy, engine);

        let wall = std::time::Instant::now();
        let rep = sched.run(trace);
        let wall = wall.elapsed().as_secs_f64();

        report::header("real-engine serving report (wall-clock seconds)");
        report::mcto_rows("tiny-mllm/tcm", &rep);

        let total_tokens: u64 = rep.outcomes.iter().map(|o| o.output_tokens as u64).sum();
        println!(
            "\ncompleted {}/{} requests | wall {:.1}s | engine iterations {} | \
             decode throughput {:.1} tok/s | scheduler planning {} key evals",
            rep.outcomes.len(),
            n,
            wall,
            sched.stats.iterations,
            total_tokens as f64 / wall,
            sched.stats.planning_evals,
        );
        sched.check_invariants().expect("invariants");
        println!("OK — three layers composed: Pallas kernel -> TinyMLLM HLO -> PJRT -> coordinator");
    }
}

#[cfg(pjrt_runtime)]
fn main() {
    real::run();
}

#[cfg(not(pjrt_runtime))]
fn main() {
    eprintln!(
        "serve_real_model needs the PJRT runtime, which is compile-gated: rebuild with \
         RUSTFLAGS=\"--cfg pjrt_runtime\" (requires the xla + anyhow crates, see rust/README.md)."
    );
    std::process::exit(1);
}
