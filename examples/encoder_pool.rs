//! Encoder-pool serving: the same video-heavy trace served by four
//! decode replicas with per-replica encoders vs the disaggregated
//! encoder pool (sand bypasses, pebbles get priority lanes, rocks are
//! capped with aging; decode replicas are late-bound at encode
//! completion, with embedding migration charged across hosts).
//!
//! Run: `cargo run --release --example encoder_pool`

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::run_cluster;
use tcm_serve::report;
use tcm_serve::request::Modality;

fn main() {
    let mut cfg = ServeConfig::default(); // llava-7b, SLO 5x
    cfg.policy = "fcfs".into();
    cfg.mix = "VH".into(); // 40% text, 20% image, 40% video
    cfg.rate = 3.0;
    cfg.num_requests = tcm_serve::util::example_requests(400);
    cfg.seed = 61;
    cfg.cluster.replicas = 4;
    cfg.cluster.router = "round-robin".into();

    println!(
        "encoder pool A/B: {} replicas, mix {}, {:.1} req/s, model {}",
        cfg.cluster.replicas, cfg.mix, cfg.rate, cfg.model
    );

    for pool in [false, true] {
        let mut c = cfg.clone();
        c.pool.enabled = pool;
        c.pool.slots = 6;
        let cr = run_cluster(&c);
        report::header(if pool {
            "disaggregated encoder pool (6 slots)"
        } else {
            "per-replica encoders (PR 3 baseline)"
        });
        report::modality_rows(if pool { "pool" } else { "local" }, &cr.report);
        if let Some(p) = &cr.pool {
            println!(
                "pool: encodes={} util={:.1}% rock_wait_max={:.2}s aged_promotions={} \
                 migrations={} ({:.1} MB)",
                p.stats.encodes,
                cr.pool_utilization() * 100.0,
                p.stats.rock_wait_max_s,
                p.stats.aged_promotions,
                p.stats.migrations,
                p.stats.migrated_bytes as f64 / 1e6
            );
        }
        let sand = cr.report.by_modality(Modality::Text);
        println!(
            "sand mean ttft={:.3}s  makespan={:.1}s  slo_attainment={:.1}%",
            sand.avg_ttft,
            cr.makespan,
            cr.report.slo_attainment() * 100.0
        );
    }

    println!("\nExpected shape: with per-replica encoders, ~40% videos put 2-3 s of");
    println!("encode work inside every replica's iteration loop — sand inherits it");
    println!("through the shared engine. The pool strips encode out of the replicas:");
    println!("sand mean TTFT collapses, rocks absorb pool queueing instead (bounded");
    println!("by the aging deadline), and late binding + migration keep the handoff");
    println!("cost explicit and conserved.");
}
