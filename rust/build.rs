fn main() {
    // Declare the custom cfg gating the PJRT path (see README.md) so
    // rustc's `unexpected_cfgs` lint (1.80+) accepts it; older toolchains
    // ignore the instruction.
    println!("cargo:rustc-check-cfg=cfg(pjrt_runtime)");
    println!("cargo:rerun-if-changed=build.rs");
}
