//! Minimal Rust lexer for simlint: produces a *masked* copy of the source
//! in which comments and string/char literals are blanked to spaces
//! (newlines preserved), plus the list of comments for `simlint: allow`
//! marker parsing.
//!
//! Masking rather than full tokenization keeps byte offsets stable: a
//! finding's offset into the masked text is its offset into the original
//! source, so line numbers and excerpts come straight from the input.
//!
//! Handled: line comments, nested block comments, plain strings with
//! escapes, raw strings `r"…"`/`r#"…"#` (any hash count), byte strings
//! `b"…"`/`br#"…"#`, char literals (including escapes and the quote char
//! `'"'`), and lifetimes/loop labels (left untouched — `'a` is code, not
//! a literal).

/// Masked source. `code` has the same byte length as the input.
pub struct Masked {
    /// The source with comments and literals blanked to spaces.
    pub code: String,
    /// `(byte offset, full comment text including delimiters)`.
    pub comments: Vec<(usize, String)>,
}

fn is_ident(c: u8) -> bool {
    c == b'_' || c.is_ascii_alphanumeric()
}

/// Blank `out[from..to]` to spaces, preserving newlines so line numbers
/// survive masking.
fn blank(out: &mut [u8], from: usize, to: usize) {
    for slot in out[from..to].iter_mut() {
        if *slot != b'\n' {
            *slot = b' ';
        }
    }
}

fn utf8_len(lead: u8) -> usize {
    match lead {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Consume a plain (escaped) string literal starting at the opening `"`.
/// Returns the index just past the closing quote; blanks the whole span.
fn mask_string(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let len = b.len();
    let mut i = start + 1;
    while i < len {
        match b[i] {
            b'\\' => i = (i + 2).min(len),
            b'"' => {
                i += 1;
                break;
            }
            _ => i += 1,
        }
    }
    blank(out, start, i);
    i
}

/// Consume a raw string starting at its opening quote, with `hashes`
/// trailing `#`s required to close. Blanks from the quote (the `r#`
/// prefix is inert for every rule, so it can stay).
fn mask_raw_string(b: &[u8], out: &mut [u8], quote: usize, hashes: usize) -> usize {
    let len = b.len();
    let mut i = quote + 1;
    while i < len {
        if b[i] == b'"' {
            let mut j = i + 1;
            let mut seen = 0;
            while seen < hashes && j < len && b[j] == b'#' {
                seen += 1;
                j += 1;
            }
            if seen == hashes {
                i = j;
                break;
            }
        }
        i += 1;
    }
    blank(out, quote, i);
    i
}

/// Consume a char (or byte-char) literal starting at the opening `'`.
fn mask_char(b: &[u8], out: &mut [u8], start: usize) -> usize {
    let len = b.len();
    let mut i = start + 1;
    while i < len {
        match b[i] {
            b'\\' => i = (i + 2).min(len),
            b'\'' => {
                i += 1;
                break;
            }
            _ => i += 1,
        }
    }
    blank(out, start, i);
    i
}

/// Mask comments and literals out of `src`.
pub fn mask(src: &str) -> Masked {
    let b = src.as_bytes();
    let len = b.len();
    let mut out = b.to_vec();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut i = 0usize;

    while i < len {
        let c = b[i];
        if c == b'/' && i + 1 < len && (b[i + 1] == b'/' || b[i + 1] == b'*') {
            let start = i;
            if b[i + 1] == b'/' {
                while i < len && b[i] != b'\n' {
                    i += 1;
                }
            } else {
                let mut depth = 1u32;
                i += 2;
                while i < len && depth > 0 {
                    if b[i] == b'/' && i + 1 < len && b[i + 1] == b'*' {
                        depth += 1;
                        i += 2;
                    } else if b[i] == b'*' && i + 1 < len && b[i + 1] == b'/' {
                        depth -= 1;
                        i += 2;
                    } else {
                        i += 1;
                    }
                }
            }
            comments.push((start, src[start..i].to_string()));
            blank(&mut out, start, i);
            continue;
        }
        if is_ident(c) {
            // Scan the whole identifier so string prefixes (`r`, `b`,
            // `br`) are recognized exactly and `format!("{r}")`-style
            // names never misparse.
            let start = i;
            while i < len && is_ident(b[i]) {
                i += 1;
            }
            let word = &src[start..i];
            if i < len {
                match (word, b[i]) {
                    ("r", b'"') | ("br", b'"') => i = mask_raw_string(b, &mut out, i, 0),
                    ("r", b'#') | ("br", b'#') => {
                        let mut j = i;
                        let mut hashes = 0;
                        while j < len && b[j] == b'#' {
                            hashes += 1;
                            j += 1;
                        }
                        if j < len && b[j] == b'"' {
                            i = mask_raw_string(b, &mut out, j, hashes);
                        }
                    }
                    ("b", b'"') => i = mask_string(b, &mut out, i),
                    ("b", b'\'') => i = mask_char(b, &mut out, i),
                    _ => {}
                }
            }
            continue;
        }
        if c == b'"' {
            i = mask_string(b, &mut out, i);
            continue;
        }
        if c == b'\'' {
            if i + 1 < len && b[i + 1] == b'\\' {
                i = mask_char(b, &mut out, i);
                continue;
            }
            // `'x'` (x possibly multibyte) is a char literal; `'a` with no
            // closing quote is a lifetime or loop label — plain code.
            let chlen = utf8_len(b.get(i + 1).copied().unwrap_or(0));
            if i + 1 + chlen < len && b[i + 1 + chlen] == b'\'' && b[i + 1] != b'\'' {
                blank(&mut out, i, i + 2 + chlen);
                i += 2 + chlen;
                continue;
            }
            i += 1;
            continue;
        }
        i += 1;
    }

    let code = String::from_utf8(out).expect("masking only writes ASCII spaces");
    Masked { code, comments }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn line_comments_blank_but_keep_newlines() {
        let m = mask("let x = 1; // HashMap here\nlet y = 2;\n");
        assert!(!m.code.contains("HashMap"));
        assert_eq!(m.code.matches('\n').count(), 2);
        assert_eq!(m.comments.len(), 1);
        assert!(m.comments[0].1.contains("HashMap"));
    }

    #[test]
    fn nested_block_comments() {
        let m = mask("a /* outer /* HashMap */ still comment */ b");
        assert!(!m.code.contains("HashMap"));
        assert!(!m.code.contains("still"));
        assert!(m.code.contains('a') && m.code.contains('b'));
        assert_eq!(m.comments.len(), 1);
    }

    #[test]
    fn strings_blank_including_escapes() {
        let m = mask(r#"let s = "Instant::now \" HashMap"; let t = 1;"#);
        assert!(!m.code.contains("Instant"));
        assert!(!m.code.contains("HashMap"));
        assert!(m.code.contains("let t = 1;"));
    }

    #[test]
    fn raw_and_byte_strings() {
        let m = mask(r###"let a = r#"HashMap " still"#; let b = br"SystemTime"; let c = b"x";"###);
        assert!(!m.code.contains("HashMap"));
        assert!(!m.code.contains("still"));
        assert!(!m.code.contains("SystemTime"));
        assert!(m.code.contains("let b ="));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let m = mask(r"fn f<'a>(x: &'a str) -> char { let q = '\''; let z = '\u{41}'; 'x' }");
        assert!(m.code.contains("<'a>"), "lifetime must survive: {}", m.code);
        assert!(m.code.contains("&'a str"));
        assert!(!m.code.contains(r"\u{41}"));
        assert!(!m.code.contains("'x'"));
    }

    #[test]
    fn quote_char_literal_does_not_open_a_string() {
        let m = mask("let q = '\"'; let h = HashMapLike;");
        assert!(m.code.contains("HashMapLike"));
        assert!(!m.code.contains('"'));
    }

    #[test]
    fn masked_length_equals_input() {
        let src = "let s = \"héllo\"; // déjà\nlet x = 'é';\n";
        let m = mask(src);
        assert_eq!(m.code.len(), src.len());
    }

    #[test]
    fn multiline_string_keeps_line_structure() {
        let m = mask("let s = \"one\ntwo\nthree\";\nlet x = 1;");
        assert_eq!(m.code.matches('\n').count(), 3);
        assert!(m.code.contains("let x = 1;"));
        assert!(!m.code.contains("two"));
    }
}
