//! CLI: `simlint --check <src-dir>`.
//!
//! Prints every finding (`file:line: [rule] excerpt`) and every
//! `simlint: allow` marker (justified exceptions stay visible), then a
//! one-line summary. Exit codes: 0 clean, 1 findings, 2 usage/IO error.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dir = match args.as_slice() {
        [flag, dir] if flag == "--check" => dir.clone(),
        _ => {
            eprintln!("usage: simlint --check <src-dir>");
            eprintln!("  e.g. cargo run -p simlint -- --check rust/src");
            return ExitCode::from(2);
        }
    };

    let report = match simlint::lint_dir(Path::new(&dir)) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("simlint: error scanning {dir}: {e}");
            return ExitCode::from(2);
        }
    };

    for f in &report.findings {
        println!("{f}");
    }
    if !report.allows.is_empty() {
        println!("-- justified exceptions ({}) --", report.allows.len());
        for a in &report.allows {
            println!("{a}");
        }
    }
    println!(
        "simlint: {} file(s) scanned, {} finding(s), {} allow marker(s)",
        report.files_scanned,
        report.findings.len(),
        report.allows.len()
    );
    if report.findings.is_empty() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}
