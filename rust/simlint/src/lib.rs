//! simlint: a determinism & panic-safety static-analysis pass over the
//! tcm-serve sim core.
//!
//! The repo's headline guarantee — bit-identical stepped==batch,
//! trait==concrete, pool-off==baseline equivalence — is only as strong as
//! the source tree's discipline: one `HashMap` iteration on a scheduling
//! path, one wall-clock read inside the virtual-time loop, or one
//! `partial_cmp().unwrap()` on an adversarial NaN breaks it. simlint
//! makes that discipline machine-checked:
//!
//! | rule                 | hazard                                        | scope                                   |
//! |----------------------|-----------------------------------------------|-----------------------------------------|
//! | `hash-container`     | `HashMap`/`HashSet` (iteration-order entropy) | sim core¹                               |
//! | `wall-clock`         | `Instant`/`SystemTime`                        | everywhere but `server/`, `bench_harness.rs`, `main.rs` |
//! | `partial-cmp-unwrap` | `partial_cmp(…).unwrap()`/`.expect()`         | all of `rust/src`                       |
//! | `entropy`            | `thread_rng`/`RandomState`/`rand::`/…         | everywhere but `util/rng.rs`            |
//! | `config-panic`       | `.unwrap()`/`.expect()` on parse paths        | `config/`                               |
//!
//! ¹ sim core = `coordinator/`, `cluster/`, `engine/`, `sim/`,
//! `backend.rs`, `request.rs`, `report.rs`.
//!
//! `#[cfg(test)]` / `#[test]` regions are skipped for every rule (tests
//! construct hazards on purpose). A justified exception is annotated
//! inline — `// simlint: allow(<rule>) — <reason>` on the offending line
//! or the line above — and is counted and printed, never silent.

pub mod lexer;

use std::fmt;
use std::path::{Path, PathBuf};

pub const RULE_HASH: &str = "hash-container";
pub const RULE_CLOCK: &str = "wall-clock";
pub const RULE_PARTIAL_CMP: &str = "partial-cmp-unwrap";
pub const RULE_ENTROPY: &str = "entropy";
pub const RULE_CONFIG_PANIC: &str = "config-panic";

/// Every rule id, in report order.
pub const RULES: [&str; 5] =
    [RULE_HASH, RULE_CLOCK, RULE_PARTIAL_CMP, RULE_ENTROPY, RULE_CONFIG_PANIC];

/// One hazard the pass found (after allow-marker suppression).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    /// Path relative to the scanned root, `/`-separated.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    /// The offending source line, trimmed.
    pub excerpt: String,
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.excerpt)
    }
}

/// One `simlint: allow(...)` marker encountered in the tree.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct AllowUse {
    pub file: String,
    pub line: usize,
    pub rules: Vec<String>,
    pub reason: String,
    /// Did the marker actually suppress a finding? Unused markers are
    /// reported so stale annotations surface.
    pub used: bool,
}

impl fmt::Display for AllowUse {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}: allow({}) — {}{}",
            self.file,
            self.line,
            self.rules.join(", "),
            if self.reason.is_empty() { "(no reason)" } else { &self.reason },
            if self.used { "" } else { " [unused]" }
        )
    }
}

/// The pass result over a tree.
#[derive(Debug, Default)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub allows: Vec<AllowUse>,
    pub files_scanned: usize,
}

/// Which rules apply to a file, by its root-relative path.
#[derive(Debug, Clone, Copy)]
struct Scope {
    hash: bool,
    clock: bool,
    partial_cmp: bool,
    entropy: bool,
    config_panic: bool,
}

fn scope_for(rel: &str) -> Scope {
    let sim_core = rel.starts_with("coordinator/")
        || rel.starts_with("cluster/")
        || rel.starts_with("engine/")
        || rel.starts_with("sim/")
        || rel.starts_with("obs/")
        || rel == "backend.rs"
        || rel == "request.rs"
        || rel == "report.rs";
    Scope {
        hash: sim_core,
        clock: !(rel.starts_with("server/") || rel == "bench_harness.rs" || rel == "main.rs"),
        partial_cmp: true,
        entropy: rel != "util/rng.rs",
        config_panic: rel.starts_with("config/"),
    }
}

/// A token over masked code: a word (`[A-Za-z0-9_]+`) or one punct char.
struct Tok<'a> {
    text: &'a str,
    off: usize,
    word: bool,
}

fn tokenize(code: &str) -> Vec<Tok<'_>> {
    let b = code.as_bytes();
    let mut toks = Vec::new();
    let mut i = 0;
    while i < b.len() {
        let c = b[i];
        if c.is_ascii_whitespace() {
            i += 1;
        } else if c == b'_' || c.is_ascii_alphanumeric() {
            let start = i;
            while i < b.len() && (b[i] == b'_' || b[i].is_ascii_alphanumeric()) {
                i += 1;
            }
            toks.push(Tok { text: &code[start..i], off: start, word: true });
        } else {
            let chlen = match c {
                0x00..=0x7F => 1,
                0xC0..=0xDF => 2,
                0xE0..=0xEF => 3,
                _ => 4,
            };
            toks.push(Tok { text: &code[i..i + chlen], off: i, word: false });
            i += chlen;
        }
    }
    toks
}

fn tok_text<'a>(toks: &'a [Tok], k: usize) -> &'a str {
    toks.get(k).map(|t| t.text).unwrap_or("")
}

/// Index of the `)` closing the `(` at `open`, by token-level balance.
fn close_paren(toks: &[Tok], open: usize) -> Option<usize> {
    if tok_text(toks, open) != "(" {
        return None;
    }
    let mut depth = 0i32;
    for (j, t) in toks.iter().enumerate().skip(open) {
        match t.text {
            "(" => depth += 1,
            ")" => {
                depth -= 1;
                if depth == 0 {
                    return Some(j);
                }
            }
            _ => {}
        }
    }
    None
}

/// Byte ranges covered by `#[cfg(test)]` / `#[test]` items (the attribute
/// through the end of the annotated item). Rules skip these: tests build
/// hazards on purpose (NaN injection, wall-clock sanity checks).
fn test_regions(toks: &[Tok], code_len: usize) -> Vec<(usize, usize)> {
    let mut regions = Vec::new();
    let mut k = 0;
    while k < toks.len() {
        let is_attr = tok_text(toks, k) == "#" && tok_text(toks, k + 1) == "[";
        if is_attr {
            let (matched, attr_end) = if tok_text(toks, k + 2) == "test"
                && tok_text(toks, k + 3) == "]"
            {
                (true, k + 4)
            } else if tok_text(toks, k + 2) == "cfg"
                && tok_text(toks, k + 3) == "("
                && tok_text(toks, k + 4) == "test"
                && tok_text(toks, k + 5) == ")"
                && tok_text(toks, k + 6) == "]"
            {
                (true, k + 7)
            } else {
                (false, k)
            };
            if matched {
                let start = toks[k].off;
                let mut j = attr_end;
                let mut depth = 0i32;
                let mut end = code_len;
                while j < toks.len() {
                    match tok_text(toks, j) {
                        "{" => depth += 1,
                        "}" if depth > 0 => {
                            depth -= 1;
                            if depth == 0 {
                                end = toks[j].off + 1;
                                break;
                            }
                        }
                        ";" if depth == 0 => {
                            end = toks[j].off + 1;
                            break;
                        }
                        _ => {}
                    }
                    j += 1;
                }
                regions.push((start, end));
                k = j + 1;
                continue;
            }
        }
        k += 1;
    }
    regions
}

/// Run every in-scope rule over the token stream. Returns raw hits as
/// `(byte offset, rule)` — suppression and test-region filtering happen
/// in [`lint_source`].
fn scan(toks: &[Tok], sc: &Scope) -> Vec<(usize, &'static str)> {
    let mut hits = Vec::new();
    for k in 0..toks.len() {
        let t = &toks[k];
        if !t.word {
            continue;
        }
        let prev_dot = k > 0 && toks[k - 1].text == ".";
        match t.text {
            "HashMap" | "HashSet" if sc.hash => hits.push((t.off, RULE_HASH)),
            "Instant" | "SystemTime" if sc.clock => hits.push((t.off, RULE_CLOCK)),
            "thread_rng" | "from_entropy" | "getrandom" | "RandomState" if sc.entropy => {
                hits.push((t.off, RULE_ENTROPY))
            }
            "rand" if sc.entropy => {
                if tok_text(toks, k + 1) == ":" && tok_text(toks, k + 2) == ":" {
                    hits.push((t.off, RULE_ENTROPY));
                }
            }
            "partial_cmp" if sc.partial_cmp && prev_dot => {
                if let Some(close) = close_paren(toks, k + 1) {
                    if tok_text(toks, close + 1) == "."
                        && matches!(tok_text(toks, close + 2), "unwrap" | "expect")
                    {
                        hits.push((t.off, RULE_PARTIAL_CMP));
                    }
                }
            }
            "unwrap" | "expect" if sc.config_panic && prev_dot => {
                hits.push((t.off, RULE_CONFIG_PANIC))
            }
            _ => {}
        }
    }
    hits
}

fn parse_allow(comment: &str) -> Option<(Vec<String>, String)> {
    let idx = comment.find("simlint: allow(")?;
    let rest = &comment[idx + "simlint: allow(".len()..];
    let close = rest.find(')')?;
    let rules: Vec<String> = rest[..close]
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return None;
    }
    let reason = rest[close + 1..]
        .trim()
        .trim_start_matches(['—', '-', ':', ' '])
        .trim()
        .trim_end_matches("*/")
        .trim()
        .to_string();
    Some((rules, reason))
}

/// Lint one file's source. `rel` is the root-relative `/`-separated path
/// (drives rule scoping). Returns suppressed-filtered findings plus every
/// allow marker seen.
pub fn lint_source(rel: &str, src: &str) -> (Vec<Finding>, Vec<AllowUse>) {
    let masked = lexer::mask(src);
    let toks = tokenize(&masked.code);
    let sc = scope_for(rel);
    let regions = test_regions(&toks, masked.code.len());

    let mut hits = scan(&toks, &sc);
    hits.retain(|&(off, _)| !regions.iter().any(|&(s, e)| s <= off && off < e));

    // Byte offset of each line start, for offset → line mapping.
    let mut line_starts = vec![0usize];
    for (i, b) in src.bytes().enumerate() {
        if b == b'\n' {
            line_starts.push(i + 1);
        }
    }
    let line_of = |off: usize| line_starts.partition_point(|&s| s <= off);
    let src_lines: Vec<&str> = src.lines().collect();
    let masked_lines: Vec<&str> = masked.code.lines().collect();

    // A marker binds to its own line and to the next line that has any
    // masked (i.e. real) code after it — so it works both appended to the
    // offending line and on a line of its own above it.
    struct Marker {
        rules: Vec<String>,
        reason: String,
        line: usize,
        binds: Vec<usize>,
        used: bool,
    }
    let mut markers: Vec<Marker> = Vec::new();
    for (off, text) in &masked.comments {
        if let Some((rules, reason)) = parse_allow(text) {
            let line = line_of(*off);
            let mut binds = vec![line];
            if let Some(next) = (line + 1..=masked_lines.len())
                .find(|&l| !masked_lines[l - 1].trim().is_empty())
            {
                binds.push(next);
            }
            markers.push(Marker { rules, reason, line, binds, used: false });
        }
    }

    let mut findings = Vec::new();
    for (off, rule) in hits {
        let line = line_of(off);
        let suppressed = markers.iter_mut().any(|m| {
            let applies = m.binds.contains(&line) && m.rules.iter().any(|r| r == rule);
            if applies {
                m.used = true;
            }
            applies
        });
        if !suppressed {
            findings.push(Finding {
                file: rel.to_string(),
                line,
                rule,
                excerpt: src_lines.get(line - 1).map(|l| l.trim()).unwrap_or("").to_string(),
            });
        }
    }

    let allows = markers
        .into_iter()
        .map(|m| AllowUse {
            file: rel.to_string(),
            line: m.line,
            rules: m.rules,
            reason: m.reason,
            used: m.used,
        })
        .collect();
    (findings, allows)
}

fn collect_rs(root: &Path, dir: &Path, out: &mut Vec<(String, PathBuf)>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(dir)?.map(|e| e.map(|e| e.path())).collect::<Result<_, _>>()?;
    entries.sort();
    for path in entries {
        if path.is_dir() {
            collect_rs(root, &path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy())
                .collect::<Vec<_>>()
                .join("/");
            out.push((rel, path));
        }
    }
    Ok(())
}

/// Lint every `.rs` file under `root`, deterministically ordered.
pub fn lint_dir(root: &Path) -> std::io::Result<LintReport> {
    let mut files = Vec::new();
    collect_rs(root, root, &mut files)?;
    files.sort();
    let mut report = LintReport::default();
    for (rel, path) in files {
        let src = std::fs::read_to_string(&path)?;
        let (findings, allows) = lint_source(&rel, &src);
        report.findings.extend(findings);
        report.allows.extend(allows);
        report.files_scanned += 1;
    }
    report.findings.sort_by(|a, b| {
        (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule))
    });
    report.allows.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_rule_fires_in_sim_core_only() {
        let src = "use std::collections::HashMap;\n";
        let (in_core, _) = lint_source("coordinator/scheduler.rs", src);
        assert_eq!(in_core.len(), 1);
        assert_eq!(in_core[0].rule, RULE_HASH);
        assert_eq!(in_core[0].line, 1);
        let (outside, _) = lint_source("server/mod.rs", src);
        assert!(outside.is_empty());
    }

    #[test]
    fn readyset_is_inside_the_sim_core_scope() {
        // the indexed ready/run sets are planner state: hash-ordered
        // containers or wall clocks there would break bit-determinism
        // exactly like in scheduler.rs, so the coordinator/ prefix must
        // keep covering the module
        let (hash, _) = lint_source("coordinator/readyset.rs", "use std::collections::HashSet;\n");
        assert_eq!(hash.len(), 1, "hash rule must cover coordinator/readyset.rs");
        assert_eq!(hash[0].rule, RULE_HASH);
        let (clock, _) =
            lint_source("coordinator/readyset.rs", "let t = std::time::Instant::now();\n");
        assert_eq!(clock.len(), 1, "clock rule must cover coordinator/readyset.rs");
        assert_eq!(clock[0].rule, RULE_CLOCK);
    }

    #[test]
    fn elastic_controller_is_inside_the_sim_core_scope() {
        // the elastic controller is planner state: a hash-ordered
        // occupancy map would reorder drain picks and an entropy-jittered
        // epoch would make autoscaling decisions non-replayable, so the
        // cluster/ prefix must keep covering the module
        let (hash, _) = lint_source("cluster/elastic.rs", "use std::collections::HashMap;\n");
        assert_eq!(hash.len(), 1, "hash rule must cover cluster/elastic.rs");
        assert_eq!(hash[0].rule, RULE_HASH);
        let (ent, _) = lint_source("cluster/elastic.rs", "let j = rand::random::<u64>();\n");
        assert_eq!(ent.len(), 1, "entropy rule must cover cluster/elastic.rs");
        assert_eq!(ent[0].rule, RULE_ENTROPY);
    }

    #[test]
    fn clock_rule_exempts_server_bench_main() {
        let src = "let t = std::time::Instant::now();\n";
        for exempt in ["server/mod.rs", "bench_harness.rs", "main.rs"] {
            let (f, _) = lint_source(exempt, src);
            assert!(f.is_empty(), "{exempt} should be exempt");
        }
        let (f, _) = lint_source("coordinator/scheduler.rs", src);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_CLOCK);
    }

    #[test]
    fn partial_cmp_rule_spans_lines_and_spares_unwrap_or() {
        let bad = "xs.sort_by(|a, b| {\n    a.partial_cmp(b)\n        .unwrap()\n});\n";
        let (f, _) = lint_source("util/stats.rs", bad);
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, RULE_PARTIAL_CMP);
        assert_eq!(f[0].line, 2, "finding anchors at the partial_cmp call");

        let ok = "a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal);\n";
        let (f, _) = lint_source("util/stats.rs", ok);
        assert!(f.is_empty(), "unwrap_or is panic-free");

        let def = "fn partial_cmp(&self, other: &Self) -> Option<Ordering> { None }\n";
        let (f, _) = lint_source("sim/mod.rs", def);
        assert!(f.is_empty(), "trait impl definitions are not calls");
    }

    #[test]
    fn nested_call_args_do_not_break_paren_matching() {
        let src = "k(a).partial_cmp(&k(b)).unwrap();\n";
        let (f, _) = lint_source("backend.rs", src);
        assert_eq!(f.len(), 1);
    }

    #[test]
    fn entropy_rule_exempts_util_rng() {
        let src = "let s = RandomState::new();\nlet x = rand::random::<u64>();\n";
        let (f, _) = lint_source("workload/mod.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|f| f.rule == RULE_ENTROPY));
        let (f, _) = lint_source("util/rng.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn config_panic_rule_scoped_to_config() {
        let src = "let x: u32 = s.parse().unwrap();\nlet y: u32 = s.parse().expect(\"bad\");\n";
        let (f, _) = lint_source("config/mod.rs", src);
        assert_eq!(f.len(), 2);
        assert!(f.iter().all(|f| f.rule == RULE_CONFIG_PANIC));
        let (f, _) = lint_source("coordinator/scheduler.rs", src);
        assert!(f.is_empty(), "bare unwrap is only policed in config/");
    }

    #[test]
    fn comments_and_strings_never_trip() {
        let src = "// a HashMap would break determinism\nlet s = \"Instant::now\";\n";
        let (f, _) = lint_source("coordinator/scheduler.rs", src);
        assert!(f.is_empty());
    }

    #[test]
    fn cfg_test_regions_are_skipped() {
        let src = concat!(
            "pub fn ok() {}\n\n",
            "#[cfg(test)]\n",
            "mod tests {\n",
            "    use std::collections::HashMap;\n",
            "    #[test]\n",
            "    fn t() {\n",
            "        let _ = HashMap::<u64, u64>::new();\n",
            "    }\n}\n"
        );
        let (f, _) = lint_source("coordinator/scheduler.rs", src);
        assert!(f.is_empty(), "hazards inside #[cfg(test)] are intentional: {f:?}");
    }

    #[test]
    fn allow_marker_suppresses_same_line_and_next_line() {
        let same = "use std::collections::HashMap; // simlint: allow(hash-container) — justified\n";
        let (f, a) = lint_source("coordinator/scheduler.rs", same);
        assert!(f.is_empty());
        assert_eq!(a.len(), 1);
        assert!(a[0].used);
        assert_eq!(a[0].reason, "justified");

        let above = concat!(
            "// simlint: allow(hash-container) — justified\n",
            "use std::collections::HashMap;\n"
        );
        let (f, a) = lint_source("coordinator/scheduler.rs", above);
        assert!(f.is_empty());
        assert!(a[0].used);
    }

    #[test]
    fn allow_marker_for_other_rule_does_not_suppress() {
        let src = "use std::collections::HashMap; // simlint: allow(wall-clock) — wrong rule\n";
        let (f, a) = lint_source("coordinator/scheduler.rs", src);
        assert_eq!(f.len(), 1);
        assert!(!a[0].used);
    }

    #[test]
    fn unused_markers_are_reported_unused() {
        let src = "// simlint: allow(entropy) — stale\nlet x = 1;\n";
        let (f, a) = lint_source("coordinator/scheduler.rs", src);
        assert!(f.is_empty());
        assert_eq!(a.len(), 1);
        assert!(!a[0].used);
    }
}
