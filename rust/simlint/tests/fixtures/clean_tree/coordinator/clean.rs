//! Fixture: disciplined sim-core code — zero findings. A HashMap named in
//! a comment must not trip the linter, and neither may string literals.

use std::cmp::Ordering;
use std::collections::BTreeMap;

pub struct Ledger {
    pub by_id: BTreeMap<u64, f64>,
}

pub fn order(xs: &mut [f64]) {
    xs.sort_by(|a, b| a.total_cmp(b));
}

pub fn describe() -> &'static str {
    "mentions Instant::now, HashMap and partial_cmp().unwrap() in a string only"
}

pub fn raw() -> &'static str {
    r#"raw string with SystemTime and a " quote"#
}

pub fn panic_free(a: f64, b: f64) -> Ordering {
    a.partial_cmp(&b).unwrap_or(Ordering::Equal)
}

pub fn first<'a>(xs: &'a [char]) -> char {
    *xs.first().unwrap_or(&'"')
}
