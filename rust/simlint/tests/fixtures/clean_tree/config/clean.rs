//! Fixture: disciplined parse path — fallible, no panics.

pub fn parse_rate(s: &str) -> Option<f64> {
    s.trim().parse::<f64>().ok().filter(|x| x.is_finite())
}

pub fn fallback(s: &str) -> f64 {
    s.parse().unwrap_or(1.0)
}
