//! Fixture: the observability layer is sim-core scope — hash containers,
//! wall clocks, and entropy must all fire under `obs/` too (spans and
//! telemetry must be pure functions of the event stream).

use std::collections::HashMap;

pub struct BadRecorder {
    pub spans: HashMap<u64, f64>,
}

impl BadRecorder {
    pub fn new() -> BadRecorder {
        BadRecorder { spans: HashMap::new() }
    }

    pub fn stamp(&self) -> f64 {
        std::time::Instant::now().elapsed().as_secs_f64()
    }

    pub fn sample(&self) -> f64 {
        rand::random::<f64>()
    }
}
