//! Fixture: the elastic control plane is sim-core scope — controller
//! decisions must be pure functions of the epoch inputs, so hash-ordered
//! occupancy maps, wall-clock epoch stamps, NaN-panicking score picks
//! and entropy all fire under `cluster/` too.

use std::collections::HashMap;

pub struct BadController {
    pub occupancy: HashMap<usize, u64>,
}

impl BadController {
    pub fn epoch_stamp(&self) -> std::time::Instant {
        std::time::Instant::now()
    }

    pub fn jittered_epoch(&self) -> f64 {
        rand::random::<f64>()
    }

    pub fn best_group(&self, scores: &[f64]) -> usize {
        let mut idx = 0;
        for (i, s) in scores.iter().enumerate() {
            if s.partial_cmp(&scores[idx]).unwrap() == std::cmp::Ordering::Greater {
                idx = i;
            }
        }
        idx
    }
}
