//! Fixture: D5 `config-panic` must fire on unwrap/expect in config/.

pub fn parse_rate(s: &str) -> f64 {
    s.trim().parse::<f64>().unwrap()
}

pub fn parse_port(s: &str) -> u16 {
    s.parse().expect("invalid port")
}
