//! Fixture: `util/rng.rs` is the one sanctioned entropy boundary — D4
//! does not apply to this path.

pub fn seed() -> u64 {
    from_entropy()
}

fn from_entropy() -> u64 {
    0xA5A5_A5A5
}
