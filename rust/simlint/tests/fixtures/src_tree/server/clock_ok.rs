//! Fixture: the server layer is real-time by nature — D2 is out of scope
//! here, and D1 only polices the sim core.

use std::collections::HashMap;
use std::time::Instant;

pub fn uptime(start: Instant) -> f64 {
    start.elapsed().as_secs_f64()
}

pub fn sessions() -> HashMap<u64, Instant> {
    HashMap::new()
}
