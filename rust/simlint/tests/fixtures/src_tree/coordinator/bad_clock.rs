//! Fixture: D2 `wall-clock` must fire on Instant and SystemTime.

pub fn stamp() -> f64 {
    let t = std::time::Instant::now();
    t.elapsed().as_secs_f64()
}

pub fn epoch() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}
