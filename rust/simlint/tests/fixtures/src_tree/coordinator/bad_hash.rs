//! Fixture: D1 `hash-container` must fire on every HashMap/HashSet token.

use std::collections::{HashMap, HashSet};

pub struct Table {
    pub by_id: HashMap<u64, f64>,
    pub seen: HashSet<u64>,
}

impl Table {
    pub fn new() -> Table {
        Table { by_id: HashMap::new(), seen: HashSet::new() }
    }
}
