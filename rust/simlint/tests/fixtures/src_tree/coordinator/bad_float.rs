//! Fixture: D3 `partial-cmp-unwrap` must fire on unwrap/expect after
//! partial_cmp, including when rustfmt splits the chain across lines.

pub fn sort_scores(xs: &mut [(f64, u64)]) {
    xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
}

pub fn max_score(xs: &[f64]) -> Option<f64> {
    xs.iter().copied().max_by(|a, b| a.partial_cmp(b).expect("nan score"))
}

pub fn min_idx(xs: &[f64]) -> Option<usize> {
    (0..xs.len()).min_by(|&i, &j| {
        xs[i]
            .partial_cmp(&xs[j])
            .unwrap()
    })
}
