//! Fixture: hazards inside `#[cfg(test)]` regions are intentional and
//! must not be flagged.

pub fn double(x: u64) -> u64 {
    x * 2
}

#[cfg(test)]
mod tests {
    use std::collections::HashMap;

    #[test]
    fn hazards_here_are_fine() {
        let mut m = HashMap::new();
        m.insert(1u64, f64::NAN);
        let t = std::time::Instant::now();
        let mut v = vec![2.0, 1.0];
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(t.elapsed().as_secs_f64() >= 0.0);
        assert!(m.len() + v.len() > 1);
    }
}
