//! Fixture: `simlint: allow` markers suppress and are counted, in both
//! the same-line and line-above positions.

use std::collections::HashMap; // simlint: allow(hash-container) — fixture: same-line marker

pub struct Cache {
    // simlint: allow(hash-container) — fixture: marker on the line above
    pub map: HashMap<u64, u64>,
}
