//! Fixture: D4 `entropy` must fire on ambient randomness sources.

pub fn jitter() -> f64 {
    let _state = RandomState::new();
    rand::thread_rng().gen::<f64>()
}
