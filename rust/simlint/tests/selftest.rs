//! Vacuity guard for simlint itself: every rule must fire on its tripping
//! fixture, the clean fixture must pass with zero findings, and — the
//! tier-1 wiring — the real `rust/src` tree must be hazard-free.
//!
//! Fixture trees live under `tests/fixtures/{src_tree,clean_tree}/` and
//! mirror the scoping layout of `rust/src` (coordinator/, cluster/,
//! config/, server/, util/rng.rs).

use simlint::{lint_dir, LintReport, RULES};
use std::path::{Path, PathBuf};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(name)
}

fn lint_fixture(name: &str) -> LintReport {
    lint_dir(&fixture(name)).expect("fixture tree readable")
}

fn count(report: &LintReport, rule: &str) -> usize {
    report.findings.iter().filter(|f| f.rule == rule).count()
}

#[test]
fn every_rule_fires_at_least_once() {
    let report = lint_fixture("src_tree");
    for rule in RULES {
        assert!(
            count(&report, rule) > 0,
            "rule {rule} is vacuous: no finding in the tripping fixtures\n{:#?}",
            report.findings
        );
    }
}

#[test]
fn tripping_fixtures_fire_exact_counts() {
    let report = lint_fixture("src_tree");
    assert_eq!(count(&report, "hash-container"), 11, "{:#?}", report.findings);
    assert_eq!(count(&report, "wall-clock"), 5, "{:#?}", report.findings);
    assert_eq!(count(&report, "partial-cmp-unwrap"), 4, "{:#?}", report.findings);
    assert_eq!(count(&report, "entropy"), 5, "{:#?}", report.findings);
    assert_eq!(count(&report, "config-panic"), 2, "{:#?}", report.findings);
}

#[test]
fn clean_fixture_has_zero_findings() {
    let report = lint_fixture("clean_tree");
    assert!(report.findings.is_empty(), "clean tree flagged:\n{:#?}", report.findings);
    assert!(report.files_scanned >= 2);
}

#[test]
fn allow_markers_suppress_and_are_counted() {
    let report = lint_fixture("src_tree");
    let in_allowed: Vec<_> =
        report.findings.iter().filter(|f| f.file.ends_with("allowed.rs")).collect();
    assert!(in_allowed.is_empty(), "allow markers failed to suppress: {in_allowed:#?}");
    let markers: Vec<_> =
        report.allows.iter().filter(|a| a.file.ends_with("allowed.rs")).collect();
    assert_eq!(markers.len(), 2, "both marker positions counted");
    assert!(markers.iter().all(|m| m.used), "markers must register as used");
    assert!(markers.iter().all(|m| !m.reason.is_empty()), "reasons survive parsing");
}

#[test]
fn test_regions_and_scope_exemptions_are_skipped() {
    let report = lint_fixture("src_tree");
    for exempt in ["test_only.rs", "clock_ok.rs", "util/rng.rs"] {
        let hits: Vec<_> =
            report.findings.iter().filter(|f| f.file.ends_with(exempt)).collect();
        assert!(hits.is_empty(), "{exempt} must produce no findings: {hits:#?}");
    }
}

#[test]
fn findings_are_deterministically_ordered() {
    let a = lint_fixture("src_tree");
    let b = lint_fixture("src_tree");
    assert_eq!(a.findings, b.findings);
    let mut sorted = a.findings.clone();
    sorted.sort_by(|x, y| (&x.file, x.line, x.rule).cmp(&(&y.file, y.line, y.rule)));
    assert_eq!(a.findings, sorted, "report order is (file, line, rule)");
}

/// Tier-1 wiring: the real sim-core tree must stay hazard-free. This is
/// the same check CI runs via `cargo run -p simlint -- --check rust/src`,
/// embedded in `cargo test` so the tree cannot regress silently.
#[test]
fn the_real_tree_is_clean() {
    let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("../src");
    let report = lint_dir(&src).expect("rust/src readable");
    assert!(report.files_scanned > 20, "walked the real tree");
    assert!(
        report.findings.is_empty(),
        "rust/src has unannotated determinism hazards:\n{}",
        report
            .findings
            .iter()
            .map(|f| f.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
    // Justified exceptions stay visible and none may go stale.
    assert!(
        report.allows.iter().all(|a| a.used),
        "stale allow markers:\n{}",
        report
            .allows
            .iter()
            .filter(|a| !a.used)
            .map(|a| a.to_string())
            .collect::<Vec<_>>()
            .join("\n")
    );
}
