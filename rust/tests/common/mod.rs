//! Helpers shared across the integration-test binaries (`mod common;`).

use tcm_serve::metrics::Report;

/// Assert two reports are bit-for-bit identical: same outcomes in the
/// same order with bit-equal timestamps and preemption counts, and the
/// same failures. This is the repo's definition of "bit-identical" for
/// cluster/pool equivalence claims — one copy, so every suite pins the
/// same thing.
pub fn assert_reports_bit_identical(label: &str, a: &Report, b: &Report) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{label}: outcome counts");
    assert_eq!(a.failed.len(), b.failed.len(), "{label}: failure counts");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{label}: outcome order");
        assert_eq!(
            x.first_token.to_bits(),
            y.first_token.to_bits(),
            "{label}: req {} first_token",
            x.id
        );
        assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{label}: req {} finish", x.id);
        assert_eq!(x.preemptions, y.preemptions, "{label}: req {} preemptions", x.id);
    }
    for (x, y) in a.failed.iter().zip(&b.failed) {
        assert_eq!(x.id, y.id, "{label}: failed order");
        assert_eq!(
            x.dropped_at.to_bits(),
            y.dropped_at.to_bits(),
            "{label}: req {} dropped_at",
            x.id
        );
    }
    assert_eq!(a.cancelled.len(), b.cancelled.len(), "{label}: cancellation counts");
    for (x, y) in a.cancelled.iter().zip(&b.cancelled) {
        assert_eq!(x.id, y.id, "{label}: cancelled order");
        assert_eq!(
            x.cancelled_at.to_bits(),
            y.cancelled_at.to_bits(),
            "{label}: req {} cancelled_at",
            x.id
        );
    }
}
