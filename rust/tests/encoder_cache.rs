//! Encoder-cache preemption invariant (regression tests for the
//! encode-overlap refactor): the `encoded` flag is preserved while a
//! multimodal request stays resident — the engine must see exactly ONE
//! `EncodeItem` for a request that is never preempted — and is cleared
//! by preemption-by-recompute, so every preemption is followed by
//! exactly one re-encode on re-admission. Previously asserted only in
//! comments (`scheduler.rs`, `preempt`).

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::engine::{Engine, StepPlan};
use tcm_serve::experiments::make_trace;
use tcm_serve::metrics::Report;
use tcm_serve::policies::build_policy;
use tcm_serve::request::Modality;

/// Wraps the sim engine and counts executed `EncodeItem`s per request —
/// the ground truth for what the vision encoder actually ran.
struct RecordingEngine {
    inner: SimEngine,
    encodes: Rc<RefCell<HashMap<u64, u32>>>,
}

impl Engine for RecordingEngine {
    fn execute(&mut self, plan: &StepPlan) -> f64 {
        let mut counts = self.encodes.borrow_mut();
        for e in &plan.encodes {
            *counts.entry(e.req_id).or_insert(0) += 1;
        }
        drop(counts);
        self.inner.execute(plan)
    }

    fn release(&mut self, req_id: u64) {
        self.inner.release(req_id);
    }

    fn name(&self) -> &'static str {
        "recording-sim"
    }
}

/// Run one memory-pressured experiment, returning (report, per-request
/// encode counts, per-request preemption-event counts).
fn run_recorded(policy: &str, seed: u64) -> (Report, HashMap<u64, u32>, HashMap<u64, u32>) {
    let mut cfg = ServeConfig::default();
    cfg.policy = policy.into();
    cfg.mix = "MH".into();
    cfg.num_requests = 60;
    cfg.memory_frac = 0.02;
    cfg.seed = seed;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let policy = build_policy(&cfg, &profile);

    let encodes = Rc::new(RefCell::new(HashMap::new()));
    let engine = RecordingEngine { inner: SimEngine::new(&profile), encodes: Rc::clone(&encodes) };
    let mut sched = Scheduler::new(cfg, policy, Box::new(engine));
    for req in trace {
        sched.inject(req);
    }
    let mut preempts: HashMap<u64, u32> = HashMap::new();
    loop {
        match sched.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
            StepOutcome::Drained => break,
        }
        for ev in sched.take_events() {
            if let RequestEvent::Preempted { id, .. } = ev {
                *preempts.entry(id).or_insert(0) += 1;
            }
        }
    }
    let report = sched.report();
    let encodes = encodes.borrow().clone();
    (report, encodes, preempts)
}

/// Every *completed* multimodal request must have been encoded exactly
/// `1 + preemptions` times: once at first admission, once more after
/// each preemption-by-recompute (which drops the encoder cache), and
/// never in between (the cache is preserved while resident). The
/// scenario is validated to actually preempt multimodal requests, so
/// the "cleared on preemption" half cannot pass vacuously.
#[test]
fn encoded_cleared_on_preemption_and_preserved_while_resident() {
    let mut saw_preempted_multimodal = false;
    for policy in ["tcm", "fcfs"] {
        for seed in [7u64, 11, 13, 23, 42] {
            let (report, encodes, preempts) = run_recorded(policy, seed);
            for o in &report.outcomes {
                if o.modality == Modality::Text {
                    assert!(
                        !encodes.contains_key(&o.id),
                        "{policy}/{seed}: text request {} reached the encoder",
                        o.id
                    );
                    continue;
                }
                let enc = encodes.get(&o.id).copied().unwrap_or(0);
                let pre = preempts.get(&o.id).copied().unwrap_or(0);
                assert_eq!(
                    enc,
                    1 + pre,
                    "{policy}/{seed}: multimodal request {} encoded {enc}x with {pre} \
                     preemptions (expected 1 + preemptions)",
                    o.id
                );
                if pre > 0 {
                    saw_preempted_multimodal = true;
                }
            }
            // dropped requests encode at most once per admission cycle too
            for f in &report.failed {
                if f.modality != Modality::Text {
                    let enc = encodes.get(&f.id).copied().unwrap_or(0);
                    let pre = preempts.get(&f.id).copied().unwrap_or(0);
                    assert!(
                        enc <= 1 + pre,
                        "{policy}/{seed}: dropped request {} encoded {enc}x with {pre} \
                         preemptions",
                        f.id
                    );
                }
            }
        }
    }
    assert!(
        saw_preempted_multimodal,
        "no multimodal request was ever preempted — the invariant was never exercised; \
         tighten memory_frac or change seeds"
    );
}
