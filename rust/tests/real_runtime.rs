//! Integration tests over the real PJRT path: artifacts must exist
//! (`make artifacts`); tests skip gracefully when they don't so
//! `cargo test` works pre-build. The whole file is gated behind
//! `RUSTFLAGS="--cfg pjrt_runtime"` because the PJRT runtime needs the
//! external xla + anyhow crates (see rust/README.md).
//!
//! The golden test is the cross-language correctness anchor: the Rust
//! runtime must reproduce JAX's greedy transcript token-for-token through
//! HLO text → PJRT compile → execute, proving L1 (Pallas kernel), L2
//! (model) and the Rust runtime agree.

#![cfg(pjrt_runtime)]

use std::path::PathBuf;
use tcm_serve::runtime::{literal_f32, Input, Runtime};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.txt").exists() && dir.join("prefill_32.hlo.txt").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: run `make artifacts` first");
        None
    }
}

fn load(keep: &[&str]) -> Option<Runtime> {
    let dir = artifacts_dir()?;
    let keep: Vec<String> = keep.iter().map(|s| s.to_string()).collect();
    Some(Runtime::load_filtered(&dir, |n| keep.iter().any(|k| n == k)).expect("runtime load"))
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0
}

#[test]
fn golden_transcript_matches_jax() {
    let Some(mut rt) = load(&["embed_32", "prefill_32", "decode_1"]) else { return };
    let golden = std::fs::read_to_string(rt.dir().join("golden.txt")).expect("golden.txt");
    let mut prompt: Vec<i32> = vec![];
    let mut expected: Vec<i32> = vec![];
    for line in golden.lines() {
        if let Some(rest) = line.strip_prefix("prompt ") {
            prompt = rest.split_whitespace().map(|t| t.parse().unwrap()).collect();
        } else if let Some(rest) = line.strip_prefix("tokens ") {
            expected = rest.split_whitespace().map(|t| t.parse().unwrap()).collect();
        }
    }
    assert!(!prompt.is_empty() && !expected.is_empty());

    let hp = rt.manifest.hparams.clone();
    let n = prompt.len();
    let mut padded = prompt.clone();
    padded.resize(32, 0);

    // embed -> prefill
    let out = rt.execute("embed_32", &[Input::I32(&padded, vec![32])]).unwrap();
    let emb = literal_f32(&out[0]).unwrap();
    let out = rt
        .execute(
            "prefill_32",
            &[Input::F32(&emb, vec![32, hp.d_model]), Input::ScalarI32(n as i32)],
        )
        .unwrap();
    let logits = literal_f32(&out[0]).unwrap();
    let mut kv = literal_f32(&out[1]).unwrap();
    let mut toks = vec![argmax(&logits) as i32];

    // decode loop (batch bucket 1)
    let mut length = n as i32;
    let kv_dims = vec![1, hp.n_layers, 2, hp.n_heads, hp.max_seq, hp.head_dim];
    while toks.len() < expected.len() {
        let ids = [*toks.last().unwrap()];
        let out = rt
            .execute(
                "decode_1",
                &[
                    Input::I32(&ids, vec![1]),
                    Input::F32(&kv, kv_dims.clone()),
                    Input::I32(&[length], vec![1]),
                ],
            )
            .unwrap();
        let lg = literal_f32(&out[0]).unwrap();
        kv = literal_f32(&out[1]).unwrap();
        toks.push(argmax(&lg) as i32);
        length += 1;
    }
    assert_eq!(toks, expected, "rust/PJRT transcript diverged from JAX");
}

#[test]
fn prefill_padding_invariance_through_pjrt() {
    let Some(mut rt) = load(&["embed_32", "embed_64", "prefill_32", "prefill_64"]) else {
        return;
    };
    let hp = rt.manifest.hparams.clone();
    let ids: Vec<i32> = (0..20).map(|i| (11 * i + 5) % hp.vocab as i32).collect();

    let logits_for = |rt: &mut Runtime, bucket: usize| -> Vec<f32> {
        let mut padded = ids.clone();
        padded.resize(bucket, 0);
        let out = rt
            .execute(&format!("embed_{bucket}"), &[Input::I32(&padded, vec![bucket])])
            .unwrap();
        let emb = literal_f32(&out[0]).unwrap();
        let out = rt
            .execute(
                &format!("prefill_{bucket}"),
                &[Input::F32(&emb, vec![bucket, hp.d_model]), Input::ScalarI32(20)],
            )
            .unwrap();
        literal_f32(&out[0]).unwrap()
    };

    let a = logits_for(&mut rt, 32);
    let b = logits_for(&mut rt, 64);
    assert_eq!(a.len(), hp.vocab);
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() < 1e-4, "padding changed logits: {x} vs {y}");
    }
}

#[test]
fn encoder_produces_finite_embeddings() {
    let Some(mut rt) = load(&["encoder_16"]) else { return };
    let hp = rt.manifest.hparams.clone();
    let pixels: Vec<f32> = (0..16 * hp.patch_dim).map(|i| ((i % 17) as f32 - 8.0) / 8.0).collect();
    let out = rt
        .execute("encoder_16", &[Input::F32(&pixels, vec![16, hp.patch_dim])])
        .unwrap();
    let emb = literal_f32(&out[0]).unwrap();
    assert_eq!(emb.len(), 16 * hp.d_model);
    assert!(emb.iter().all(|v| v.is_finite()));
    // non-degenerate
    let mean: f32 = emb.iter().sum::<f32>() / emb.len() as f32;
    assert!(emb.iter().any(|v| (v - mean).abs() > 1e-3));
}

#[test]
fn batched_decode_matches_solo_decode() {
    let Some(mut rt) = load(&["embed_32", "prefill_32", "decode_1", "decode_2"]) else {
        return;
    };
    let hp = rt.manifest.hparams.clone();
    let kv_dims1 = vec![1, hp.n_layers, 2, hp.n_heads, hp.max_seq, hp.head_dim];
    let kv_dims2 = vec![2, hp.n_layers, 2, hp.n_heads, hp.max_seq, hp.head_dim];

    // two different prompts
    let prep = |rt: &mut Runtime, seed: i32, n: usize| -> (Vec<f32>, i32) {
        let mut ids: Vec<i32> = (0..n as i32).map(|i| (seed * i + 7) % hp.vocab as i32).collect();
        ids.resize(32, 0);
        let out = rt.execute("embed_32", &[Input::I32(&ids, vec![32])]).unwrap();
        let emb = literal_f32(&out[0]).unwrap();
        let out = rt
            .execute(
                "prefill_32",
                &[Input::F32(&emb, vec![32, hp.d_model]), Input::ScalarI32(n as i32)],
            )
            .unwrap();
        let logits = literal_f32(&out[0]).unwrap();
        let kv = literal_f32(&out[1]).unwrap();
        (kv, argmax(&logits) as i32)
    };
    let (kv_a, tok_a) = prep(&mut rt, 3, 9);
    let (kv_b, tok_b) = prep(&mut rt, 5, 14);

    let solo = |rt: &mut Runtime, kv: &[f32], tok: i32, len: i32| -> Vec<f32> {
        let out = rt
            .execute(
                "decode_1",
                &[
                    Input::I32(&[tok], vec![1]),
                    Input::F32(kv, kv_dims1.clone()),
                    Input::I32(&[len], vec![1]),
                ],
            )
            .unwrap();
        literal_f32(&out[0]).unwrap()
    };
    let la = solo(&mut rt, &kv_a, tok_a, 9);
    let lb = solo(&mut rt, &kv_b, tok_b, 14);

    let mut kv2 = kv_a.clone();
    kv2.extend_from_slice(&kv_b);
    let out = rt
        .execute(
            "decode_2",
            &[
                Input::I32(&[tok_a, tok_b], vec![2]),
                Input::F32(&kv2, kv_dims2),
                Input::I32(&[9, 14], vec![2]),
            ],
        )
        .unwrap();
    let lg = literal_f32(&out[0]).unwrap();
    for i in 0..hp.vocab {
        assert!((lg[i] - la[i]).abs() < 1e-4, "slot 0 logit {i}");
        assert!((lg[hp.vocab + i] - lb[i]).abs() < 1e-4, "slot 1 logit {i}");
    }
}
