//! Property-test sweep for the elastic control plane: random seeds ×
//! replica counts × epochs × hysteresis bands × pool modes on the
//! modality-partition router, with the controller's safety contract
//! asserted from the outside:
//!
//! * re-partition conservation — after every step the (sand, pebble,
//!   rock) groups are a disjoint cover of the fleet with no group ever
//!   empty, no matter how many moves the controller made;
//! * `finished + failed + cancelled == submitted` across reassignments —
//!   drain-then-reassign loses no request and double-owns none;
//! * zero occupancy at the flip — a draining replica changes groups only
//!   once it holds no active requests and no KV blocks
//!   (`max_active_at_flip == 0`, `max_kv_at_flip == 0`);
//! * elastic-off inertness — with `enabled = false` every other
//!   `[elastic]` knob is dead weight: the event stream, outcomes, and
//!   makespan are bit-identical to the static partition cluster with the
//!   default `[elastic]` section;
//! * reruns are bit-deterministic (controller decisions are a pure
//!   function of virtual time).
//!
//! CI runs this suite in the `property-tests` job over a fixed 3-seed
//! matrix (`ELASTIC_PROPTEST_SEED=1|2|3` selects one seed; unset runs
//! all three).

use tcm_serve::cluster::{Cluster, ClusterReport};
use tcm_serve::config::{ElasticConfig, ServeConfig};
use tcm_serve::coordinator::{RequestEvent, StepOutcome};
use tcm_serve::experiments::make_trace;
use tcm_serve::request::Request;
use tcm_serve::util::proptest_lite as pt;

/// The fixed seed matrix (one CI job per entry).
const SEED_MATRIX: [u64; 3] = [0xE1A5_71C0_0001, 0xE1A5_71C0_0002, 0xE1A5_71C0_0003];

fn random_elastic_cfg(g: &mut pt::Gen) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.policy = (*g.pick(&["fcfs", "tcm"])).into();
    cfg.mix = (*g.pick(&["T0", "ML", "MH", "VH"])).into();
    cfg.rate = g.f64_in(1.0, 4.0).max(0.5);
    cfg.seed = g.rng.next_u64();
    cfg.num_requests = g.usize_in(10, 40).max(5);
    cfg.memory_frac = *g.pick(&[1.0, 0.25]);
    // >= 3 replicas so the static split is a *disjoint* partition (the
    // 2-replica split shares the non-sand replica by design) and the
    // controller has room to move one
    cfg.cluster.replicas = g.usize_in(3, 6).max(3);
    cfg.cluster.router = "modality-partition".into();
    cfg.pool.enabled = g.rng.bool(0.5);
    cfg.pool.slots = g.usize_in(1, 4).max(1);
    cfg.elastic.enabled = true;
    cfg.elastic.epoch_s = *g.pick(&[0.5, 1.0, 3.0]);
    cfg.elastic.hysteresis = *g.pick(&[0.0, 0.25, 0.75]);
    cfg.elastic.cooldown_epochs = g.usize_in(0, 2) as u32;
    cfg.elastic.slots_min = 1;
    cfg.elastic.slots_max = *g.pick(&[2, 6]);
    cfg.elastic.attainment_floor = *g.pick(&[0.5, 0.9]);
    cfg
}

/// The groups must be a disjoint cover of `0..n` with no group empty —
/// the repartition-conservation invariant, checked after every step.
fn check_partition(cluster: &Cluster, n: usize) -> Result<(), String> {
    let (sand, pebble, rock) = cluster
        .router_groups()
        .ok_or_else(|| "modality-partition router lost its groups".to_string())?;
    let mut all: Vec<usize> = Vec::with_capacity(n);
    all.extend(&sand);
    all.extend(&pebble);
    all.extend(&rock);
    all.sort_unstable();
    if all != (0..n).collect::<Vec<_>>() {
        return Err(format!(
            "groups are not a disjoint cover of 0..{n}: \
             sand {sand:?} pebble {pebble:?} rock {rock:?}"
        ));
    }
    if sand.is_empty() || pebble.is_empty() || rock.is_empty() {
        return Err(format!("empty group: sand {sand:?} pebble {pebble:?} rock {rock:?}"));
    }
    Ok(())
}

/// Drive a cluster step by step, checking the partition invariant on
/// every step and structural invariants periodically; returns the final
/// report alongside the full event stream (for bit-identity checks).
fn run_stepped(
    cfg: &ServeConfig,
    trace: Vec<Request>,
) -> Result<(ClusterReport, Vec<RequestEvent>), String> {
    let mut cluster = Cluster::new(cfg);
    let n = cluster.replica_count();
    for req in trace {
        cluster.inject(req);
    }
    let mut events = Vec::new();
    let mut steps = 0u64;
    loop {
        let out = cluster.step();
        events.extend(cluster.take_events());
        match out {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => cluster.drop_blocked(),
            StepOutcome::Drained => break,
        }
        check_partition(&cluster, n).map_err(|e| format!("step {steps}: {e}"))?;
        if steps % 32 == 0 {
            cluster.check_invariants().map_err(|e| format!("step {steps}: {e}"))?;
        }
        steps += 1;
        if steps >= 5_000_000 {
            return Err("stepping did not drain".into());
        }
    }
    events.extend(cluster.take_events());
    cluster.check_invariants().map_err(|e| format!("at drain: {e}"))?;
    check_partition(&cluster, n).map_err(|e| format!("at drain: {e}"))?;
    Ok((cluster.report(), events))
}

fn check_case(g: &mut pt::Gen) -> Result<(), String> {
    let cfg = random_elastic_cfg(g);
    let profile = tcm_serve::model::by_name(&cfg.model).expect("default model");
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();
    let label = format!(
        "{}/{}/r{}/epoch{}/pool={}",
        cfg.policy, cfg.mix, cfg.cluster.replicas, cfg.elastic.epoch_s, cfg.pool.enabled
    );

    let (cr, _) = run_stepped(&cfg, trace.clone())?;

    // conservation across reassignments: every submitted request reaches
    // exactly one terminal outcome
    if cr.report.total() != n {
        return Err(format!("{label}: {} outcomes for {n} submitted", cr.report.total()));
    }
    let e = cr.elastic.as_ref().ok_or_else(|| format!("{label}: elastic snapshot missing"))?;
    // drain-then-reassign: a replica changes groups only once empty
    if e.stats.max_active_at_flip != 0 || e.stats.max_kv_at_flip != 0 {
        return Err(format!(
            "{label}: replica flipped groups with {} active requests / {} KV blocks",
            e.stats.max_active_at_flip, e.stats.max_kv_at_flip
        ));
    }
    if e.stats.repartitions > e.stats.drains_started {
        return Err(format!(
            "{label}: {} repartitions from {} started drains",
            e.stats.repartitions, e.stats.drains_started
        ));
    }
    if cfg.pool.enabled {
        let p = cr.pool.as_ref().ok_or_else(|| format!("{label}: pool snapshot missing"))?;
        if p.slots == 0 {
            return Err(format!("{label}: pool shrank to zero slots"));
        }
        if p.max_concurrent_slots < p.slots.max(cfg.pool.slots) {
            return Err(format!(
                "{label}: peak {} slots below current {} / configured {}",
                p.max_concurrent_slots, p.slots, cfg.pool.slots
            ));
        }
        if p.slot_grow_events == 0 && p.max_concurrent_slots != cfg.pool.slots {
            return Err(format!(
                "{label}: peak {} slots without a grow event",
                p.max_concurrent_slots
            ));
        }
    }

    // determinism: the identical config and trace reproduce bit-for-bit,
    // controller decisions included
    let (cr2, _) = run_stepped(&cfg, trace)?;
    if cr2.makespan.to_bits() != cr.makespan.to_bits() {
        return Err(format!("{label}: makespan diverged between identical runs"));
    }
    if cr2.report.outcomes.len() != cr.report.outcomes.len() {
        return Err(format!("{label}: outcome counts diverged"));
    }
    for (x, y) in cr.report.outcomes.iter().zip(&cr2.report.outcomes) {
        if x.id != y.id
            || x.first_token.to_bits() != y.first_token.to_bits()
            || x.finish.to_bits() != y.finish.to_bits()
        {
            return Err(format!("{label}: req {} diverged between identical runs", x.id));
        }
    }
    let e2 = cr2.elastic.as_ref().ok_or_else(|| format!("{label}: rerun snapshot missing"))?;
    if e2.stats != e.stats {
        return Err(format!("{label}: controller stats diverged: {:?} vs {:?}", e.stats, e2.stats));
    }
    if e2.sand != e.sand || e2.pebble != e.pebble || e2.rock != e.rock {
        return Err(format!("{label}: final groups diverged between identical runs"));
    }
    Ok(())
}

/// With `enabled = false`, every other `[elastic]` knob must be inert:
/// the run is bit-identical — event stream, outcomes, makespan — to the
/// static modality-partition cluster carrying the default `[elastic]`
/// section, and no elastic snapshot is reported.
fn check_elastic_off_inert(g: &mut pt::Gen) -> Result<(), String> {
    let mut cfg = random_elastic_cfg(g);
    cfg.elastic.enabled = false;
    let mut baseline = cfg.clone();
    baseline.elastic = ElasticConfig::default();
    let profile = tcm_serve::model::by_name(&cfg.model).expect("default model");
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();
    let label = format!("off/{}/{}/r{}", cfg.policy, cfg.mix, cfg.cluster.replicas);

    let (cr, events) = run_stepped(&cfg, trace.clone())?;
    let (crb, events_b) = run_stepped(&baseline, trace)?;

    if cr.elastic.is_some() || crb.elastic.is_some() {
        return Err(format!("{label}: elastic snapshot present with the controller off"));
    }
    if cr.report.total() != n {
        return Err(format!("{label}: {} outcomes for {n} submitted", cr.report.total()));
    }
    if events != events_b {
        return Err(format!(
            "{label}: event streams diverged ({} vs {} events)",
            events.len(),
            events_b.len()
        ));
    }
    if cr.makespan.to_bits() != crb.makespan.to_bits() {
        return Err(format!("{label}: makespan diverged from the static cluster"));
    }
    if cr.report.outcomes.len() != crb.report.outcomes.len() {
        return Err(format!("{label}: outcome counts diverged from the static cluster"));
    }
    for (x, y) in cr.report.outcomes.iter().zip(&crb.report.outcomes) {
        if x.id != y.id
            || x.first_token.to_bits() != y.first_token.to_bits()
            || x.finish.to_bits() != y.finish.to_bits()
        {
            return Err(format!("{label}: req {} diverged from the static cluster", x.id));
        }
    }
    Ok(())
}

fn seeds_to_run() -> Vec<u64> {
    match std::env::var("ELASTIC_PROPTEST_SEED") {
        Ok(v) => {
            let i: usize = v.parse().unwrap_or_else(|_| {
                panic!("ELASTIC_PROPTEST_SEED must be 1..={}, got {v:?}", SEED_MATRIX.len())
            });
            assert!(
                (1..=SEED_MATRIX.len()).contains(&i),
                "ELASTIC_PROPTEST_SEED must be 1..={}, got {i}",
                SEED_MATRIX.len()
            );
            vec![SEED_MATRIX[i - 1]]
        }
        Err(_) => SEED_MATRIX.to_vec(),
    }
}

#[test]
fn elastic_conservation_and_determinism_sweep() {
    for seed in seeds_to_run() {
        pt::run_seeded(seed, 10, check_case);
    }
}

#[test]
fn elastic_off_is_bit_identical_to_static() {
    for seed in seeds_to_run() {
        pt::run_seeded(seed ^ 0x0FF, 6, check_elastic_off_inert);
    }
}

/// A pure-text flood against the default 1/1/2 split of four replicas:
/// all-text demand targets a 2/1/1 split, so the controller must drain a
/// rock and hand it to sand — with the drained replica empty at the
/// flip. Drives the batch runner, whose arrival loop is a distinct
/// epoch-hook path from the stepping sweep above.
#[test]
fn text_flood_repartitions_toward_sand() {
    let mut cfg = ServeConfig::default();
    cfg.mix = "T0".into();
    cfg.rate = 6.0;
    cfg.seed = 11;
    cfg.num_requests = 120;
    cfg.cluster.replicas = 4;
    cfg.cluster.router = "modality-partition".into();
    cfg.elastic.enabled = true;
    cfg.elastic.epoch_s = 1.0;
    cfg.elastic.hysteresis = 0.0;
    cfg.elastic.cooldown_epochs = 0;
    let profile = tcm_serve::model::by_name(&cfg.model).expect("default model");
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();

    let mut cluster = Cluster::new(&cfg);
    let cr = cluster.run(trace.clone());
    assert_eq!(cr.report.total(), n, "requests lost across reassignment");
    check_partition(&cluster, 4).unwrap();
    let e = cr.elastic.as_ref().expect("controller attached");
    assert!(e.stats.epochs >= 1, "no epochs evaluated over a {}s run", cr.makespan);
    assert!(e.stats.repartitions >= 1, "text flood never repartitioned: {:?}", e.stats);
    assert!(e.stats.drains_started >= e.stats.repartitions);
    assert_eq!(e.stats.max_active_at_flip, 0, "replica flipped groups while occupied");
    assert_eq!(e.stats.max_kv_at_flip, 0, "replica flipped groups holding KV blocks");
    assert!(e.sand.len() >= 2, "sand never grew: {:?}/{:?}/{:?}", e.sand, e.pebble, e.rock);

    // the batch driver's elastic decisions are bit-deterministic too
    let cr2 = Cluster::new(&cfg).run(trace);
    assert_eq!(cr.makespan.to_bits(), cr2.makespan.to_bits());
    let e2 = cr2.elastic.as_ref().expect("controller attached");
    assert_eq!(e.stats, e2.stats);
    assert_eq!((&e.sand, &e.pebble, &e.rock), (&e2.sand, &e2.pebble, &e2.rock));
}
