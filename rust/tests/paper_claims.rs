//! Directional reproduction of the paper's headline claims at test scale
//! (small request counts so `cargo test` stays fast; the full-scale runs
//! live in `cargo bench`, see EXPERIMENTS.md).
//!
//! These assert the *shape* of each result — who wins, in which metric —
//! not absolute numbers.

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::run_sim;
use tcm_serve::request::{Class, Modality};

fn cfg(policy: &str, mix: &str, n: usize) -> ServeConfig {
    let mut c = ServeConfig::default();
    c.policy = policy.into();
    c.mix = mix.into();
    c.num_requests = n;
    c.seed = 2026;
    c
}

/// §2.3 / Fig 3: multimodality degrades FCFS sharply, text suffers most.
#[test]
fn fig3_multimodality_degrades_fcfs() {
    let t0 = run_sim(&cfg("fcfs", "T0", 300));
    let ml = run_sim(&cfg("fcfs", "ML", 300));
    let mh = run_sim(&cfg("fcfs", "MH", 300));

    let v = |r: &tcm_serve::experiments::RunResult| r.report.overall().slo_violation_rate;
    assert!(v(&t0) < 0.05, "T0 nearly violation-free: {}", v(&t0));
    assert!(v(&mh) > v(&ml), "MH worse than ML");
    assert!(v(&mh) > 0.3, "MH causes widespread violations: {}", v(&mh));

    // text normalized latency blows up by orders of magnitude under MH
    let text_t0 = t0.report.by_modality(Modality::Text).avg_norm_latency;
    let text_mh = mh.report.by_modality(Modality::Text).avg_norm_latency;
    assert!(
        text_mh > 5.0 * text_t0,
        "text norm latency must degrade sharply: {text_t0} -> {text_mh}"
    );
}

/// §2.4 / Fig 4: memory pressure amplifies degradation under FCFS.
#[test]
fn fig4_memory_pressure_amplifies() {
    let full = run_sim(&cfg("fcfs", "MH", 250));
    let mut half = cfg("fcfs", "MH", 250);
    half.memory_frac = 0.25;
    let half = run_sim(&half);
    let v_full = full.report.overall().slo_violation_rate;
    let v_half = half.report.overall().slo_violation_rate
        + half.stats.dropped as f64 / 250.0;
    assert!(
        v_half >= v_full,
        "less memory cannot reduce violations: {v_full} -> {v_half}"
    );
    assert!(half.stats.preemptions >= full.stats.preemptions);
}

/// Fig 8 ablation: vLLM < classification < classification+aging (TCM),
/// measured on overall normalized latency.
#[test]
fn fig8_ablation_ordering() {
    let fcfs = run_sim(&cfg("fcfs", "MH", 300)).report.overall().avg_norm_latency;
    let smart = run_sim(&cfg("static-priority", "MH", 300)).report.overall().avg_norm_latency;
    let tcm_r = run_sim(&cfg("tcm", "MH", 300)).report;
    let tcm = tcm_r.overall().avg_norm_latency;
    assert!(smart < fcfs, "smart classification must beat FCFS: {smart} vs {fcfs}");
    assert!(tcm < fcfs, "TCM must beat FCFS: {tcm} vs {fcfs}");
    // paper: classification+priority cuts overall norm latency by ~50%
    assert!(tcm < 0.7 * fcfs, "TCM should cut norm latency substantially");
}

/// Fig 8: naive classification penalizes videos (it maps every video to
/// the lowest priority); the smart classifier lets small videos run as
/// cars, improving the video modality overall.
#[test]
fn fig8_naive_classifier_penalizes_videos() {
    let naive = run_sim(&cfg("naive-class", "MH", 300));
    let smart = run_sim(&cfg("static-priority", "MH", 300));
    let n = naive.report.by_modality(Modality::Video).avg_norm_latency;
    let s = smart.report.by_modality(Modality::Video).avg_norm_latency;
    assert!(
        s < n,
        "smart classifier must improve videos over naive: smart {s} vs naive {n}"
    );
}

/// Fig 10 / headline: TCM cuts motorcycle TTFT vs vLLM-FCFS, across models.
#[test]
fn fig10_tcm_cuts_latency_critical_ttft() {
    for model in ["llava-7b", "qwen-7b", "gemma-4b"] {
        let mut f = cfg("fcfs", "MH", 250);
        f.model = model.into();
        let mut t = cfg("tcm", "MH", 250);
        t.model = model.into();
        let fcfs = run_sim(&f).report.by_class(Class::Motorcycle).avg_ttft;
        let tcm = run_sim(&t).report.by_class(Class::Motorcycle).avg_ttft;
        assert!(
            tcm < 0.6 * fcfs,
            "{model}: motorcycle TTFT should drop sharply: {tcm} vs {fcfs}"
        );
    }
}

/// Fig 11: TCM eliminates preemptions for motorcycles.
#[test]
fn fig11_tcm_motorcycles_never_preempted() {
    let mut c = cfg("tcm", "MH", 300);
    c.memory_frac = 0.25; // enough pressure that preemption happens
    let r = run_sim(&c);
    let m = r.report.by_class(Class::Motorcycle);
    assert_eq!(m.preemptions, 0, "TCM must not preempt motorcycles");
}

/// Fig 12: under increasing load TCM sustains lower tail latency than FCFS.
#[test]
fn fig12_tcm_scales_better_under_load() {
    for rate in [2.0, 4.0] {
        let mut f = cfg("fcfs", "MH", 250);
        f.rate = rate;
        let mut t = cfg("tcm", "MH", 250);
        t.rate = rate;
        let fcfs = run_sim(&f).report.overall();
        let tcm = run_sim(&t).report.overall();
        assert!(
            tcm.p90_ttft < fcfs.p90_ttft,
            "rate {rate}: TCM P90 TTFT {:.2} !< FCFS {:.2}",
            tcm.p90_ttft,
            fcfs.p90_ttft
        );
    }
}

/// Fig 13: TCM keeps motorcycles interactive across mixes and excels at T0.
#[test]
fn fig13_tcm_across_workloads() {
    let t0 = run_sim(&cfg("tcm", "T0", 300));
    assert!(t0.report.overall().slo_violation_rate < 0.02);

    for mix in ["ML", "MH"] {
        let r = run_sim(&cfg("tcm", mix, 300));
        let m = r.report.by_class(Class::Motorcycle);
        assert!(
            m.avg_ttft < 0.5,
            "{mix}: motorcycle avg TTFT should stay interactive: {}",
            m.avg_ttft
        );
    }
}

/// Fig 14: TCM keeps motorcycles responsive even at 25% KV memory.
#[test]
fn fig14_tcm_under_memory_pressure() {
    let mut c = cfg("tcm", "MH", 250);
    c.memory_frac = 0.25;
    let r = run_sim(&c);
    let m = r.report.by_class(Class::Motorcycle);
    assert!(
        m.avg_ttft < 1.0,
        "motorcycle TTFT must stay under 1 s at 25% memory: {}",
        m.avg_ttft
    );
}

/// §4.2: trucks are deliberately sacrificed — but not starved.
#[test]
fn trucks_slower_but_not_starved() {
    let tcm = run_sim(&cfg("tcm", "MH", 300));
    let t = tcm.report.by_class(Class::Truck);
    let m = tcm.report.by_class(Class::Motorcycle);
    assert!(t.n > 0);
    assert!(t.avg_ttft > m.avg_ttft, "trucks are slower by design");
    // not starved: every truck finished (conservation checked elsewhere),
    // and average e2e stays bounded relative to its own SLO scale
    assert!(t.avg_e2e.is_finite());
}
