//! Request-lifecycle contracts at the scheduler level: cancellation from
//! every live state, client deadlines, and SLO classes — the primitives
//! the serving front end's cancel/deadline/backpressure API sits on.

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::policies::build_policy;
use tcm_serve::request::{Modality, Request, SloClass};

fn scheduler(policy: &str) -> Scheduler {
    let mut cfg = ServeConfig::default();
    cfg.policy = policy.into();
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let pol = build_policy(&cfg, &profile);
    Scheduler::new(cfg, pol, Box::new(SimEngine::new(&profile)))
}

fn text(id: u64, arrival: f64, text_tokens: u32, output_tokens: u32) -> Request {
    Request { id, arrival, text_tokens, output_tokens, ..Request::default() }
}

fn image(id: u64, arrival: f64) -> Request {
    Request {
        id,
        arrival,
        modality: Modality::Image,
        text_tokens: 40,
        mm_tokens: 729,
        output_tokens: 16,
        ..Request::default()
    }
}

fn drain(sched: &mut Scheduler) -> Vec<RequestEvent> {
    let mut events = Vec::new();
    let mut steps = 0;
    loop {
        match sched.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
            StepOutcome::Drained => break,
        }
        events.extend(sched.take_events());
        sched.check_invariants().unwrap();
        steps += 1;
        assert!(steps < 1_000_000);
    }
    events.extend(sched.take_events());
    events
}

fn terminal_events(events: &[RequestEvent], id: u64) -> Vec<&RequestEvent> {
    events
        .iter()
        .filter(|e| {
            matches!(
                e,
                RequestEvent::Finished { id: i, .. }
                | RequestEvent::Dropped { id: i, .. }
                | RequestEvent::Cancelled { id: i, .. } if *i == id
            )
        })
        .collect()
}

/// Cancel while the request is still a pending (not yet due) arrival:
/// it never preprocesses, never becomes Ready, and still conserves.
#[test]
fn cancel_pending_arrival() {
    let mut s = scheduler("fcfs");
    s.inject(text(0, 5.0, 64, 4));
    assert!(s.cancel(0), "pending arrival must be cancellable");
    assert!(!s.cancel(0), "second cancel is a no-op");
    let events = s.take_events();
    assert!(matches!(events.as_slice(), [RequestEvent::Cancelled { id: 0, .. }]));
    let events = drain(&mut s);
    assert!(events.is_empty(), "nothing further happens: {events:?}");
    let report = s.report();
    assert_eq!(report.cancelled.len(), 1);
    assert_eq!(report.total(), 1);
    assert_eq!(s.active_requests(), 0);
}

/// Cancel during CPU preprocessing: the queued ready event fires later
/// and must be ignored; exactly one terminal event.
#[test]
fn cancel_during_preprocessing() {
    let mut s = scheduler("fcfs");
    s.inject(image(0, 0.0));
    // first step ingests the arrival and starts preprocessing (image
    // preprocess takes 60 ms of virtual time, so it is not ready yet)
    match s.step() {
        StepOutcome::Idle { .. } => {}
        other => panic!("expected Idle while preprocessing, got {other:?}"),
    }
    assert!(s.cancel(0));
    let mut events = s.take_events();
    events.extend(drain(&mut s));
    assert_eq!(terminal_events(&events, 0).len(), 1);
    assert!(
        !events.iter().any(|e| matches!(e, RequestEvent::Ready { .. })),
        "a cancelled request must not become ready: {events:?}"
    );
    assert_eq!(s.report().cancelled.len(), 1);
    assert_eq!(s.kv().used_blocks(), 0);
}

/// Cancel a running (mid-prefill) request: KV is freed immediately and
/// later requests proceed unaffected.
#[test]
fn cancel_running_frees_kv() {
    let mut s = scheduler("fcfs");
    s.inject(text(0, 0.0, 50_000, 1_000)); // ~98 prefill iterations
    s.inject(text(1, 0.0, 64, 4));
    // run a few iterations so request 0 holds KV rows
    let mut executed = 0;
    while executed < 4 {
        match s.step() {
            StepOutcome::Executed { .. } => executed += 1,
            StepOutcome::Idle { next_event } => s.advance_to(next_event),
            other => panic!("unexpected {other:?}"),
        }
    }
    assert!(s.kv().used_blocks() > 0, "request 0 must hold KV before the cancel");
    assert!(s.cancel(0));
    let events = drain(&mut s);
    let report = s.report();
    assert_eq!(report.outcomes.len(), 1, "request 1 completes");
    assert_eq!(report.cancelled.len(), 1);
    assert_eq!(report.total(), 2);
    assert_eq!(s.kv().used_blocks(), 0, "all KV returned at drain");
    assert_eq!(terminal_events(&events, 0).len(), 1);
    assert!(s.ready_set().is_empty());
}

/// Cancel after completion loses quietly: no Cancelled event, the
/// Finished outcome stands, stats untouched.
#[test]
fn cancel_after_finish_is_refused() {
    let mut s = scheduler("fcfs");
    s.inject(text(0, 0.0, 64, 4));
    let events = drain(&mut s);
    assert!(events.iter().any(|e| matches!(e, RequestEvent::Finished { id: 0, .. })));
    assert!(!s.cancel(0));
    assert!(!s.cancel(42), "unknown ids are refused too");
    assert_eq!(s.stats.cancelled, 0);
    assert_eq!(s.report().outcomes.len(), 1);
}

/// Cancelled outcomes flow through the retire/compact API exactly like
/// finished ones: take_finished reclaims their state.
#[test]
fn take_finished_retires_cancelled_state() {
    let mut s = scheduler("fcfs");
    s.inject(text(0, 5.0, 64, 4));
    s.inject(text(1, 0.0, 64, 4));
    assert!(s.cancel(0));
    let part = s.take_finished();
    assert_eq!(part.cancelled.len(), 1);
    assert_eq!(part.outcomes.len(), 0);
    let _ = drain(&mut s);
    let rest = s.take_finished();
    assert_eq!(rest.outcomes.len(), 1);
    assert_eq!(rest.cancelled.len(), 0, "already retired");
    s.check_invariants().unwrap();
    assert_eq!(s.active_requests(), 0);
}

/// A client deadline overrides the slo_scale default end-to-end: the
/// outcome's SLO latency is the requested budget, and EDF schedules an
/// urgent-deadline request ahead of an earlier, laxer one.
#[test]
fn deadline_overrides_slo_and_orders_edf() {
    // outcome accounting
    let mut s = scheduler("fcfs");
    let mut req = text(0, 0.0, 64, 4);
    req.deadline_s = Some(0.25);
    s.inject(req);
    let _ = drain(&mut s);
    let report = s.report();
    assert_eq!(report.outcomes[0].slo_latency, 0.25);

    // EDF ordering: two requests become ready together; the one with
    // the tight explicit deadline goes first despite the later id
    let mut s = scheduler("edf");
    let mut lax = text(0, 0.0, 2_000, 4);
    lax.deadline_s = Some(500.0);
    let mut tight = text(1, 0.0, 2_000, 4);
    tight.deadline_s = Some(1.0);
    s.inject(lax);
    s.inject(tight);
    let events = drain(&mut s);
    let first_token_order: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::FirstToken { id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    assert_eq!(first_token_order, vec![1, 0], "tight deadline must outrank earlier arrival");
}

/// Hostile deadline inputs (NaN, infinities, non-positive) are ignored
/// — they must not poison order keys and panic the planner's sort; the
/// request falls back to the configured SLO default.
#[test]
fn non_finite_deadlines_fall_back_to_default_slo() {
    for bad in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, 0.0, -3.0] {
        let mut s = scheduler("edf");
        let mut req = text(0, 64, 4);
        req.deadline_s = Some(bad);
        s.inject(req);
        let _ = drain(&mut s); // must not panic in the order-key sort
        let report = s.report();
        assert_eq!(report.outcomes.len(), 1, "deadline {bad} broke scheduling");
        assert!(
            report.outcomes[0].slo_latency.is_finite() && report.outcomes[0].slo_latency > 0.0,
            "deadline {bad} leaked into SLO accounting: {}",
            report.outcomes[0].slo_latency
        );
    }
}

/// SLO classes shift the class-priority schedule: a BestEffort flood
/// does not delay a Critical request, and the Critical request beats
/// identical Standard peers to its first token.
#[test]
fn critical_class_outranks_standard_peers() {
    let mut s = scheduler("tcm");
    // identical requests, same arrival: the Critical one must win TTFT
    for id in 0..6u64 {
        let mut r = text(id, 0.0, 4_000, 8);
        r.slo_class = match id {
            5 => Some(SloClass::Critical),
            0 | 1 => Some(SloClass::BestEffort),
            _ => None,
        };
        s.inject(r);
    }
    let events = drain(&mut s);
    let first: Vec<u64> = events
        .iter()
        .filter_map(|e| match e {
            RequestEvent::FirstToken { id, .. } => Some(*id),
            _ => None,
        })
        .collect();
    assert_eq!(first.first(), Some(&5), "critical request must reach its token first: {first:?}");
    let be_positions: Vec<usize> = first
        .iter()
        .enumerate()
        .filter(|(_, id)| **id <= 1)
        .map(|(i, _)| i)
        .collect();
    assert!(
        be_positions.iter().all(|&i| i >= first.len() - 2),
        "best-effort requests must trail: {first:?}"
    );
}

/// Conservation under a cancel storm: cancel every other request at
/// assorted moments; finished + cancelled == submitted, zero KV at
/// drain, one terminal event each.
#[test]
fn cancel_storm_conserves() {
    let mut s = scheduler("tcm");
    let n = 40u64;
    for id in 0..n {
        s.inject(text(id, id as f64 * 0.05, 512, 16));
    }
    let mut events = Vec::new();
    let mut cancelled = Vec::new();
    let mut steps = 0u64;
    loop {
        match s.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => s.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => s.advance_to(t),
            StepOutcome::Blocked { next_event: None } => s.drop_blocked(),
            StepOutcome::Drained => break,
        }
        if steps % 3 == 0 {
            let id = (steps / 3) * 2;
            if id < n && s.cancel(id) {
                cancelled.push(id);
            }
        }
        events.extend(s.take_events());
        s.check_invariants().unwrap();
        steps += 1;
        assert!(steps < 1_000_000);
    }
    events.extend(s.take_events());
    assert!(!cancelled.is_empty(), "the storm must land some cancels");
    let report = s.report();
    assert_eq!(report.total(), n as usize);
    assert_eq!(report.cancelled.len(), cancelled.len());
    for id in 0..n {
        assert_eq!(terminal_events(&events, id).len(), 1, "request {id}");
    }
    assert_eq!(s.kv().used_blocks(), 0);
    assert_eq!(s.active_requests(), 0);
}
