//! Retire/compact API contract (`Scheduler::take_finished`): terminal
//! request state can be drained incrementally by a long-lived server, the
//! union of the partial reports equals the batch report bit for bit, and
//! invariants (including drop accounting) hold across retirement.

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::{make_trace, run_sim_with_trace};
use tcm_serve::metrics::Report;
use tcm_serve::policies::build_policy;

fn new_scheduler(cfg: &ServeConfig) -> Scheduler {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(cfg, &profile);
    Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&profile)))
}

#[test]
fn incremental_retirement_matches_batch_report() {
    for (policy, memory_frac) in [("fcfs", 1.0), ("tcm", 0.02)] {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 120;
        cfg.rate = 2.0;
        cfg.seed = 7;
        cfg.memory_frac = memory_frac;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let mut batch = run_sim_with_trace(&cfg, trace.clone()).report;
        batch.sort_by_id();

        let mut sched = new_scheduler(&cfg);
        for req in trace {
            sched.inject(req);
        }
        let mut collected = Report::default();
        let mut steps = 0u64;
        loop {
            match sched.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => sched.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
                StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
                StepOutcome::Drained => break,
            }
            sched.take_events();
            // retire every few iterations, like the server leader does
            if steps % 5 == 0 {
                collected.merge(sched.take_finished());
            }
            sched
                .check_invariants()
                .unwrap_or_else(|e| panic!("{policy}: after step {steps}: {e}"));
            steps += 1;
            assert!(steps < 5_000_000, "{policy}: did not drain");
        }
        collected.merge(sched.take_finished());

        // everything terminal was handed out: the residual report is empty
        assert_eq!(sched.report().total(), 0, "{policy}: retired state resurfaced");
        let (fin, fail) = sched.retired();
        assert_eq!(fin + fail, collected.total(), "{policy}: retirement counters");

        collected.sort_by_id();
        assert_eq!(collected.total(), 120, "{policy}: lost requests across retirement");
        assert_eq!(collected.outcomes.len(), batch.outcomes.len(), "{policy}");
        assert_eq!(collected.failed.len(), batch.failed.len(), "{policy}");
        for (x, y) in collected.outcomes.iter().zip(&batch.outcomes) {
            assert_eq!(x.id, y.id, "{policy}");
            assert_eq!(x.first_token.to_bits(), y.first_token.to_bits(), "{policy}: req {}", x.id);
            assert_eq!(x.finish.to_bits(), y.finish.to_bits(), "{policy}: req {}", x.id);
            assert_eq!(x.preemptions, y.preemptions, "{policy}: req {}", x.id);
        }
        for (x, y) in collected.failed.iter().zip(&batch.failed) {
            assert_eq!(x.id, y.id, "{policy}");
            assert_eq!(x.dropped_at.to_bits(), y.dropped_at.to_bits(), "{policy}: req {}", x.id);
        }
    }
}

#[test]
fn take_finished_is_move_semantics_not_copy() {
    let mut cfg = ServeConfig::default();
    cfg.policy = "fcfs".into();
    cfg.num_requests = 10;
    cfg.rate = 4.0;
    cfg.seed = 3;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let mut sched = new_scheduler(&cfg);
    let n = trace.len();
    for req in trace {
        sched.inject(req);
    }
    let full = sched.drain();
    assert_eq!(full.total(), n, "drain() still reports everything first");

    let first = sched.take_finished();
    assert_eq!(first.total(), n, "first take hands out every terminal request");
    let second = sched.take_finished();
    assert_eq!(second.total(), 0, "second take must be empty — state was reclaimed");
    assert_eq!(sched.report().total(), 0);
    sched.check_invariants().unwrap();
}
