//! Property-test sweep for the disaggregated encoder pool (the PR's
//! headline archetype): random seeds × replica counts × routers × pool
//! sizes × mixes × policies, with conservation invariants asserted from
//! the *event stream* — the only vantage point that crosses the
//! pool→replica handoff boundary:
//!
//! * every request is routed exactly once, becomes Ready exactly once,
//!   and ends in exactly one terminal event (Finished xor Dropped) —
//!   nothing lost or duplicated across the handoff;
//! * every finished multimodal request is encoded exactly
//!   `1 + preemptions` times (the pool encode plus one local re-encode
//!   per preemption-by-recompute); text never touches an encoder;
//! * `failed outcomes == dropped` accounting holds fleet-wide;
//! * reruns are bit-identical (pool mode is deterministic);
//! * scheduler + KV + pool structural invariants hold at every sampled
//!   step.
//!
//! CI runs this suite as a dedicated `property-tests` job over a fixed
//! 3-seed matrix (`POOL_PROPTEST_SEED=1|2|3` selects one seed; unset
//! runs all three with the same reduced request counts, sized to keep
//! the sweep under ~2 minutes).

use std::collections::HashMap;
use tcm_serve::cluster::Cluster;
use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::coordinator::{RequestEvent, StepOutcome};
use tcm_serve::experiments::make_trace;
use tcm_serve::request::Request;
use tcm_serve::util::proptest_lite as pt;

/// The fixed seed matrix (one CI job per entry).
const SEED_MATRIX: [u64; 3] = [0x9001_5EED_0001, 0x9001_5EED_0002, 0x9001_5EED_0003];

#[derive(Default, Clone)]
struct EventCounts {
    ready: u32,
    encoded: u32,
    preempted: u32,
    requeued: u32,
    first_token: u32,
    finished: u32,
    dropped: u32,
    cancelled: u32,
}

fn random_pool_cfg(g: &mut pt::Gen) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.policy = (*g.pick(&["fcfs", "tcm"])).into();
    cfg.mix = (*g.pick(&["ML", "MH", "VH"])).into();
    cfg.rate = g.f64_in(1.0, 4.0).max(0.5);
    cfg.seed = g.rng.next_u64();
    cfg.num_requests = g.usize_in(10, 40).max(5);
    cfg.memory_frac = *g.pick(&[1.0, 0.25]);
    cfg.cluster.replicas = g.usize_in(1, 4).max(1);
    cfg.cluster.router = (*g.pick(&ROUTERS)).into();
    // mostly pool mode (the subject under test), with a pool-off control
    // sweep so the same invariants pin the legacy path too
    cfg.pool.enabled = !g.rng.bool(0.2);
    cfg.pool.slots = g.usize_in(1, 6).max(1);
    cfg.pool.aging_deadline_s = *g.pick(&[0.5, 2.0]);
    cfg.pool.migration_cost_s_per_ktok = *g.pick(&[0.0, 0.002, 0.02]);
    cfg
}

/// Drive a cluster step by step, collecting per-request event counts and
/// checking structural invariants as it goes; returns the final report
/// alongside the counts.
fn run_stepped(
    cfg: &ServeConfig,
    trace: Vec<Request>,
) -> Result<(tcm_serve::cluster::ClusterReport, HashMap<u64, EventCounts>), String> {
    let mut cluster = Cluster::new(cfg);
    for req in trace {
        cluster.inject(req);
    }
    let mut counts: HashMap<u64, EventCounts> = HashMap::new();
    fn record(counts: &mut HashMap<u64, EventCounts>, ev: RequestEvent) {
        let (id, field): (u64, fn(&mut EventCounts) -> &mut u32) = match ev {
            RequestEvent::Ready { id, .. } => (id, |c| &mut c.ready),
            RequestEvent::Encoded { id, .. } => (id, |c| &mut c.encoded),
            RequestEvent::Preempted { id, .. } => (id, |c| &mut c.preempted),
            RequestEvent::Requeued { id, .. } => (id, |c| &mut c.requeued),
            RequestEvent::FirstToken { id, .. } => (id, |c| &mut c.first_token),
            RequestEvent::Finished { id, .. } => (id, |c| &mut c.finished),
            RequestEvent::Dropped { id, .. } => (id, |c| &mut c.dropped),
            RequestEvent::Cancelled { id, .. } => (id, |c| &mut c.cancelled),
        };
        *field(counts.entry(id).or_default()) += 1;
    }
    let mut steps = 0u64;
    loop {
        let out = cluster.step();
        for ev in cluster.take_events() {
            record(&mut counts, ev);
        }
        match out {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => cluster.drop_blocked(),
            StepOutcome::Drained => break,
        }
        if steps % 32 == 0 {
            cluster.check_invariants().map_err(|e| format!("step {steps}: {e}"))?;
        }
        steps += 1;
        if steps >= 5_000_000 {
            return Err("stepping did not drain".into());
        }
    }
    for ev in cluster.take_events() {
        record(&mut counts, ev);
    }
    cluster.check_invariants().map_err(|e| format!("at drain: {e}"))?;
    Ok((cluster.report(), counts))
}

fn check_case(g: &mut pt::Gen) -> Result<(), String> {
    let cfg = random_pool_cfg(g);
    let profile = tcm_serve::model::by_name(&cfg.model).expect("default model");
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();
    let mm: HashMap<u64, bool> = trace.iter().map(|r| (r.id, r.mm_tokens > 0)).collect();
    let label = format!(
        "{}/{}/r{}/pool={}x{}",
        cfg.policy, cfg.cluster.router, cfg.cluster.replicas, cfg.pool.enabled, cfg.pool.slots
    );

    let (cr, counts) = run_stepped(&cfg, trace.clone())?;

    // conservation: nothing lost or duplicated across the handoff
    if cr.report.total() != n {
        return Err(format!("{label}: {} outcomes+failures for {n} requests", cr.report.total()));
    }
    let routed: usize = cr.per_replica.iter().map(|r| r.routed).sum();
    if routed != n {
        return Err(format!("{label}: routed {routed} != {n}"));
    }
    let dropped: u64 = cr.per_replica.iter().map(|r| r.dropped).sum();
    if dropped as usize != cr.report.failed.len() {
        return Err(format!(
            "{label}: {} failed outcomes != {dropped} dropped",
            cr.report.failed.len()
        ));
    }
    if cfg.pool.enabled {
        let p = cr.pool.as_ref().ok_or_else(|| format!("{label}: pool stats missing"))?;
        let mm_total = mm.values().filter(|&&is_mm| is_mm).count() as u64;
        if p.stats.encodes != mm_total {
            return Err(format!(
                "{label}: pool encoded {} of {mm_total} multimodal requests",
                p.stats.encodes
            ));
        }
        if p.stats.migrated_bytes
            != p.stats.migrated_mm_tokens * tcm_serve::cluster::pool::BYTES_PER_MM_TOKEN
        {
            return Err(format!("{label}: migration byte accounting inconsistent"));
        }
    } else if cr.pool.is_some() {
        return Err(format!("{label}: pool stats present with the pool disabled"));
    }

    // per-request event-stream invariants
    for (id, c) in &counts {
        if c.ready != 1 {
            return Err(format!("{label}: req {id} Ready x{}", c.ready));
        }
        if c.finished + c.dropped != 1 {
            return Err(format!(
                "{label}: req {id} terminal events: {} finished + {} dropped",
                c.finished, c.dropped
            ));
        }
        if c.first_token > 1 {
            return Err(format!("{label}: req {id} FirstToken x{}", c.first_token));
        }
        let is_mm = *mm.get(id).ok_or_else(|| format!("{label}: unknown req {id}"))?;
        if !is_mm && c.encoded != 0 {
            return Err(format!("{label}: text req {id} encoded x{}", c.encoded));
        }
        if is_mm && c.finished == 1 && c.encoded != 1 + c.preempted {
            return Err(format!(
                "{label}: req {id} encoded x{} with {} preemptions (want 1 + preemptions)",
                c.encoded, c.preempted
            ));
        }
        if is_mm && c.dropped == 1 && c.encoded > 1 + c.preempted {
            return Err(format!(
                "{label}: dropped req {id} encoded x{} with {} preemptions",
                c.encoded, c.preempted
            ));
        }
    }
    if counts.len() != n {
        return Err(format!("{label}: events cover {} of {n} requests", counts.len()));
    }
    for o in &cr.report.outcomes {
        let c = &counts[&o.id];
        if c.preempted != o.preemptions {
            return Err(format!(
                "{label}: req {} Preempted events {} != outcome {}",
                o.id, c.preempted, o.preemptions
            ));
        }
        // every preempted gap of a *finished* request closed with a
        // re-admission, so the events pair up exactly
        if c.requeued != c.preempted {
            return Err(format!(
                "{label}: req {} Requeued events {} != Preempted events {}",
                o.id, c.requeued, c.preempted
            ));
        }
    }

    // determinism: the identical config and trace reproduce bit-for-bit
    let (cr2, _) = run_stepped(&cfg, trace)?;
    if cr2.makespan.to_bits() != cr.makespan.to_bits() {
        return Err(format!("{label}: makespan diverged between identical runs"));
    }
    if cr2.report.outcomes.len() != cr.report.outcomes.len() {
        return Err(format!("{label}: outcome counts diverged"));
    }
    for (x, y) in cr.report.outcomes.iter().zip(&cr2.report.outcomes) {
        if x.id != y.id
            || x.first_token.to_bits() != y.first_token.to_bits()
            || x.finish.to_bits() != y.finish.to_bits()
        {
            return Err(format!("{label}: req {} diverged between identical runs", x.id));
        }
    }
    Ok(())
}

fn seeds_to_run() -> Vec<u64> {
    match std::env::var("POOL_PROPTEST_SEED") {
        Ok(v) => {
            let i: usize = v.parse().unwrap_or_else(|_| {
                panic!("POOL_PROPTEST_SEED must be 1..={}, got {v:?}", SEED_MATRIX.len())
            });
            assert!(
                (1..=SEED_MATRIX.len()).contains(&i),
                "POOL_PROPTEST_SEED must be 1..={}, got {i}",
                SEED_MATRIX.len()
            );
            vec![SEED_MATRIX[i - 1]]
        }
        Err(_) => SEED_MATRIX.to_vec(),
    }
}

#[test]
fn pool_conservation_and_determinism_sweep() {
    for seed in seeds_to_run() {
        pt::run_seeded(seed, 12, check_case);
    }
}

/// Report, per-request event counts, and accepted-cancel ids from one
/// cancellation-schedule run.
type CancelRun = (tcm_serve::cluster::ClusterReport, HashMap<u64, EventCounts>, Vec<u64>);

/// Drive a cluster step by step while applying a pre-generated
/// cancellation schedule (`(step, id)` pairs — cancels fire between
/// steps, the only place the serving leader can issue them). Returns the
/// report, per-request event counts, and the ids whose cancel was
/// accepted (returned `true`).
fn run_stepped_with_cancels(
    cfg: &ServeConfig,
    trace: Vec<Request>,
    schedule: &[(u64, u64)],
) -> Result<CancelRun, String> {
    let mut cluster = Cluster::new(cfg);
    for req in trace {
        cluster.inject(req);
    }
    let mut counts: HashMap<u64, EventCounts> = HashMap::new();
    let mut record = |ev: RequestEvent| {
        let (id, field): (u64, fn(&mut EventCounts) -> &mut u32) = match ev {
            RequestEvent::Ready { id, .. } => (id, |c| &mut c.ready),
            RequestEvent::Encoded { id, .. } => (id, |c| &mut c.encoded),
            RequestEvent::Preempted { id, .. } => (id, |c| &mut c.preempted),
            RequestEvent::Requeued { id, .. } => (id, |c| &mut c.requeued),
            RequestEvent::FirstToken { id, .. } => (id, |c| &mut c.first_token),
            RequestEvent::Finished { id, .. } => (id, |c| &mut c.finished),
            RequestEvent::Dropped { id, .. } => (id, |c| &mut c.dropped),
            RequestEvent::Cancelled { id, .. } => (id, |c| &mut c.cancelled),
        };
        *field(counts.entry(id).or_default()) += 1;
    };
    let mut accepted = Vec::new();
    let mut next_cancel = 0usize;
    let mut steps = 0u64;
    loop {
        while next_cancel < schedule.len() && schedule[next_cancel].0 <= steps {
            let id = schedule[next_cancel].1;
            if cluster.cancel(id) {
                accepted.push(id);
            }
            next_cancel += 1;
        }
        let out = cluster.step();
        for ev in cluster.take_events() {
            record(ev);
        }
        match out {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => cluster.drop_blocked(),
            StepOutcome::Drained => break,
        }
        if steps % 32 == 0 {
            cluster.check_invariants().map_err(|e| format!("step {steps}: {e}"))?;
        }
        steps += 1;
        if steps >= 5_000_000 {
            return Err("stepping did not drain".into());
        }
    }
    for ev in cluster.take_events() {
        record(ev);
    }
    cluster.check_invariants().map_err(|e| format!("at drain: {e}"))?;
    // occupancy must return to zero: cancellation released every KV
    // block and encoder slot it touched
    if cluster.kv_blocks_in_use() != 0 {
        return Err(format!("{} KV blocks still reserved at drain", cluster.kv_blocks_in_use()));
    }
    if cluster.pool_active() != 0 {
        return Err(format!("{} encodes still occupy the pool at drain", cluster.pool_active()));
    }
    if cluster.active_requests() != 0 {
        return Err(format!("{} requests still active at drain", cluster.active_requests()));
    }
    Ok((cluster.report(), counts, accepted))
}

/// Random cancellation injection (the lifecycle satellite): across seeds
/// × routers × pool modes, every accepted cancel yields exactly one
/// terminal event (`Cancelled`, no `Finished`/`Dropped`), occupancy
/// returns to zero at drain, and the report conserves
/// `finished + failed + cancelled == submitted` — deterministically.
fn check_cancellation_case(g: &mut pt::Gen) -> Result<(), String> {
    let cfg = random_pool_cfg(g);
    let profile = tcm_serve::model::by_name(&cfg.model).expect("default model");
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();
    let label = format!(
        "cancel/{}/{}/r{}/pool={}x{}",
        cfg.policy, cfg.cluster.router, cfg.cluster.replicas, cfg.pool.enabled, cfg.pool.slots
    );
    // Pre-generate the schedule so runs are reproducible: ~40% of ids,
    // each cancelled at a small step index (early cancels hit pending
    // arrivals and pool queues; later ones hit waiting/running state).
    let mut schedule: Vec<(u64, u64)> = trace
        .iter()
        .filter(|_| g.rng.bool(0.4))
        .map(|r| (g.u64_in(0, 80), r.id))
        .collect();
    schedule.sort_unstable();

    let (cr, counts, accepted) = run_stepped_with_cancels(&cfg, trace.clone(), &schedule)?;

    // conservation across all three terminal kinds
    if cr.report.total() != n {
        return Err(format!(
            "{label}: {} outcomes + {} failed + {} cancelled for {n} submitted",
            cr.report.outcomes.len(),
            cr.report.failed.len(),
            cr.report.cancelled.len()
        ));
    }
    if cr.report.cancelled.len() != accepted.len() {
        return Err(format!(
            "{label}: {} cancelled outcomes for {} accepted cancels",
            cr.report.cancelled.len(),
            accepted.len()
        ));
    }
    for (id, c) in &counts {
        let terminals = c.finished + c.dropped + c.cancelled;
        if terminals != 1 {
            return Err(format!(
                "{label}: req {id} terminal events: {} finished + {} dropped + {} cancelled",
                c.finished, c.dropped, c.cancelled
            ));
        }
    }
    for id in &accepted {
        let c = counts
            .get(id)
            .ok_or_else(|| format!("{label}: accepted cancel of {id} left no events"))?;
        if c.cancelled != 1 || c.finished != 0 || c.dropped != 0 {
            return Err(format!(
                "{label}: cancelled req {id} events: {} cancelled / {} finished / {} dropped",
                c.cancelled, c.finished, c.dropped
            ));
        }
    }
    // ids whose cancel was rejected must have completed or dropped
    for (step_id, id) in &schedule {
        let _ = step_id;
        if !accepted.contains(id) {
            let c = &counts[id];
            if c.cancelled != 0 {
                return Err(format!("{label}: rejected cancel of {id} still emitted Cancelled"));
            }
        }
    }
    if counts.len() != n {
        return Err(format!("{label}: events cover {} of {n} requests", counts.len()));
    }

    // determinism: identical trace + schedule reproduce bit-for-bit
    let (cr2, _, accepted2) = run_stepped_with_cancels(&cfg, trace, &schedule)?;
    if accepted2 != accepted {
        return Err(format!("{label}: accepted-cancel set diverged between identical runs"));
    }
    if cr2.makespan.to_bits() != cr.makespan.to_bits() {
        return Err(format!("{label}: makespan diverged between identical runs"));
    }
    for (x, y) in cr.report.cancelled.iter().zip(&cr2.report.cancelled) {
        if x.id != y.id || x.cancelled_at.to_bits() != y.cancelled_at.to_bits() {
            return Err(format!("{label}: cancelled outcome {} diverged", x.id));
        }
    }
    Ok(())
}

#[test]
fn cancellation_conservation_sweep() {
    for seed in seeds_to_run() {
        pt::run_seeded(seed ^ 0xCA9C_E1, 10, check_cancellation_case);
    }
}
