//! Property sweep for the client-population workload engine.
//!
//! The engine must be usable as a *reproducibility instrument*: every
//! figure regenerated from a (spec, seed) pair has to be bit-identical
//! run to run, and the structural claims the benches narrate (growing
//! session context, re-attached media, MMPP burstiness, k×-scaled
//! replays) have to hold for any seed, not just the one a bench
//! happened to pick. This sweep checks those invariants across the CI
//! 3-seed matrix (`WORKLOAD_PROPTEST_SEED=1|2|3` selects one seed;
//! unset runs all three):
//!
//!   1. generation is a pure function of (spec, seed, n) — bitwise —
//!      and different seeds actually diverge;
//!   2. within a session: arrivals strictly increase, context
//!      (text_tokens) grows monotonically, and the same attachment
//!      (mm_tokens, video_duration_s) is re-sent bit-identically on
//!      every turn;
//!   3. the MMPP phase process spends ~duty of its time in the on
//!      phase over a long horizon;
//!   4. `scale_trace` at k× preserves copy-0 bits (up to the exact
//!      /k time compression), relative order, and the modality mix;
//!   5. a mid-run mix flip shows up in the modality composition;
//!   6. a population trace (deadlines + SLO classes included) survives
//!      the v2 on-disk format bit-exactly.

use tcm_serve::config::WorkloadConfig;
use tcm_serve::model::by_name;
use tcm_serve::request::{Modality, Request};
use tcm_serve::util::rng::Rng;
use tcm_serve::workload::{
    load_trace, save_trace, scale_trace, Mix, MmppPhases, PopulationGen, ReqMeta, WorkloadSpec,
};

const SEED_MATRIX: [u64; 3] = [0x9001_5EED_0001, 0x9001_5EED_0002, 0x9001_5EED_0003];

fn seeds_to_run() -> Vec<u64> {
    match std::env::var("WORKLOAD_PROPTEST_SEED") {
        Ok(v) => {
            let i: usize = v.parse().unwrap_or_else(|_| {
                panic!("WORKLOAD_PROPTEST_SEED must be 1..={}, got {v:?}", SEED_MATRIX.len())
            });
            assert!(
                (1..=SEED_MATRIX.len()).contains(&i),
                "WORKLOAD_PROPTEST_SEED must be 1..={}, got {i}",
                SEED_MATRIX.len()
            );
            vec![SEED_MATRIX[i - 1]]
        }
        Err(_) => SEED_MATRIX.to_vec(),
    }
}

fn population(mix: Mix, rate: f64, seed: u64, n: usize) -> (Vec<Request>, Vec<ReqMeta>) {
    let profile = by_name("llava-7b").unwrap();
    let spec = WorkloadSpec::from_config(&WorkloadConfig::default(), mix, rate);
    PopulationGen::new(&profile, spec, seed).generate_with_meta(n)
}

fn assert_bitwise_eq(a: &[Request], b: &[Request], label: &str) {
    assert_eq!(a.len(), b.len(), "{label}: lengths diverged");
    for (x, y) in a.iter().zip(b) {
        assert_eq!(x.id, y.id, "{label}: ids diverged");
        assert_eq!(
            x.arrival.to_bits(),
            y.arrival.to_bits(),
            "{label}: arrival bits diverged at id {}",
            x.id
        );
        assert_eq!(x.modality, y.modality, "{label}: modality diverged at id {}", x.id);
        assert_eq!(x.text_tokens, y.text_tokens, "{label}: text diverged at id {}", x.id);
        assert_eq!(x.mm_tokens, y.mm_tokens, "{label}: mm diverged at id {}", x.id);
        assert_eq!(
            x.video_duration_s.to_bits(),
            y.video_duration_s.to_bits(),
            "{label}: video_dur bits diverged at id {}",
            x.id
        );
        assert_eq!(x.output_tokens, y.output_tokens, "{label}: output diverged at id {}", x.id);
        assert_eq!(x.deadline_s, y.deadline_s, "{label}: deadline diverged at id {}", x.id);
        assert_eq!(x.slo_class, y.slo_class, "{label}: slo diverged at id {}", x.id);
    }
}

/// 1. Same (spec, seed, n) → bit-identical populations; a different
/// seed must actually change the trace (the engine is seeded, not
/// seed-blind).
#[test]
fn population_is_bit_deterministic_per_seed() {
    for seed in seeds_to_run() {
        for mix in [tcm_serve::workload::MIX_MH, tcm_serve::workload::MIX_VH] {
            let (a, ma) = population(mix, 3.0, seed, 250);
            let (b, mb) = population(mix, 3.0, seed, 250);
            assert_bitwise_eq(&a, &b, &format!("seed {seed:#x} mix {}", mix.name));
            assert_eq!(ma, mb, "seed {seed:#x}: provenance diverged between identical runs");
            let (c, _) = population(mix, 3.0, seed ^ 0xDEAD_BEEF, 250);
            assert!(
                a.iter().zip(&c).any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()),
                "seed {seed:#x}: a different seed produced an identical trace"
            );
        }
    }
}

/// 2. Session structure: grouping requests by (client, session) and
/// walking turns in order, arrivals and context must strictly grow and
/// the attachment drawn at turn 0 must be re-sent bit-identically.
#[test]
fn sessions_grow_context_and_reattach_media() {
    for seed in seeds_to_run() {
        let (reqs, meta) = population(tcm_serve::workload::MIX_VH, 3.0, seed, 300);
        let mut sessions: std::collections::BTreeMap<(u32, u32), Vec<usize>> =
            std::collections::BTreeMap::new();
        for (i, m) in meta.iter().enumerate() {
            sessions.entry((m.client, m.session)).or_default().push(i);
        }
        let mut deep = 0usize;
        let mut mm_deep = 0usize;
        for ((client, session), mut idx) in sessions {
            idx.sort_by_key(|&i| meta[i].turn);
            // turns must be the contiguous prefix 0..k (whole sessions
            // are emitted; a truncated tail drops whole turns from the
            // end, never the middle)
            for (k, &i) in idx.iter().enumerate() {
                assert_eq!(
                    meta[i].turn as usize,
                    k,
                    "seed {seed:#x}: client {client} session {session} has a turn gap"
                );
            }
            if idx.len() >= 2 {
                deep += 1;
            }
            let head = &reqs[idx[0]];
            for w in idx.windows(2) {
                let (a, b) = (&reqs[w[0]], &reqs[w[1]]);
                assert!(
                    b.arrival > a.arrival,
                    "seed {seed:#x}: turn arrivals not strictly increasing"
                );
                assert!(
                    b.text_tokens > a.text_tokens,
                    "seed {seed:#x}: context did not grow (turn {} {} -> {})",
                    meta[w[1]].turn,
                    a.text_tokens,
                    b.text_tokens
                );
                assert_eq!(
                    b.mm_tokens,
                    head.mm_tokens,
                    "seed {seed:#x}: attachment changed mid-session"
                );
                assert_eq!(
                    b.video_duration_s.to_bits(),
                    head.video_duration_s.to_bits(),
                    "seed {seed:#x}: video duration changed mid-session"
                );
                assert_eq!(b.modality, head.modality, "seed {seed:#x}: modality changed");
            }
            if idx.len() >= 2 && head.mm_tokens > 0 {
                mm_deep += 1;
            }
        }
        assert!(deep >= 1, "seed {seed:#x}: no multi-turn session in 300 requests (vacuous)");
        assert!(
            mm_deep >= 1,
            "seed {seed:#x}: no multi-turn multimodal session under VH (vacuous re-attach check)"
        );
    }
}

/// 3. The MMPP phase process, driven on its own, spends ~duty of a
/// long horizon in the on phase.
#[test]
fn mmpp_phase_occupancy_matches_duty() {
    for seed in seeds_to_run() {
        for (mean_on, mean_off) in [(20.0, 60.0), (10.0, 10.0), (30.0, 7.5)] {
            let duty = mean_on / (mean_on + mean_off);
            let mut rng = Rng::new(seed);
            let mut phases = MmppPhases::init(&mut rng, mean_on, mean_off);
            let horizon = 300_000.0;
            let mut t = 0.0;
            let mut on_time = 0.0;
            while phases.phase_end_s < horizon {
                if phases.on {
                    on_time += phases.phase_end_s - t;
                }
                t = phases.phase_end_s;
                phases.flip(&mut rng);
            }
            if phases.on {
                on_time += horizon - t;
            }
            let occupancy = on_time / horizon;
            assert!(
                (occupancy - duty).abs() < 0.02,
                "seed {seed:#x}: on-occupancy {occupancy:.4} vs duty {duty:.4}"
            );
        }
    }
}

/// 4. k×-scaled replay: copy 0 keeps the original ids and its arrivals
/// are exactly arrival/k; the result is sorted; the modality mix is
/// exactly k copies of the original.
#[test]
fn scaled_trace_preserves_order_mix_and_copy0() {
    for seed in seeds_to_run() {
        let (trace, _) = population(tcm_serve::workload::MIX_MH, 3.0, seed, 200);
        let k = 3;
        let scaled = scale_trace(&trace, k);
        assert_eq!(scaled.len(), k * trace.len());
        for w in scaled.windows(2) {
            assert!(
                w[1].arrival >= w[0].arrival,
                "seed {seed:#x}: scaled trace not sorted by arrival"
            );
        }
        let max_id = trace.iter().map(|r| r.id).max().unwrap_or(0);
        let mut copy0: Vec<&Request> = scaled.iter().filter(|r| r.id <= max_id).collect();
        copy0.sort_by_key(|r| r.id);
        assert_eq!(copy0.len(), trace.len(), "seed {seed:#x}: copy 0 lost requests");
        for (orig, s) in trace.iter().zip(&copy0) {
            assert_eq!(orig.id, s.id);
            assert_eq!(
                (orig.arrival / k as f64).to_bits(),
                s.arrival.to_bits(),
                "seed {seed:#x}: copy-0 arrival is not exactly arrival/k"
            );
            assert_eq!(orig.modality, s.modality);
            assert_eq!(orig.text_tokens, s.text_tokens);
            assert_eq!(orig.mm_tokens, s.mm_tokens);
            assert_eq!(orig.output_tokens, s.output_tokens);
            assert_eq!(orig.slo_class, s.slo_class);
        }
        for m in Modality::ALL {
            let orig = trace.iter().filter(|r| r.modality == m).count();
            let got = scaled.iter().filter(|r| r.modality == m).count();
            assert_eq!(got, k * orig, "seed {seed:#x}: {m} mix not preserved under scaling");
        }
        // k = 1 is the exact identity
        assert_bitwise_eq(&trace, &scale_trace(&trace, 1), &format!("seed {seed:#x} k=1"));
    }
}

/// 5. A VH → ML flip mid-run must show up as a drop in the video
/// fraction after the flip.
#[test]
fn mix_flip_shifts_modality_composition() {
    for seed in seeds_to_run() {
        let profile = by_name("llava-7b").unwrap();
        let mut w = WorkloadConfig::default();
        w.engine = "population".into();
        w.mix_flip_at_s = 50.0;
        w.mix_flip_to = "ML".into();
        let spec = WorkloadSpec::from_config(&w, tcm_serve::workload::MIX_VH, 3.0);
        let reqs = PopulationGen::new(&profile, spec, seed).generate(400);
        let frac = |lo: f64, hi: f64| {
            let win: Vec<_> = reqs.iter().filter(|r| r.arrival >= lo && r.arrival < hi).collect();
            assert!(!win.is_empty(), "seed {seed:#x}: empty window [{lo}, {hi})");
            win.iter().filter(|r| r.modality == Modality::Video).count() as f64 / win.len() as f64
        };
        let last = reqs.last().map(|r| r.arrival).unwrap_or(0.0);
        let before = frac(0.0, 50.0);
        // sessions started before the flip keep their modality across
        // later turns, so measure well after the boundary
        let after = frac(70.0, last + 1.0);
        assert!(
            after < before,
            "seed {seed:#x}: video fraction did not drop across the flip \
             ({before:.3} -> {after:.3})"
        );
    }
}

/// 6. A population trace — deadlines and SLO classes included — must
/// survive the v2 on-disk format bit-exactly.
#[test]
fn population_trace_roundtrips_exactly() {
    let dir = std::env::temp_dir().join("tcm_workload_props");
    std::fs::create_dir_all(&dir).unwrap();
    for seed in seeds_to_run() {
        let (mut trace, _) = population(tcm_serve::workload::MIX_VH, 3.0, seed, 150);
        // the population engine stamps slo_class; add deadlines the way
        // the lifecycle path does so both v2 columns are non-vacuous
        for r in trace.iter_mut() {
            if r.id % 4 == 0 {
                r.deadline_s = Some(r.arrival + 2.5);
            }
        }
        assert!(
            trace.iter().any(|r| r.slo_class.is_some()),
            "seed {seed:#x}: population engine stopped stamping slo_class"
        );
        let path = dir.join(format!("pop_{seed:x}.trace"));
        save_trace(&path, &trace).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_bitwise_eq(&trace, &loaded, &format!("seed {seed:#x} roundtrip"));
        std::fs::remove_file(path).unwrap();
    }
}
