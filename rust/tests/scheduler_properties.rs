//! Property-based tests over coordinator invariants (proptest substitute:
//! the in-repo `proptest_lite` harness with seeded shrinking).
//!
//! Invariants, per DESIGN.md:
//!  * conservation — every generated request either completes or is
//!    explicitly dropped, under any policy/memory/budget combination;
//!  * causality — arrival ≤ first token ≤ finish for every outcome;
//!  * KV hygiene — no leaked blocks after the run;
//!  * determinism — identical configs produce identical outcomes.

use std::cell::Cell;

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, SchedStats, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::{make_trace, run_sim, run_sim_with_trace};
use tcm_serve::metrics::Report;
use tcm_serve::obs::ObsEvent;
use tcm_serve::policies::build_policy;
use tcm_serve::request::{Modality, Request};
use tcm_serve::util::proptest_lite as pt;

const POLICIES: [&str; 6] =
    ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];

/// Seeds for the indexed-vs-rescore equivalence sweep. CI fans these out
/// one per job (`SCHED_PROPTEST_SEED=1|2|3` selects one); unset runs all
/// three, so a plain `cargo test` covers the full matrix.
const SEED_MATRIX: [u64; 3] = [0x5C4ED_1, 0x5C4ED_2, 0x5C4ED_3];

fn seeds_to_run() -> Vec<u64> {
    match std::env::var("SCHED_PROPTEST_SEED") {
        Ok(v) => {
            let i: usize = v.parse().unwrap_or_else(|_| {
                panic!("SCHED_PROPTEST_SEED must be 1..={}, got {v:?}", SEED_MATRIX.len())
            });
            assert!(
                (1..=SEED_MATRIX.len()).contains(&i),
                "SCHED_PROPTEST_SEED must be 1..={}, got {i}",
                SEED_MATRIX.len()
            );
            vec![SEED_MATRIX[i - 1]]
        }
        Err(_) => SEED_MATRIX.to_vec(),
    }
}

fn random_cfg(g: &mut pt::Gen) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.policy = (*g.pick(&POLICIES)).into();
    cfg.model = (*g.pick(&["llava-7b", "qwen-3b", "gemma-4b", "llava-500m"])).into();
    cfg.mix = (*g.pick(&["T0", "ML", "MH"])).into();
    cfg.rate = g.f64_in(0.5, 8.0);
    cfg.seed = g.rng.next_u64();
    cfg.num_requests = g.usize_in(5, 80);
    cfg.memory_frac = *g.pick(&[1.0, 0.5, 0.1, 0.02]);
    cfg.scheduler.token_budget = *g.pick(&[512u32, 2048, 8192]);
    cfg.scheduler.max_running = g.usize_in(2, 64);
    cfg.slo_scale = g.f64_in(2.0, 10.0);
    cfg
}

#[test]
fn conservation_and_causality_all_policies() {
    pt::run(60, |g| {
        let cfg = random_cfg(g);
        let r = run_sim(&cfg);
        let total = r.report.outcomes.len() + r.stats.dropped as usize;
        if total != cfg.num_requests {
            return Err(format!(
                "{}: {} outcomes + {} dropped != {} requests",
                cfg.policy,
                r.report.outcomes.len(),
                r.stats.dropped,
                cfg.num_requests
            ));
        }
        // drops are surfaced in the report itself, never silent
        if r.report.failed.len() != r.stats.dropped as usize {
            return Err(format!(
                "{}: {} failed outcomes != {} stats.dropped",
                cfg.policy,
                r.report.failed.len(),
                r.stats.dropped
            ));
        }
        if r.report.total() != cfg.num_requests {
            return Err(format!("{}: report.total() != num_requests", cfg.policy));
        }
        for o in &r.report.outcomes {
            if o.first_token < o.arrival {
                return Err(format!("req {}: first token before arrival", o.id));
            }
            if o.finish < o.first_token {
                return Err(format!("req {}: finish before first token", o.id));
            }
            if !o.ttft().is_finite() || !o.e2e().is_finite() {
                return Err(format!("req {}: non-finite latency", o.id));
            }
        }
        Ok(())
    });
}

#[test]
fn determinism_under_random_configs() {
    pt::run(15, |g| {
        let cfg = random_cfg(g);
        let a = run_sim(&cfg);
        let b = run_sim(&cfg);
        if a.makespan != b.makespan {
            return Err(format!("{}: makespans differ", cfg.policy));
        }
        if a.report.outcomes.len() != b.report.outcomes.len() {
            return Err("outcome counts differ".into());
        }
        for (x, y) in a.report.outcomes.iter().zip(&b.report.outcomes) {
            if x.id != y.id || x.first_token != y.first_token || x.finish != y.finish {
                return Err(format!("req {} diverged between identical runs", x.id));
            }
        }
        Ok(())
    });
}

#[test]
fn adversarial_traces_never_wedge() {
    // pathological hand-rolled traces: bursts, monsters, duplicates of
    // size, zero-ish outputs.
    pt::run(40, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = (*g.pick(&POLICIES)).into();
        cfg.memory_frac = *g.pick(&[1.0, 0.05, 0.01]);
        cfg.scheduler.token_budget = *g.pick(&[256u32, 2048]);
        let n = g.usize_in(1, 40);
        let mut trace = Vec::new();
        for id in 0..n as u64 {
            let arrival = g.f64_in(0.0, 3.0);
            let m = *g.pick(&[Modality::Text, Modality::Image, Modality::Video]);
            let (text, mm, dur) = match m {
                Modality::Text => (g.u64_in(1, 12_000) as u32, 0, 0.0),
                Modality::Image => (g.u64_in(1, 100) as u32, g.u64_in(64, 2000) as u32, 0.0),
                Modality::Video => {
                    (g.u64_in(1, 100) as u32, g.u64_in(1000, 150_000) as u32, 60.0)
                }
            };
            trace.push(Request {
                id,
                arrival,
                modality: m,
                text_tokens: text,
                mm_tokens: mm,
                video_duration_s: dur,
                output_tokens: g.u64_in(1, 600) as u32,
                ..Request::default()
            });
        }
        let r = run_sim_with_trace(&cfg, trace);
        let total = r.report.outcomes.len() + r.stats.dropped as usize;
        if total != n {
            return Err(format!(
                "{}: conservation violated ({} != {n})",
                cfg.policy, total
            ));
        }
        Ok(())
    });
}

#[test]
fn preempted_requests_eventually_finish() {
    pt::run(25, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = (*g.pick(&["tcm", "edf", "naive-aging"])).into();
        cfg.memory_frac = 0.03;
        cfg.rate = g.f64_in(1.0, 4.0);
        cfg.seed = g.rng.next_u64();
        cfg.num_requests = 50;
        let r = run_sim(&cfg);
        // preempted requests that were not dropped must have finished
        let preempted_done = r.report.outcomes.iter().filter(|o| o.preemptions > 0).count();
        let any_preempt = r.stats.preemptions > 0;
        if any_preempt && preempted_done == 0 && r.stats.dropped == 0 {
            return Err("preemptions occurred but nothing preempted ever finished".into());
        }
        for o in &r.report.outcomes {
            if o.preemptions > 0 && o.preempted_time < 0.0 {
                return Err("negative preempted time".into());
            }
        }
        Ok(())
    });
}

/// Everything one stepped run exposes, captured for bit-level comparison.
/// `StepOutcome` and `RequestEvent` are compared through their `Debug`
/// strings: f64 `Debug` is the shortest round-trip representation, so two
/// values print identically iff they are the same value (modulo NaN
/// payloads, which the planner never produces).
struct SteppedRun {
    step_log: Vec<String>,
    events: Vec<String>,
    report: Report,
    stats: SchedStats,
    makespan: f64,
}

/// Drive one scheduler over `trace` through the public stepping API,
/// recording every `StepOutcome` and every drained `RequestEvent`.
fn run_stepped(cfg: &ServeConfig, trace: Vec<Request>) -> Result<SteppedRun, String> {
    let profile =
        tcm_serve::model::by_name(&cfg.model).ok_or_else(|| format!("model {}", cfg.model))?;
    let policy = build_policy(cfg, &profile);
    let mut s =
        Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&cfg.engine_profile())));
    for r in trace {
        s.inject(r);
    }
    let mut step_log = Vec::new();
    let mut events = Vec::new();
    let mut steps = 0u64;
    loop {
        let out = s.step();
        step_log.push(format!("{out:?}"));
        match out {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => s.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => s.advance_to(t),
            StepOutcome::Blocked { next_event: None } => s.drop_blocked(),
            StepOutcome::Drained => break,
        }
        for e in s.take_events() {
            events.push(format!("{e:?}"));
        }
        if let Err(v) = s.check_invariants() {
            return Err(format!("invariant violated mid-run: {v}"));
        }
        steps += 1;
        if steps > 2_000_000 {
            return Err("stepping did not drain".into());
        }
    }
    for e in s.take_events() {
        events.push(format!("{e:?}"));
    }
    Ok(SteppedRun {
        step_log,
        events,
        report: s.report(),
        stats: s.stats.clone(),
        makespan: s.now(),
    })
}

/// First index at which two string logs diverge, with context.
fn first_divergence(label: &str, what: &str, a: &[String], b: &[String]) -> Result<(), String> {
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        if x != y {
            return Err(format!("{label}: {what}[{i}] diverged:\n  indexed: {x}\n  rescore: {y}"));
        }
    }
    if a.len() != b.len() {
        return Err(format!(
            "{label}: {what} length {} (indexed) != {} (rescore)",
            a.len(),
            b.len()
        ));
    }
    Ok(())
}

/// Bit-level report comparison, `Err`-returning so the property harness
/// can shrink (the panic-based `common::assert_reports_bit_identical`
/// would abort the shrink loop).
fn reports_bit_identical(label: &str, a: &Report, b: &Report) -> Result<(), String> {
    if a.outcomes.len() != b.outcomes.len()
        || a.failed.len() != b.failed.len()
        || a.cancelled.len() != b.cancelled.len()
    {
        return Err(format!("{label}: report section lengths diverged"));
    }
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        if x.id != y.id
            || x.first_token.to_bits() != y.first_token.to_bits()
            || x.finish.to_bits() != y.finish.to_bits()
            || x.preemptions != y.preemptions
        {
            return Err(format!("{label}: outcome for req {} diverged", x.id));
        }
    }
    for (x, y) in a.failed.iter().zip(&b.failed) {
        if x.id != y.id || x.dropped_at.to_bits() != y.dropped_at.to_bits() {
            return Err(format!("{label}: failed outcome for req {} diverged", x.id));
        }
    }
    for (x, y) in a.cancelled.iter().zip(&b.cancelled) {
        if x.id != y.id || x.cancelled_at.to_bits() != y.cancelled_at.to_bits() {
            return Err(format!("{label}: cancelled outcome for req {} diverged", x.id));
        }
    }
    Ok(())
}

/// The tentpole's correctness contract: the indexed planner
/// (`scheduler.indexed = true`, the default) is observationally identical
/// to the full-rescore oracle on the same trace — every `StepOutcome`,
/// every `RequestEvent`, the report, the makespan and every `SchedStats`
/// field except `planning_evals` (the one field the two modes are
/// documented to disagree on: it *measures* the work each mode does).
/// Swept over all six policies per random config, across a 3-seed matrix.
#[test]
fn indexed_planner_matches_full_rescore_oracle() {
    let preemptions = Cell::new(0u64);
    for seed in seeds_to_run() {
        pt::run_seeded(seed, 6, |g| {
            let mut cfg = random_cfg(g);
            cfg.num_requests = g.usize_in(5, 40);
            for policy in POLICIES {
                cfg.policy = policy.into();
                let profile = tcm_serve::model::by_name(&cfg.model).expect("validated model");
                let trace = make_trace(&cfg, &profile);
                cfg.scheduler.indexed = true;
                let a = run_stepped(&cfg, trace.clone()).map_err(|e| format!("{policy}: {e}"))?;
                cfg.scheduler.indexed = false;
                let b = run_stepped(&cfg, trace).map_err(|e| format!("{policy} oracle: {e}"))?;
                first_divergence(policy, "step", &a.step_log, &b.step_log)?;
                first_divergence(policy, "event", &a.events, &b.events)?;
                reports_bit_identical(policy, &a.report, &b.report)?;
                if a.makespan.to_bits() != b.makespan.to_bits() {
                    return Err(format!("{policy}: makespans diverged"));
                }
                if a.stats.iterations != b.stats.iterations
                    || a.stats.preemptions != b.stats.preemptions
                    || a.stats.dropped != b.stats.dropped
                    || a.stats.cancelled != b.stats.cancelled
                    || a.stats.busy_time_s.to_bits() != b.stats.busy_time_s.to_bits()
                {
                    return Err(format!(
                        "{policy}: stats diverged: indexed {:?} vs rescore {:?}",
                        a.stats, b.stats
                    ));
                }
                preemptions.set(preemptions.get() + a.stats.preemptions);
            }
            Ok(())
        });
    }
    // Non-vacuity: the sweep must have exercised the preemption path
    // (re-queues are where indexed rank maintenance is subtlest). The
    // 0.02/0.1 memory fractions in random_cfg make this overwhelmingly
    // likely; a zero here means the generator rotted, not bad luck.
    assert!(preemptions.get() > 0, "equivalence sweep exercised no preemptions — vacuous");
}

/// One serving run for the aging-promotion probe: a single-slot engine
/// decodes a long text request (~500 virtual seconds) while a truck-class
/// video waits; the instant the slot frees, a fresh motorcycle arrives.
/// Returns the obs-tap admission order.
fn admitted_order(aging: bool, indexed: bool) -> Vec<u64> {
    let mut cfg = ServeConfig::default();
    cfg.policy = "tcm".into();
    cfg.model = "llava-7b".into();
    cfg.scheduler.max_running = 1;
    cfg.scheduler.indexed = indexed;
    cfg.regulator.aging_enabled = aging;
    let profile = tcm_serve::model::by_name("llava-7b").unwrap();
    // enough decode steps to span ~500 virtual seconds of truck waiting
    let n_out = (500.0 / profile.decode_step_time(1)).ceil() as u32;
    let policy = build_policy(&cfg, &profile);
    let mut s =
        Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&cfg.engine_profile())));
    s.set_obs(true);
    s.inject(Request {
        id: 0,
        arrival: 0.0,
        text_tokens: 64,
        output_tokens: n_out,
        ..Request::default()
    });
    s.inject(Request {
        id: 1,
        arrival: 0.0,
        modality: Modality::Video,
        text_tokens: 40,
        mm_tokens: profile.tokenizer.video_tokens(120.0),
        video_duration_s: 120.0,
        output_tokens: 8,
        ..Request::default()
    });
    let mut injected_moto = false;
    let mut steps = 0u64;
    loop {
        match s.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => s.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => s.advance_to(t),
            StepOutcome::Blocked { next_event: None } => s.drop_blocked(),
            StepOutcome::Drained => break,
        }
        for e in s.take_events() {
            // the motorcycle arrives the instant the blocker's slot frees,
            // before the next planning pass, so its waiting time is zero
            // at the decision point
            if !injected_moto && matches!(e, RequestEvent::Finished { id: 0, .. }) {
                injected_moto = true;
                s.inject(Request {
                    id: 2,
                    arrival: s.now(),
                    text_tokens: 64,
                    output_tokens: 8,
                    ..Request::default()
                });
            }
        }
        steps += 1;
        assert!(steps < 5_000_000, "aging probe did not drain");
    }
    assert!(injected_moto, "blocker never finished");
    // resource pressure would confound the ordering probe
    assert_eq!(s.stats.preemptions, 0, "aging probe must not preempt");
    assert_eq!(s.stats.dropped, 0, "aging probe must not drop");
    s.take_obs_events()
        .iter()
        .filter_map(|e| match e {
            ObsEvent::Admitted { id, .. } => Some(*id),
            _ => None,
        })
        .collect()
}

/// Non-vacuity for the equivalence sweep's aging leg: the regulator's
/// aging term actually reorders admissions (a truck that waited ~500 s
/// outranks a just-arrived motorcycle; with aging disabled the static
/// priorities put the motorcycle first) — and the indexed planner
/// reproduces the promotion exactly.
#[test]
fn aging_promotes_waited_truck_over_fresh_motorcycle() {
    for indexed in [true, false] {
        let with_aging = admitted_order(true, indexed);
        let without = admitted_order(false, indexed);
        assert_eq!(
            with_aging,
            vec![0, 1, 2],
            "indexed={indexed}: aged truck must be admitted before the fresh motorcycle"
        );
        assert_eq!(
            without,
            vec![0, 2, 1],
            "indexed={indexed}: without aging, static priority favors the motorcycle"
        );
    }
}

#[test]
fn zero_mm_tokens_means_no_encode_cost() {
    // text-only run: busy time must equal prefill+decode cost exactly;
    // indirectly asserts no phantom encode items are planned.
    pt::run(20, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.mix = "T0".into();
        cfg.rate = g.f64_in(0.5, 4.0);
        cfg.seed = g.rng.next_u64();
        cfg.num_requests = 20;
        let r = run_sim(&cfg);
        if r.report.outcomes.len() + r.stats.dropped as usize != 20 {
            return Err("conservation".into());
        }
        // TTFT of a text request can't include preprocess (it is 0)
        for o in &r.report.outcomes {
            if o.modality == Modality::Text && o.ttft() < 0.0 {
                return Err("negative ttft".into());
            }
        }
        Ok(())
    });
}
