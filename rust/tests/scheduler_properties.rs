//! Property-based tests over coordinator invariants (proptest substitute:
//! the in-repo `proptest_lite` harness with seeded shrinking).
//!
//! Invariants, per DESIGN.md:
//!  * conservation — every generated request either completes or is
//!    explicitly dropped, under any policy/memory/budget combination;
//!  * causality — arrival ≤ first token ≤ finish for every outcome;
//!  * KV hygiene — no leaked blocks after the run;
//!  * determinism — identical configs produce identical outcomes.

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::{run_sim, run_sim_with_trace};
use tcm_serve::request::{Modality, Request};
use tcm_serve::util::proptest_lite as pt;

const POLICIES: [&str; 6] =
    ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];

fn random_cfg(g: &mut pt::Gen) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.policy = (*g.pick(&POLICIES)).into();
    cfg.model = (*g.pick(&["llava-7b", "qwen-3b", "gemma-4b", "llava-500m"])).into();
    cfg.mix = (*g.pick(&["T0", "ML", "MH"])).into();
    cfg.rate = g.f64_in(0.5, 8.0);
    cfg.seed = g.rng.next_u64();
    cfg.num_requests = g.usize_in(5, 80);
    cfg.memory_frac = *g.pick(&[1.0, 0.5, 0.1, 0.02]);
    cfg.scheduler.token_budget = *g.pick(&[512u32, 2048, 8192]);
    cfg.scheduler.max_running = g.usize_in(2, 64);
    cfg.slo_scale = g.f64_in(2.0, 10.0);
    cfg
}

#[test]
fn conservation_and_causality_all_policies() {
    pt::run(60, |g| {
        let cfg = random_cfg(g);
        let r = run_sim(&cfg);
        let total = r.report.outcomes.len() + r.stats.dropped as usize;
        if total != cfg.num_requests {
            return Err(format!(
                "{}: {} outcomes + {} dropped != {} requests",
                cfg.policy,
                r.report.outcomes.len(),
                r.stats.dropped,
                cfg.num_requests
            ));
        }
        // drops are surfaced in the report itself, never silent
        if r.report.failed.len() != r.stats.dropped as usize {
            return Err(format!(
                "{}: {} failed outcomes != {} stats.dropped",
                cfg.policy,
                r.report.failed.len(),
                r.stats.dropped
            ));
        }
        if r.report.total() != cfg.num_requests {
            return Err(format!("{}: report.total() != num_requests", cfg.policy));
        }
        for o in &r.report.outcomes {
            if o.first_token < o.arrival {
                return Err(format!("req {}: first token before arrival", o.id));
            }
            if o.finish < o.first_token {
                return Err(format!("req {}: finish before first token", o.id));
            }
            if !o.ttft().is_finite() || !o.e2e().is_finite() {
                return Err(format!("req {}: non-finite latency", o.id));
            }
        }
        Ok(())
    });
}

#[test]
fn determinism_under_random_configs() {
    pt::run(15, |g| {
        let cfg = random_cfg(g);
        let a = run_sim(&cfg);
        let b = run_sim(&cfg);
        if a.makespan != b.makespan {
            return Err(format!("{}: makespans differ", cfg.policy));
        }
        if a.report.outcomes.len() != b.report.outcomes.len() {
            return Err("outcome counts differ".into());
        }
        for (x, y) in a.report.outcomes.iter().zip(&b.report.outcomes) {
            if x.id != y.id || x.first_token != y.first_token || x.finish != y.finish {
                return Err(format!("req {} diverged between identical runs", x.id));
            }
        }
        Ok(())
    });
}

#[test]
fn adversarial_traces_never_wedge() {
    // pathological hand-rolled traces: bursts, monsters, duplicates of
    // size, zero-ish outputs.
    pt::run(40, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = (*g.pick(&POLICIES)).into();
        cfg.memory_frac = *g.pick(&[1.0, 0.05, 0.01]);
        cfg.scheduler.token_budget = *g.pick(&[256u32, 2048]);
        let n = g.usize_in(1, 40);
        let mut trace = Vec::new();
        for id in 0..n as u64 {
            let arrival = g.f64_in(0.0, 3.0);
            let m = *g.pick(&[Modality::Text, Modality::Image, Modality::Video]);
            let (text, mm, dur) = match m {
                Modality::Text => (g.u64_in(1, 12_000) as u32, 0, 0.0),
                Modality::Image => (g.u64_in(1, 100) as u32, g.u64_in(64, 2000) as u32, 0.0),
                Modality::Video => {
                    (g.u64_in(1, 100) as u32, g.u64_in(1000, 150_000) as u32, 60.0)
                }
            };
            trace.push(Request {
                id,
                arrival,
                modality: m,
                text_tokens: text,
                mm_tokens: mm,
                video_duration_s: dur,
                output_tokens: g.u64_in(1, 600) as u32,
                ..Request::default()
            });
        }
        let r = run_sim_with_trace(&cfg, trace);
        let total = r.report.outcomes.len() + r.stats.dropped as usize;
        if total != n {
            return Err(format!(
                "{}: conservation violated ({} != {n})",
                cfg.policy, total
            ));
        }
        Ok(())
    });
}

#[test]
fn preempted_requests_eventually_finish() {
    pt::run(25, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = (*g.pick(&["tcm", "edf", "naive-aging"])).into();
        cfg.memory_frac = 0.03;
        cfg.rate = g.f64_in(1.0, 4.0);
        cfg.seed = g.rng.next_u64();
        cfg.num_requests = 50;
        let r = run_sim(&cfg);
        // preempted requests that were not dropped must have finished
        let preempted_done = r.report.outcomes.iter().filter(|o| o.preemptions > 0).count();
        let any_preempt = r.stats.preemptions > 0;
        if any_preempt && preempted_done == 0 && r.stats.dropped == 0 {
            return Err("preemptions occurred but nothing preempted ever finished".into());
        }
        for o in &r.report.outcomes {
            if o.preemptions > 0 && o.preempted_time < 0.0 {
                return Err("negative preempted time".into());
            }
        }
        Ok(())
    });
}

#[test]
fn zero_mm_tokens_means_no_encode_cost() {
    // text-only run: busy time must equal prefill+decode cost exactly;
    // indirectly asserts no phantom encode items are planned.
    pt::run(20, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.mix = "T0".into();
        cfg.rate = g.f64_in(0.5, 4.0);
        cfg.seed = g.rng.next_u64();
        cfg.num_requests = 20;
        let r = run_sim(&cfg);
        if r.report.outcomes.len() + r.stats.dropped as usize != 20 {
            return Err("conservation".into());
        }
        // TTFT of a text request can't include preprocess (it is 0)
        for o in &r.report.outcomes {
            if o.modality == Modality::Text && o.ttft() < 0.0 {
                return Err("negative ttft".into());
            }
        }
        Ok(())
    });
}
