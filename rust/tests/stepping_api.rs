//! The stepping API contract: `inject`-all-then-`step`-until-drained must
//! produce a `Report` identical to the batch `Scheduler::run` wrapper on
//! seeded traces, for every policy, with `check_invariants` holding after
//! every step — and the event stream must account for every request.

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::make_trace;
use tcm_serve::metrics::Report;
use tcm_serve::policies::build_policy;
use tcm_serve::request::Request;
use tcm_serve::util::proptest_lite as pt;

const POLICIES: [&str; 6] =
    ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];

fn new_scheduler(cfg: &ServeConfig) -> Scheduler {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(cfg, &profile);
    Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&profile)))
}

/// Drive the stepping API by hand (inject everything, step until
/// drained), checking invariants after every step and collecting the
/// event stream. Mirrors what `drain()` does, but from the outside.
fn run_stepped(
    cfg: &ServeConfig,
    trace: Vec<Request>,
) -> Result<(Report, f64, Vec<RequestEvent>), String> {
    let mut sched = new_scheduler(cfg);
    let mut trace = trace;
    trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for req in trace {
        sched.inject(req);
    }
    let mut events = Vec::new();
    let mut steps = 0u64;
    loop {
        match sched.step() {
            StepOutcome::Executed { dt } => {
                if dt < 0.0 {
                    return Err(format!("negative dt {dt}"));
                }
            }
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
            StepOutcome::Drained => break,
        }
        events.extend(sched.take_events());
        sched.check_invariants().map_err(|e| format!("after step {steps}: {e}"))?;
        steps += 1;
        if steps > 5_000_000 {
            return Err("stepping did not drain".into());
        }
    }
    events.extend(sched.take_events());
    Ok((sched.report(), sched.now(), events))
}

fn reports_identical(policy: &str, a: &Report, b: &Report) -> Result<(), String> {
    if a.outcomes.len() != b.outcomes.len() {
        return Err(format!(
            "{policy}: outcome counts differ ({} vs {})",
            a.outcomes.len(),
            b.outcomes.len()
        ));
    }
    if a.failed.len() != b.failed.len() {
        return Err(format!("{policy}: drop counts differ"));
    }
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        if x.id != y.id {
            return Err(format!("{policy}: outcome order diverged at req {}/{}", x.id, y.id));
        }
        if x.first_token.to_bits() != y.first_token.to_bits() {
            return Err(format!("{policy}: req {} first_token not bit-identical", x.id));
        }
        if x.finish.to_bits() != y.finish.to_bits() {
            return Err(format!("{policy}: req {} finish not bit-identical", x.id));
        }
        if x.preemptions != y.preemptions {
            return Err(format!("{policy}: req {} preemption counts differ", x.id));
        }
    }
    for (x, y) in a.failed.iter().zip(&b.failed) {
        if x.id != y.id || x.dropped_at.to_bits() != y.dropped_at.to_bits() {
            return Err(format!("{policy}: failed outcome diverged at req {}", x.id));
        }
    }
    Ok(())
}

/// The event stream must tell the whole story: one FirstToken and one
/// Finished per completed request, one Dropped per failure, and ordering
/// (Ready before FirstToken before Finished) per request.
fn events_consistent(policy: &str, report: &Report, events: &[RequestEvent]) -> Result<(), String> {
    let mut readies = 0usize;
    let mut firsts = 0usize;
    let mut finishes = 0usize;
    let mut drops = 0usize;
    for e in events {
        match e {
            RequestEvent::Ready { .. } => readies += 1,
            RequestEvent::FirstToken { .. } => firsts += 1,
            RequestEvent::Finished { .. } => finishes += 1,
            RequestEvent::Dropped { .. } => drops += 1,
            RequestEvent::Encoded { .. }
            | RequestEvent::Preempted { .. }
            | RequestEvent::Requeued { .. }
            | RequestEvent::Cancelled { .. } => {}
        }
    }
    if finishes != report.outcomes.len() {
        return Err(format!(
            "{policy}: {finishes} Finished events for {} outcomes",
            report.outcomes.len()
        ));
    }
    // exactly one FirstToken per completed request (even across
    // preemptions); dropped requests may or may not have reached theirs
    if firsts < report.outcomes.len() || firsts > report.outcomes.len() + drops {
        return Err(format!(
            "{policy}: {firsts} FirstToken events for {} outcomes + {drops} drops",
            report.outcomes.len()
        ));
    }
    if drops != report.failed.len() {
        return Err(format!(
            "{policy}: {drops} Dropped events for {} failures",
            report.failed.len()
        ));
    }
    if readies != report.total() {
        return Err(format!("{policy}: {readies} Ready events for {} requests", report.total()));
    }
    for o in &report.outcomes {
        let ready =
            events.iter().position(|e| matches!(*e, RequestEvent::Ready { id, .. } if id == o.id));
        let first = events
            .iter()
            .position(|e| matches!(*e, RequestEvent::FirstToken { id, .. } if id == o.id));
        let fin = events
            .iter()
            .position(|e| matches!(*e, RequestEvent::Finished { id, .. } if id == o.id));
        match (ready, first, fin) {
            (Some(r), Some(f), Some(n)) if r < f && f < n => {}
            _ => {
                return Err(format!(
                    "{policy}: req {} event order broken: ready={ready:?} first={first:?} \
                     finished={fin:?}",
                    o.id
                ))
            }
        }
    }
    Ok(())
}

#[test]
fn stepped_equals_batch_all_policies_fixed_seed() {
    for policy in POLICIES {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 120;
        cfg.rate = 2.0;
        cfg.seed = 7;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let batch = new_scheduler(&cfg).run(trace.clone());
        let (stepped, _, events) = run_stepped(&cfg, trace).unwrap();
        reports_identical(policy, &stepped, &batch).unwrap();
        events_consistent(policy, &stepped, &events).unwrap();
    }
}

#[test]
fn stepped_equals_batch_under_memory_pressure() {
    // preemptions and drops in the mix: the paths must still agree bit
    // for bit, and every preempted request must emit Preempted events
    for policy in ["fcfs", "tcm", "edf"] {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 60;
        cfg.memory_frac = 0.02;
        cfg.seed = 11;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let mut batch_sched = new_scheduler(&cfg);
        let batch = batch_sched.run(trace.clone());
        let (stepped, now, events) = run_stepped(&cfg, trace).unwrap();
        reports_identical(policy, &stepped, &batch).unwrap();
        assert_eq!(now.to_bits(), batch_sched.now().to_bits(), "{policy}: makespan diverged");

        let preempt_events =
            events.iter().filter(|e| matches!(e, RequestEvent::Preempted { .. })).count() as u64;
        let preempt_outcomes: u64 = stepped
            .outcomes
            .iter()
            .map(|o| o.preemptions as u64)
            .sum();
        assert!(
            preempt_events >= preempt_outcomes,
            "{policy}: {preempt_events} Preempted events < {preempt_outcomes} recorded on outcomes"
        );
    }
}

#[test]
fn property_stepped_equals_batch() {
    pt::run(18, |g| {
        let mut cfg = ServeConfig::default();
        cfg.policy = (*g.pick(&POLICIES)).into();
        cfg.model = (*g.pick(&["llava-7b", "qwen-3b", "llava-500m"])).into();
        cfg.mix = (*g.pick(&["T0", "ML", "MH"])).into();
        cfg.rate = g.f64_in(0.5, 6.0);
        cfg.seed = g.rng.next_u64();
        cfg.num_requests = g.usize_in(5, 60);
        cfg.memory_frac = *g.pick(&[1.0, 0.5, 0.05]);
        cfg.scheduler.token_budget = *g.pick(&[512u32, 2048]);
        cfg.scheduler.max_running = g.usize_in(2, 64);

        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);
        let batch = new_scheduler(&cfg).run(trace.clone());
        let (stepped, _, events) = run_stepped(&cfg, trace)?;
        reports_identical(&cfg.policy, &stepped, &batch)?;
        events_consistent(&cfg.policy, &stepped, &events)?;
        Ok(())
    });
}

/// Online injection mid-flight: a request injected *between* steps (after
/// earlier ones already executed) is scheduled and finishes — the core
/// capability the old monolithic `run` loop could not express.
#[test]
fn injection_between_steps_is_scheduled() {
    let mut cfg = ServeConfig::default();
    cfg.policy = "fcfs".into();
    let mut sched = new_scheduler(&cfg);

    let req = |id: u64, arrival: f64| Request {
        id,
        arrival,
        modality: tcm_serve::request::Modality::Text,
        text_tokens: 64,
        mm_tokens: 0,
        video_duration_s: 0.0,
        output_tokens: 8,
        ..Request::default()
    };

    sched.inject(req(0, 0.0));
    // run a few iterations so request 0 is genuinely in flight
    let mut executed = 0;
    while executed < 3 {
        match sched.step() {
            StepOutcome::Executed { .. } => executed += 1,
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    // late injection with an arrival in the past relative to the clock
    sched.inject(req(1, 0.0));
    // drain the rest
    loop {
        match sched.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
            StepOutcome::Drained => break,
        }
    }
    let report = sched.report();
    assert_eq!(report.outcomes.len(), 2, "late injection must be served");
    let events = sched.take_events();
    assert!(
        events.iter().any(|e| matches!(e, RequestEvent::Finished { id: 1, .. })),
        "finish event for the late request must have been emitted"
    );
}
