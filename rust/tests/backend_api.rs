//! The `ServeBackend` contract: driving either backend through the
//! trait is bit-identical to driving it through its concrete API — the
//! unification adds no timing, ordering, or accounting artifacts. This
//! is the equivalence proof behind collapsing the two leader loops: the
//! generic leader issues exactly the trait verbs, so trait == concrete
//! (here, deterministic virtual time) plus the unchanged leader topology
//! (tests in `server::tests`) pins server behavior to pre-refactor
//! semantics on a no-cancel, no-deadline trace.

mod common;

use common::assert_reports_bit_identical;
use tcm_serve::backend::{self, ServeBackend};
use tcm_serve::cluster::Cluster;
use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::coordinator::{Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::make_trace;
use tcm_serve::policies::build_policy;
use tcm_serve::request::{Modality, Request};

const POLICIES: [&str; 6] =
    ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];

fn bare_scheduler(cfg: &ServeConfig) -> Scheduler {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(cfg, &profile);
    Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&cfg.engine_profile())))
}

/// `backend::build` on a 1-replica no-pool config yields a scheduler
/// backend whose `run_trace` is bit-identical to the monolithic
/// `Scheduler::run` (modulo the canonical id sort), for every policy.
#[test]
fn scheduler_backend_run_trace_is_bit_identical_to_concrete_run() {
    for policy in POLICIES {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 120;
        cfg.rate = 2.0;
        cfg.seed = 7;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let mut concrete = bare_scheduler(&cfg).run(trace.clone());
        concrete.sort_by_id();

        let mut backend = backend::build(&cfg);
        assert_eq!(backend.name(), "scheduler", "{policy}: 1-replica config must stay bare");
        let via_trait = backend.run_trace(trace);
        assert_reports_bit_identical(policy, &via_trait, &concrete);
    }
}

/// The cluster backend's `run_trace` delegates to the arrival-faithful
/// batch driver: bit-identical to `Cluster::run` for every router, with
/// and without the encoder pool.
#[test]
fn cluster_backend_run_trace_is_bit_identical_to_concrete_run() {
    for router in ROUTERS {
        for pool in [false, true] {
            let mut cfg = ServeConfig::default();
            cfg.policy = "fcfs".into();
            cfg.num_requests = 200;
            cfg.rate = 3.0;
            cfg.seed = 23;
            cfg.cluster.replicas = 3;
            cfg.cluster.router = router.into();
            cfg.pool.enabled = pool;
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);

            let concrete = Cluster::new(&cfg).run(trace.clone()).report;

            let mut backend = backend::build(&cfg);
            assert_eq!(backend.name(), "cluster");
            let via_trait = backend.run_trace(trace);
            assert_reports_bit_identical(&format!("{router}/pool={pool}"), &via_trait, &concrete);
        }
    }
}

/// The generic leader's actual verb sequence — inject everything, then
/// step/advance/drop_blocked with incremental `take_finished` retirement
/// (`drain_report`) — reproduces the batch run bit for bit on backends
/// where injection order is time-free (bare scheduler; round-robin
/// cluster; any pool-mode cluster, whose ingress timeline makes
/// dispatch arrival-faithful regardless of injection time).
#[test]
fn stepping_verbs_with_retirement_match_batch() {
    // scheduler
    let mut cfg = ServeConfig::default();
    cfg.policy = "tcm".into();
    cfg.num_requests = 100;
    cfg.seed = 11;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let mut batch = bare_scheduler(&cfg).run(trace.clone());
    batch.sort_by_id();
    let mut b = backend::build(&cfg);
    for req in trace {
        b.inject(req);
    }
    let stepped = b.drain_report();
    assert_reports_bit_identical("scheduler-drain", &stepped, &batch);

    // pool-mode cluster
    let mut cfg = ServeConfig::default();
    cfg.policy = "fcfs".into();
    cfg.num_requests = 120;
    cfg.rate = 3.0;
    cfg.seed = 13;
    cfg.cluster.replicas = 2;
    cfg.pool.enabled = true;
    cfg.pool.slots = 2;
    let trace = make_trace(&cfg, &profile);
    let batch = Cluster::new(&cfg).run(trace.clone()).report;
    let mut b = backend::build(&cfg);
    for req in trace {
        b.inject(req);
    }
    let stepped = b.drain_report();
    assert_reports_bit_identical("pool-cluster-drain", &stepped, &batch);
}

/// Cancellation through the trait behaves identically against both
/// backends: same verb, same conservation, same terminal accounting —
/// and is deterministic.
#[test]
fn cancel_through_the_trait_conserves_on_both_backends() {
    let run = |mut backend: Box<dyn ServeBackend>, trace: Vec<Request>| {
        let n = trace.len();
        let cancel_ids: Vec<u64> = trace.iter().map(|r| r.id).filter(|id| id % 3 == 0).collect();
        for req in trace {
            backend.inject(req);
        }
        // cancel a third of the ids after a handful of steps
        let mut steps = 0;
        let mut cancelled_accepted = 0usize;
        loop {
            match backend.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => backend.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => backend.advance_to(t),
                StepOutcome::Blocked { next_event: None } => backend.drop_blocked(),
                StepOutcome::Drained => break,
            }
            if steps == 5 {
                for &id in &cancel_ids {
                    if backend.cancel(id) {
                        cancelled_accepted += 1;
                    }
                }
            }
            backend.check_invariants().unwrap();
            steps += 1;
            assert!(steps < 1_000_000, "did not drain");
        }
        let mut report = backend.take_finished();
        report.sort_by_id();
        assert_eq!(report.total(), n, "conservation: finished + failed + cancelled == submitted");
        assert_eq!(report.cancelled.len(), cancelled_accepted);
        assert_eq!(backend.active_requests(), 0);
        report
    };

    let mut cfg = ServeConfig::default();
    cfg.policy = "tcm".into();
    cfg.num_requests = 60;
    cfg.seed = 29;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let sched_a = run(backend::build(&cfg), trace.clone());
    let sched_b = run(backend::build(&cfg), trace.clone());
    assert_reports_bit_identical("sched-cancel-determinism", &sched_a, &sched_b);
    assert!(!sched_a.cancelled.is_empty(), "the schedule must exercise cancellation");

    let mut ccfg = cfg.clone();
    ccfg.cluster.replicas = 2;
    ccfg.pool.enabled = true;
    let cluster_a = run(backend::build(&ccfg), trace.clone());
    let cluster_b = run(backend::build(&ccfg), trace);
    assert_reports_bit_identical("cluster-cancel-determinism", &cluster_a, &cluster_b);
    assert!(!cluster_a.cancelled.is_empty());
}

/// `inject_preencoded` through the trait: both backends admit an
/// externally encoded request without charging local encoder work, and
/// account for it exactly once.
#[test]
fn inject_preencoded_through_the_trait() {
    let image = Request {
        id: 0,
        modality: Modality::Image,
        text_tokens: 40,
        mm_tokens: 729,
        output_tokens: 8,
        ..Request::default()
    };

    let cfg = ServeConfig::default();
    let mut sched = backend::build(&cfg);
    sched.inject_preencoded(image.clone(), 0.5);
    let report = sched.drain_report();
    assert_eq!(report.outcomes.len(), 1);
    assert!(report.outcomes[0].first_token >= 0.5, "schedulable only from the handoff time");

    let mut ccfg = ServeConfig::default();
    ccfg.cluster.replicas = 2;
    ccfg.cluster.router = "least-work".into();
    let mut cluster = backend::build(&ccfg);
    cluster.inject_preencoded(image, 0.5);
    let report = cluster.drain_report();
    assert_eq!(report.outcomes.len(), 1);
}
