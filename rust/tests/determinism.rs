//! Determinism evidence for the simlint-enforced discipline (see
//! `rust/README.md`, "Determinism discipline"): reruns of the same
//! seeded trace are bit-identical — reports, makespans, *and* scheduler
//! stats, now that planning cost is a key-evaluation counter instead of
//! a wall-clock timer — across every policy and router; hostile
//! non-finite floats injected at the API boundary are sanitized instead
//! of panicking or poisoning virtual time; and the full event stream
//! hashes to the same FNV-1a digest on every rerun, with golden digests
//! pinned per seed once recorded.

mod common;

use common::assert_reports_bit_identical;
use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::{make_trace, run_cluster_with_trace, run_sim_with_trace};
use tcm_serve::policies::build_policy;
use tcm_serve::request::{Modality, Request};

const POLICIES: [&str; 6] =
    ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];

fn new_scheduler(cfg: &ServeConfig) -> Scheduler {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(cfg, &profile);
    Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&cfg.engine_profile())))
}

/// Rerunning the same trace must reproduce not just the report but the
/// whole `SchedStats` struct, field for field. This is the regression
/// test for the old `planning_time_s` wall-clock leak: a stat derived
/// from `Instant::now()` differs between two executions of identical
/// work, so `assert_eq!` on the full struct would catch any such field
/// creeping back in.
#[test]
fn rerun_reports_and_stats_are_bit_identical_per_policy() {
    for policy in POLICIES {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 150;
        cfg.rate = 2.5;
        cfg.mix = "MH".into();
        cfg.seed = 11;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let a = run_sim_with_trace(&cfg, trace.clone());
        let b = run_sim_with_trace(&cfg, trace);
        assert_reports_bit_identical(policy, &a.report, &b.report);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{policy}: makespan diverged");
        assert_eq!(a.stats, b.stats, "{policy}: scheduler stats diverged between reruns");
        assert!(
            a.stats.planning_evals > 0,
            "{policy}: planning work happened, so the eval counter must move"
        );
    }
}

/// Same property one layer up: cluster reruns agree on per-replica stats
/// too, under every router. `ReplicaStats::planning_evals` is part of
/// the comparison — the cluster layer must not reintroduce wall-clock
/// state of its own.
#[test]
fn cluster_rerun_stats_are_identical_per_router() {
    for router in ROUTERS {
        let mut cfg = ServeConfig::default();
        cfg.policy = "tcm".into();
        cfg.mix = "MH".into();
        cfg.num_requests = 180;
        cfg.rate = 3.0;
        cfg.seed = 29;
        cfg.cluster.replicas = 3;
        cfg.cluster.router = router.into();
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let a = run_cluster_with_trace(&cfg, trace.clone());
        let b = run_cluster_with_trace(&cfg, trace);
        assert_reports_bit_identical(router, &a.report, &b.report);
        for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
            assert_eq!(x.routed, y.routed, "{router}: replica {i} routed");
            assert_eq!(x.iterations, y.iterations, "{router}: replica {i} iterations");
            assert_eq!(
                x.planning_evals, y.planning_evals,
                "{router}: replica {i} planning_evals diverged between reruns"
            );
        }
    }
}

/// Batch (`run`) and stepped execution are two different code paths over
/// the migrated `BTreeMap` plan/state containers; they must agree on the
/// report *and* on every stats counter, including planning work.
#[test]
fn stepped_run_matches_batch_stats_including_planning_evals() {
    for policy in ["fcfs", "tcm"] {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        cfg.num_requests = 100;
        cfg.rate = 2.0;
        cfg.seed = 17;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let mut batch = new_scheduler(&cfg);
        let batch_report = batch.run(trace.clone());

        let mut stepped = new_scheduler(&cfg);
        let mut sorted = trace;
        sorted.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for req in sorted {
            stepped.inject(req);
        }
        loop {
            match stepped.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => stepped.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => stepped.advance_to(t),
                StepOutcome::Blocked { next_event: None } => stepped.drop_blocked(),
                StepOutcome::Drained => break,
            }
        }
        assert_reports_bit_identical(policy, &stepped.report(), &batch_report);
        assert_eq!(stepped.stats, batch.stats, "{policy}: stepped vs batch stats diverged");
    }
}

/// A request carrying every hostile float a client can send — NaN and
/// infinite arrivals, NaN/negative durations, NaN/∞/negative deadlines —
/// must degrade to a servable request at the injection boundary, not
/// panic and not distort the rest of the run.
fn hostile_trace() -> Vec<Request> {
    let normal = |id: u64, arrival: f64| Request {
        id,
        arrival,
        modality: Modality::Image,
        text_tokens: 40,
        mm_tokens: 729,
        output_tokens: 60,
        ..Request::default()
    };
    let mut trace = vec![
        Request { arrival: f64::NAN, ..normal(100, 0.0) },
        Request { arrival: f64::NEG_INFINITY, ..normal(101, 0.0) },
        Request { arrival: f64::INFINITY, ..normal(102, 0.0) },
        Request {
            modality: Modality::Video,
            video_duration_s: f64::NAN,
            mm_tokens: 4000,
            ..normal(103, 0.5)
        },
        Request {
            modality: Modality::Video,
            video_duration_s: -30.0,
            mm_tokens: 4000,
            ..normal(104, 0.6)
        },
        Request { deadline_s: Some(f64::NAN), ..normal(105, 0.7) },
        Request { deadline_s: Some(f64::NEG_INFINITY), ..normal(106, 0.8) },
        Request { deadline_s: Some(0.0), ..normal(107, 0.9) },
    ];
    for id in 0..8u64 {
        trace.push(normal(id, 0.1 * id as f64));
    }
    trace
}

#[test]
fn hostile_floats_are_sanitized_at_the_scheduler_boundary() {
    for policy in POLICIES {
        let mut cfg = ServeConfig::default();
        cfg.policy = policy.into();
        let trace = hostile_trace();
        let n = trace.len();
        let report = run_sim_with_trace(&cfg, trace).report;
        assert_eq!(
            report.outcomes.len() + report.failed.len() + report.cancelled.len(),
            n,
            "{policy}: every request must reach a terminal state"
        );
        for o in &report.outcomes {
            assert!(o.first_token.is_finite(), "{policy}: req {} TTFT not finite", o.id);
            assert!(o.finish.is_finite(), "{policy}: req {} finish not finite", o.id);
        }
    }
}

#[test]
fn hostile_floats_are_sanitized_at_the_cluster_boundary() {
    // the router's cost estimates read the same untrusted floats the
    // scheduler does; 2 replicas exercise the routing decision on them
    for router in ROUTERS {
        let mut cfg = ServeConfig::default();
        cfg.policy = "tcm".into();
        cfg.cluster.replicas = 2;
        cfg.cluster.router = router.into();
        let trace = hostile_trace();
        let n = trace.len();
        let cr = run_cluster_with_trace(&cfg, trace);
        assert_eq!(
            cr.report.outcomes.len() + cr.report.failed.len() + cr.report.cancelled.len(),
            n,
            "{router}: every request must reach a terminal state"
        );
        assert!(cr.makespan.is_finite(), "{router}: makespan poisoned by hostile floats");
    }
}

#[test]
fn sanitize_clamps_exactly_the_non_finite_fields() {
    let hostile = Request {
        arrival: f64::NAN,
        video_duration_s: f64::INFINITY,
        deadline_s: Some(f64::NAN),
        ..Request::default()
    };
    let clean = hostile.sanitize();
    assert_eq!(clean.arrival.to_bits(), 0.0f64.to_bits());
    assert_eq!(clean.video_duration_s.to_bits(), 0.0f64.to_bits());
    assert_eq!(clean.deadline_s, None);

    // negative duration and non-positive deadline are clamped too
    let negative = Request {
        video_duration_s: -1.0,
        deadline_s: Some(-5.0),
        ..Request::default()
    }
    .sanitize();
    assert_eq!(negative.video_duration_s.to_bits(), 0.0f64.to_bits());
    assert_eq!(negative.deadline_s, None);

    // well-formed fields pass through bit-untouched
    let good = Request {
        arrival: 3.25,
        video_duration_s: 45.0,
        deadline_s: Some(12.5),
        ..Request::default()
    }
    .sanitize();
    assert_eq!(good.arrival.to_bits(), 3.25f64.to_bits());
    assert_eq!(good.video_duration_s.to_bits(), 45.0f64.to_bits());
    assert_eq!(good.deadline_s, Some(12.5));
}

// ---------------------------------------------------------------------
// Golden event streams: the entire observable history of a seeded run,
// folded into one FNV-1a digest.

fn fnv1a(hash: &mut u64, bytes: &[u8]) {
    for &b in bytes {
        *hash ^= b as u64;
        *hash = hash.wrapping_mul(0x100_0000_01b3);
    }
}

fn hash_events(events: &[RequestEvent]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for e in events {
        let (tag, id, t) = match *e {
            RequestEvent::Ready { id, t } => (1u8, id, t),
            RequestEvent::Encoded { id, t } => (2, id, t),
            RequestEvent::FirstToken { id, t } => (3, id, t),
            RequestEvent::Preempted { id, t } => (4, id, t),
            RequestEvent::Finished { id, t } => (5, id, t),
            RequestEvent::Dropped { id, t } => (6, id, t),
            RequestEvent::Cancelled { id, t } => (7, id, t),
            RequestEvent::Requeued { id, t } => (8, id, t),
        };
        fnv1a(&mut h, &[tag]);
        fnv1a(&mut h, &id.to_le_bytes());
        fnv1a(&mut h, &t.to_bits().to_le_bytes());
    }
    h
}

fn event_stream(cfg: &ServeConfig, trace: Vec<Request>) -> Vec<RequestEvent> {
    let mut sched = new_scheduler(cfg);
    let mut trace = trace;
    trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for req in trace {
        sched.inject(req);
    }
    let mut events = Vec::new();
    loop {
        match sched.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => sched.drop_blocked(),
            StepOutcome::Drained => break,
        }
        events.extend(sched.take_events());
    }
    events.extend(sched.take_events());
    events
}

/// Pinned digests per seed. `None` means "not yet recorded": the test
/// still asserts rerun self-agreement and prints the digest so a run
/// with a toolchain can arm it (same convention as the null medians in
/// `BENCH_baseline.json`). Once armed, any change to event content,
/// order or timing for these seeds fails loudly.
const GOLDEN_STREAMS: [(u64, Option<u64>); 3] = [(7, None), (21, None), (42, None)];

#[test]
fn golden_event_streams_are_stable_across_reruns_for_three_seeds() {
    for (seed, golden) in GOLDEN_STREAMS {
        let mut cfg = ServeConfig::default();
        cfg.policy = "tcm".into();
        cfg.mix = "MH".into();
        cfg.num_requests = 120;
        cfg.rate = 2.0;
        cfg.seed = seed;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let a = event_stream(&cfg, trace.clone());
        let b = event_stream(&cfg, trace);
        assert!(!a.is_empty(), "seed {seed}: run produced no events");
        let (ha, hb) = (hash_events(&a), hash_events(&b));
        assert_eq!(ha, hb, "seed {seed}: event stream diverged between reruns");
        match golden {
            Some(g) => assert_eq!(
                ha, g,
                "seed {seed}: event stream digest changed from the pinned golden"
            ),
            None => eprintln!(
                "seed {seed}: golden event-stream digest not yet recorded; observed {ha:#018x}"
            ),
        }
    }
}
