//! The observability contract, end to end:
//!
//! 1. **Conservation** — for every request, the reconstructed span
//!    segments exactly partition `[arrival, terminal]` (bit-contiguous),
//!    the `PreemptedGap` total matches the scheduler's `preempted_time`,
//!    and encode segments count `1 + preemptions` for finished
//!    multimodal requests — across policies × routers × pool modes ×
//!    seeds, with enough memory pressure that the grid is non-vacuous
//!    (preemptions, pool encodes, and migrations all actually occur).
//! 2. **Invisibility** — attaching the observer changes nothing: the
//!    event stream and the report are bit-identical to the undecorated
//!    backend, and a `Scheduler` with the obs tap enabled produces
//!    identical stats.

use tcm_serve::backend::{self, ServeBackend};
use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::{RequestEvent, Scheduler, StepOutcome};
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::make_trace;
use tcm_serve::metrics::Report;
use tcm_serve::obs::{ObsBackend, SpanKind, Terminal};
use tcm_serve::policies::build_policy;
use tcm_serve::request::Request;

fn grid_cfg(policy: &str, pool: bool, router: &str, seed: u64) -> ServeConfig {
    let mut cfg = ServeConfig::default();
    cfg.policy = policy.into();
    cfg.mix = "MH".into();
    cfg.num_requests = 60;
    cfg.rate = 3.0;
    cfg.seed = seed;
    cfg.memory_frac = 0.06;
    cfg.cluster.replicas = 2;
    cfg.cluster.router = router.into();
    cfg.pool.enabled = pool;
    cfg.pool.slots = 2;
    cfg
}

fn observed(cfg: &ServeConfig) -> ObsBackend {
    // wrap explicitly (cfg.obs stays off) so the test controls both the
    // decorated and undecorated builds from one config
    ObsBackend::new(backend::build(cfg))
}

/// Conservation + accounting checks for one finished run, returning
/// (preemptions, pool-encode segments, migration segments) observed.
fn check_spans(ctx: &str, b: &mut ObsBackend, report: &Report) -> (u64, usize, usize) {
    let spans = b.spans();
    assert_eq!(spans.len(), report.total(), "{ctx}: every request must have a span tree");
    let by_id: std::collections::BTreeMap<u64, &tcm_serve::metrics::Outcome> =
        report.outcomes.iter().map(|o| (o.id, o)).collect();
    let mut preemptions = 0u64;
    let mut pool_encodes = 0usize;
    let mut migrations = 0usize;
    for s in &spans {
        s.check_conservation().unwrap_or_else(|e| panic!("{ctx}: {e}"));
        pool_encodes +=
            s.segments.iter().filter(|g| g.kind == SpanKind::Encode && g.slot.is_some()).count();
        migrations += s.segments.iter().filter(|g| g.kind == SpanKind::Migration).count();
        let Some(o) = by_id.get(&s.id) else { continue };
        assert_eq!(
            s.terminal,
            Some(Terminal::Finished),
            "{ctx}: req {} completed but span terminal is {:?}",
            s.id,
            s.terminal
        );
        assert_eq!(
            s.end.to_bits(),
            o.finish.to_bits(),
            "{ctx}: req {} span ends at {} but outcome finished at {}",
            s.id,
            s.end,
            o.finish
        );
        assert!(
            (s.gap_total() - o.preempted_time).abs() <= 1e-9,
            "{ctx}: req {} gap total {} != preempted_time {}",
            s.id,
            s.gap_total(),
            o.preempted_time
        );
        if s.multimodal {
            assert_eq!(
                s.encode_count(),
                1 + o.preemptions as usize,
                "{ctx}: req {} must encode once plus once per preemption",
                s.id
            );
        }
        preemptions += o.preemptions as u64;
    }
    (preemptions, pool_encodes, migrations)
}

#[test]
fn span_conservation_across_grid() {
    let mut total_preemptions = 0u64;
    let mut total_pool_encodes = 0usize;
    let mut total_migrations = 0usize;
    for policy in ["fcfs", "tcm", "edf"] {
        for pool in [false, true] {
            for router in ["round-robin", "least-work"] {
                for seed in [7u64, 21, 42] {
                    let cfg = grid_cfg(policy, pool, router, seed);
                    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
                    let trace = make_trace(&cfg, &profile);
                    let mut b = observed(&cfg);
                    let report = b.run_trace(trace);
                    let ctx = format!("{policy}/{router}/pool={pool}/seed={seed}");
                    let (p, e, m) = check_spans(&ctx, &mut b, &report);
                    total_preemptions += p;
                    if pool {
                        total_pool_encodes += e;
                        total_migrations += m;
                    } else {
                        assert_eq!(e, 0, "{ctx}: slot-tagged encodes without a pool");
                        assert_eq!(m, 0, "{ctx}: migrations without a pool");
                    }
                }
            }
        }
    }
    // the invariants above must not have held vacuously
    assert!(total_preemptions > 0, "grid produced no preemptions — raise memory pressure");
    assert!(total_pool_encodes > 0, "pool runs produced no slot-tagged encode segments");
    assert!(total_migrations > 0, "pool runs produced no migration segments");
}

#[test]
fn span_conservation_single_scheduler() {
    for policy in ["fcfs", "tcm", "edf"] {
        for seed in [7u64, 21, 42] {
            let mut cfg = ServeConfig::default();
            cfg.policy = policy.into();
            cfg.mix = "MH".into();
            cfg.num_requests = 60;
            cfg.rate = 3.0;
            cfg.seed = seed;
            cfg.memory_frac = 0.05;
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);
            let mut b = observed(&cfg);
            let report = b.run_trace(trace);
            check_spans(&format!("scheduler/{policy}/seed={seed}"), &mut b, &report);
            // the stepping path samples telemetry on every epoch
            let snap = b.telemetry_snapshot().expect("observer attached");
            assert!(snap.epochs > 0, "telemetry must have observed epochs");
            assert_eq!(snap.finished, report.outcomes.len() as u64);
        }
    }
}

/// Drive a backend through the public stepping verbs (the server's
/// loop), collecting events — the apples-to-apples harness for the
/// invisibility proof.
fn run_stepped(b: &mut dyn ServeBackend, trace: Vec<Request>) -> (Report, Vec<RequestEvent>) {
    let mut trace = trace;
    trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    for req in trace {
        b.inject(req);
    }
    let mut events = Vec::new();
    let mut collected = Report::default();
    let mut steps = 0u64;
    loop {
        match b.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => b.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => b.advance_to(t),
            StepOutcome::Blocked { next_event: None } => b.drop_blocked(),
            StepOutcome::Drained => break,
        }
        events.extend(b.take_events());
        collected.merge(b.take_finished());
        steps += 1;
        assert!(steps < 5_000_000, "stepping did not drain");
    }
    events.extend(b.take_events());
    collected.merge(b.take_finished());
    collected.sort_by_id();
    (collected, events)
}

fn assert_reports_bit_identical(ctx: &str, a: &Report, b: &Report) {
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: outcome counts differ");
    assert_eq!(a.failed.len(), b.failed.len(), "{ctx}: drop counts differ");
    assert_eq!(a.cancelled.len(), b.cancelled.len(), "{ctx}: cancel counts differ");
    for (x, y) in a.outcomes.iter().zip(&b.outcomes) {
        assert_eq!(x.id, y.id, "{ctx}: outcome order diverged");
        assert_eq!(
            x.first_token.to_bits(),
            y.first_token.to_bits(),
            "{ctx}: req {} first_token not bit-identical",
            x.id
        );
        assert_eq!(
            x.finish.to_bits(),
            y.finish.to_bits(),
            "{ctx}: req {} finish not bit-identical",
            x.id
        );
        assert_eq!(x.preemptions, y.preemptions, "{ctx}: req {} preemptions differ", x.id);
        assert_eq!(
            x.preempted_time.to_bits(),
            y.preempted_time.to_bits(),
            "{ctx}: req {} preempted_time not bit-identical",
            x.id
        );
    }
    for (x, y) in a.failed.iter().zip(&b.failed) {
        assert_eq!(x.id, y.id, "{ctx}: failed order diverged");
        assert_eq!(x.dropped_at.to_bits(), y.dropped_at.to_bits(), "{ctx}: drop time differs");
    }
}

/// The tentpole guarantee: attaching the observer is invisible. Event
/// streams and reports from the decorated and undecorated backends are
/// identical element for element, bit for bit — scheduler topology,
/// plain cluster, and pool-mode cluster alike.
#[test]
fn observer_is_bit_invisible() {
    let mut scheduler_cfg = ServeConfig::default();
    scheduler_cfg.policy = "tcm".into();
    scheduler_cfg.mix = "MH".into();
    scheduler_cfg.num_requests = 50;
    scheduler_cfg.rate = 3.0;
    scheduler_cfg.seed = 11;
    scheduler_cfg.memory_frac = 0.05;
    let cluster_cfg = grid_cfg("tcm", false, "least-work", 7);
    let pool_cfg = grid_cfg("fcfs", true, "least-work", 7);
    for (ctx, cfg) in [
        ("scheduler", scheduler_cfg),
        ("cluster", cluster_cfg),
        ("cluster+pool", pool_cfg),
    ] {
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let mut plain = backend::build(&cfg);
        let (plain_report, plain_events) = run_stepped(plain.as_mut(), trace.clone());

        let mut obs = observed(&cfg);
        let (obs_report, obs_events) = run_stepped(&mut obs, trace);

        assert_eq!(
            plain_events, obs_events,
            "{ctx}: the observer altered the event stream"
        );
        assert_reports_bit_identical(ctx, &plain_report, &obs_report);

        // and the observer actually observed: spans exist and conserve
        check_spans(ctx, &mut obs, &obs_report);
    }
}

/// The raw scheduler tap is equally invisible: same trace, obs on vs
/// off, identical stats (PartialEq over every counter) and report.
#[test]
fn scheduler_obs_tap_does_not_change_results() {
    let mut cfg = ServeConfig::default();
    cfg.policy = "tcm".into();
    cfg.mix = "MH".into();
    cfg.num_requests = 80;
    cfg.rate = 3.0;
    cfg.seed = 13;
    cfg.memory_frac = 0.05;
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);

    let new_scheduler = |cfg: &ServeConfig| {
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let policy = build_policy(cfg, &profile);
        Scheduler::new(cfg.clone(), policy, Box::new(SimEngine::new(&profile)))
    };

    let mut off = new_scheduler(&cfg);
    let report_off = off.run(trace.clone());

    let mut on = new_scheduler(&cfg);
    on.set_obs(true);
    let report_on = on.run(trace);

    assert_eq!(off.stats, on.stats, "obs tap changed scheduler stats");
    assert_reports_bit_identical("scheduler-tap", &report_off, &report_on);

    assert!(
        !on.take_obs_events().is_empty(),
        "tap enabled but no obs events were buffered"
    );
    assert!(
        !on.take_events().is_empty(),
        "obs-enabled batch drain must retain the event stream for harvest"
    );
    assert!(
        off.take_events().is_empty(),
        "without obs the batch drain must keep clearing events (flat memory)"
    );
}

/// The Perfetto export is non-trivial for a pool run: request slices,
/// slot-occupancy slices, counter samples — and byte-deterministic
/// across two identical runs.
#[test]
fn perfetto_trace_exports_pool_run() {
    let cfg = grid_cfg("tcm", true, "least-work", 21);
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);

    let render = |trace: Vec<Request>| {
        let mut b = observed(&cfg);
        b.run_trace(trace);
        ServeBackend::trace_json(&mut b).expect("observer renders a trace")
    };
    let json = render(trace.clone());
    assert!(json.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
    assert!(json.contains("\"ph\":\"X\""), "trace must contain complete events");
    assert!(json.contains("\"ph\":\"C\""), "trace must contain counter samples");
    assert!(json.contains("encoder pool"), "trace must contain the pool process");
    assert!(json.contains("\"slot\":"), "trace must tag pool encodes with slots");
    assert_eq!(json, render(trace), "trace export must be byte-deterministic");
}
