//! Cluster-layer contracts: a 1-replica round-robin cluster is
//! bit-identical to a bare `Scheduler`, cluster runs are deterministic
//! for a fixed seed under every router policy, rocks/pebbles/sand
//! partition routing beats round-robin on sand TTFT p99 at ≥2 replicas,
//! and encode-overlap strictly lowers multimodal TTFT on the same seed.

mod common;

use common::assert_reports_bit_identical;
use tcm_serve::cluster::Cluster;
use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::coordinator::{RequestEvent, StepOutcome};
use tcm_serve::experiments::{
    make_trace, run_cluster_with_trace, run_sim_with_trace,
};
use tcm_serve::metrics::Report;
use tcm_serve::request::Modality;

fn cluster_cfg(replicas: usize, router: &str) -> ServeConfig {
    let mut c = ServeConfig::default();
    c.policy = "fcfs".into();
    c.mix = "MH".into();
    c.rate = 1.5 * replicas as f64;
    c.num_requests = 150 * replicas;
    c.seed = 23;
    c.cluster.replicas = replicas;
    c.cluster.router = router.into();
    c
}

/// The acceptance regression: one replica behind a round-robin router
/// reproduces the bare single-`Scheduler` results bit for bit — the
/// cluster layer adds no timing or ordering artifacts of its own.
#[test]
fn single_replica_round_robin_is_bit_identical_to_bare_scheduler() {
    // overlap=true included: `run_sim` and the cluster build engines from
    // the same `ServeConfig::engine_profile`, so the knob must not break
    // the equivalence either
    for (policy, overlap) in [("fcfs", false), ("tcm", false), ("fcfs", true)] {
        let mut cfg = cluster_cfg(1, "round-robin");
        cfg.policy = policy.into();
        cfg.num_requests = 120;
        cfg.rate = 2.0;
        cfg.cluster.encode_overlap = overlap;
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);

        let bare = run_sim_with_trace(&cfg, trace.clone());
        let mut bare_report = bare.report.clone();
        bare_report.sort_by_id();

        let cr = run_cluster_with_trace(&cfg, trace);
        assert_reports_bit_identical(policy, &cr.report, &bare_report);
        assert_eq!(
            cr.makespan.to_bits(),
            bare.makespan.to_bits(),
            "{policy}: makespan diverged"
        );
        assert_eq!(cr.per_replica.len(), 1);
        assert_eq!(cr.per_replica[0].routed, 120);
    }
}

/// Bit-identical reruns for a fixed seed under every router policy: the
/// router interleaving introduces no nondeterminism.
#[test]
fn cluster_runs_are_deterministic_per_router() {
    for router in ROUTERS {
        let cfg = cluster_cfg(3, router);
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);
        let a = run_cluster_with_trace(&cfg, trace.clone());
        let b = run_cluster_with_trace(&cfg, trace);
        assert_reports_bit_identical(router, &a.report, &b.report);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{router}: makespan");
        for (x, y) in a.per_replica.iter().zip(&b.per_replica) {
            assert_eq!(x.routed, y.routed, "{router}: routing diverged");
            assert_eq!(x.iterations, y.iterations, "{router}: iteration counts diverged");
        }
    }
}

/// Conservation: every request routed somewhere, every request accounted
/// for in the merged report, under every router and scale.
#[test]
fn every_router_conserves_requests() {
    for replicas in [2usize, 4] {
        for router in ROUTERS {
            let cfg = cluster_cfg(replicas, router);
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);
            let n = trace.len();
            let cr = run_cluster_with_trace(&cfg, trace);
            assert_eq!(cr.report.total(), n, "{router}/r{replicas}: lost requests");
            let routed: usize = cr.per_replica.iter().map(|r| r.routed).sum();
            assert_eq!(routed, n, "{router}/r{replicas}: routing not conservative");
            if router == "round-robin" {
                for r in &cr.per_replica {
                    assert!(r.routed > 0, "round-robin must use every replica");
                }
            }
        }
    }
}

/// The tentpole acceptance claim: modality-partition routing beats
/// round-robin on sand (text) TTFT p99 for a mixed workload at ≥2
/// replicas — a video routed onto the sand replica recreates rock
/// head-of-line blocking one level above the scheduler.
#[test]
fn partition_beats_round_robin_on_sand_ttft_p99() {
    for replicas in [2usize, 4] {
        let cfg_rr = cluster_cfg(replicas, "round-robin");
        let cfg_part = cluster_cfg(replicas, "modality-partition");
        let profile = tcm_serve::model::by_name(&cfg_rr.model).unwrap();
        let trace = make_trace(&cfg_rr, &profile);

        let rr = run_cluster_with_trace(&cfg_rr, trace.clone());
        let part = run_cluster_with_trace(&cfg_part, trace);
        let rr_p99 = rr.report.by_modality(Modality::Text).p99_ttft;
        let part_p99 = part.report.by_modality(Modality::Text).p99_ttft;
        assert!(
            part_p99 < rr_p99,
            "r={replicas}: partition sand p99 {part_p99:.3}s !< round-robin {rr_p99:.3}s"
        );
    }
}

/// Encode/prefill overlap strictly lowers multimodal TTFT on the same
/// seed and never slows the fleet (per-iteration cost is clamped to the
/// serialized sum).
#[test]
fn encode_overlap_strictly_lowers_multimodal_ttft() {
    let mean_mm_ttft = |r: &Report| {
        let mm: Vec<f64> = r
            .outcomes
            .iter()
            .filter(|o| o.modality != Modality::Text)
            .map(|o| o.ttft())
            .collect();
        assert!(!mm.is_empty());
        mm.iter().sum::<f64>() / mm.len() as f64
    };
    for replicas in [1usize, 2] {
        let base = cluster_cfg(replicas, "round-robin");
        let profile = tcm_serve::model::by_name(&base.model).unwrap();
        let trace = make_trace(&base, &profile);

        let serial = run_cluster_with_trace(&base, trace.clone());
        let mut overlapped_cfg = base.clone();
        overlapped_cfg.cluster.encode_overlap = true;
        let overlapped = run_cluster_with_trace(&overlapped_cfg, trace);

        let s = mean_mm_ttft(&serial.report);
        let o = mean_mm_ttft(&overlapped.report);
        assert!(
            o < s,
            "r={replicas}: overlap multimodal mean ttft {o:.4}s !< serialized {s:.4}s"
        );
        // per-iteration cost is clamped to the serialized sum, so the
        // fleet must not get slower overall (small tolerance: faster
        // iterations can re-compose plans near the tail)
        assert!(
            overlapped.makespan <= serial.makespan * 1.01 + 1e-9,
            "r={replicas}: overlap makespan {:.3}s vs serialized {:.3}s",
            overlapped.makespan,
            serial.makespan
        );
    }
}

/// Drive the cluster through the stepping API directly (inject
/// everything, step to drained) — the server-leader path — checking
/// per-replica invariants at every step and event accounting at the end.
/// For the round-robin router this is bit-identical to `Cluster::run`
/// (routing ignores replica state, and arrivals are due at their
/// timestamps regardless of when they were injected).
#[test]
fn stepped_cluster_equals_batch_run_for_round_robin() {
    let cfg = cluster_cfg(2, "round-robin");
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();

    let batch = run_cluster_with_trace(&cfg, trace.clone());

    let mut cluster = Cluster::new(&cfg);
    for req in trace {
        cluster.inject(req);
    }
    let mut finished_events = 0usize;
    let mut dropped_events = 0usize;
    let mut steps = 0u64;
    loop {
        match cluster.step() {
            StepOutcome::Executed { dt } => assert!(dt >= 0.0),
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => cluster.drop_blocked(),
            StepOutcome::Drained => break,
        }
        for ev in cluster.take_events() {
            match ev {
                RequestEvent::Finished { .. } => finished_events += 1,
                RequestEvent::Dropped { .. } => dropped_events += 1,
                _ => {}
            }
        }
        cluster.check_invariants().unwrap_or_else(|e| panic!("after step {steps}: {e}"));
        steps += 1;
        assert!(steps < 5_000_000, "stepping did not drain");
    }
    for ev in cluster.take_events() {
        match ev {
            RequestEvent::Finished { .. } => finished_events += 1,
            RequestEvent::Dropped { .. } => dropped_events += 1,
            _ => {}
        }
    }
    let stepped = cluster.report();
    assert_eq!(stepped.report.total(), n);
    assert_eq!(finished_events, stepped.report.outcomes.len());
    assert_eq!(dropped_events, stepped.report.failed.len());
    assert_reports_bit_identical("stepped-vs-batch", &stepped.report, &batch.report);
}
