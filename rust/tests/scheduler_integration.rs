//! Integration tests over the full coordinator + SimEngine stack, plus an
//! end-to-end run of the coordinator over the RealEngine (PJRT) when
//! artifacts are built.

use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::Scheduler;
use tcm_serve::engine::sim_engine::SimEngine;
use tcm_serve::experiments::{run_sim, run_sim_with_trace};
use tcm_serve::policies::build_policy;
use tcm_serve::request::{Modality, Request};

fn base_cfg(policy: &str) -> ServeConfig {
    let mut c = ServeConfig::default();
    c.policy = policy.into();
    c.num_requests = 200;
    c.seed = 11;
    c
}

fn req(id: u64, arrival: f64, m: Modality, text: u32, mm: u32, out: u32) -> Request {
    Request {
        id,
        arrival,
        modality: m,
        text_tokens: text,
        mm_tokens: mm,
        video_duration_s: if m == Modality::Video { 30.0 } else { 0.0 },
        output_tokens: out,
        ..Request::default()
    }
}

#[test]
fn chunked_prefill_splits_long_prompts() {
    // a 9000-token text prompt must take multiple iterations at budget 2048
    let mut cfg = base_cfg("fcfs");
    cfg.scheduler.token_budget = 2048;
    let trace = vec![req(0, 0.0, Modality::Text, 9000, 0, 4)];
    let r = run_sim_with_trace(&cfg, trace);
    assert_eq!(r.report.outcomes.len(), 1);
    // ceil(9000/2048)=5 prefill iterations + 3 decode iterations
    assert!(r.stats.iterations >= 8, "iterations={}", r.stats.iterations);
}

#[test]
fn hol_blocking_under_fcfs_vs_tcm() {
    // one giant video then a burst of tiny text requests: FCFS makes the
    // texts wait for the whole video prefill; TCM lets them through.
    // The video needs ~0.7 s of CPU preprocessing, then ~7 s of GPU
    // prefill; the text burst arrives while it is prefilling.
    let video_tokens = 40_000;
    let mk_trace = || {
        let mut t = vec![req(0, 0.0, Modality::Video, 30, video_tokens, 64)];
        for i in 1..=20 {
            t.push(req(i, 1.0 + i as f64 * 0.05, Modality::Text, 60, 0, 16));
        }
        t
    };
    let fcfs = run_sim_with_trace(&base_cfg("fcfs"), mk_trace());
    let tcm = run_sim_with_trace(&base_cfg("tcm"), mk_trace());
    let f = fcfs.report.by_modality(Modality::Text).avg_ttft;
    let t = tcm.report.by_modality(Modality::Text).avg_ttft;
    assert!(
        t < f * 0.5,
        "TCM should at least halve text TTFT under HOL blocking: {t} vs {f}"
    );
}

#[test]
fn memory_pressure_triggers_preemption() {
    let mut cfg = base_cfg("fcfs");
    cfg.memory_frac = 0.02; // 8k tokens for llava-7b
    cfg.num_requests = 60;
    cfg.mix = "MH".into();
    let r = run_sim(&cfg);
    assert!(r.stats.preemptions > 0, "tight memory must force preemptions");
    // everything still conserved
    assert_eq!(r.report.outcomes.len() + r.stats.dropped as usize, 60);
}

#[test]
fn oversized_prompt_is_dropped_not_wedged() {
    let mut cfg = base_cfg("fcfs");
    cfg.memory_frac = 0.01; // 4000 tokens capacity
    let trace = vec![
        req(0, 0.0, Modality::Video, 30, 100_000, 64), // can never fit
        req(1, 0.1, Modality::Text, 50, 0, 8),
    ];
    let r = run_sim_with_trace(&cfg, trace);
    assert_eq!(r.stats.dropped, 1);
    assert_eq!(r.report.outcomes.len(), 1);
    assert_eq!(r.report.outcomes[0].id, 1);
}

#[test]
fn dropped_requests_surface_as_failed_outcomes() {
    // Drops must not vanish from the report: `outcomes + failed` accounts
    // for every request, and the dropped request is identifiable.
    let mut cfg = base_cfg("tcm");
    cfg.memory_frac = 0.01;
    let trace = vec![
        req(0, 0.0, Modality::Video, 30, 100_000, 64), // can never fit
        req(1, 0.1, Modality::Text, 50, 0, 8),
        req(2, 0.2, Modality::Image, 40, 729, 16),
    ];
    let n = trace.len();
    let r = run_sim_with_trace(&cfg, trace);
    assert_eq!(
        r.report.outcomes.len() + r.report.failed.len(),
        n,
        "conservation must hold inside the report itself"
    );
    assert_eq!(r.report.failed.len(), r.stats.dropped as usize);
    assert_eq!(r.report.total(), n);
    assert!(r.report.failed.iter().any(|f| f.id == 0), "the oversized video is the drop");
    for f in &r.report.failed {
        assert!(f.dropped_at >= f.arrival, "drop time precedes arrival");
        assert!(
            !r.report.outcomes.iter().any(|o| o.id == f.id),
            "req {} both completed and dropped",
            f.id
        );
    }
    // dropped requests count against SLO attainment
    assert!(r.report.slo_attainment() < 1.0);
}

#[test]
fn decode_growth_eviction_drops_sole_oversized_request() {
    // prompt fits but prompt+output exceeds capacity and nothing else can
    // be evicted: the request must be dropped, not loop forever.
    let mut cfg = base_cfg("fcfs");
    cfg.memory_frac = 0.002; // 800 tokens
    let trace = vec![req(0, 0.0, Modality::Text, 700, 0, 512)];
    let r = run_sim_with_trace(&cfg, trace);
    assert_eq!(r.stats.dropped, 1);
    assert_eq!(r.report.outcomes.len(), 0);
}

#[test]
fn ready_set_sees_classified_requests() {
    let cfg = base_cfg("tcm");
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let policy = build_policy(&cfg, &profile);
    let engine = Box::new(SimEngine::new(&profile));
    let mut sched = Scheduler::new(cfg.clone(), policy, engine);
    let trace = tcm_serve::experiments::make_trace(&cfg, &profile);
    let n = trace.len() as u64;
    sched.run(trace);
    let rs = sched.ready_set();
    let enq: u64 = tcm_serve::request::Class::ALL
        .iter()
        .map(|&c| rs.stats(c).enqueued)
        .sum();
    assert!(enq >= n, "every request must pass through a class queue");
    assert!(rs.is_empty(), "queues drained at completion");
    sched.check_invariants().unwrap();
}

#[test]
fn ttft_not_before_preprocess_completes() {
    let cfg = base_cfg("fcfs");
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let r = run_sim_with_trace(&cfg, vec![req(0, 1.0, Modality::Video, 30, 5000, 16)]);
    let o = &r.report.outcomes[0];
    let pre = profile.preprocess_time(&req(0, 1.0, Modality::Video, 30, 5000, 16));
    assert!(o.ttft() >= pre, "ttft {} < preprocess {pre}", o.ttft());
}

#[test]
fn preprocess_pool_contention_serializes() {
    // more simultaneous videos than workers: later ones wait for a CPU
    // slot. Long videos (heavy preprocess) with small token counts (light
    // GPU) make the CPU stage the bottleneck.
    let mut cfg = base_cfg("fcfs");
    cfg.scheduler.preprocess_workers = 2;
    let trace: Vec<Request> = (0..6)
        .map(|i| {
            let mut r = req(i, 0.0, Modality::Video, 30, 500, 8);
            r.video_duration_s = 60.0;
            r
        })
        .collect();
    let a = run_sim_with_trace(&cfg, trace.clone());
    cfg.scheduler.preprocess_workers = 6;
    let b = run_sim_with_trace(&cfg, trace);
    assert!(
        a.report.overall().avg_ttft > b.report.overall().avg_ttft,
        "fewer preprocess workers must increase TTFT"
    );
}

#[test]
fn slo_scale_loosens_violations() {
    let mut strict = base_cfg("tcm");
    strict.slo_scale = 1.5;
    strict.rate = 4.0;
    let mut loose = strict.clone();
    loose.slo_scale = 20.0;
    let s = run_sim(&strict).report.overall().slo_violation_rate;
    let l = run_sim(&loose).report.overall().slo_violation_rate;
    assert!(l <= s, "looser SLO cannot violate more: {l} > {s}");
}

// ---------------------------------------------------------------------
// Real engine end-to-end (skips unless `make artifacts` has run; the
// PJRT runtime itself is compile-gated — see rust/README.md)
// ---------------------------------------------------------------------

#[cfg(pjrt_runtime)]
#[test]
fn coordinator_drives_real_engine_end_to_end() {
    let dir = std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = tcm_serve::runtime::Runtime::load(&dir).expect("runtime");
    let engine = Box::new(tcm_serve::engine::real::RealEngine::new(rt));

    let mut cfg = ServeConfig::default();
    cfg.model = "tiny-mllm".into();
    cfg.policy = "tcm".into();
    cfg.rate = 50.0; // tiny model is fast; saturate a bit
    cfg.num_requests = 12;
    cfg.seed = 3;
    cfg.scheduler.atomic_prefill = true;
    cfg.scheduler.max_running = 8;

    let profile = tcm_serve::model::by_name("tiny-mllm").unwrap();
    let trace = tcm_serve::experiments::make_trace(&cfg, &profile);
    let policy = build_policy(&cfg, &profile);
    let mut sched = Scheduler::new(cfg, policy, engine);
    let report = sched.run(trace);

    assert_eq!(report.outcomes.len(), 12, "all requests served");
    for o in &report.outcomes {
        assert!(o.ttft() > 0.0);
        assert!(o.finish >= o.first_token);
    }
    assert_eq!(sched.engine().name(), "real-pjrt");
    sched.check_invariants().unwrap();
}
