//! Disaggregated encoder-pool contracts (tentpole acceptance tests):
//!
//! * pool **off** is inert — every pool knob is dead config and the
//!   cluster reproduces its pre-pool (PR 3) results bit for bit, for
//!   every router (the PR 3 suite in `tests/cluster.rs` additionally
//!   pins that path against the bare scheduler);
//! * pool **on** is deterministic per router, conserves every request
//!   across the pool→replica handoff, and beats per-replica encoders on
//!   sand mean TTFT at 4 replicas under the video-heavy mix;
//! * migration cost applies only across hosts, with exact token/byte
//!   conservation;
//! * rocks saturated out of the pool by a pebble flood start encoding
//!   within the aging deadline plus one in-flight encode (and the bound
//!   is genuinely exercised, not vacuous);
//! * the stepped pool cluster equals the batch `run`.

mod common;

use common::assert_reports_bit_identical;
use tcm_serve::cluster::pool::BYTES_PER_MM_TOKEN;
use tcm_serve::cluster::Cluster;
use tcm_serve::config::{ServeConfig, ROUTERS};
use tcm_serve::coordinator::{RequestEvent, StepOutcome};
use tcm_serve::experiments::{make_trace, run_cluster_with_trace};
use tcm_serve::request::{Modality, Request};

fn pool_cfg(replicas: usize, router: &str, slots: usize) -> ServeConfig {
    let mut c = ServeConfig::default();
    c.policy = "fcfs".into();
    c.mix = "MH".into();
    c.rate = 1.5 * replicas as f64;
    c.num_requests = 120 * replicas;
    c.seed = 29;
    c.cluster.replicas = replicas;
    c.cluster.router = router.into();
    c.pool.enabled = true;
    c.pool.slots = slots;
    c
}

fn image(id: u64, arrival: f64) -> Request {
    Request {
        id,
        arrival,
        modality: Modality::Image,
        text_tokens: 40,
        mm_tokens: 729,
        video_duration_s: 0.0,
        output_tokens: 4,
        ..Request::default()
    }
}

fn video(id: u64, arrival: f64) -> Request {
    Request {
        id,
        arrival,
        modality: Modality::Video,
        text_tokens: 40,
        mm_tokens: 17_640,
        video_duration_s: 45.0,
        output_tokens: 4,
        ..Request::default()
    }
}

/// Acceptance: `--encoder-pool` off ⇒ bit-identical `ClusterReport`
/// (including makespan) whatever the pool knobs say, for every router —
/// the pool's config surface is completely inert until enabled. Together
/// with `tests/cluster.rs` (which pins the pool-off cluster against the
/// bare scheduler, stepped-vs-batch, and per-router determinism), this
/// proves pool-off is exactly the PR 3 behavior.
#[test]
fn disabled_pool_is_inert_for_every_router() {
    for router in ROUTERS {
        let mut base = pool_cfg(2, router, 2);
        base.pool.enabled = false;
        let profile = tcm_serve::model::by_name(&base.model).unwrap();
        let trace = make_trace(&base, &profile);

        let mut exotic = base.clone();
        exotic.pool.slots = 7;
        exotic.pool.aging_deadline_s = 0.01;
        exotic.pool.migration_cost_s_per_ktok = 9.9;

        let a = run_cluster_with_trace(&base, trace.clone());
        let b = run_cluster_with_trace(&exotic, trace);
        assert_reports_bit_identical(router, &a.report, &b.report);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{router}: makespan");
        assert!(a.pool.is_none() && b.pool.is_none(), "{router}: no pool stats when off");
    }
}

/// Bit-identical reruns in pool mode for every router: late binding,
/// aging and migration introduce no nondeterminism.
#[test]
fn pool_mode_is_deterministic_per_router() {
    for router in ROUTERS {
        let cfg = pool_cfg(3, router, 3);
        let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
        let trace = make_trace(&cfg, &profile);
        let a = run_cluster_with_trace(&cfg, trace.clone());
        let b = run_cluster_with_trace(&cfg, trace);
        assert_reports_bit_identical(router, &a.report, &b.report);
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits(), "{router}: makespan");
        let (pa, pb) = (a.pool.unwrap(), b.pool.unwrap());
        assert_eq!(pa.stats.encodes, pb.stats.encodes, "{router}: encode counts");
        assert_eq!(pa.stats.migrations, pb.stats.migrations, "{router}: migrations");
        assert_eq!(
            pa.stats.migrated_mm_tokens, pb.stats.migrated_mm_tokens,
            "{router}: migrated tokens"
        );
    }
}

/// Conservation across the pool→replica handoff: every request is routed
/// exactly once, accounted for in the merged report, and every
/// multimodal request is encoded by the pool exactly once.
#[test]
fn pool_conserves_requests_across_routers_and_scales() {
    for replicas in [1usize, 2, 4] {
        for router in ROUTERS {
            let cfg = pool_cfg(replicas, router, 2);
            let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
            let trace = make_trace(&cfg, &profile);
            let n = trace.len();
            let mm = trace.iter().filter(|r| r.mm_tokens > 0).count() as u64;
            let cr = run_cluster_with_trace(&cfg, trace);
            assert_eq!(cr.report.total(), n, "{router}/r{replicas}: lost requests");
            let routed: usize = cr.per_replica.iter().map(|r| r.routed).sum();
            assert_eq!(routed, n, "{router}/r{replicas}: routing not conservative");
            let p = cr.pool.as_ref().unwrap();
            assert_eq!(p.stats.encodes, mm, "{router}/r{replicas}: pool encode count");
            assert_eq!(
                p.stats.enqueued_pebbles + p.stats.enqueued_rocks,
                mm,
                "{router}/r{replicas}: pool admission count"
            );
            let dropped: u64 = cr.per_replica.iter().map(|r| r.dropped).sum();
            assert_eq!(
                dropped as usize,
                cr.report.failed.len(),
                "{router}/r{replicas}: failed != dropped"
            );
        }
    }
}

/// The headline acceptance claim: at 4 replicas under the video-heavy
/// mix, the disaggregated pool beats per-replica encoders on sand (text)
/// mean TTFT — rock encodes no longer serialize with sand iterations
/// inside the replica engines (deterministic seed; `fig_encoder_pool`
/// shows the same A/B).
#[test]
fn pool_beats_per_replica_encoders_on_sand_mean_ttft_at_4_replicas() {
    let mut local = ServeConfig::default();
    local.policy = "fcfs".into();
    local.mix = "VH".into();
    // ~0.75 req/s per replica: with per-replica encoders the video
    // encode work alone pushes each replica past saturation; with the
    // pool the same replicas run well under capacity
    local.rate = 3.0;
    local.num_requests = 400;
    local.seed = 61;
    local.cluster.replicas = 4;
    local.cluster.router = "round-robin".into();
    let profile = tcm_serve::model::by_name(&local.model).unwrap();
    let trace = make_trace(&local, &profile);

    let mut pooled = local.clone();
    pooled.pool.enabled = true;
    pooled.pool.slots = 6; // ~1.2 videos/s × ~3.4 s pool work each

    let off = run_cluster_with_trace(&local, trace.clone());
    let on = run_cluster_with_trace(&pooled, trace);

    let sand_off = off.report.by_modality(Modality::Text).avg_ttft;
    let sand_on = on.report.by_modality(Modality::Text).avg_ttft;
    assert!(
        sand_on < sand_off,
        "pool sand mean ttft {sand_on:.3}s !< per-replica {sand_off:.3}s"
    );
    let p = on.pool.as_ref().unwrap();
    assert!(p.stats.encodes > 0 && on.pool_utilization() > 0.0, "pool actually worked");
}

/// Migration cost applies only when the encode slot's host differs from
/// the late-bound decode replica: with a single decode replica every
/// slot is co-hosted with it, so the migration knob is provably dead —
/// runs at cost 0 and at an absurd cost are bit-identical and report
/// zero migrations.
#[test]
fn migration_cost_only_applies_across_hosts() {
    let mut a = pool_cfg(1, "round-robin", 2);
    a.pool.migration_cost_s_per_ktok = 0.0;
    let mut b = a.clone();
    b.pool.migration_cost_s_per_ktok = 5.0;
    let profile = tcm_serve::model::by_name(&a.model).unwrap();
    let trace = make_trace(&a, &profile);

    let ra = run_cluster_with_trace(&a, trace.clone());
    let rb = run_cluster_with_trace(&b, trace);
    assert_reports_bit_identical("migration-dead-knob", &ra.report, &rb.report);
    assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
    assert_eq!(ra.pool.as_ref().unwrap().stats.migrations, 0);
    assert_eq!(rb.pool.as_ref().unwrap().stats.migrations, 0);
    assert_eq!(rb.pool.as_ref().unwrap().stats.migrated_bytes, 0);
}

/// Exact end-to-end migration conservation. One encode slot (host =
/// replica 0), two decode replicas, a pure-image trace (no sand to
/// perturb the round-robin counter): handoffs leave the pool in arrival
/// order and alternate 0, 1, 0, 1, …, so exactly every second handoff
/// crosses hosts. Token and byte counters must match that to the digit.
#[test]
fn migrated_tokens_and_bytes_are_exactly_conserved() {
    let mut cfg = pool_cfg(2, "round-robin", 1);
    cfg.pool.migration_cost_s_per_ktok = 0.002;
    let n = 10u64;
    let trace: Vec<Request> = (0..n).map(|id| image(id, id as f64)).collect();

    let cr = run_cluster_with_trace(&cfg, trace);
    assert_eq!(cr.report.total(), n as usize);
    let p = cr.pool.as_ref().unwrap();
    assert_eq!(p.stats.encodes, n);
    // round-robin over 2 replicas with host pinned to 0: handoffs 2, 4,
    // … land on replica 1 and migrate — exactly n/2 migrations
    assert_eq!(p.stats.migrations, n / 2, "alternating late binding");
    assert_eq!(p.stats.migrated_mm_tokens, (n / 2) * 729);
    assert_eq!(p.stats.migrated_bytes, (n / 2) * 729 * BYTES_PER_MM_TOKEN);
}

/// Starvation regression: a pebble flood saturates the pool, and rocks
/// still start encoding within `aging_deadline + max in-flight encode`.
/// Non-vacuous: the flood is provisioned past pool capacity, so the
/// rocks *cannot* start before aging promotes them — the run must report
/// both aged promotions and a max rock wait at or past the deadline.
#[test]
fn rock_encode_start_bounded_by_aging_under_pebble_flood() {
    let mut cfg = pool_cfg(2, "round-robin", 2); // rock cap 1
    cfg.pool.aging_deadline_s = 1.0;
    let mut trace = Vec::new();
    let mut id = 0u64;
    // 600 images over 30 s: 20 pebbles/s offered vs ~12.4/s of pool
    // capacity (2 slots / 0.161 s per image encode) — the pebble lane
    // queue grows for the whole run
    for k in 0..600u64 {
        trace.push(image(id, k as f64 * 0.05));
        id += 1;
    }
    // two rocks, spaced so at most one is queued or in flight at a time
    trace.push(video(id, 2.0));
    id += 1;
    trace.push(video(id, 10.0));

    let cr = run_cluster_with_trace(&cfg, trace);
    let p = cr.pool.as_ref().unwrap();
    assert_eq!(p.stats.enqueued_rocks, 2);
    assert_eq!(p.stats.encodes, 602, "nothing starved out entirely");
    assert_eq!(
        p.stats.aged_promotions, 2,
        "both rocks must have been admitted via aging over waiting pebbles"
    );
    assert!(
        p.stats.rock_wait_max_s >= cfg.pool.aging_deadline_s,
        "bound never exercised: max rock wait {:.3}s under the {:.1}s deadline",
        p.stats.rock_wait_max_s,
        cfg.pool.aging_deadline_s
    );
    let bound = cfg.pool.aging_deadline_s + p.stats.max_encode_s + 1e-6;
    assert!(
        p.stats.rock_wait_max_s <= bound,
        "rock waited {:.3}s, past the aging bound {bound:.3}s",
        p.stats.rock_wait_max_s
    );
}

/// Pool-mode stepping API == batch `run`: driving the cluster step by
/// step (the server-leader path), with invariants checked as it goes and
/// events accounted, lands on the identical report — and the event
/// stream shows at least one encode per multimodal request flowing
/// across the handoff boundary.
#[test]
fn stepped_pool_cluster_equals_batch_run() {
    let cfg = pool_cfg(2, "round-robin", 2);
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = make_trace(&cfg, &profile);
    let n = trace.len();
    let mm = trace.iter().filter(|r| r.mm_tokens > 0).count();

    let batch = run_cluster_with_trace(&cfg, trace.clone());

    let mut cluster = Cluster::new(&cfg);
    for req in trace {
        cluster.inject(req);
    }
    let mut finished_events = 0usize;
    let mut dropped_events = 0usize;
    let mut encoded_events = 0usize;
    let mut steps = 0u64;
    loop {
        match cluster.step() {
            StepOutcome::Executed { dt } => assert!(dt >= 0.0),
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => cluster.drop_blocked(),
            StepOutcome::Drained => break,
        }
        for ev in cluster.take_events() {
            match ev {
                RequestEvent::Finished { .. } => finished_events += 1,
                RequestEvent::Dropped { .. } => dropped_events += 1,
                RequestEvent::Encoded { .. } => encoded_events += 1,
                _ => {}
            }
        }
        if steps % 64 == 0 {
            cluster.check_invariants().unwrap_or_else(|e| panic!("after step {steps}: {e}"));
        }
        steps += 1;
        assert!(steps < 5_000_000, "stepping did not drain");
    }
    for ev in cluster.take_events() {
        match ev {
            RequestEvent::Finished { .. } => finished_events += 1,
            RequestEvent::Dropped { .. } => dropped_events += 1,
            RequestEvent::Encoded { .. } => encoded_events += 1,
            _ => {}
        }
    }
    cluster.check_invariants().unwrap();
    let stepped = cluster.report();
    assert_eq!(stepped.report.total(), n);
    assert_eq!(finished_events, stepped.report.outcomes.len());
    assert_eq!(dropped_events, stepped.report.failed.len());
    assert!(
        encoded_events >= mm,
        "every multimodal request encodes at least once: {encoded_events} < {mm}"
    );
    assert_reports_bit_identical("stepped-vs-batch", &stepped.report, &batch.report);
    assert_eq!(stepped.makespan.to_bits(), batch.makespan.to_bits(), "makespan");
    assert_eq!(
        stepped.pool.as_ref().unwrap().stats.migrations,
        batch.pool.as_ref().unwrap().stats.migrations,
        "migration accounting"
    );
}

/// Pool-aware late binding (ROADMAP item): on a tied-ledger trace, a
/// non-zero `pool.late_bind_epsilon_s` binds handoffs to the encode
/// slot's host replica and the migration byte count drops; epsilon 0
/// keeps the plain argmin (which migrates) — and both modes conserve
/// every request.
#[test]
fn late_bind_epsilon_cuts_migration_bytes_on_tied_ledger_trace() {
    // 2 replicas, least-work router, ONE pool slot co-hosted with
    // replica 0. Three identical long-decode text requests land 2-on-0,
    // 1-on-1 (ledger ties break to the lowest id), so at encode
    // completion replica 1 is the strict argmin while the slot's host
    // (replica 0) is within a small epsilon: the baseline migrates the
    // video's embeddings, the epsilon build keeps them on the host.
    let mut base = ServeConfig::default();
    base.policy = "fcfs".into();
    base.cluster.replicas = 2;
    base.cluster.router = "least-work".into();
    base.pool.enabled = true;
    base.pool.slots = 1;
    let mut trace = Vec::new();
    for id in 0..3u64 {
        trace.push(Request {
            id,
            modality: Modality::Text,
            text_tokens: 64,
            output_tokens: 3_000, // still decoding when the encode completes
            ..Request::default()
        });
    }
    trace.push(video(99, 0.0));

    let run = |epsilon: f64| {
        let mut cfg = base.clone();
        cfg.pool.late_bind_epsilon_s = epsilon;
        run_cluster_with_trace(&cfg, trace.clone())
    };

    let plain = run(0.0);
    let prefer_host = run(10.0);
    for (label, cr) in [("epsilon=0", &plain), ("epsilon=10", &prefer_host)] {
        assert_eq!(cr.report.total(), 4, "{label}: conservation");
        assert_eq!(cr.report.outcomes.len(), 4, "{label}: all four complete");
    }

    let p0 = plain.pool.as_ref().unwrap();
    let p1 = prefer_host.pool.as_ref().unwrap();
    assert_eq!(p0.stats.migrations, 1, "baseline must migrate the handoff off the host");
    assert_eq!(p0.stats.migrated_bytes, 17_640 * BYTES_PER_MM_TOKEN);
    assert_eq!(p1.stats.migrations, 0, "epsilon binds the near-tied handoff to the host");
    assert!(
        p1.stats.migrated_bytes < p0.stats.migrated_bytes,
        "migration bytes must drop: {} !< {}",
        p1.stats.migrated_bytes,
        p0.stats.migrated_bytes
    );
}
