//! Model zoo: cost-model profiles for the paper's Table-1 MLLM families
//! plus the tiny PJRT-executed model.

pub mod profiles;

pub use profiles::{by_name, names, profiles, tiny_mllm, ModelProfile, Tokenizer};
