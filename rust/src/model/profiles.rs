//! Model cost profiles: the paper's Table-1 model zoo as calibrated cost
//! models for the discrete-event engine.
//!
//! Each profile encodes the per-family characteristics the paper measures
//! in §2 (Fig 2: token-count distributions; Fig 6: TTFT breakdown into
//! preprocess/encode/prefill; §2.2: latency bands) on an A100-40GB-class
//! device. Absolute constants are calibrated so the *bands and ratios*
//! match the paper: text TTFT ≈ 0.01 s, image < 1 s, video 1–10 s; videos
//! one to three orders of magnitude more KV tokens than text; Pixtral
//! prefill-heavy vs Qwen/Gemma preprocess/encode-heavy.
//!
//! The `tiny-mllm` profile describes the model the RealEngine actually
//! executes through PJRT (python/compile/model.py); its constants are
//! irrelevant for simulation but its tokenization contract matters.

use crate::request::{Modality, Request};

/// How a family turns an image/video into vision tokens.
#[derive(Debug, Clone, Copy)]
pub struct Tokenizer {
    /// Tokens per image (median). Near-constant for grid-patch models.
    pub image_tokens: f64,
    /// Multiplicative jitter (lognormal sigma) on image tokens — 0 for
    /// fixed-grid models, >0 for dynamic-resolution models (Qwen).
    pub image_jitter: f64,
    /// Tokens per sampled video frame.
    pub frame_tokens: f64,
    /// Frames sampled per second of video.
    pub frame_rate: f64,
    /// Maximum frames sampled (uniform sampling caps long videos).
    pub max_frames: u32,
}

impl Tokenizer {
    /// Vision tokens for a video of the given duration.
    pub fn video_tokens(&self, duration_s: f64) -> u32 {
        let frames = (duration_s * self.frame_rate).ceil().min(self.max_frames as f64);
        (frames.max(1.0) * self.frame_tokens) as u32
    }
}

/// Calibrated cost model for one model family on the reference device.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: &'static str,
    pub vision_encoder: &'static str,
    pub llm_backend: &'static str,
    /// LLM backend parameter count (billions) — documentation only.
    pub llm_params_b: f64,

    pub tokenizer: Tokenizer,

    // --- GPU prefill (LLM) ---
    /// Fixed per-prefill-launch overhead (s).
    pub prefill_base_s: f64,
    /// Linear prefill throughput (prompt tokens / s).
    pub prefill_tok_per_s: f64,
    /// Quadratic attention coefficient (s per token^2): dominates for
    /// 10^4–10^5-token video prompts.
    pub prefill_quad_s: f64,

    // --- GPU decode ---
    /// Per-iteration decode step time at batch size 1 (s).
    pub decode_base_s: f64,
    /// Additional step time per extra sequence in the decode batch (s).
    pub decode_per_seq_s: f64,

    // --- vision preprocess (CPU) + encode (GPU) ---
    /// Image preprocess (decode/resize/patch) time (s).
    pub preprocess_image_s: f64,
    /// Video preprocess time per second of video (frame extraction).
    pub preprocess_video_s_per_s: f64,
    /// Fixed encoder launch overhead (s).
    pub encode_base_s: f64,
    /// Encoder throughput (vision tokens / s).
    pub encode_tok_per_s: f64,

    // --- memory ---
    /// KV-cache capacity in tokens at 100% memory (weights already
    /// subtracted from the 40 GB device).
    pub kv_capacity_tokens: u64,

    // --- encode/prefill overlap (RServe-style, arXiv 2509.24381) ---
    /// When true the vision encoder runs on its own stream, concurrent
    /// with the iteration's prefill/decode pass: the engine charges
    /// `max(encode, prefill + decode) + encode_overlap_penalty_s` instead
    /// of the serialized sum (and never more than the sum — a real engine
    /// would fall back to serializing when overlap is unprofitable).
    /// Default `false`: the serialized cost model stays bit-identical.
    pub encode_overlap: bool,
    /// Synchronization/interference cost charged when an encode actually
    /// overlaps a prefill/decode pass (stream sync + SM contention).
    pub encode_overlap_penalty_s: f64,
}

impl ModelProfile {
    /// Preprocessing time (CPU stage) for a request.
    pub fn preprocess_time(&self, req: &Request) -> f64 {
        match req.modality {
            Modality::Text => 0.0,
            Modality::Image => self.preprocess_image_s,
            Modality::Video => 0.05 + self.preprocess_video_s_per_s * req.video_duration_s,
        }
    }

    /// Vision-encoder time (GPU stage) for a request.
    pub fn encode_time(&self, req: &Request) -> f64 {
        if req.mm_tokens == 0 {
            return 0.0;
        }
        self.encode_base_s + req.mm_tokens as f64 / self.encode_tok_per_s
    }

    /// Time to prefill `chunk` tokens given `ctx` tokens already cached
    /// (chunked prefill: attention cost scales with context length).
    pub fn prefill_chunk_time(&self, ctx_before: u32, chunk: u32) -> f64 {
        let chunk = chunk as f64;
        let ctx_mid = ctx_before as f64 + chunk / 2.0;
        self.prefill_base_s
            + chunk / self.prefill_tok_per_s
            + self.prefill_quad_s * chunk * ctx_mid
    }

    /// Full (unchunked) prefill time for `tokens` prompt tokens.
    pub fn prefill_time(&self, tokens: u32) -> f64 {
        self.prefill_chunk_time(0, tokens)
    }

    /// Decode step time for a batch of `n` sequences.
    pub fn decode_step_time(&self, n: usize) -> f64 {
        if n == 0 {
            return 0.0;
        }
        self.decode_base_s + self.decode_per_seq_s * (n as f64 - 1.0)
    }

    /// Isolated (no-contention) TTFT: preprocess + encode + prefill.
    pub fn isolated_ttft(&self, req: &Request) -> f64 {
        self.preprocess_time(req) + self.encode_time(req) + self.prefill_time(req.prefill_tokens())
    }

    /// Isolated end-to-end latency; the SLO is `slo_scale ×` this (§4.1).
    pub fn isolated_e2e(&self, req: &Request) -> f64 {
        self.isolated_ttft(req) + req.output_tokens as f64 * self.decode_base_s
    }

    /// Enable encode/prefill overlap with the given sync penalty (builder
    /// for cluster configs; the zoo defaults stay serialized).
    pub fn with_encode_overlap(mut self, penalty_s: f64) -> ModelProfile {
        self.encode_overlap = true;
        self.encode_overlap_penalty_s = penalty_s;
        self
    }
}

/// The evaluation model zoo (paper Table 1).
pub fn profiles() -> Vec<ModelProfile> {
    vec![
        ModelProfile {
            name: "llava-500m",
            vision_encoder: "SigLIP (400M)",
            llm_backend: "Qwen2 (500M)",
            llm_params_b: 0.5,
            tokenizer: Tokenizer {
                image_tokens: 729.0,
                image_jitter: 0.0,
                frame_tokens: 196.0,
                frame_rate: 2.0,
                max_frames: 128,
            },
            prefill_base_s: 0.003,
            prefill_tok_per_s: 60_000.0,
            prefill_quad_s: 4e-11,
            decode_base_s: 0.008,
            decode_per_seq_s: 0.00008,
            preprocess_image_s: 0.06,
            preprocess_video_s_per_s: 0.020,
            encode_base_s: 0.010,
            encode_tok_per_s: 10_000.0,
            kv_capacity_tokens: 1_500_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "llava-7b",
            vision_encoder: "SigLIP (400M)",
            llm_backend: "Qwen2 (7B)",
            llm_params_b: 7.0,
            tokenizer: Tokenizer {
                image_tokens: 729.0,
                image_jitter: 0.0,
                frame_tokens: 196.0,
                frame_rate: 2.0,
                max_frames: 128,
            },
            prefill_base_s: 0.005,
            prefill_tok_per_s: 12_000.0,
            prefill_quad_s: 2e-10,
            decode_base_s: 0.025,
            decode_per_seq_s: 0.0003,
            preprocess_image_s: 0.06,
            preprocess_video_s_per_s: 0.020,
            encode_base_s: 0.010,
            encode_tok_per_s: 8_000.0,
            kv_capacity_tokens: 400_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "gemma-4b",
            vision_encoder: "SigLIP (400M)",
            llm_backend: "Gemma3 (4B)",
            llm_params_b: 4.0,
            tokenizer: Tokenizer {
                image_tokens: 256.0,
                image_jitter: 0.0,
                // Gemma has no native video support: frames as images.
                frame_tokens: 256.0,
                frame_rate: 1.0,
                max_frames: 96,
            },
            prefill_base_s: 0.004,
            prefill_tok_per_s: 20_000.0,
            prefill_quad_s: 1.2e-10,
            decode_base_s: 0.016,
            decode_per_seq_s: 0.0002,
            // Gemma/Qwen allocate relatively more time to preprocess+encode
            // (paper Fig 6).
            preprocess_image_s: 0.11,
            preprocess_video_s_per_s: 0.022,
            encode_base_s: 0.015,
            encode_tok_per_s: 4_000.0,
            kv_capacity_tokens: 700_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "gemma-12b",
            vision_encoder: "SigLIP (400M)",
            llm_backend: "Gemma3 (12B)",
            llm_params_b: 12.0,
            tokenizer: Tokenizer {
                image_tokens: 256.0,
                image_jitter: 0.0,
                frame_tokens: 256.0,
                frame_rate: 1.0,
                max_frames: 96,
            },
            prefill_base_s: 0.006,
            prefill_tok_per_s: 8_000.0,
            prefill_quad_s: 3e-10,
            decode_base_s: 0.040,
            decode_per_seq_s: 0.0005,
            preprocess_image_s: 0.11,
            preprocess_video_s_per_s: 0.022,
            encode_base_s: 0.015,
            encode_tok_per_s: 4_000.0,
            kv_capacity_tokens: 250_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "qwen-3b",
            vision_encoder: "Custom ViT (500M)",
            llm_backend: "Qwen2.5 (3B)",
            llm_params_b: 3.0,
            tokenizer: Tokenizer {
                // dynamic resolution: variable image tokens
                image_tokens: 720.0,
                image_jitter: 0.45,
                frame_tokens: 180.0,
                frame_rate: 2.0,
                max_frames: 768,
            },
            prefill_base_s: 0.004,
            prefill_tok_per_s: 25_000.0,
            prefill_quad_s: 1e-10,
            decode_base_s: 0.014,
            decode_per_seq_s: 0.0002,
            preprocess_image_s: 0.13,
            preprocess_video_s_per_s: 0.012,
            encode_base_s: 0.012,
            encode_tok_per_s: 12_000.0,
            kv_capacity_tokens: 800_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "qwen-7b",
            vision_encoder: "Custom ViT (500M)",
            llm_backend: "Qwen2.5 (7B)",
            llm_params_b: 7.0,
            tokenizer: Tokenizer {
                image_tokens: 720.0,
                image_jitter: 0.45,
                frame_tokens: 180.0,
                frame_rate: 2.0,
                max_frames: 768,
            },
            prefill_base_s: 0.005,
            prefill_tok_per_s: 12_000.0,
            prefill_quad_s: 2e-10,
            decode_base_s: 0.025,
            decode_per_seq_s: 0.0003,
            preprocess_image_s: 0.13,
            preprocess_video_s_per_s: 0.012,
            encode_base_s: 0.012,
            encode_tok_per_s: 12_000.0,
            kv_capacity_tokens: 400_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
        ModelProfile {
            name: "pixtral-12b",
            vision_encoder: "Pixtral-ViT (400M)",
            llm_backend: "Mistral NeMo (12B)",
            llm_params_b: 12.0,
            tokenizer: Tokenizer {
                image_tokens: 1024.0,
                image_jitter: 0.0,
                // No native video: frames as images, sparse sampling.
                frame_tokens: 1024.0,
                frame_rate: 0.5,
                max_frames: 64,
            },
            prefill_base_s: 0.006,
            prefill_tok_per_s: 8_000.0,
            prefill_quad_s: 3e-10,
            decode_base_s: 0.040,
            decode_per_seq_s: 0.0005,
            // prefill-dominant TTFT breakdown (paper Fig 6)
            preprocess_image_s: 0.05,
            preprocess_video_s_per_s: 0.010,
            encode_base_s: 0.008,
            encode_tok_per_s: 20_000.0,
            kv_capacity_tokens: 250_000,
            encode_overlap: false,
            encode_overlap_penalty_s: 0.0005,
        },
    ]
}

/// The model the RealEngine actually executes (python/compile/model.py).
/// Token counts match the tiny model's patch contract: image = 64 patches,
/// video = 16 patches/frame. Cost constants are only used for SLO targets
/// when simulating this profile.
pub fn tiny_mllm() -> ModelProfile {
    ModelProfile {
        name: "tiny-mllm",
        vision_encoder: "TinyViT (0.5M)",
        llm_backend: "TinyLM (0.7M)",
        llm_params_b: 0.0007,
        tokenizer: Tokenizer {
            image_tokens: 64.0,
            image_jitter: 0.0,
            frame_tokens: 16.0,
            frame_rate: 1.0,
            max_frames: 12,
        },
        prefill_base_s: 0.001,
        prefill_tok_per_s: 50_000.0,
        prefill_quad_s: 1e-10,
        decode_base_s: 0.004,
        decode_per_seq_s: 0.0002,
        preprocess_image_s: 0.002,
        preprocess_video_s_per_s: 0.001,
        encode_base_s: 0.001,
        encode_tok_per_s: 50_000.0,
        kv_capacity_tokens: 64 * 640,
        encode_overlap: false,
        encode_overlap_penalty_s: 0.0005,
    }
}

/// Look up a profile by name (including tiny-mllm).
pub fn by_name(name: &str) -> Option<ModelProfile> {
    if name == "tiny-mllm" {
        return Some(tiny_mllm());
    }
    profiles().into_iter().find(|p| p.name == name)
}

pub fn names() -> Vec<&'static str> {
    profiles().iter().map(|p| p.name).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::{Modality, Request};

    fn req(modality: Modality, text: u32, mm: u32, dur: f64) -> Request {
        Request {
            id: 0,
            arrival: 0.0,
            modality,
            text_tokens: text,
            mm_tokens: mm,
            video_duration_s: dur,
            output_tokens: 128,
            ..Request::default()
        }
    }

    #[test]
    fn all_table1_models_present() {
        let names = names();
        for expect in [
            "llava-500m", "llava-7b", "gemma-4b", "gemma-12b", "qwen-3b", "qwen-7b",
            "pixtral-12b",
        ] {
            assert!(names.contains(&expect), "{expect} missing");
        }
        assert_eq!(names.len(), 7);
    }

    #[test]
    fn text_ttft_is_milliseconds() {
        // paper §2.2: text "typically around 0.01 seconds, always < 1 s"
        for p in profiles() {
            let r = req(Modality::Text, 100, 0, 0.0);
            let t = p.isolated_ttft(&r);
            assert!(t < 0.05, "{}: {t}", p.name);
            let long = req(Modality::Text, 10_000, 0, 0.0);
            assert!(p.isolated_ttft(&long) < 1.5, "{}", p.name);
        }
    }

    #[test]
    fn image_ttft_under_one_second() {
        for p in profiles() {
            let mm = p.tokenizer.image_tokens as u32;
            let r = req(Modality::Image, 40, mm, 0.0);
            let t = p.isolated_ttft(&r);
            assert!((0.05..1.0).contains(&t), "{}: {t}", p.name);
        }
    }

    #[test]
    fn video_ttft_band_matches_fig2b() {
        // Fig 2b: videos range ~1-10 s with a tail slightly past 10 s for
        // the largest prompts; median-duration videos must sit in-band.
        for p in profiles() {
            let mm = p.tokenizer.video_tokens(45.0);
            let r = req(Modality::Video, 40, mm, 45.0);
            let t = p.isolated_ttft(&r);
            assert!((0.8..10.0).contains(&t), "{}: {t} (mm={mm})", p.name);
            let mm = p.tokenizer.video_tokens(240.0);
            let long = req(Modality::Video, 40, mm, 240.0);
            let t = p.isolated_ttft(&long);
            assert!(t < 20.0, "{}: long-video tail {t}", p.name);
        }
    }

    #[test]
    fn modality_hierarchy_in_time_and_space() {
        // videos dominate, then images, then text (Insight 1)
        for p in profiles() {
            let text = req(Modality::Text, 100, 0, 0.0);
            let img = req(Modality::Image, 40, p.tokenizer.image_tokens as u32, 0.0);
            let vid = req(Modality::Video, 40, p.tokenizer.video_tokens(60.0), 60.0);
            assert!(p.isolated_ttft(&text) < p.isolated_ttft(&img), "{}", p.name);
            assert!(p.isolated_ttft(&img) < p.isolated_ttft(&vid), "{}", p.name);
            assert!(text.prefill_tokens() < img.prefill_tokens());
            assert!(img.prefill_tokens() < vid.prefill_tokens());
        }
    }

    #[test]
    fn qwen_long_videos_exceed_1e5_tokens() {
        // paper Fig 2a: Qwen-7B videos can exceed 10^5 tokens
        let p = by_name("qwen-7b").unwrap();
        assert!(p.tokenizer.video_tokens(400.0) > 100_000);
    }

    #[test]
    fn chunked_prefill_sums_to_full_prefill() {
        let p = by_name("llava-7b").unwrap();
        let total = 4096u32;
        let full = p.prefill_time(total);
        let mut chunked = 0.0;
        let mut ctx = 0u32;
        while ctx < total {
            let chunk = 512.min(total - ctx);
            chunked += p.prefill_chunk_time(ctx, chunk);
            ctx += chunk;
        }
        // chunking pays extra per-launch overhead but the quadratic part
        // must integrate to the same area (midpoint rule is exact here)
        let overhead = 7.0 * p.prefill_base_s;
        assert!((chunked - full - overhead).abs() < 1e-6, "{chunked} vs {full}");
    }

    #[test]
    fn pixtral_is_prefill_dominant_gemma_is_not() {
        // paper Fig 6: Pixtral spends most TTFT in prefill; Gemma/Qwen
        // allocate more to preprocessing+encoding.
        let pix = by_name("pixtral-12b").unwrap();
        let r = req(Modality::Image, 40, pix.tokenizer.image_tokens as u32, 0.0);
        let pre = pix.preprocess_time(&r) + pix.encode_time(&r);
        let pf = pix.prefill_time(r.prefill_tokens());
        assert!(pf > pre, "pixtral should be prefill-dominant");

        let gem = by_name("gemma-4b").unwrap();
        let r = req(Modality::Image, 40, gem.tokenizer.image_tokens as u32, 0.0);
        let pre = gem.preprocess_time(&r) + gem.encode_time(&r);
        let pf = gem.prefill_time(r.prefill_tokens());
        assert!(pre > pf, "gemma should be preprocess/encode-heavy");
    }

    #[test]
    fn decode_step_scales_with_batch() {
        let p = by_name("llava-7b").unwrap();
        assert_eq!(p.decode_step_time(0), 0.0);
        assert!(p.decode_step_time(8) > p.decode_step_time(1));
        // decode stays memory-bound: batch-64 step < 64x batch-1 step
        assert!(p.decode_step_time(64) < 2.0 * p.decode_step_time(1));
    }

    #[test]
    fn video_tokens_capped_by_max_frames() {
        let p = by_name("llava-7b").unwrap();
        assert_eq!(
            p.tokenizer.video_tokens(1000.0),
            p.tokenizer.video_tokens(64.0) // 128 frames at 2 fps
        );
    }

    #[test]
    fn tiny_mllm_lookup() {
        assert!(by_name("tiny-mllm").is_some());
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn zoo_defaults_to_serialized_encode() {
        // the overlap knob must be opt-in: the calibrated zoo stays
        // bit-identical to the pre-knob cost model
        for p in profiles() {
            assert!(!p.encode_overlap, "{}", p.name);
        }
        assert!(!tiny_mllm().encode_overlap);
        let p = by_name("llava-7b").unwrap().with_encode_overlap(0.001);
        assert!(p.encode_overlap);
        assert_eq!(p.encode_overlap_penalty_s, 0.001);
    }
}
