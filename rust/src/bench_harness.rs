//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Provides the criterion-like subset the `rust/benches/` targets use:
//! warmup, timed iterations, min/median/mean/max reporting, and throughput
//! annotation. Figure-level benches mostly run *one* deterministic
//! simulation and print table rows; the harness is used for the hot-path
//! perf benches where distributional timing matters.

use std::io::Write as _;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<5} min={:>12?} median={:>12?} mean={:>12?} max={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.max
        );
    }

    /// ns per iteration (median).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }

    /// Record this result to the `BENCH_JSON` sink (if configured) for
    /// the CI bench-regression gate; `hot` marks hot-path benches whose
    /// median regression fails the job.
    pub fn record(&self, hot: bool) {
        record_named(&self.name, self.median_ns(), None, hot);
    }
}

/// Record an arbitrary named metric to the `BENCH_JSON` sink, if
/// configured — for figure benches whose gate metric isn't a harness
/// timing (virtual makespans, TTFT means). No-op without the sink.
pub fn record_named(name: &str, median_ns: f64, throughput: Option<f64>, hot: bool) {
    if let Some(path) = json_sink() {
        let rec = JsonRecord { name, median_ns, throughput, hot };
        append_json(&path, &rec).expect("write BENCH_JSON sink");
    }
}

/// One machine-readable bench record (`tools/bench_compare.py` merges
/// the JSONL sink into the uploaded results artifact and gates hot-path
/// regressions against `BENCH_baseline.json`).
pub struct JsonRecord<'a> {
    pub name: &'a str,
    /// Gate metric. Harness benches report the median iteration time;
    /// figure-level cluster benches report the virtual makespan in ns.
    pub median_ns: f64,
    /// Optional domain throughput (tokens per virtual second for the
    /// cluster bench); informational, never gated.
    pub throughput: Option<f64>,
    /// Hot-path marker: only hot records fail CI on regression.
    pub hot: bool,
}

/// The JSONL sink path, when bench recording is requested
/// (`BENCH_JSON=/path/to/file.jsonl`).
pub fn json_sink() -> Option<String> {
    std::env::var("BENCH_JSON").ok().filter(|s| !s.is_empty())
}

/// Append one record as a JSON line. Bench names are plain identifiers,
/// so no escaping machinery: refuse anything that would need it rather
/// than emit malformed JSON.
pub fn append_json(path: &str, rec: &JsonRecord) -> std::io::Result<()> {
    assert!(
        rec.name.chars().all(|c| c.is_ascii_alphanumeric() || "/-_.:x ()".contains(c)),
        "bench name {:?} would need JSON escaping",
        rec.name
    );
    let mut f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
    let throughput = match rec.throughput {
        Some(t) => format!("{t:.3}"),
        None => "null".to_string(),
    };
    writeln!(
        f,
        "{{\"name\": \"{}\", \"median_ns\": {:.1}, \"throughput\": {}, \"hot\": {}}}",
        rec.name, rec.median_ns, throughput, rec.hot
    )
}

/// Time `f` for at least `min_iters` iterations and ~`target_ms` total.
pub fn bench<R>(name: &str, mut f: impl FnMut() -> R) -> BenchResult {
    bench_config(name, 10, 300, &mut f)
}

pub fn bench_config<R>(
    name: &str,
    min_iters: usize,
    target_ms: u64,
    f: &mut impl FnMut() -> R,
) -> BenchResult {
    // Warmup: one call, also estimates per-iter cost.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let warm = t0.elapsed();

    let budget = Duration::from_millis(target_ms);
    let est_iters = if warm.is_zero() {
        min_iters.max(1000)
    } else {
        ((budget.as_secs_f64() / warm.as_secs_f64()).ceil() as usize).clamp(min_iters, 100_000)
    };

    let mut samples = Vec::with_capacity(est_iters);
    let start = Instant::now();
    for _ in 0..est_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
        if start.elapsed() > budget * 4 && samples.len() >= min_iters {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchResult {
        name: name.to_string(),
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        mean,
        max: samples[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_ordered_stats() {
        let r = bench_config("noop", 10, 5, &mut || 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.min <= r.median);
        assert!(r.median <= r.max);
    }

    #[test]
    fn json_lines_are_well_formed() {
        let path = std::env::temp_dir()
            .join(format!("tcm_bench_json_{}.jsonl", std::process::id()));
        let path_s = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        append_json(
            &path_s,
            &JsonRecord { name: "hot/one", median_ns: 1234.5, throughput: None, hot: true },
        )
        .unwrap();
        append_json(
            &path_s,
            &JsonRecord {
                name: "cluster/rr/r2",
                median_ns: 9.0e9,
                throughput: Some(1523.25),
                hot: false,
            },
        )
        .unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            lines[0],
            "{\"name\": \"hot/one\", \"median_ns\": 1234.5, \"throughput\": null, \"hot\": true}"
        );
        assert!(lines[1].contains("\"throughput\": 1523.250"));
        assert!(lines[1].ends_with("\"hot\": false}"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn measures_real_work() {
        let mut acc = 0u64;
        let r = bench_config("sum", 5, 5, &mut || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.min.as_nanos() > 0);
    }
}
