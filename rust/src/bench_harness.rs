//! Micro/macro benchmark harness (criterion is unavailable offline).
//!
//! Provides the criterion-like subset the `rust/benches/` targets use:
//! warmup, timed iterations, min/median/mean/max reporting, and throughput
//! annotation. Figure-level benches mostly run *one* deterministic
//! simulation and print table rows; the harness is used for the hot-path
//! perf benches where distributional timing matters.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub max: Duration,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "bench {:<44} iters={:<5} min={:>12?} median={:>12?} mean={:>12?} max={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.max
        );
    }

    /// ns per iteration (median).
    pub fn median_ns(&self) -> f64 {
        self.median.as_nanos() as f64
    }
}

/// Time `f` for at least `min_iters` iterations and ~`target_ms` total.
pub fn bench<R>(name: &str, mut f: impl FnMut() -> R) -> BenchResult {
    bench_config(name, 10, 300, &mut f)
}

pub fn bench_config<R>(
    name: &str,
    min_iters: usize,
    target_ms: u64,
    f: &mut impl FnMut() -> R,
) -> BenchResult {
    // Warmup: one call, also estimates per-iter cost.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let warm = t0.elapsed();

    let budget = Duration::from_millis(target_ms);
    let est_iters = if warm.is_zero() {
        min_iters.max(1000)
    } else {
        ((budget.as_secs_f64() / warm.as_secs_f64()).ceil() as usize).clamp(min_iters, 100_000)
    };

    let mut samples = Vec::with_capacity(est_iters);
    let start = Instant::now();
    for _ in 0..est_iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
        if start.elapsed() > budget * 4 && samples.len() >= min_iters {
            break;
        }
    }
    samples.sort();
    let n = samples.len();
    let mean = samples.iter().sum::<Duration>() / n as u32;
    BenchResult {
        name: name.to_string(),
        iters: n,
        min: samples[0],
        median: samples[n / 2],
        mean,
        max: samples[n - 1],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reports_ordered_stats() {
        let r = bench_config("noop", 10, 5, &mut || 1 + 1);
        assert!(r.iters >= 10);
        assert!(r.min <= r.median);
        assert!(r.median <= r.max);
    }

    #[test]
    fn measures_real_work() {
        let mut acc = 0u64;
        let r = bench_config("sum", 5, 5, &mut || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert!(r.min.as_nanos() > 0);
    }
}
