//! Table formatting shared by the figure benches and the CLI: prints the
//! same rows/series the paper's figures plot, in aligned plain text.

use crate::metrics::{Report, Summary};
use crate::request::{Class, Modality};

/// Print a figure/table header with a rule.
pub fn header(title: &str) {
    println!();
    println!("=== {title} ===");
}

/// One metrics row: label + the standard column set used across figures.
pub fn summary_row(label: &str, s: &Summary) {
    println!(
        "{label:<26} n={:<6} norm_lat={:>9.4} s/tok  ttft_avg={:>8.3} s  ttft_p90={:>8.3} s  \
         slo_viol={:>5.1}%  severity={:>7.2} s",
        s.n,
        s.avg_norm_latency,
        s.avg_ttft,
        s.p90_ttft,
        s.slo_violation_rate * 100.0,
        s.violation_severity
    );
}

/// The paper's per-figure breakdown: Motorcycles / Cars / Trucks / Overall.
pub fn mcto_rows(label: &str, report: &Report) {
    for c in Class::ALL {
        summary_row(&format!("{label} [{}]", c.short()), &report.by_class(c));
    }
    summary_row(&format!("{label} [O]"), &report.overall());
}

/// Per-modality breakdown (motivation figures group by text/image/video).
pub fn modality_rows(label: &str, report: &Report) {
    for m in Modality::ALL {
        summary_row(&format!("{label} [{m}]"), &report.by_modality(m));
    }
    summary_row(&format!("{label} [all]"), &report.overall());
}

/// Preemption row (Fig 11).
pub fn preemption_row(label: &str, s: &Summary) {
    println!(
        "{label:<26} n={:<6} preemptions={:<8} preempted_time={:>9.2} s",
        s.n, s.preemptions, s.preempted_time
    );
}

/// Simple fixed-width CDF print: deciles of a sample (Fig 2).
pub fn cdf_deciles(label: &str, xs: &[f64]) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    print!("{label:<28}");
    for q in [10.0, 25.0, 50.0, 75.0, 90.0, 99.0, 100.0] {
        print!(" p{q:<3}={:<10.3}", crate::util::stats::percentile_sorted(&s, q));
    }
    println!();
}
