//! The serving-backend abstraction: one stepping contract for every
//! engine topology.
//!
//! [`Scheduler`] (one engine) and [`Cluster`] (N replicas, optionally
//! behind the disaggregated encoder pool) grew the same verbs across
//! PR 1–4 — inject, step, advance_to, take_events, take_finished,
//! drop_blocked, drain — but with no shared trait, so the server carried
//! two near-duplicate leader loops and every driver branched on
//! single-vs-cluster at the call site. [`ServeBackend`] captures the
//! contract once:
//!
//! * the **server leader** ([`crate::server::Server::spawn`]) runs one
//!   generic loop over `Box<dyn ServeBackend>`;
//! * **drivers** (`main`, `experiments::run_serve`, benches, examples)
//!   call [`build`] and stop caring which topology the config names;
//! * the **request lifecycle** (cancellation, deadlines) has one surface:
//!   [`ServeBackend::cancel`] works identically against both backends,
//!   and both prove the same conservation invariant
//!   (`finished + failed + cancelled == submitted`).
//!
//! Semantics every implementation must honor:
//!
//! * `step` is re-entrant and deterministic for a fixed injection/cancel
//!   sequence; `advance_to` is monotone.
//! * `take_events` drains the per-iteration [`RequestEvent`]s; every
//!   request emits exactly one terminal event (`Finished` xor `Dropped`
//!   xor `Cancelled`).
//! * `take_finished` retires terminal state into a partial [`Report`];
//!   long-lived callers merge partials so backend memory stays flat.
//! * `cancel` works in any live state and releases KV/encoder resources
//!   at the cancel instant; cancelling an unknown or already-terminal id
//!   returns `false` and changes nothing.
//!
//! # Drain
//!
//! Three verbs hand accumulated state out of a backend, and they are the
//! *only* ways state leaves it — everything else observes without
//! consuming. Each drains an independent buffer, returns everything
//! accumulated since its last call, and leaves that buffer empty:
//!
//! | verb | drains | granularity |
//! |------|--------|-------------|
//! | [`ServeBackend::take_events`] | lifecycle [`RequestEvent`]s | per iteration applied |
//! | [`ServeBackend::take_finished`] | terminal request state, as a partial [`Report`] | per request retired |
//! | [`ServeBackend::take_obs_events`] | obs-only [`crate::obs::ObsEvent`]s | per observer-visible transition |
//!
//! Rules every implementation honors:
//!
//! * Draining never changes scheduling decisions: two runs that differ
//!   only in when (or whether) the drain verbs were called produce the
//!   same iteration-by-iteration behavior.
//! * `take_events` and `take_finished` are always live. `take_obs_events`
//!   returns an empty vec unless the tap was enabled via
//!   [`ServeBackend::set_obs`].
//! * `take_finished` *retires*: the per-request state backing the partial
//!   report is reclaimed, so callers must merge partials themselves
//!   (long-lived servers call it every iteration to keep memory flat).
//! * One exception couples the buffers: while an obs tap is active, the
//!   batch `drain`/`run_trace` paths retain `take_events`'s buffer
//!   instead of clearing it between iterations, so a post-hoc observer
//!   can harvest the full stream after a batch run.

use crate::cluster::Cluster;
use crate::config::ServeConfig;
use crate::coordinator::state::Phase;
use crate::coordinator::{RequestEvent, Scheduler, StepOutcome};
use crate::engine::sim_engine::SimEngine;
use crate::engine::Engine;
use crate::metrics::Report;
use crate::policies::build_policy;
use crate::request::Request;

/// A failed structural-consistency check, typed so callers can match on
/// what broke and where instead of parsing strings. `Display` renders the
/// exact messages the stringly predecessor produced, so log-grepping
/// asserts keep working.
#[derive(Debug, Clone, PartialEq)]
pub enum InvariantViolation {
    /// KV-cache accounting failure (reported by the cache itself).
    Kv(String),
    /// An indexed ready/run-set's internal views disagree about `id`.
    IndexDesync { structure: &'static str, id: u64 },
    /// Request `id` sits in the named scheduler list but its phase says
    /// otherwise (e.g. a `waiting` entry not in [`Phase::Waiting`]).
    PhaseMismatch { list: &'static str, id: u64, phase: Phase },
    /// A cancelled request is still in the ready or running set.
    CancelledStillScheduled { id: u64 },
    /// `stats.cancelled` disagrees with live + retired cancelled counts.
    CancelAccounting { live: usize, retired: usize, counted: u64 },
    /// `stats.dropped` disagrees with live + retired failed counts.
    DropAccounting { live: usize, retired: usize, counted: u64 },
    /// Encoder pool: the rock in-flight counter drifted from a recount.
    RockCounterMismatch { counter: usize, recount: usize },
    /// Encoder pool: more rocks in flight than the configured cap.
    RockCapExceeded { in_flight: usize, cap: usize },
    /// Encoder pool: a busy slot's completion time is behind the clock.
    SlotBehindClock { slot: usize, busy_until: f64, clock: f64 },
    /// Encoder pool: a free slot coexists with waiting pebbles.
    IdleSlotWithPebbles,
    /// Encoder pool: a free slot coexists with an under-cap rock queue.
    IdleSlotWithAdmissibleRock,
    /// A cluster replica's scheduler violated an invariant.
    Replica { index: usize, source: Box<InvariantViolation> },
    /// The cluster's encoder pool violated an invariant.
    Pool(Box<InvariantViolation>),
}

impl std::fmt::Display for InvariantViolation {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            InvariantViolation::Kv(msg) => write!(f, "{msg}"),
            InvariantViolation::IndexDesync { structure, id } => {
                write!(f, "{structure} index desync at id {id}")
            }
            InvariantViolation::PhaseMismatch { list, id, phase } => {
                write!(f, "{list} req {id} in phase {phase:?}")
            }
            InvariantViolation::CancelledStillScheduled { id } => {
                write!(f, "cancelled req {id} still scheduled")
            }
            InvariantViolation::CancelAccounting { live, retired, counted } => write!(
                f,
                "cancel accounting: {live} cancelled + {retired} retired-cancelled \
                 but stats.cancelled={counted}"
            ),
            InvariantViolation::DropAccounting { live, retired, counted } => write!(
                f,
                "drop accounting: {live} failed + {retired} retired-failed outcomes \
                 but stats.dropped={counted}"
            ),
            InvariantViolation::RockCounterMismatch { counter, recount } => {
                write!(f, "rock in-flight counter {counter} != recount {recount}")
            }
            InvariantViolation::RockCapExceeded { in_flight, cap } => {
                write!(f, "rock cap violated: {in_flight} in flight > cap {cap}")
            }
            InvariantViolation::SlotBehindClock { slot, busy_until, clock } => {
                write!(f, "slot {slot} busy_until {busy_until} behind pool clock {clock}")
            }
            InvariantViolation::IdleSlotWithPebbles => {
                write!(f, "free slot while pebbles wait")
            }
            InvariantViolation::IdleSlotWithAdmissibleRock => {
                write!(f, "free slot while an admissible rock waits")
            }
            InvariantViolation::Replica { index, source } => {
                write!(f, "replica {index}: {source}")
            }
            InvariantViolation::Pool(source) => write!(f, "encoder pool: {source}"),
        }
    }
}

impl std::error::Error for InvariantViolation {}

/// The stepping contract shared by [`Scheduler`] and [`Cluster`].
///
/// Not `Send` by design: backends may hold non-Send engines, so the
/// server builds its backend *inside* the leader thread from a Send
/// factory (see [`crate::server::Server::spawn`]).
pub trait ServeBackend {
    /// Topology label ("scheduler" / "cluster") for logs and reports.
    fn name(&self) -> &'static str;

    /// Hand a request over; it becomes schedulable once the backend
    /// clock reaches its arrival (cluster backends route it per their
    /// router/pool configuration).
    fn inject(&mut self, req: Request);

    /// Admit a request whose vision encode already ran elsewhere, ready
    /// at `ready_at`. Single-scheduler backends skip CPU preprocessing
    /// and the local admission encode; the cluster late-binds a decode
    /// replica with an encode-free ledger charge.
    fn inject_preencoded(&mut self, req: Request, ready_at: f64);

    /// Cancel a request in any live state (pending, preprocessing,
    /// pool-queued, waiting, running): resources are released at the
    /// current clock and [`RequestEvent::Cancelled`] is the request's
    /// terminal event. `false` when unknown or already terminal.
    fn cancel(&mut self, id: u64) -> bool;

    /// One scheduling round; see [`StepOutcome`] for the caller's
    /// follow-up obligations.
    fn step(&mut self) -> StepOutcome;

    /// Move the backend clock forward (monotone; never rewinds).
    fn advance_to(&mut self, t: f64);

    /// Drain the request events emitted since the last call.
    fn take_events(&mut self) -> Vec<RequestEvent>;

    /// Retire terminal request state into a partial [`Report`].
    fn take_finished(&mut self) -> Report;

    /// Fail every terminally blocked request (shutdown/drain guard).
    fn drop_blocked(&mut self);

    /// The backend clock (the fleet-wide maximum for clusters).
    fn now(&self) -> f64;

    /// Requests the backend still owes work (non-terminal, including
    /// pending arrivals and pool occupancy).
    fn active_requests(&self) -> usize;

    /// Structural consistency invariants (property tests).
    fn check_invariants(&self) -> Result<(), InvariantViolation>;

    /// Batch driver: run a whole trace to completion with each backend's
    /// arrival-faithful semantics (the cluster advances replicas to each
    /// arrival before routing it, so load-aware routers observe the
    /// fleet as it stood at that moment) and return the merged report,
    /// id-sorted. Terminal state already handed out via `take_finished`
    /// is not re-reported.
    fn run_trace(&mut self, trace: Vec<Request>) -> Report;

    /// Step to completion through the public verbs and return everything
    /// that turned terminal, id-sorted — the drain-to-[`Report`] used by
    /// drivers that injected requests themselves. Events are discarded
    /// (batch semantics); drive [`ServeBackend::step`] directly to
    /// observe them.
    fn drain_report(&mut self) -> Report {
        let mut collected = Report::default();
        loop {
            match self.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => self.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => self.advance_to(t),
                StepOutcome::Blocked { next_event: None } => self.drop_blocked(),
                StepOutcome::Drained => break,
            }
            self.take_events();
            collected.merge(self.take_finished());
        }
        self.take_events();
        collected.merge(self.take_finished());
        collected.sort_by_id();
        collected
    }

    /// Human-readable backend detail for the CLI (per-replica rows, pool
    /// counters, iteration/preemption totals) — what `ClusterReport`
    /// carries structurally, available without downcasting.
    fn summary_lines(&self) -> Vec<String>;

    // --- observability hooks (all optional; see crate::obs) ---

    /// Enable/disable buffering of [`crate::obs::ObsEvent`]s. Off by
    /// default: the disabled path must not allocate or change behavior.
    fn set_obs(&mut self, _enabled: bool) {}

    /// Drain buffered obs-only events (empty when obs is disabled).
    fn take_obs_events(&mut self) -> Vec<crate::obs::ObsEvent> {
        Vec::new()
    }

    /// Sample current backend state for telemetry. `None` when the
    /// backend doesn't support probing.
    fn probe(&self) -> Option<crate::obs::Probe> {
        None
    }

    /// Telemetry aggregate, when an observer is attached
    /// ([`crate::obs::ObsBackend`]); `None` otherwise.
    fn telemetry_snapshot(&self) -> Option<crate::obs::TelemetrySnapshot> {
        None
    }

    /// Perfetto JSON for everything observed so far, when an observer is
    /// attached; `None` otherwise. Drains pending events into the
    /// recorder.
    fn trace_json(&mut self) -> Option<String> {
        None
    }
}

impl ServeBackend for Scheduler {
    fn name(&self) -> &'static str {
        "scheduler"
    }

    fn inject(&mut self, req: Request) {
        Scheduler::inject(self, req);
    }

    fn inject_preencoded(&mut self, req: Request, ready_at: f64) {
        Scheduler::inject_preencoded(self, req, ready_at);
    }

    fn cancel(&mut self, id: u64) -> bool {
        Scheduler::cancel(self, id)
    }

    fn step(&mut self) -> StepOutcome {
        Scheduler::step(self)
    }

    fn advance_to(&mut self, t: f64) {
        Scheduler::advance_to(self, t);
    }

    fn take_events(&mut self) -> Vec<RequestEvent> {
        Scheduler::take_events(self)
    }

    fn take_finished(&mut self) -> Report {
        Scheduler::take_finished(self)
    }

    fn drop_blocked(&mut self) {
        Scheduler::drop_blocked(self);
    }

    fn now(&self) -> f64 {
        Scheduler::now(self)
    }

    fn active_requests(&self) -> usize {
        Scheduler::active_requests(self)
    }

    fn check_invariants(&self) -> Result<(), InvariantViolation> {
        Scheduler::check_invariants(self)
    }

    fn run_trace(&mut self, trace: Vec<Request>) -> Report {
        // inject + drain — proven bit-identical to the monolithic
        // `Scheduler::run` in tests/stepping_api.rs and
        // tests/backend_api.rs (modulo the canonical id sort).
        let mut trace = trace;
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for req in trace {
            Scheduler::inject(self, req);
        }
        self.drain_report()
    }

    fn summary_lines(&self) -> Vec<String> {
        vec![format!(
            "iterations={} preemptions={} dropped={} cancelled={} makespan={:.1}s \
             engine_busy={:.1}s planning={:.1}evals/iter",
            self.stats.iterations,
            self.stats.preemptions,
            self.stats.dropped,
            self.stats.cancelled,
            Scheduler::now(self),
            self.stats.busy_time_s,
            self.stats.planning_evals as f64 / self.stats.iterations.max(1) as f64
        )]
    }

    fn set_obs(&mut self, enabled: bool) {
        Scheduler::set_obs(self, enabled);
    }

    fn take_obs_events(&mut self) -> Vec<crate::obs::ObsEvent> {
        Scheduler::take_obs_events(self)
    }

    fn probe(&self) -> Option<crate::obs::Probe> {
        Some(Scheduler::probe(self))
    }
}

impl ServeBackend for Cluster {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn inject(&mut self, req: Request) {
        Cluster::inject(self, req);
    }

    fn inject_preencoded(&mut self, req: Request, ready_at: f64) {
        Cluster::inject_preencoded(self, req, ready_at);
    }

    fn cancel(&mut self, id: u64) -> bool {
        Cluster::cancel(self, id)
    }

    fn step(&mut self) -> StepOutcome {
        Cluster::step(self)
    }

    fn advance_to(&mut self, t: f64) {
        Cluster::advance_to(self, t);
    }

    fn take_events(&mut self) -> Vec<RequestEvent> {
        Cluster::take_events(self)
    }

    fn take_finished(&mut self) -> Report {
        Cluster::take_finished(self)
    }

    fn drop_blocked(&mut self) {
        Cluster::drop_blocked(self);
    }

    fn now(&self) -> f64 {
        Cluster::now(self)
    }

    fn active_requests(&self) -> usize {
        Cluster::active_requests(self)
    }

    fn check_invariants(&self) -> Result<(), InvariantViolation> {
        Cluster::check_invariants(self)
    }

    fn run_trace(&mut self, trace: Vec<Request>) -> Report {
        // the cluster's batch driver advances every replica to each
        // arrival's timestamp before routing it — load-aware routers
        // must see the fleet as it stood at that moment
        Cluster::run(self, trace).report
    }

    fn summary_lines(&self) -> Vec<String> {
        let makespan = Cluster::now(self);
        let mut lines = Vec::new();
        let mut max_busy = 0.0f64;
        let mut sum_busy = 0.0f64;
        for r in self.replica_stats() {
            max_busy = max_busy.max(r.busy_time_s);
            sum_busy += r.busy_time_s;
            lines.push(format!(
                "replica {:<3} routed={:<6} iterations={:<8} preempt={:<6} \
                 dropped={:<5} cancelled={:<5} busy={:>9.1}s util={:>5.1}%",
                r.replica,
                r.routed,
                r.iterations,
                r.preemptions,
                r.dropped,
                r.cancelled,
                r.busy_time_s,
                if makespan > 0.0 { 100.0 * r.busy_time_s / makespan } else { 0.0 }
            ));
        }
        if let Some(p) = self.pool_snapshot() {
            lines.push(format!(
                "pool: slots={} rock_cap={} encodes={} cancelled={} aged_promotions={} \
                 migrations={} migrated={} tokens ({:.1} MB)",
                p.slots,
                p.rock_cap,
                p.stats.encodes,
                p.stats.cancelled,
                p.stats.aged_promotions,
                p.stats.migrations,
                p.stats.migrated_mm_tokens,
                p.stats.migrated_bytes as f64 / 1e6
            ));
            if p.slot_grow_events > 0 || p.slot_shrink_events > 0 {
                lines.push(format!(
                    "pool resize: grows={} shrinks={} peak_slots={}",
                    p.slot_grow_events, p.slot_shrink_events, p.max_concurrent_slots
                ));
            }
        }
        if let Some(e) = self.elastic_snapshot() {
            lines.push(format!(
                "elastic: epochs={} drains={} repartitions={} slot_grows={} slot_shrinks={} \
                 groups={}/{}/{} (sand/pebble/rock)",
                e.stats.epochs,
                e.stats.drains_started,
                e.stats.repartitions,
                e.stats.slot_grows,
                e.stats.slot_shrinks,
                e.sand.len(),
                e.pebble.len(),
                e.rock.len()
            ));
        }
        let n = self.replica_count().max(1) as f64;
        let mean = sum_busy / n;
        lines.push(format!(
            "makespan={makespan:.1}s imbalance={:.2} (max/mean busy)",
            if mean > 0.0 { max_busy / mean } else { 1.0 }
        ));
        lines
    }

    fn set_obs(&mut self, enabled: bool) {
        Cluster::set_obs(self, enabled);
    }

    fn take_obs_events(&mut self) -> Vec<crate::obs::ObsEvent> {
        Cluster::take_obs_events(self)
    }

    fn probe(&self) -> Option<crate::obs::Probe> {
        Some(Cluster::probe(self))
    }
}

/// Build the backend a config describes — a bare [`Scheduler`] over a
/// simulated engine, or a [`Cluster`] when `cfg.cluster.replicas > 1`,
/// the encoder pool is enabled, or the elastic controller is on. This is
/// the single branch point every driver shares; a 1-replica no-pool
/// config stays on the scheduler path (bit-identical to the pre-trait
/// drivers).
pub fn build(cfg: &ServeConfig) -> Box<dyn ServeBackend> {
    let cluster = cfg.cluster.replicas > 1 || cfg.pool.enabled || cfg.elastic.enabled;
    let inner: Box<dyn ServeBackend> = if cluster {
        Box::new(Cluster::new(cfg))
    } else {
        let profile = crate::model::by_name(&cfg.model).expect("validated model name");
        let policy = build_policy(cfg, &profile);
        let engine: Box<dyn Engine> = Box::new(SimEngine::new(&cfg.engine_profile()));
        Box::new(Scheduler::new(cfg.clone(), policy, engine))
    };
    if cfg.obs.active() {
        Box::new(crate::obs::ObsBackend::new(inner))
    } else {
        inner
    }
}

/// Build a single-scheduler backend over an explicit engine (the real
/// PJRT engine, a test double) — the server's engine-carrying spawn path.
pub fn scheduler_backend(cfg: &ServeConfig, engine: Box<dyn Engine>) -> Box<dyn ServeBackend> {
    let profile = crate::model::by_name(&cfg.model).expect("validated model name");
    let policy = build_policy(cfg, &profile);
    let inner: Box<dyn ServeBackend> = Box::new(Scheduler::new(cfg.clone(), policy, engine));
    if cfg.obs.active() {
        Box::new(crate::obs::ObsBackend::new(inner))
    } else {
        inner
    }
}
