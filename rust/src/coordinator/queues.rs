//! Queue Manager (paper §3.5): three independent queues for trucks, cars
//! and motorcycles, with queue-level load metrics.
//!
//! Classification is decoupled from scheduling: the Queue Manager only
//! tracks membership and waiting statistics; the Priority Regulator
//! decides cross-queue order each iteration (scores are monotone in
//! waiting time within a class, so FCFS-within-queue is preserved by
//! construction).

use crate::request::Class;
use std::collections::VecDeque;

/// Running statistics for one class queue.
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    /// Distinct requests enqueued on readiness (first entry only;
    /// re-enqueues after preemption are counted in `requeued`).
    pub enqueued: u64,
    /// Re-enqueues after preemption-by-recompute.
    pub requeued: u64,
    /// Distinct requests that left the queue (first dequeue only, so a
    /// preempted-and-readmitted request counts once).
    pub dequeued: u64,
    /// Sum of time-in-queue across *all* visits, including post-preemption
    /// requeues (avg_wait = sum / dequeued = average total queueing time
    /// per request).
    pub total_wait: f64,
    /// High-water mark of queue length.
    pub peak_len: usize,
}

impl QueueStats {
    /// Average total time-in-queue per request (all visits summed).
    pub fn avg_wait(&self) -> f64 {
        if self.dequeued == 0 {
            0.0
        } else {
            self.total_wait / self.dequeued as f64
        }
    }
}

/// Entry tracked per queued request.
#[derive(Debug, Clone, Copy)]
struct Entry {
    id: u64,
    enqueue_time: f64,
    /// Re-enqueue after preemption (not a fresh arrival).
    requeue: bool,
}

/// Three class queues (M, C, T) with FCFS order within each.
#[derive(Debug, Default)]
pub struct QueueManager {
    queues: [VecDeque<Entry>; 3],
    stats: [QueueStats; 3],
}

impl QueueManager {
    pub fn new() -> QueueManager {
        QueueManager::default()
    }

    pub fn enqueue(&mut self, class: Class, id: u64, now: f64) {
        let q = &mut self.queues[class as usize];
        q.push_back(Entry { id, enqueue_time: now, requeue: false });
        let s = &mut self.stats[class as usize];
        s.enqueued += 1;
        s.peak_len = s.peak_len.max(q.len());
    }

    /// Re-enqueue a preempted request. Tracked in `requeued` (not
    /// `enqueued`) so preemptions don't inflate arrival counts, while its
    /// renewed waiting time still accrues into `total_wait` at dequeue.
    pub fn requeue(&mut self, class: Class, id: u64, now: f64) {
        let q = &mut self.queues[class as usize];
        q.push_back(Entry { id, enqueue_time: now, requeue: true });
        let s = &mut self.stats[class as usize];
        s.requeued += 1;
        s.peak_len = s.peak_len.max(q.len());
    }

    /// Remove a specific request (admission is score-ordered, so dequeues
    /// are not always from the front). Returns false if not present.
    pub fn dequeue(&mut self, class: Class, id: u64, now: f64) -> bool {
        let q = &mut self.queues[class as usize];
        if let Some(pos) = q.iter().position(|e| e.id == id) {
            let e = q.remove(pos).unwrap();
            let s = &mut self.stats[class as usize];
            if !e.requeue {
                s.dequeued += 1;
            }
            s.total_wait += (now - e.enqueue_time).max(0.0);
            true
        } else {
            false
        }
    }

    pub fn len(&self, class: Class) -> usize {
        self.queues[class as usize].len()
    }

    pub fn total_len(&self) -> usize {
        self.queues.iter().map(|q| q.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.total_len() == 0
    }

    /// Front (oldest) entry of a class queue.
    pub fn front(&self, class: Class) -> Option<u64> {
        self.queues[class as usize].front().map(|e| e.id)
    }

    /// Ids in FCFS order for one class.
    pub fn ids(&self, class: Class) -> impl Iterator<Item = u64> + '_ {
        self.queues[class as usize].iter().map(|e| e.id)
    }

    pub fn stats(&self, class: Class) -> &QueueStats {
        &self.stats[class as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fcfs_within_class() {
        let mut qm = QueueManager::new();
        qm.enqueue(Class::Car, 1, 0.0);
        qm.enqueue(Class::Car, 2, 1.0);
        qm.enqueue(Class::Truck, 3, 0.5);
        assert_eq!(qm.front(Class::Car), Some(1));
        assert_eq!(qm.ids(Class::Car).collect::<Vec<_>>(), vec![1, 2]);
        assert_eq!(qm.front(Class::Truck), Some(3));
        assert_eq!(qm.front(Class::Motorcycle), None);
    }

    #[test]
    fn dequeue_tracks_wait() {
        let mut qm = QueueManager::new();
        qm.enqueue(Class::Motorcycle, 1, 0.0);
        qm.enqueue(Class::Motorcycle, 2, 0.0);
        assert!(qm.dequeue(Class::Motorcycle, 2, 4.0)); // out of order OK
        assert!(qm.dequeue(Class::Motorcycle, 1, 6.0));
        assert!(!qm.dequeue(Class::Motorcycle, 1, 7.0));
        let s = qm.stats(Class::Motorcycle);
        assert_eq!(s.enqueued, 2);
        assert_eq!(s.dequeued, 2);
        assert!((s.avg_wait() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn requeues_tracked_separately_with_total_wait() {
        let mut qm = QueueManager::new();
        qm.enqueue(Class::Car, 1, 0.0);
        assert!(qm.dequeue(Class::Car, 1, 2.0)); // admitted after 2 s
        qm.requeue(Class::Car, 1, 3.0); // preempted, back in queue
        assert!(qm.dequeue(Class::Car, 1, 5.0)); // readmitted after 2 more s
        let s = qm.stats(Class::Car);
        assert_eq!(s.enqueued, 1, "requeue must not count as a fresh enqueue");
        assert_eq!(s.requeued, 1);
        assert_eq!(s.dequeued, 1, "one distinct request left the queue");
        assert!((s.avg_wait() - 4.0).abs() < 1e-12, "total time-in-queue, not last visit");
    }

    #[test]
    fn peak_length_tracked() {
        let mut qm = QueueManager::new();
        for i in 0..5 {
            qm.enqueue(Class::Truck, i, 0.0);
        }
        for i in 0..5 {
            qm.dequeue(Class::Truck, i, 1.0);
        }
        assert_eq!(qm.stats(Class::Truck).peak_len, 5);
        assert!(qm.is_empty());
    }
}
