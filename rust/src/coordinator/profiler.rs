//! Workload Profiler (paper §3.2): offline, per model–modality pair.
//!
//! Executes a representative workload one request at a time (no
//! interference) and records preprocessing time, encoder time, prefill
//! time and KV token counts. The resulting [`ProfileData`] trains the
//! Impact Estimator (§3.3) and the Request Classifier (§3.4).
//!
//! In simulation the "measurement" comes from the model's cost profile
//! plus multiplicative lognormal noise (SimEngine::with_noise), so the
//! estimator genuinely has to fit through scatter, as in the paper's
//! Fig 7.

use crate::engine::sim_engine::SimEngine;
use crate::engine::{EncodeItem, PrefillItem, StepPlan};
use crate::model::ModelProfile;
use crate::request::{Modality, Request};
use crate::workload::{Mix, WorkloadGen};

/// One isolated-request measurement.
#[derive(Debug, Clone)]
pub struct ProfileSample {
    pub modality: Modality,
    /// Prompt tokens entering prefill (text + vision).
    pub prefill_tokens: u32,
    pub preprocess_s: f64,
    pub encode_s: f64,
    pub prefill_s: f64,
    /// Peak KV footprint in tokens (prompt + measured output).
    pub kv_tokens: u32,
}

impl ProfileSample {
    pub fn ttft(&self) -> f64 {
        self.preprocess_s + self.encode_s + self.prefill_s
    }
}

/// Per-model profiling dataset.
#[derive(Debug, Clone, Default)]
pub struct ProfileData {
    pub samples: Vec<ProfileSample>,
}

impl ProfileData {
    pub fn of_modality(&self, m: Modality) -> Vec<&ProfileSample> {
        self.samples.iter().filter(|s| s.modality == m).collect()
    }

    /// Median measured output length (the estimator's KV projection uses
    /// it since TCM-Serve does not predict output lengths).
    pub fn median_output_tokens(&self) -> f64 {
        let outs: Vec<f64> = self
            .samples
            .iter()
            .map(|s| (s.kv_tokens - s.prefill_tokens) as f64)
            .collect();
        crate::util::stats::median(&outs)
    }
}

/// Offline profiler: runs `n_per_modality` isolated requests per modality
/// through a noisy SimEngine instance of the target model.
pub struct Profiler {
    pub profile: ModelProfile,
    pub noise_sigma: f64,
    pub seed: u64,
}

impl Profiler {
    pub fn new(profile: &ModelProfile, seed: u64) -> Profiler {
        Profiler { profile: profile.clone(), noise_sigma: 0.06, seed }
    }

    pub fn run(&self, n_per_modality: usize) -> ProfileData {
        let mut engine = SimEngine::with_noise(&self.profile, self.noise_sigma, self.seed);
        // Profiling uses the heavy mix's marginals so every modality's
        // token range is covered (the generator is per-modality anyway).
        let mut gen =
            WorkloadGen::new(&self.profile, Mix { name: "prof", text: 1.0, image: 1.0, video: 1.0 },
                             1.0, self.seed ^ 0xBEEF);
        let mut data = ProfileData::default();
        for modality in Modality::ALL {
            for req in gen.generate_isolated(modality, n_per_modality) {
                data.samples.push(self.measure(&mut engine, &req));
            }
        }
        data
    }

    /// Measure one request in isolation (preprocess + encode + whole-prompt
    /// prefill; output length measured by running decode to completion is
    /// equivalent to reading the ground truth, so we read it directly).
    fn measure(&self, engine: &mut SimEngine, req: &Request) -> ProfileSample {
        let preprocess_s = self.profile.preprocess_time(req);
        let plan = StepPlan {
            encodes: if req.mm_tokens > 0 {
                vec![EncodeItem {
                    req_id: req.id,
                    modality: req.modality,
                    mm_tokens: req.mm_tokens,
                    video_duration_s: req.video_duration_s,
                }]
            } else {
                vec![]
            },
            prefills: vec![PrefillItem {
                req_id: req.id,
                ctx_before: 0,
                chunk_tokens: req.prefill_tokens(),
                last_chunk: true,
                text_tokens: req.text_tokens,
                mm_tokens: req.mm_tokens,
                prefill_total: req.prefill_tokens(),
            }],
            decodes: vec![],
        };
        let (encode_s, prefill_s, _) = engine.plan_cost(&plan);
        ProfileSample {
            modality: req.modality,
            prefill_tokens: req.prefill_tokens(),
            preprocess_s,
            encode_s,
            prefill_s,
            kv_tokens: req.peak_kv_tokens(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::util::stats;

    fn data() -> ProfileData {
        Profiler::new(&by_name("llava-7b").unwrap(), 1).run(200)
    }

    #[test]
    fn covers_all_modalities() {
        let d = data();
        for m in Modality::ALL {
            assert_eq!(d.of_modality(m).len(), 200);
        }
    }

    #[test]
    fn text_has_no_vision_stages() {
        let d = data();
        for s in d.of_modality(Modality::Text) {
            assert_eq!(s.preprocess_s, 0.0);
            assert_eq!(s.encode_s, 0.0);
            assert!(s.prefill_s > 0.0);
        }
    }

    #[test]
    fn video_ttft_dominates_image_dominates_text() {
        let d = data();
        let med = |m: Modality| {
            stats::median(&d.of_modality(m).iter().map(|s| s.ttft()).collect::<Vec<_>>())
        };
        assert!(med(Modality::Text) < med(Modality::Image));
        assert!(med(Modality::Image) < med(Modality::Video));
    }

    #[test]
    fn noise_produces_scatter() {
        let d = data();
        // same token count should not always produce the same prefill time
        let imgs = d.of_modality(Modality::Image);
        let times: Vec<f64> = imgs.iter().map(|s| s.prefill_s).collect();
        assert!(stats::std_dev(&times) > 0.0);
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Profiler::new(&by_name("llava-7b").unwrap(), 9).run(50);
        let b = Profiler::new(&by_name("llava-7b").unwrap(), 9).run(50);
        for (x, y) in a.samples.iter().zip(&b.samples) {
            assert_eq!(x.prefill_s, y.prefill_s);
        }
    }

    #[test]
    fn median_output_positive() {
        assert!(data().median_output_tokens() > 0.0);
    }
}
