//! Impact Estimator (paper §3.3): predicts each incoming request's
//! *temporal* impact (prefill latency) and *spatial* impact (KV-cache
//! footprint in tokens) before it is scheduled.
//!
//! Model- and modality-specific estimators, trained once at system
//! initialization from Workload Profiler data:
//! * text — ordinary linear regression of prefill time on prompt tokens
//!   (prefill "scales predictably with prompt length");
//! * image / video — quantile regression at the 90th percentile "to avoid
//!   underestimation and protect SLO compliance".
//!
//! The KV projection adds the profile's median output length to the known
//! prompt token count (TCM-Serve deliberately avoids output-length
//! *prediction models*, §4.1).

use super::profiler::ProfileData;
use crate::request::{Modality, Request};
use crate::util::stats::{LinearFit, QuantileFit};

/// Impact estimate for one request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Impact {
    /// Predicted prefill latency (seconds). Includes encode for
    /// multimodal requests — both run on the GPU ahead of the first token.
    pub prefill_s: f64,
    /// Projected peak KV footprint (tokens).
    pub kv_tokens: f64,
}

/// Trained estimator for one model.
#[derive(Debug, Clone)]
pub struct ImpactEstimator {
    text_fit: LinearFit,
    image_fit: QuantileFit,
    video_fit: QuantileFit,
    median_output: f64,
}

impl ImpactEstimator {
    /// Fit from profiling data. Requires at least 2 samples per modality.
    pub fn train(data: &ProfileData) -> ImpactEstimator {
        let xy = |m: Modality| -> (Vec<f64>, Vec<f64>) {
            let ss = data.of_modality(m);
            (
                ss.iter().map(|s| s.prefill_tokens as f64).collect(),
                // GPU-side pre-first-token time: encode + prefill.
                ss.iter().map(|s| s.encode_s + s.prefill_s).collect(),
            )
        };
        let (tx, ty) = xy(Modality::Text);
        let (ix, iy) = xy(Modality::Image);
        let (vx, vy) = xy(Modality::Video);
        ImpactEstimator {
            text_fit: LinearFit::fit(&tx, &ty),
            image_fit: QuantileFit::fit(&ix, &iy, 0.9),
            video_fit: QuantileFit::fit(&vx, &vy, 0.9),
            median_output: data.median_output_tokens(),
        }
    }

    /// Predict the impact of a request from its metadata.
    pub fn estimate(&self, req: &Request) -> Impact {
        let tokens = req.prefill_tokens() as f64;
        let prefill_s = match req.modality {
            Modality::Text => self.text_fit.predict(tokens),
            Modality::Image => self.image_fit.predict(tokens),
            Modality::Video => self.video_fit.predict(tokens),
        }
        .max(1e-6);
        Impact { prefill_s, kv_tokens: tokens + self.median_output }
    }

    /// Predict the impact of a request whose vision encode already ran
    /// elsewhere (encoder-pool handoff): the replica owes LLM prefill
    /// only, no encoder time. LLM prefill cost scales with prompt-token
    /// count regardless of where the tokens came from, so the text fit —
    /// trained on encode-free samples — is the right model for any
    /// pre-encoded prompt.
    pub fn estimate_preencoded(&self, req: &Request) -> Impact {
        let tokens = req.prefill_tokens() as f64;
        Impact {
            prefill_s: self.text_fit.predict(tokens).max(1e-6),
            kv_tokens: tokens + self.median_output,
        }
    }

    /// Mean absolute prediction error per modality on a dataset (Fig 7).
    pub fn mae(&self, data: &ProfileData, m: Modality) -> f64 {
        let ss = data.of_modality(m);
        if ss.is_empty() {
            return 0.0;
        }
        ss.iter()
            .map(|s| {
                let pred = match m {
                    Modality::Text => self.text_fit.predict(s.prefill_tokens as f64),
                    Modality::Image => self.image_fit.predict(s.prefill_tokens as f64),
                    Modality::Video => self.video_fit.predict(s.prefill_tokens as f64),
                };
                (pred - (s.encode_s + s.prefill_s)).abs()
            })
            .sum::<f64>()
            / ss.len() as f64
    }

    pub fn median_output(&self) -> f64 {
        self.median_output
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::profiler::Profiler;
    use crate::model::by_name;

    fn trained() -> (ImpactEstimator, ProfileData) {
        let prof = Profiler::new(&by_name("llava-7b").unwrap(), 3);
        let data = prof.run(300);
        (ImpactEstimator::train(&data), data)
    }

    fn req(m: Modality, text: u32, mm: u32, dur: f64) -> Request {
        Request {
            id: 0,
            arrival: 0.0,
            modality: m,
            text_tokens: text,
            mm_tokens: mm,
            video_duration_s: dur,
            output_tokens: 100,
            ..Request::default()
        }
    }

    #[test]
    fn errors_small_relative_to_scale() {
        // Fig 7: "prediction errors remain within a few milliseconds even
        // for visual-heavy requests whose TTFT spans seconds"
        let (est, data) = trained();
        let p = by_name("llava-7b").unwrap();
        assert!(est.mae(&data, Modality::Text) < 0.05);
        assert!(est.mae(&data, Modality::Image) < 0.1);
        let vid_scale = p.prefill_time(6272);
        assert!(est.mae(&data, Modality::Video) < 0.35 * vid_scale.max(1.0));
    }

    #[test]
    fn quantile_fits_overestimate_on_average() {
        // P90 target: most actual latencies sit below the prediction.
        let (est, data) = trained();
        for m in [Modality::Image, Modality::Video] {
            let ss = data.of_modality(m);
            let below = ss
                .iter()
                .filter(|s| {
                    est.estimate(&req(m, 0, s.prefill_tokens, 60.0)).prefill_s
                        >= s.encode_s + s.prefill_s
                })
                .count();
            let frac = below as f64 / ss.len() as f64;
            assert!(frac > 0.75, "{m}: only {frac} below P90 line");
        }
    }

    #[test]
    fn video_estimate_dominates_image_dominates_text() {
        let (est, _) = trained();
        let p = by_name("llava-7b").unwrap();
        let t = est.estimate(&req(Modality::Text, 100, 0, 0.0));
        let i = est.estimate(&req(Modality::Image, 40, p.tokenizer.image_tokens as u32, 0.0));
        let v = est.estimate(&req(
            Modality::Video,
            40,
            p.tokenizer.video_tokens(120.0),
            120.0,
        ));
        assert!(t.prefill_s < i.prefill_s);
        assert!(i.prefill_s < v.prefill_s);
        assert!(t.kv_tokens < i.kv_tokens);
        assert!(i.kv_tokens < v.kv_tokens);
    }

    #[test]
    fn kv_projection_adds_median_output() {
        let (est, _) = trained();
        let r = req(Modality::Text, 500, 0, 0.0);
        let imp = est.estimate(&r);
        assert!((imp.kv_tokens - 500.0 - est.median_output()).abs() < 1e-9);
        assert!(est.median_output() > 0.0);
    }

    #[test]
    fn estimates_are_positive() {
        let (est, _) = trained();
        let imp = est.estimate(&req(Modality::Text, 1, 0, 0.0));
        assert!(imp.prefill_s > 0.0);
    }
}
