//! Request Classifier (paper §3.4): maps requests to trucks, cars and
//! motorcycles.
//!
//! Two implementations, matching the paper's ablation:
//! * [`NaiveClassifier`] — coarse modality labels (text→M, image→C,
//!   video→T). Simple but wrong at the margins: long text prompts match
//!   image demands, short videos resemble images, and it penalizes all
//!   videos regardless of size (Fig 8).
//! * [`SmartClassifier`] — k-means (k=3) over resource-aware features
//!   from the Impact Estimator: (log prefill latency, log KV tokens).
//!   Clusters are ordered by centroid magnitude so the lightest cluster
//!   is always the motorcycle class, regardless of seed.

use super::estimator::{Impact, ImpactEstimator};
use super::profiler::ProfileData;
use crate::request::{Class, Modality, Request};
use crate::util::stats::KMeans;

/// A classifier assigns a class given the request and its impact estimate.
pub trait Classifier {
    fn classify(&self, req: &Request, impact: &Impact) -> Class;
    fn name(&self) -> &'static str;
}

/// Modality-label classifier (ablation baseline).
#[derive(Debug, Default, Clone)]
pub struct NaiveClassifier;

impl Classifier for NaiveClassifier {
    fn classify(&self, req: &Request, _impact: &Impact) -> Class {
        match req.modality {
            Modality::Text => Class::Motorcycle,
            Modality::Image => Class::Car,
            Modality::Video => Class::Truck,
        }
    }

    fn name(&self) -> &'static str {
        "naive"
    }
}

/// Resource-aware clustering classifier (the paper's smart classifier).
#[derive(Debug, Clone)]
pub struct SmartClassifier {
    kmeans: KMeans,
    /// cluster index -> class, ordered by centroid resource magnitude.
    cluster_class: Vec<Class>,
}

fn features(impact: &Impact) -> Vec<f64> {
    // log-space features: the paper's orders-of-magnitude spreads make
    // linear-space k-means collapse everything but the largest videos.
    vec![impact.prefill_s.max(1e-6).log10(), impact.kv_tokens.max(1.0).log10()]
}

impl SmartClassifier {
    /// Train on profiling data through a trained estimator (so training
    /// and runtime features come from the same pipeline).
    pub fn train(data: &ProfileData, estimator: &ImpactEstimator, seed: u64) -> SmartClassifier {
        let pts: Vec<Vec<f64>> = data
            .samples
            .iter()
            .map(|s| {
                // Rebuild the estimator's runtime features for the sample.
                let req = Request {
                    id: 0,
                    arrival: 0.0,
                    modality: s.modality,
                    text_tokens: if s.modality == Modality::Text { s.prefill_tokens } else { 0 },
                    mm_tokens: if s.modality == Modality::Text { 0 } else { s.prefill_tokens },
                    video_duration_s: 0.0,
                    output_tokens: 0,
                    ..Request::default()
                };
                features(&estimator.estimate(&req))
            })
            .collect();
        let kmeans = KMeans::fit(&pts, 3, seed);
        let norms = kmeans.centroid_norms();
        // Order clusters by magnitude: smallest -> Motorcycle, ... but
        // note log features can be negative; order by the *kv* coordinate
        // + latency coordinate sum instead of the norm to keep monotone
        // ordering in log space.
        let scores: Vec<f64> = kmeans.centroids.iter().map(|c| c.iter().sum()).collect();
        let mut order: Vec<usize> = (0..kmeans.centroids.len()).collect();
        order.sort_by(|&a, &b| scores[a].total_cmp(&scores[b]));
        let mut cluster_class = vec![Class::Truck; kmeans.centroids.len()];
        for (rank, &cluster) in order.iter().enumerate() {
            cluster_class[cluster] = Class::from_index(rank.min(2));
        }
        let _ = norms;
        SmartClassifier { kmeans, cluster_class }
    }

    pub fn centroids(&self) -> &[Vec<f64>] {
        &self.kmeans.centroids
    }
}

impl Classifier for SmartClassifier {
    fn classify(&self, _req: &Request, impact: &Impact) -> Class {
        self.cluster_class[self.kmeans.assign(&features(impact))]
    }

    fn name(&self) -> &'static str {
        "smart"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::estimator::ImpactEstimator;
    use crate::coordinator::profiler::Profiler;
    use crate::model::by_name;

    fn pipeline() -> (ImpactEstimator, SmartClassifier) {
        let data = Profiler::new(&by_name("llava-7b").unwrap(), 5).run(300);
        let est = ImpactEstimator::train(&data);
        let cls = SmartClassifier::train(&data, &est, 42);
        (est, cls)
    }

    fn req(m: Modality, text: u32, mm: u32, dur: f64) -> Request {
        Request {
            id: 0,
            arrival: 0.0,
            modality: m,
            text_tokens: text,
            mm_tokens: mm,
            video_duration_s: dur,
            output_tokens: 100,
            ..Request::default()
        }
    }

    #[test]
    fn naive_maps_by_modality() {
        let c = NaiveClassifier;
        let i = Impact { prefill_s: 1.0, kv_tokens: 10.0 };
        assert_eq!(c.classify(&req(Modality::Text, 9000, 0, 0.0), &i), Class::Motorcycle);
        assert_eq!(c.classify(&req(Modality::Image, 10, 729, 0.0), &i), Class::Car);
        assert_eq!(c.classify(&req(Modality::Video, 10, 400, 2.0), &i), Class::Truck);
    }

    #[test]
    fn smart_typical_requests_follow_modality() {
        let (est, cls) = pipeline();
        let p = by_name("llava-7b").unwrap();
        let t = req(Modality::Text, 80, 0, 0.0);
        let i = req(Modality::Image, 40, p.tokenizer.image_tokens as u32, 0.0);
        let v = req(Modality::Video, 40, p.tokenizer.video_tokens(120.0), 120.0);
        assert_eq!(cls.classify(&t, &est.estimate(&t)), Class::Motorcycle);
        assert_eq!(cls.classify(&i, &est.estimate(&i)), Class::Car);
        assert_eq!(cls.classify(&v, &est.estimate(&v)), Class::Truck);
    }

    #[test]
    fn smart_long_text_is_not_motorcycle() {
        // the naive classifier's blind spot: a 10^4-token text prompt has
        // image-class resource demands
        let (est, cls) = pipeline();
        let long = req(Modality::Text, 10_000, 0, 0.0);
        assert_ne!(cls.classify(&long, &est.estimate(&long)), Class::Motorcycle);
    }

    #[test]
    fn smart_short_video_is_not_truck() {
        // a 5-second LLaVA video = 5 frames x 196 tokens ≈ image weight
        let (est, cls) = pipeline();
        let p = by_name("llava-7b").unwrap();
        let short = req(Modality::Video, 20, p.tokenizer.video_tokens(5.0), 5.0);
        assert_ne!(cls.classify(&short, &est.estimate(&short)), Class::Truck);
    }

    #[test]
    fn classes_monotone_in_resource_magnitude() {
        let (_, cls) = pipeline();
        // synthetic impacts spanning the spectrum must be non-decreasing
        let impacts = [
            Impact { prefill_s: 0.01, kv_tokens: 100.0 },
            Impact { prefill_s: 0.3, kv_tokens: 900.0 },
            Impact { prefill_s: 5.0, kv_tokens: 60_000.0 },
        ];
        let dummy = req(Modality::Text, 1, 0, 0.0);
        let classes: Vec<Class> = impacts.iter().map(|i| cls.classify(&dummy, i)).collect();
        assert_eq!(classes[0], Class::Motorcycle);
        assert_eq!(classes[2], Class::Truck);
        assert!(classes[0] <= classes[1] && classes[1] <= classes[2]);
    }

    #[test]
    fn all_three_classes_reachable() {
        let (est, cls) = pipeline();
        let p = by_name("llava-7b").unwrap();
        let mut seen = std::collections::BTreeSet::new();
        let mut gen = crate::workload::WorkloadGen::new(&p, crate::workload::MIX_MH, 2.0, 3);
        for r in gen.generate(2000) {
            seen.insert(cls.classify(&r, &est.estimate(&r)));
        }
        assert_eq!(seen.len(), 3);
    }
}
