//! Priority Regulator (paper §3.6): dynamic priorities with aging.
//!
//! `Priority_c(w) = StaticPriority_c + (1 − e^{−k_c · w^{p_c}})` where `w`
//! is the request's waiting time and `c` its class. The score used for
//! ordering is `Score_c = −log(Priority_c)` — higher priority, lower
//! score, earlier scheduling (as in vLLM's priority scheduler).
//!
//! With the paper's constants, motorcycle priority rises almost
//! immediately (k=0.05, p=3.5), cars after moderate waits (k=0.003,
//! p=2.5) and trucks only after long waits (k=0.00075, p=1.1) — Fig 9.
//!
//! A property the indexed scheduler depends on
//! ([`crate::policies::Policy::rank_key`]): for a fixed class, `priority`
//! is non-decreasing and `score` non-increasing in the waiting time `w`
//! (`k, p ≥ 0`, so `e^{−k·wᵖ}` only falls as `w` grows). Equivalently, at
//! any instant, requests of one class score in `first_enqueue` order —
//! aging can reorder *classes* against each other but never two requests
//! *within* a class. Score plateaus (aging disabled, the `max(1e-9)`
//! clamp, exp saturation) are broken by the scheduler's `ready_time`
//! tie-break, which equals `first_enqueue`, so the within-class order
//! stays total and time-invariant.

use crate::config::RegulatorConfig;
use crate::request::Class;

/// Stateless scorer around the regulator constants.
#[derive(Debug, Clone)]
pub struct PriorityRegulator {
    cfg: RegulatorConfig,
}

impl PriorityRegulator {
    pub fn new(cfg: RegulatorConfig) -> PriorityRegulator {
        PriorityRegulator { cfg }
    }

    /// Priority of class `c` after waiting `wait` seconds (Fig 9a).
    pub fn priority(&self, c: Class, wait: f64) -> f64 {
        let w = wait.max(0.0);
        let stat = self.cfg.static_for(c);
        if !self.cfg.aging_enabled {
            // Static-priority ablation: constant per class; epsilon keeps
            // the -log finite for trucks (static 0).
            return stat.max(1e-9);
        }
        let age = 1.0 - (-self.cfg.k_for(c) * w.powf(self.cfg.p_for(c))).exp();
        (stat + age).max(1e-9)
    }

    /// Scheduling score (Fig 9b): lower runs earlier.
    pub fn score(&self, c: Class, wait: f64) -> f64 {
        -self.priority(c, wait).ln()
    }

    pub fn config(&self) -> &RegulatorConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> PriorityRegulator {
        PriorityRegulator::new(RegulatorConfig::default())
    }

    #[test]
    fn zero_wait_orders_by_static_priority() {
        let r = reg();
        let m = r.priority(Class::Motorcycle, 0.0);
        let c = r.priority(Class::Car, 0.0);
        let t = r.priority(Class::Truck, 0.0);
        assert!(m > c && c > t);
        assert!(r.score(Class::Motorcycle, 0.0) < r.score(Class::Car, 0.0));
        assert!(r.score(Class::Car, 0.0) < r.score(Class::Truck, 0.0));
    }

    #[test]
    fn priority_monotone_in_wait() {
        let r = reg();
        for c in Class::ALL {
            let mut prev = r.priority(c, 0.0);
            for w in [0.5, 1.0, 2.0, 5.0, 10.0, 30.0, 100.0, 500.0] {
                let p = r.priority(c, w);
                assert!(p >= prev, "{c}: priority not monotone at {w}");
                prev = p;
            }
        }
    }

    #[test]
    fn priority_bounded() {
        let r = reg();
        for c in Class::ALL {
            for w in [0.0, 1.0, 1e3, 1e6] {
                let p = r.priority(c, w);
                assert!(p > 0.0 && p <= 1.1 + 1e-9, "{c} at {w}: {p}");
                assert!(r.score(c, w).is_finite());
            }
        }
    }

    #[test]
    fn motorcycles_age_fastest_fig9() {
        // Fig 9a: motorcycles gain priority rapidly, cars gradually,
        // trucks very slowly.
        let r = reg();
        let gain = |c: Class, w: f64| r.priority(c, w) - r.priority(c, 0.0);
        assert!(gain(Class::Motorcycle, 3.0) > 0.5, "{}", gain(Class::Motorcycle, 3.0));
        assert!(gain(Class::Car, 3.0) < 0.2);
        assert!(gain(Class::Truck, 3.0) < 0.01);
        // trucks do eventually make progress (no starvation)
        assert!(gain(Class::Truck, 600.0) > 0.3, "{}", gain(Class::Truck, 600.0));
    }

    #[test]
    fn waited_truck_beats_fresh_motorcycle_eventually() {
        // the anti-starvation property: an old truck outranks a fresh
        // motorcycle once its age term dominates the static gap
        let r = reg();
        let fresh_m = r.score(Class::Motorcycle, 0.0);
        assert!(r.score(Class::Truck, 0.0) > fresh_m);
        assert!(r.score(Class::Truck, 3000.0) < fresh_m);
    }

    #[test]
    fn static_ablation_ignores_wait() {
        let mut cfg = RegulatorConfig::default();
        cfg.aging_enabled = false;
        let r = PriorityRegulator::new(cfg);
        assert_eq!(r.priority(Class::Car, 0.0), r.priority(Class::Car, 1e4));
        // ordering still static
        assert!(r.score(Class::Motorcycle, 0.0) < r.score(Class::Truck, 1e6));
    }

    #[test]
    fn negative_wait_clamped() {
        let r = reg();
        assert_eq!(r.priority(Class::Car, -5.0), r.priority(Class::Car, 0.0));
    }
}
