//! Per-request lifecycle state tracked by the scheduler.

use crate::coordinator::estimator::Impact;
use crate::request::{Class, Request};

/// Lifecycle phase of a request inside the coordinator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// CPU preprocessing (image decode / frame extraction) in flight.
    Preprocessing,
    /// Ready and queued, not yet admitted (or re-queued after preemption).
    Waiting,
    /// Admitted; prefill chunks in progress.
    Prefilling,
    /// Prompt fully cached; decoding one token per iteration.
    Decoding,
    Finished,
    /// Dropped without completing (prompt can never fit, or terminally
    /// blocked at drain). Surfaced as a failed outcome, never silent.
    Dropped,
    /// Cancelled by the client ([`crate::coordinator::Scheduler::cancel`])
    /// from any live state; KV and engine resources were released at the
    /// cancel instant. Surfaced as a cancelled outcome.
    Cancelled,
}

/// Scheduler-side request state.
#[derive(Debug, Clone)]
pub struct ReqState {
    pub req: Request,
    pub phase: Phase,
    /// Class from the active policy's classifier (None for baselines).
    pub class: Option<Class>,
    /// Impact estimate (None for baselines without estimators).
    pub impact: Option<Impact>,
    /// End-to-end latency SLO (seconds), = slo_scale × isolated E2E.
    pub slo_latency: f64,
    /// When CPU preprocessing finished and the request became schedulable.
    /// Set once, in `mark_ready`, and always equal to [`first_enqueue`](
    /// Self::first_enqueue) — they are kept as separate fields because
    /// they answer different questions (tie-breaking vs aging), but the
    /// indexed planner's rank contract ([`crate::policies::Policy::rank_key`])
    /// relies on their equality: a score plateau falling through to the
    /// `ready_time` tie-break must agree with a `first_enqueue` rank.
    pub ready_time: f64,
    /// First time the request entered the waiting queue (aging baseline).
    /// Set once, in `mark_ready`, alongside `ready_time`; preemption
    /// re-queues deliberately do NOT update it (aging credit survives).
    pub first_enqueue: f64,
    /// Vision encode has run. Cleared on preemption-by-recompute (the
    /// recompute path rebuilds everything, encoder output included).
    pub encoded: bool,
    /// The current encode was produced outside this scheduler (encoder
    /// pool handoff): prefill charges no local encoder work. Cleared on
    /// preemption-by-recompute — the re-encode happens locally.
    pub encoded_externally: bool,
    /// KV rows currently cached for this request: prefill chunks plus one
    /// row per decode step. Resets to 0 on preemption-by-recompute.
    pub cached_rows: u32,
    /// Output tokens emitted (the first token counts).
    pub decoded: u32,
    pub first_token: Option<f64>,
    pub finish: Option<f64>,
    pub preemptions: u32,
    pub preempted_at: Option<f64>,
    pub preempted_time: f64,
}

impl ReqState {
    pub fn new(req: Request, slo_latency: f64) -> ReqState {
        ReqState {
            req,
            phase: Phase::Preprocessing,
            class: None,
            impact: None,
            slo_latency,
            ready_time: 0.0,
            first_enqueue: 0.0,
            encoded: false,
            encoded_externally: false,
            cached_rows: 0,
            decoded: 0,
            first_token: None,
            finish: None,
            preemptions: 0,
            preempted_at: None,
            preempted_time: 0.0,
        }
    }

    /// Age since the request first became schedulable (the regulator's
    /// waiting time `w`).
    #[inline]
    pub fn waiting_time(&self, now: f64) -> f64 {
        (now - self.first_enqueue).max(0.0)
    }

    /// Total prefill target in KV rows: the prompt, plus — after a
    /// preemption-by-recompute — the already-emitted tokens except the
    /// newest one (which becomes the next decode input, exactly as in
    /// vLLM's recompute path).
    #[inline]
    pub fn prefill_target(&self) -> u32 {
        self.req.prefill_tokens() + self.decoded.saturating_sub(1)
    }

    /// Remaining prefill rows to (re)build.
    #[inline]
    pub fn prefill_remaining(&self) -> u32 {
        self.prefill_target().saturating_sub(self.cached_rows)
    }

    /// KV rows needed for the next decode step (writes one new row).
    #[inline]
    pub fn kv_for_next_decode(&self) -> u32 {
        self.cached_rows + 1
    }

    /// EDF's absolute deadline.
    #[inline]
    pub fn deadline(&self) -> f64 {
        self.req.arrival + self.slo_latency
    }

    pub fn to_outcome(&self) -> crate::metrics::Outcome {
        crate::metrics::Outcome {
            id: self.req.id,
            modality: self.req.modality,
            class: self.class,
            arrival: self.req.arrival,
            first_token: self.first_token.expect("finished request lacks first token"),
            finish: self.finish.expect("unfinished request"),
            output_tokens: self.req.output_tokens,
            slo_latency: self.slo_latency,
            preemptions: self.preemptions,
            preempted_time: self.preempted_time,
            slo_class: self.req.slo_class,
        }
    }

    /// Outcome record for a cancelled request (`finish` holds the cancel
    /// time).
    pub fn to_cancelled_outcome(&self) -> crate::metrics::CancelledOutcome {
        crate::metrics::CancelledOutcome {
            id: self.req.id,
            modality: self.req.modality,
            class: self.class,
            arrival: self.req.arrival,
            cancelled_at: self.finish.unwrap_or(self.req.arrival),
        }
    }

    /// Outcome record for a dropped request (`finish` holds the drop
    /// time; there may be no first token).
    pub fn to_failed_outcome(&self) -> crate::metrics::FailedOutcome {
        crate::metrics::FailedOutcome {
            id: self.req.id,
            modality: self.req.modality,
            class: self.class,
            arrival: self.req.arrival,
            dropped_at: self.finish.unwrap_or(self.req.arrival),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Modality;

    fn state() -> ReqState {
        ReqState::new(
            Request {
                id: 1,
                arrival: 2.0,
                modality: Modality::Image,
                text_tokens: 40,
                mm_tokens: 729,
                video_duration_s: 0.0,
                output_tokens: 50,
                ..Request::default()
            },
            10.0,
        )
    }

    #[test]
    fn prefill_accounting() {
        let mut s = state();
        assert_eq!(s.prefill_target(), 769);
        s.cached_rows = 500;
        assert_eq!(s.prefill_remaining(), 269);
        // decode path: after prefill completes and the first token is out
        s.cached_rows = 769;
        s.decoded = 1;
        assert_eq!(s.kv_for_next_decode(), 770);
        // three more decode steps write three rows
        s.cached_rows = 772;
        s.decoded = 4;
        assert_eq!(s.kv_for_next_decode(), 773);
        // preempted: rebuild prompt + decoded-1 rows
        s.cached_rows = 0;
        assert_eq!(s.prefill_target(), 772);
        assert_eq!(s.prefill_remaining(), 772);
    }

    #[test]
    fn deadline_is_arrival_plus_slo() {
        assert_eq!(state().deadline(), 12.0);
    }

    #[test]
    fn waiting_time_clamped() {
        let mut s = state();
        s.first_enqueue = 5.0;
        assert_eq!(s.waiting_time(9.0), 4.0);
        assert_eq!(s.waiting_time(3.0), 0.0);
    }
}
