//! The TCM-Serve coordinator: the paper's system contribution (§3).
//!
//! Components map one-to-one to Fig 5:
//! * [`profiler`] — Workload Profiler (§3.2, offline)
//! * [`estimator`] — Impact Estimator (§3.3)
//! * [`classifier`] — Request Classifier (§3.4)
//! * [`readyset`] — Queue Manager (§3.5): indexed ready/run sets
//! * [`priority`] — Priority Regulator (§3.6)
//! * [`scheduler`] — the continuous-batching core that ties them to an
//!   execution engine (shared with all baseline policies)
//! * [`state`] — per-request lifecycle bookkeeping

pub mod classifier;
pub mod estimator;
pub mod priority;
pub mod profiler;
pub mod readyset;
pub mod scheduler;
pub mod state;

pub use scheduler::{RequestEvent, SchedStats, Scheduler, StepOutcome};
