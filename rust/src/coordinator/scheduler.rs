//! The continuous-batching scheduler: one vLLM-V1-style iteration loop
//! shared by every policy (TCM-Serve and all baselines) and both engines
//! (simulated and real).
//!
//! Each iteration (paper §3.1):
//! 1. ingest arrivals → CPU preprocess pool → ready queue (classified by
//!    the policy on readiness);
//! 2. plan under a token budget: running decodes first, then ongoing
//!    prefill chunks, then admissions in policy order (chunked prefill);
//! 3. reserve KV blocks per item; on exhaustion preempt-by-recompute the
//!    max-key (lowest-priority) running request — admission preemption is
//!    policy-gated, decode-growth preemption always applies (vLLM
//!    semantics);
//! 4. execute the plan on the engine; advance time; emit tokens
//!    (prefill-completing iterations emit the first token → TTFT).
//!
//! # Indexed planning (`scheduler.indexed`)
//!
//! Admission planning has two interchangeable implementations, proven
//! bit-identical on events, reports and stats (minus `planning_evals`)
//! by `tests/scheduler_properties.rs`:
//!
//! * **indexed** (default): waiting requests live pre-sorted in the
//!   [`ReadySet`] rank index, one stream per time-invariant *family*
//!   (see [`Policy::rank_key`]); the planner lazily merges the family
//!   heads with the (≤ `max_running`) ongoing-prefill stream, paying one
//!   key evaluation per visited head instead of one per waiting request.
//!   Per-iteration planning cost is bounded by the running set and the
//!   work actually admitted — near-constant in queue depth.
//! * **full rescore** (`scheduler.indexed = false`): the original oracle
//!   — snapshot every waiting id, evaluate every key, sort. O(n log n)
//!   per iteration; kept as the escape hatch and equivalence oracle.
//!
//! # Stepping API (online serving)
//!
//! The loop is re-entrant: callers drive it one iteration at a time with
//! new arrivals injected between iterations, which is what real online
//! serving needs (the server leader ingests from an mpsc channel between
//! steps) and what the batch path wraps:
//!
//! * [`Scheduler::inject`] — hand a request to the scheduler; it enters
//!   the CPU preprocess stage when virtual time reaches its arrival.
//! * [`Scheduler::step`] — process due arrivals/readiness, then plan,
//!   execute and apply **one** iteration; returns a [`StepOutcome`]
//!   telling the caller whether work happened and when to come back.
//! * [`Scheduler::advance_to`] — move the clock forward (wall-clock
//!   mapping for servers, event jumps for simulations).
//! * [`Scheduler::take_events`] — drain the [`RequestEvent`]s emitted
//!   since the last call, so callers observe per-iteration progress
//!   (first tokens, preemptions, drops) instead of a post-hoc report.
//! * [`Scheduler::drain`] — step until nothing is left; the batch
//!   [`Scheduler::run`] is exactly `inject` everything + `drain`.

use crate::backend::InvariantViolation;
use crate::config::ServeConfig;
use crate::coordinator::readyset::{ReadySet, RunSet};
use crate::coordinator::state::{Phase, ReqState};
use crate::engine::kv_cache::KvCache;
use crate::engine::{DecodeItem, EncodeItem, Engine, PrefillItem, StepPlan};
use crate::metrics::Report;
use crate::model::ModelProfile;
use crate::policies::{cmp_order_key, cmp_victim_key, OrderKey, Policy, VictimKey};
use crate::request::Request;
use crate::sim::EventQueue;
use std::collections::BTreeMap;

/// How a KV reservation may obtain memory (see
/// [`Scheduler::reserve_with_preemption`]).
#[derive(Debug, Clone, Copy)]
enum ReserveMode {
    /// Running request growing/continuing: preempt lowest-priority others;
    /// if alone and still too large, the request can never fit — drop.
    Growth,
    /// Admission for a policy that may preempt: victims must have strictly
    /// worse keys than the candidate.
    AdmitPreempting { cand_key: OrderKey },
    /// Admission without preemption (vLLM FCFS): fail quietly.
    AdmitPlain,
}

/// What happened when the planner visited one phase-2 candidate (an
/// ongoing prefill or a waiting admission). Both planning modes dispatch
/// through one visit function so their side effects — events, queue
/// stats, plan items, budget — are identical by construction; the
/// outcome tells the driving loop how to advance.
#[derive(Debug, Clone, Copy, PartialEq)]
enum Visit {
    /// Work was planned for this candidate.
    Planned,
    /// Passed over with no claim on its merge position (memory-blocked
    /// under a skip_blocked policy, zero-chunk, phase changed mid-pass,
    /// or dropped as unschedulable).
    Skipped,
    /// A waiting candidate hit the `max_running` slot ceiling under a
    /// skip_blocked policy: it and every waiting request until the next
    /// slot-freeing preemption are passed without side effects.
    SkippedSaturated,
    /// Head-of-line blocking for a strict-order policy: stop planning.
    Blocked,
}

/// Result of one [`Scheduler::step`] call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StepOutcome {
    /// One iteration plan was executed; the clock advanced by `dt`
    /// seconds (virtual for the simulator, wall for the real engine).
    Executed { dt: f64 },
    /// No request is ready or running. `next_event` is the scheduler-time
    /// of the next internal wake-up (a pending arrival or a preprocess
    /// completion); `advance_to` it (or wait that long in wall-clock) and
    /// step again.
    Idle { next_event: f64 },
    /// Requests exist but nothing could be planned (memory/slot blocked).
    /// `next_event` is the next internal wake-up, if any; with `None`
    /// the blockage is permanent unless new requests are injected —
    /// batch callers `drop_blocked` at that point.
    Blocked { next_event: Option<f64> },
    /// No requests anywhere (pending, ready, running) — fully drained.
    Drained,
}

/// Per-request lifecycle notifications, emitted as the iteration that
/// causes them is applied and drained by callers via
/// [`Scheduler::take_events`]. Times are scheduler-clock seconds.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RequestEvent {
    /// CPU preprocessing finished; the request is schedulable.
    Ready { id: u64, t: f64 },
    /// A vision encode ran for this request: emitted by the scheduler
    /// when it plans a local `EncodeItem` (at the iteration that launches
    /// it) and by the cluster's encoder pool at handoff. Together with
    /// `Preempted`, this makes the paper's encode-count invariant
    /// (`encodes == 1 + preemptions` for finished multimodal requests)
    /// observable from the event stream alone, across the pool→replica
    /// boundary (see `tests/pool_properties.rs`).
    Encoded { id: u64, t: f64 },
    /// The prefill-completing iteration produced the first token (TTFT).
    FirstToken { id: u64, t: f64 },
    /// Preempted-by-recompute and re-queued.
    Preempted { id: u64, t: f64 },
    /// A previously preempted request was re-admitted into the running
    /// set. Paired with the preceding `Preempted`, the interval
    /// `[Preempted.t, Requeued.t]` is exactly one preempted gap — span
    /// reconstruction (`obs::SpanRecorder`) never has to infer gap
    /// boundaries, and the per-request sum of gaps equals the outcome's
    /// `preempted_time`.
    Requeued { id: u64, t: f64 },
    /// All output tokens emitted.
    Finished { id: u64, t: f64 },
    /// Dropped: the request can never be scheduled (prompt exceeds KV
    /// capacity, or terminally blocked at drain).
    Dropped { id: u64, t: f64 },
    /// Cancelled by the client ([`Scheduler::cancel`]) from any live
    /// state — pending arrival, preprocessing, waiting, or running. KV
    /// and engine resources are released at the cancel instant; this is
    /// the request's terminal event (no `Finished`/`Dropped` follows).
    Cancelled { id: u64, t: f64 },
}

/// Aggregate counters for introspection and the perf benches.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct SchedStats {
    pub iterations: u64,
    pub preemptions: u64,
    pub dropped: u64,
    /// Requests cancelled by the client ([`Scheduler::cancel`]).
    pub cancelled: u64,
    /// Order/victim-key evaluations performed while planning (L3
    /// overhead, §Perf). A deterministic proxy for planning cost: the
    /// perf bench divides wall time by this to get ns/eval, while the
    /// counter itself stays bit-identical across runs — the sim core
    /// never reads a wall clock. In full-rescore mode this counts one
    /// evaluation per snapshot entry per iteration; in indexed mode it
    /// counts the incremental work instead — rank rescores on state
    /// transitions (enqueue, preemption re-queue) plus one evaluation
    /// per visited family head — so it is the quantity the
    /// `perf/sched/planning_evals_per_iter` sweep drives to
    /// near-constant. It is the one field the two planning modes are
    /// *allowed* to disagree on.
    pub planning_evals: u64,
    /// Virtual/wall seconds the engine was busy.
    pub busy_time_s: f64,
}

/// Cursor over one rank-index family stream during an indexed planning
/// pass (see [`Scheduler::plan_prefills_indexed`]).
struct FamilyCursor {
    family: u8,
    /// Last consumed `(rank, seq)` position; `None` = stream start.
    after: Option<(f64, u64)>,
    /// Cached head beyond `after`: `(order_key, seq, rank, id)`.
    head: Option<(OrderKey, u64, f64, u64)>,
    /// Whether `head` reflects the current cursor position.
    head_valid: bool,
}

/// The coordinator's scheduling core.
pub struct Scheduler {
    cfg: ServeConfig,
    profile: ModelProfile,
    policy: Box<dyn Policy>,
    engine: Box<dyn Engine>,
    kv: KvCache,

    states: BTreeMap<u64, ReqState>,
    /// Requests arriving already encoded (pool handoffs): id → handoff
    /// time. They skip CPU preprocessing and the admission encode.
    preencoded: BTreeMap<u64, f64>,
    /// Waiting requests: rank-indexed, insertion-ordered, with the
    /// per-class queue statistics that used to live in `QueueManager`.
    ready: ReadySet,
    /// Running requests in admission order.
    running: RunSet,
    /// `cfg.scheduler.indexed`, cached for the planner hot path.
    indexed: bool,
    preproc_free: Vec<f64>,
    /// Injected requests not yet due (keyed by arrival time).
    arrivals: EventQueue<Request>,
    ready_events: EventQueue<u64>,
    now: f64,

    finished: Vec<u64>,
    failed: Vec<u64>,
    cancelled: Vec<u64>,
    /// Terminal outcomes already handed out via [`Scheduler::take_finished`]
    /// (report bookkeeping: `failed.len() + retired_failed == stats.dropped`).
    retired_finished: usize,
    retired_failed: usize,
    retired_cancelled: usize,
    events: Vec<RequestEvent>,
    /// Obs-only event buffer ([`crate::obs::ObsEvent`]); `None` unless an
    /// observer enabled it via [`Scheduler::set_obs`]. The tap's only
    /// effect on the shared buffers is batch-drain retention:
    /// [`Scheduler::drain`] clears `events` between iterations *unless*
    /// the tap is active, so a post-hoc observer can harvest the full
    /// stream after a batch run. The stepping drain verbs are
    /// tap-independent — `take_events` always hands over and empties
    /// `events`, and `take_obs_events` empties this buffer (returning
    /// nothing while the tap is off). See the `Drain` section of
    /// [`crate::backend::ServeBackend`] for the unified contract.
    obs_tap: Option<Vec<crate::obs::ObsEvent>>,
    pub stats: SchedStats,

    // Persistent planning scratch (allocation reuse across steps): the
    // decorate-sort buffers, the family cursors, and the plan itself are
    // taken out at plan start and handed back after execution, so a
    // steady-state iteration allocates nothing in the planner.
    scratch_order: Vec<(OrderKey, u64)>,
    scratch_prefill: Vec<(OrderKey, u64)>,
    scratch_cursors: Vec<FamilyCursor>,
    scratch_plan: StepPlan,
}

impl Scheduler {
    pub fn new(cfg: ServeConfig, policy: Box<dyn Policy>, engine: Box<dyn Engine>) -> Scheduler {
        let profile = crate::model::by_name(&cfg.model).expect("validated model name");
        let capacity = (profile.kv_capacity_tokens as f64 * cfg.memory_frac) as u64;
        let kv = KvCache::new(capacity, cfg.scheduler.kv_block_tokens);
        let preproc_free = vec![0.0; cfg.scheduler.preprocess_workers.max(1)];
        let indexed = cfg.scheduler.indexed;
        Scheduler {
            cfg,
            profile,
            policy,
            engine,
            kv,
            states: BTreeMap::new(),
            preencoded: BTreeMap::new(),
            ready: ReadySet::new(),
            running: RunSet::new(),
            indexed,
            preproc_free,
            arrivals: EventQueue::new(),
            ready_events: EventQueue::new(),
            now: 0.0,
            finished: Vec::new(),
            failed: Vec::new(),
            cancelled: Vec::new(),
            retired_finished: 0,
            retired_failed: 0,
            retired_cancelled: 0,
            events: Vec::new(),
            obs_tap: None,
            stats: SchedStats::default(),
            scratch_order: Vec::new(),
            scratch_prefill: Vec::new(),
            scratch_cursors: Vec::new(),
            scratch_plan: StepPlan::default(),
        }
    }

    /// Enable/disable the obs-only event tap (see [`crate::obs`]). Off
    /// by default; scheduling decisions are unaffected either way.
    pub fn set_obs(&mut self, enabled: bool) {
        self.obs_tap = if enabled { Some(Vec::new()) } else { None };
    }

    /// Drain buffered obs-only events (empty when the tap is off).
    pub fn take_obs_events(&mut self) -> Vec<crate::obs::ObsEvent> {
        self.obs_tap.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Sample current state for telemetry: queue depths and batch
    /// occupancy by modality, KV utilization, cumulative planning work.
    pub fn probe(&self) -> crate::obs::Probe {
        let mut waiting = [0u32; 3];
        let mut running = [0u32; 3];
        for id in self.ready.iter() {
            if let Some(st) = self.states.get(&id) {
                waiting[st.req.modality as usize] += 1;
            }
        }
        for id in self.running.iter() {
            if let Some(st) = self.states.get(&id) {
                running[st.req.modality as usize] += 1;
            }
        }
        crate::obs::Probe {
            t: self.now,
            waiting,
            running,
            kv_utilization: self.kv.utilization(),
            planning_evals: self.stats.planning_evals,
            ..crate::obs::Probe::default()
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn kv(&self) -> &KvCache {
        &self.kv
    }

    /// The waiting set, including the per-class queue statistics that
    /// the retired `QueueManager` used to carry.
    pub fn ready_set(&self) -> &ReadySet {
        &self.ready
    }

    pub fn engine(&self) -> &dyn Engine {
        self.engine.as_ref()
    }

    pub fn engine_mut(&mut self) -> &mut dyn Engine {
        self.engine.as_mut()
    }

    pub fn waiting_len(&self) -> usize {
        self.ready.len()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Requests the scheduler still owes work: pending (not yet due)
    /// arrivals, preprocessing, waiting and running — everything that is
    /// not terminal. Routers use this to spot idle replicas. O(states),
    /// called once per replica per routed arrival.
    pub fn active_requests(&self) -> usize {
        self.arrivals.len()
            + self
                .states
                .values()
                .filter(|s| !matches!(s.phase, Phase::Finished | Phase::Dropped | Phase::Cancelled))
                .count()
    }

    // -----------------------------------------------------------------
    // stepping API
    // -----------------------------------------------------------------

    /// Hand a request to the scheduler. It enters CPU preprocessing once
    /// the clock reaches its arrival time; a request whose arrival is
    /// already in the past is ingested on the next step.
    pub fn inject(&mut self, req: Request) {
        let req = req.sanitize();
        let due = req.arrival.max(self.arrivals.now());
        self.arrivals.schedule(due, req);
    }

    /// Hand over a request whose vision encode already ran elsewhere (the
    /// cluster's encoder pool). `ready_at` is the handoff time — encode
    /// completion plus any migration cost; the request becomes
    /// schedulable then, skipping CPU preprocessing and the local
    /// admission encode. `req.arrival` keeps the *original* arrival so
    /// TTFT/SLO accounting still covers pool queueing and encode time.
    /// A later preemption-by-recompute re-encodes locally, exactly as for
    /// locally encoded requests.
    pub fn inject_preencoded(&mut self, req: Request, ready_at: f64) {
        let req = req.sanitize();
        let ready_at = if ready_at.is_finite() { ready_at } else { req.arrival };
        let due = ready_at.max(self.arrivals.now());
        self.preencoded.insert(req.id, ready_at);
        self.arrivals.schedule(due, req);
    }

    /// Move the scheduler clock forward (never backward). Servers call
    /// this with wall-clock elapsed time between steps; simulations jump
    /// to the `next_event` times returned by [`Scheduler::step`].
    pub fn advance_to(&mut self, t: f64) {
        if t > self.now {
            self.now = t;
        }
    }

    /// Drain the request events emitted since the last call.
    pub fn take_events(&mut self) -> Vec<RequestEvent> {
        std::mem::take(&mut self.events)
    }

    /// Cancel a request in any live state — a pending (not yet due)
    /// arrival, preprocessing, waiting, or running. Frees its KV
    /// reservation and engine state at the current clock, records a
    /// cancelled outcome, and emits [`RequestEvent::Cancelled`] as the
    /// request's terminal event. Returns `false` when the id is unknown
    /// or already terminal (finished/dropped/cancelled/retired) — a
    /// cancel that races completion loses quietly, which is what a
    /// serving front end wants. Committed preprocessing-worker time is
    /// not reclaimed (the CPU work is already spent).
    pub fn cancel(&mut self, id: u64) -> bool {
        let now = self.now;
        // Known state first (O(1)); only an id the scheduler has never
        // ingested warrants the O(pending) arrival-heap scan below.
        let Some(phase) = self.states.get(&id).map(|s| s.phase) else {
            // Still on the arrival timeline: pull it out before it is due.
            if let Some((_, req)) = self.arrivals.remove_where(|r| r.id == id) {
                self.preencoded.remove(&id);
                let slo = self.effective_slo(&req);
                let mut st = ReqState::new(req, slo);
                st.phase = Phase::Cancelled;
                st.finish = Some(now);
                self.states.insert(id, st);
                self.cancelled.push(id);
                self.stats.cancelled += 1;
                self.events.push(RequestEvent::Cancelled { id, t: now });
                return true;
            }
            return false;
        };
        match phase {
            Phase::Finished | Phase::Dropped | Phase::Cancelled => return false,
            Phase::Preprocessing => {
                // the scheduled ready event stays queued; mark_ready
                // ignores non-preprocessing ids when it fires
            }
            Phase::Waiting => {
                // O(log n); also closes out the class queue-stats visit
                self.ready.remove(id, now);
            }
            Phase::Prefilling | Phase::Decoding => {
                self.running.remove(id);
                self.kv.free(id);
                self.engine.release(id);
            }
        }
        let st = self.states.get_mut(&id).unwrap();
        st.phase = Phase::Cancelled;
        st.finish = Some(now);
        self.cancelled.push(id);
        self.stats.cancelled += 1;
        self.events.push(RequestEvent::Cancelled { id, t: now });
        true
    }

    /// Run one plan/execute/apply iteration, after processing arrivals
    /// and preprocess completions due at the current clock.
    pub fn step(&mut self) -> StepOutcome {
        // 1. ingest arrivals due now
        while let Some((_, req)) = self.arrivals.pop_until(self.now) {
            self.start_preprocess(req);
        }
        // 2. preprocess completions due now
        while let Some((t, id)) = self.ready_events.pop_until(self.now) {
            self.mark_ready(id, t);
        }

        let has_work = !self.ready.is_empty() || !self.running.is_empty();
        if !has_work {
            return match self.next_event_time() {
                Some(t) => StepOutcome::Idle { next_event: t },
                None => StepOutcome::Drained,
            };
        }

        // 3. plan — cost is accounted in key evaluations (see
        // `SchedStats::planning_evals`), not wall time: a wall clock here
        // would make `stats` differ between two runs of the same trace.
        // The plan's item buffers are recycled across steps.
        let mut plan = std::mem::take(&mut self.scratch_plan);
        plan.clear();
        self.build_plan(&mut plan);

        if plan.is_empty() {
            // Everything schedulable is blocked; the caller decides
            // whether to jump to the next event, wait for injections, or
            // drop the blocked tail.
            self.scratch_plan = plan;
            return StepOutcome::Blocked { next_event: self.next_event_time() };
        }

        // 4. execute
        let dt = self.engine.execute(&plan);
        self.stats.busy_time_s += dt;
        self.stats.iterations += 1;
        self.now += dt;
        self.apply_results(&plan);

        // Troubleshooting aid: TCM_TRACE=2 dumps iterations 1000-1060.
        if std::env::var_os("TCM_TRACE").map(|v| v == "2").unwrap_or(false)
            && (1000..1060).contains(&self.stats.iterations)
        {
            let desc: Vec<String> = self
                .running
                .iter()
                .chain(self.ready.iter())
                .map(|id| {
                    let s = &self.states[&id];
                    format!(
                        "r{id}[{:?} c={} d={} prompt={} key={:?} vkey={:?} rdy={:.3} cls={:?}]",
                        s.phase,
                        s.cached_rows,
                        s.decoded,
                        s.req.prefill_tokens(),
                        self.policy.order_key(s, self.now),
                        self.policy.victim_key(s, self.now),
                        s.ready_time,
                        s.class,
                    )
                })
                .collect();
            eprintln!(
                "[it {}] plan: pf={:?} dec={:?} | {}",
                self.stats.iterations,
                plan.prefills
                    .iter()
                    .map(|p| (p.req_id, p.chunk_tokens))
                    .collect::<Vec<_>>(),
                plan.decodes.iter().map(|d| d.req_id).collect::<Vec<_>>(),
                desc.join(" ")
            );
        }
        // Troubleshooting aid: TCM_TRACE=1 dumps periodic state.
        if self.stats.iterations % 100_000 == 0 && std::env::var_os("TCM_TRACE").is_some() {
            eprintln!(
                "[tcm-trace] iter={} now={:.1} waiting={} running={} finished={} \
                 dropped={} preempt={} kv_used={}/{} dt={dt:.6}",
                self.stats.iterations,
                self.now,
                self.ready.len(),
                self.running.len(),
                self.finished.len(),
                self.stats.dropped,
                self.stats.preemptions,
                self.kv.used_blocks(),
                self.kv.total_blocks(),
            );
        }

        self.scratch_plan = plan;
        StepOutcome::Executed { dt }
    }

    /// Step until nothing is left, jumping virtual time across idle gaps
    /// and dropping terminally blocked requests (no future event can ever
    /// unblock them), then report. Callers that care about per-iteration
    /// events should drive [`Scheduler::step`] themselves.
    pub fn drain(&mut self) -> Report {
        loop {
            // with an observer attached, retain events for post-hoc
            // harvest (take_events); the unobserved batch path keeps its
            // flat-memory behavior
            if self.obs_tap.is_none() {
                self.events.clear();
            }
            match self.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => self.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => self.advance_to(t),
                StepOutcome::Blocked { next_event: None } => self.drop_blocked(),
                StepOutcome::Drained => break,
            }
        }
        if self.obs_tap.is_none() {
            self.events.clear();
        }
        self.report()
    }

    /// Run a full trace to completion and report outcomes — a thin
    /// wrapper over the stepping API (inject everything, drain).
    pub fn run(&mut self, trace: Vec<Request>) -> Report {
        let mut trace = trace;
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for req in trace {
            self.inject(req);
        }
        self.drain()
    }

    /// Outcomes accumulated since the last [`Scheduler::take_finished`]
    /// call (or since construction): completed requests plus explicitly
    /// dropped ones (surfaced as failed outcomes so SLO/goodput
    /// accounting sees every request). Long-lived callers that retire
    /// state incrementally merge these partial reports themselves
    /// ([`Report::merge`]).
    pub fn report(&self) -> Report {
        let outcomes = self.finished.iter().map(|id| self.states[id].to_outcome()).collect();
        let failed = self.failed.iter().map(|id| self.states[id].to_failed_outcome()).collect();
        let mut report = Report::with_failed(outcomes, failed);
        report.cancelled =
            self.cancelled.iter().map(|id| self.states[id].to_cancelled_outcome()).collect();
        report
    }

    /// Retire/compact API (online serving): drain every terminal request
    /// into a partial [`Report`] and reclaim its scheduler-side state.
    /// Without this, `states` grows linearly with total requests served —
    /// a long-lived server calls it after emitting each iteration's
    /// events and merges the partials into its own running report.
    pub fn take_finished(&mut self) -> Report {
        let outcomes: Vec<_> = self
            .finished
            .drain(..)
            .map(|id| self.states.remove(&id).expect("finished state present").to_outcome())
            .collect();
        let failed: Vec<_> = self
            .failed
            .drain(..)
            .map(|id| self.states.remove(&id).expect("failed state present").to_failed_outcome())
            .collect();
        let cancelled: Vec<_> = self
            .cancelled
            .drain(..)
            .map(|id| {
                self.states.remove(&id).expect("cancelled state present").to_cancelled_outcome()
            })
            .collect();
        self.retired_finished += outcomes.len();
        self.retired_failed += failed.len();
        self.retired_cancelled += cancelled.len();
        let mut report = Report::with_failed(outcomes, failed);
        report.cancelled = cancelled;
        report
    }

    /// Terminal requests retired via [`Scheduler::take_finished`] so far,
    /// as `(finished, failed)` counts.
    pub fn retired(&self) -> (usize, usize) {
        (self.retired_finished, self.retired_failed)
    }

    /// The request's effective SLO latency: the client deadline when it
    /// is usable, else the configured `slo_scale` default. A non-finite
    /// or non-positive deadline is ignored rather than honored — a NaN
    /// here would poison every order key and panic the planner's sort,
    /// and clients are untrusted input.
    fn effective_slo(&self, req: &Request) -> f64 {
        match req.deadline_s {
            Some(d) if d.is_finite() && d > 0.0 => d,
            _ => self.cfg.slo_scale * self.profile.isolated_e2e(req),
        }
    }

    /// Next internal wake-up: the earliest pending arrival or preprocess
    /// completion.
    fn next_event_time(&self) -> Option<f64> {
        match (self.arrivals.peek_time(), self.ready_events.peek_time()) {
            (Some(a), Some(r)) => Some(a.min(r)),
            (Some(a), None) => Some(a),
            (None, Some(r)) => Some(r),
            (None, None) => None,
        }
    }

    // -----------------------------------------------------------------
    // arrival / readiness
    // -----------------------------------------------------------------

    fn start_preprocess(&mut self, req: Request) {
        // A client-attached deadline (SubmitOptions::deadline_s) becomes
        // the request's SLO latency, so EDF ordering and SLO accounting
        // honor it; otherwise the configured scale applies.
        let slo = self.effective_slo(&req);
        let id = req.id;
        let t_pre = self.profile.preprocess_time(&req);
        self.states.insert(id, ReqState::new(req, slo));

        // Pool handoffs arrive preprocessed and encoded: no CPU worker,
        // schedulable at the handoff time (clamped to the clock, exactly
        // like a preprocess completion in the past would be).
        if let Some(ready_at) = self.preencoded.remove(&id) {
            let st = self.states.get_mut(&id).unwrap();
            st.encoded = true;
            st.encoded_externally = true;
            self.ready_events.schedule(ready_at.max(self.now), id);
            return;
        }

        // earliest-free CPU worker
        let (w, _) = self
            .preproc_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .unwrap();
        let arrival = self.states[&id].req.arrival;
        let start = self.preproc_free[w].max(arrival);
        let done = start + t_pre;
        self.preproc_free[w] = done;
        self.ready_events.schedule(done.max(self.now), id);
    }

    fn mark_ready(&mut self, id: u64, t: f64) {
        // A ready event can fire for a request cancelled during
        // preprocessing (the event stays queued; the state may even be
        // retired already) — ignore anything no longer preprocessing.
        match self.states.get(&id) {
            Some(st) if st.phase == Phase::Preprocessing => {}
            _ => return,
        }
        let req = self.states[&id].req.clone();
        let (class, impact) = self.policy.admit(&req);
        let st = self.states.get_mut(&id).unwrap();
        st.phase = Phase::Waiting;
        st.ready_time = t;
        st.first_enqueue = t;
        st.class = class;
        st.impact = impact;
        let (family, rank) = self.policy.rank_key(st);
        if self.indexed {
            // the state-transition rescore of incremental maintenance
            self.stats.planning_evals += 1;
        }
        self.ready.insert(id, family, rank, class, t, false);
        self.events.push(RequestEvent::Ready { id, t });
    }

    // -----------------------------------------------------------------
    // planning
    // -----------------------------------------------------------------

    fn key(&self, id: u64) -> OrderKey {
        self.policy.order_key(&self.states[&id], self.now)
    }

    fn vkey(&self, id: u64) -> VictimKey {
        self.policy.victim_key(&self.states[&id], self.now)
    }

    fn build_plan(&mut self, plan: &mut StepPlan) {
        let mut budget = self.cfg.scheduler.token_budget as u64;
        // planned item index per request, for preemption surgery (empty
        // BTreeMaps don't allocate, so locals are fine here)
        let mut planned_decode: BTreeMap<u64, usize> = BTreeMap::new();
        let mut planned_prefill: BTreeMap<u64, usize> = BTreeMap::new();

        // Decorate-sort: compute each key once (policy key evaluation is
        // a dyn call and, for TCM, an exp/log — O(n log n) comparator
        // invocations tripled planning time before this, §Perf). Bounded
        // by `max_running`, so both planning modes share it.
        let mut order = std::mem::take(&mut self.scratch_order);
        order.clear();
        for id in self.running.iter() {
            order.push((self.key(id), id));
        }
        self.stats.planning_evals += order.len() as u64;
        order.sort_by(|a, b| cmp_order_key(&a.0, &b.0));

        // Phase 1: decodes
        for &(_, id) in order.iter() {
            if self.states[&id].phase != Phase::Decoding {
                continue;
            }
            if budget == 0 {
                break;
            }
            let need = self.states[&id].kv_for_next_decode();
            if !self.reserve_with_preemption(
                id, need, ReserveMode::Growth, plan, &mut budget,
                &mut planned_decode, &mut planned_prefill,
            ) {
                continue; // self-preempted or dropped
            }
            let ctx = self.states[&id].cached_rows;
            planned_decode.insert(id, plan.decodes.len());
            plan.decodes.push(DecodeItem { req_id: id, ctx_tokens: ctx });
            budget -= 1;
        }
        self.scratch_order = order;

        // Phase 2: prefill work — running continuations and waiting
        // admissions compete in ONE policy-ordered pass (vLLM V1 priority
        // scheduling is global: a waiting motorcycle outranks a running
        // truck's next chunk).
        if self.indexed {
            self.plan_prefills_indexed(
                plan,
                &mut budget,
                &mut planned_decode,
                &mut planned_prefill,
            );
        } else {
            self.plan_prefills_rescore(
                plan,
                &mut budget,
                &mut planned_decode,
                &mut planned_prefill,
            );
        }
    }

    /// Full-rescore phase 2 (the oracle): snapshot every ongoing prefill
    /// and every waiting request, evaluate every key, sort, walk.
    /// O(n log n) per iteration in queue depth — superlinear over a run.
    fn plan_prefills_rescore(
        &mut self,
        plan: &mut StepPlan,
        budget: &mut u64,
        planned_decode: &mut BTreeMap<u64, usize>,
        planned_prefill: &mut BTreeMap<u64, usize>,
    ) {
        let mut snapshot = std::mem::take(&mut self.scratch_prefill);
        snapshot.clear();
        for id in self.running.iter() {
            if self.states[&id].phase == Phase::Prefilling {
                snapshot.push((self.key(id), id));
            }
        }
        for id in self.ready.iter() {
            snapshot.push((self.key(id), id));
        }
        self.stats.planning_evals += snapshot.len() as u64;
        snapshot.sort_by(|a, b| cmp_order_key(&a.0, &b.0));

        for &(_, id) in snapshot.iter() {
            if *budget == 0 {
                break;
            }
            if self.visit_prefill_candidate(id, plan, budget, planned_decode, planned_prefill)
                == Visit::Blocked
            {
                break;
            }
        }
        self.scratch_prefill = snapshot;
    }

    /// Indexed phase 2: lazily merge the (≤ `max_running`) ongoing-prefill
    /// stream with the ready set's per-family rank streams, visiting
    /// candidates in exactly the oracle's order without touching — or
    /// rescoring — the waiting requests behind the admission frontier.
    ///
    /// Equivalence to the oracle rests on three facts:
    /// * within a family, `order_key` order equals `(rank, seq)` order at
    ///   every `now` (the [`Policy::rank_key`] contract), so each family
    ///   stream is pre-sorted and only its head needs a key evaluation;
    /// * the oracle's stable sort resolves equal keys by snapshot
    ///   position — ongoing prefills (in admission order) before waiting
    ///   requests (in insertion order) — which the merge reproduces with
    ///   the (key, stream, seq) comparison below;
    /// * requests preempted *during* this pass re-enter the ready set at
    ///   `seq >= watermark` and are excluded, exactly as they were absent
    ///   from the oracle's snapshot.
    ///
    /// When the running set is full under a skip_blocked policy, the
    /// oracle visits every waiting request and `continue`s with no side
    /// effects; the merge instead records a *saturation floor* (the next
    /// prefill-stream key) and skips the waiting streams wholesale. If a
    /// later growth preemption frees a slot mid-pass, the floor is
    /// consumed: each family cursor advances past the entries the oracle
    /// would already have passed (paying their key evaluations only
    /// then), and the merge resumes.
    fn plan_prefills_indexed(
        &mut self,
        plan: &mut StepPlan,
        budget: &mut u64,
        planned_decode: &mut BTreeMap<u64, usize>,
        planned_prefill: &mut BTreeMap<u64, usize>,
    ) {
        let mut pf = std::mem::take(&mut self.scratch_prefill);
        pf.clear();
        for id in self.running.iter() {
            if self.states[&id].phase == Phase::Prefilling {
                pf.push((self.key(id), id));
            }
        }
        self.stats.planning_evals += pf.len() as u64;
        pf.sort_by(|a, b| cmp_order_key(&a.0, &b.0));

        let watermark = self.ready.watermark();
        let mut cursors = std::mem::take(&mut self.scratch_cursors);
        cursors.clear();
        for family in self.ready.families() {
            cursors.push(FamilyCursor { family, after: None, head: None, head_valid: false });
        }

        let max_running = self.cfg.scheduler.max_running;
        let skip_blocked = self.policy.skip_blocked();
        let mut pf_i = 0usize;
        let mut sat_floor: Option<OrderKey> = None;

        loop {
            if *budget == 0 {
                break;
            }

            if self.running.len() >= max_running && skip_blocked {
                // Saturated: no admission can proceed, so waiting heads
                // need no evaluation. Work through the prefill stream;
                // every waiting request below the current prefill key is
                // passed (the oracle's per-entry `continue`), recorded in
                // the floor instead of walked.
                match pf.get(pf_i) {
                    None => break, // only blocked admissions remain
                    Some(&(key, id)) => {
                        sat_floor = Some(key);
                        pf_i += 1;
                        let v = self.visit_prefill_candidate(
                            id,
                            plan,
                            budget,
                            planned_decode,
                            planned_prefill,
                        );
                        if v == Visit::Blocked {
                            break;
                        }
                    }
                }
                continue;
            }

            // A slot freed up (or we never saturated): settle any pending
            // floor by advancing each family cursor past the entries the
            // oracle already passed while the batch was full.
            if let Some(floor) = sat_floor.take() {
                for c in cursors.iter_mut() {
                    loop {
                        let Some((rank, seq, id)) =
                            self.ready.next_in_family(c.family, c.after, watermark)
                        else {
                            c.head = None;
                            break;
                        };
                        self.stats.planning_evals += 1;
                        let key = self.key(id);
                        if cmp_order_key(&key, &floor).is_lt() {
                            c.after = Some((rank, seq));
                        } else {
                            c.head = Some((key, seq, rank, id));
                            break;
                        }
                    }
                    c.head_valid = true;
                }
            }

            // Refresh stale family heads (one key evaluation each).
            for c in cursors.iter_mut() {
                if !c.head_valid {
                    match self.ready.next_in_family(c.family, c.after, watermark) {
                        Some((rank, seq, id)) => {
                            self.stats.planning_evals += 1;
                            let key = self.key(id);
                            c.head = Some((key, seq, rank, id));
                        }
                        None => c.head = None,
                    }
                    c.head_valid = true;
                }
            }

            // Best waiting head across families: (key, seq) replicates the
            // oracle's stable tie-break (insertion order).
            let best = cursors
                .iter()
                .enumerate()
                .filter_map(|(i, c)| c.head.map(|h| (i, h)))
                .min_by(|a, b| cmp_order_key(&a.1 .0, &b.1 .0).then(a.1 .1.cmp(&b.1 .1)));
            let pf_head = pf.get(pf_i).copied();

            // Equal keys take the prefill stream first: it preceded the
            // waiting ids in the oracle's snapshot.
            let take_pf = match (pf_head, &best) {
                (None, None) => break,
                (Some(_), None) => true,
                (None, Some(_)) => false,
                (Some((pk, _)), Some((_, (wk, _, _, _)))) => !cmp_order_key(&pk, wk).is_gt(),
            };

            if take_pf {
                let (_, id) = pf_head.unwrap();
                pf_i += 1;
                if self.visit_prefill_candidate(id, plan, budget, planned_decode, planned_prefill)
                    == Visit::Blocked
                {
                    break;
                }
            } else {
                let (ci, (_, seq, rank, id)) = best.unwrap();
                match self.visit_prefill_candidate(
                    id,
                    plan,
                    budget,
                    planned_decode,
                    planned_prefill,
                ) {
                    Visit::Blocked => break,
                    Visit::SkippedSaturated => {
                        // The batch filled since the loop-top check could
                        // see it (defensive: admissions re-check inside
                        // the visit). Fold into the floor path.
                        match pf.get(pf_i) {
                            None => break,
                            Some(&(pk, _)) => sat_floor = Some(pk),
                        }
                    }
                    Visit::Planned | Visit::Skipped => {
                        let c = &mut cursors[ci];
                        c.after = Some((rank, seq));
                        c.head = None;
                        c.head_valid = false;
                    }
                }
            }
        }

        self.scratch_prefill = pf;
        self.scratch_cursors = cursors;
    }

    /// Visit one phase-2 candidate — an ongoing prefill chunk or a
    /// waiting admission — and plan its work if budget, slots and KV
    /// admit it. This is the single side-effect path shared by both
    /// planning modes: every event, queue-stat update, plan item and
    /// budget charge happens here, identically, regardless of how the
    /// candidate was ordered.
    fn visit_prefill_candidate(
        &mut self,
        id: u64,
        plan: &mut StepPlan,
        budget: &mut u64,
        planned_decode: &mut BTreeMap<u64, usize>,
        planned_prefill: &mut BTreeMap<u64, usize>,
    ) -> Visit {
        match self.states[&id].phase {
            Phase::Prefilling => {
                let st = &self.states[&id];
                let chunk = ((*budget).min(st.prefill_remaining() as u64)) as u32;
                if chunk == 0 {
                    return Visit::Skipped;
                }
                let target = st.cached_rows + chunk;
                if !self.reserve_with_preemption(
                    id, target, ReserveMode::Growth, plan, budget,
                    planned_decode, planned_prefill,
                ) {
                    return Visit::Skipped;
                }
                let st = &self.states[&id];
                planned_prefill.insert(id, plan.prefills.len());
                plan.prefills.push(PrefillItem {
                    req_id: id,
                    ctx_before: st.cached_rows,
                    chunk_tokens: chunk,
                    last_chunk: st.cached_rows + chunk == st.prefill_target(),
                    text_tokens: st.req.text_tokens,
                    // externally encoded (pool handoff): the local
                    // engine owes no encoder work during prefill
                    mm_tokens: if st.encoded_externally { 0 } else { st.req.mm_tokens },
                    prefill_total: st.prefill_target(),
                });
                *budget -= chunk as u64;
                Visit::Planned
            }
            Phase::Waiting => {
                if self.running.len() >= self.cfg.scheduler.max_running {
                    if self.policy.skip_blocked() {
                        return Visit::SkippedSaturated;
                    } else {
                        return Visit::Blocked;
                    }
                }
                // Requests whose prompt can never fit are failed early.
                let prompt_need = self.states[&id].prefill_target() as u64 + 1;
                if prompt_need > self.kv.capacity_tokens() {
                    self.drop_request(id);
                    return Visit::Skipped;
                }
                let st = &self.states[&id];
                let chunk = ((*budget).min(st.prefill_remaining() as u64)) as u32;
                if self.cfg.scheduler.atomic_prefill && chunk < st.prefill_remaining() {
                    // whole-prompt-only engines: wait for a budget-
                    // fresh iteration rather than splitting the prompt
                    if self.policy.skip_blocked() {
                        return Visit::Skipped;
                    } else {
                        return Visit::Blocked;
                    }
                }
                let mode = if self.policy.preempt_for_admission() {
                    ReserveMode::AdmitPreempting { cand_key: self.key(id) }
                } else {
                    ReserveMode::AdmitPlain
                };
                let ok = self.reserve_with_preemption(
                    id, chunk, mode, plan, budget,
                    planned_decode, planned_prefill,
                );
                if !ok {
                    if self.policy.skip_blocked() {
                        return Visit::Skipped;
                    } else {
                        return Visit::Blocked;
                    }
                }
                // admit
                let now = self.now;
                self.ready.remove(id, now);
                self.running.insert(id);
                let st = self.states.get_mut(&id).unwrap();
                st.phase = Phase::Prefilling;
                if let Some(t0) = st.preempted_at.take() {
                    st.preempted_time += now - t0;
                    // the preempted gap closes at this re-admission
                    self.events.push(RequestEvent::Requeued { id, t: now });
                }
                if let Some(tap) = self.obs_tap.as_mut() {
                    tap.push(crate::obs::ObsEvent::Admitted { id, t: now });
                }
                let st = self.states.get_mut(&id).unwrap();
                // `encoded_externally` implies `encoded`, so an
                // EncodeItem is only ever planned for a local encode
                let needs_encode = st.req.mm_tokens > 0 && !st.encoded;
                if needs_encode {
                    st.encoded = true;
                    plan.encodes.push(EncodeItem {
                        req_id: id,
                        modality: st.req.modality,
                        mm_tokens: st.req.mm_tokens,
                        video_duration_s: st.req.video_duration_s,
                    });
                    // the iteration being planned launches this encode
                    self.events.push(RequestEvent::Encoded { id, t: now });
                }
                let st = &self.states[&id];
                planned_prefill.insert(id, plan.prefills.len());
                plan.prefills.push(PrefillItem {
                    req_id: id,
                    ctx_before: st.cached_rows,
                    chunk_tokens: chunk,
                    last_chunk: st.cached_rows + chunk == st.prefill_target(),
                    text_tokens: st.req.text_tokens,
                    // externally encoded (pool handoff): the local
                    // engine owes no encoder work during prefill
                    mm_tokens: if st.encoded_externally { 0 } else { st.req.mm_tokens },
                    prefill_total: st.prefill_target(),
                });
                *budget -= chunk as u64;
                Visit::Planned
            }
            _ => Visit::Skipped, // finished/preempted during this round
        }
    }

    /// Try to reserve `tokens` total KV rows for `id`, preempting max-key
    /// (lowest-priority) running victims as the mode allows. Returns false
    /// if the reservation ultimately failed (under `Growth` the requester
    /// may have been self-preempted or dropped).
    fn reserve_with_preemption(
        &mut self,
        id: u64,
        tokens: u32,
        mode: ReserveMode,
        plan: &mut StepPlan,
        budget: &mut u64,
        planned_decode: &mut BTreeMap<u64, usize>,
        planned_prefill: &mut BTreeMap<u64, usize>,
    ) -> bool {
        loop {
            if self.kv.try_reserve(id, tokens) {
                return true;
            }
            match mode {
                ReserveMode::AdmitPlain => return false,
                ReserveMode::AdmitPreempting { cand_key } => {
                    // select by victim_key (class-aware policies evict
                    // trucks first); gate on order_key so a candidate
                    // never evicts someone more urgent than itself
                    self.stats.planning_evals += self.running.len() as u64;
                    let victim = self
                        .running
                        .iter()
                        .max_by(|&a, &b| cmp_victim_key(&self.vkey(a), &self.vkey(b)))
                        .filter(|&v| cmp_order_key(&self.key(v), &cand_key).is_gt());
                    match victim {
                        Some(v) => {
                            self.preempt(v, plan, budget, planned_decode, planned_prefill)
                        }
                        None => return false, // candidate stays queued
                    }
                }
                ReserveMode::Growth => {
                    // vLLM recompute semantics with a progress guarantee:
                    // evict only strictly-worse-priority victims. If none
                    // exists, the requester preempts ITSELF and waits for
                    // the better-priority requests to finish — without the
                    // strict gate, two half-prefilled requests whose
                    // combined footprints exceed capacity evict each other
                    // forever (live-lock). A requester alone in the cache
                    // that still cannot fit can never fit: drop it.
                    let my_key = self.vkey(id);
                    self.stats.planning_evals += self.running.len() as u64;
                    let victim = self
                        .running
                        .iter()
                        .filter(|&v| v != id)
                        .max_by(|&a, &b| cmp_victim_key(&self.vkey(a), &self.vkey(b)))
                        .filter(|&v| cmp_victim_key(&self.vkey(v), &my_key).is_gt());
                    match victim {
                        Some(v) => {
                            self.preempt(v, plan, budget, planned_decode, planned_prefill)
                        }
                        None => {
                            let alone = self.running.iter().all(|v| v == id);
                            if alone {
                                self.drop_request(id);
                            } else if self.running.contains(id) {
                                self.preempt(id, plan, budget, planned_decode, planned_prefill);
                            } else {
                                // waiting requester (cannot happen today:
                                // Growth is only used for running ids)
                                return false;
                            }
                            return false;
                        }
                    }
                }
            }
        }
    }

    /// Preempt-by-recompute: evict KV, undo planned items, requeue.
    fn preempt(
        &mut self,
        id: u64,
        plan: &mut StepPlan,
        budget: &mut u64,
        planned_decode: &mut BTreeMap<u64, usize>,
        planned_prefill: &mut BTreeMap<u64, usize>,
    ) {
        // Undo planned work (plan surgery keeps indices valid by swapping
        // with the last element and fixing its index entry).
        if let Some(i) = planned_decode.remove(&id) {
            plan.decodes.swap_remove(i);
            if let Some(moved) = plan.decodes.get(i) {
                planned_decode.insert(moved.req_id, i);
            }
            *budget += 1;
        }
        if let Some(i) = planned_prefill.remove(&id) {
            let item = plan.prefills.swap_remove(i);
            if let Some(moved) = plan.prefills.get(i) {
                planned_prefill.insert(moved.req_id, i);
            }
            *budget += item.chunk_tokens as u64;
        }
        // Encodes are never undone: the encoder cache persists host-side.
        self.kv.free(id);
        self.engine.release(id);
        self.running.remove(id);
        let now = self.now;
        let st = self.states.get_mut(&id).unwrap();
        st.phase = Phase::Waiting;
        st.cached_rows = 0;
        st.encoded = false; // recompute drops the encoder cache too
        st.encoded_externally = false; // the re-encode will run locally
        st.preemptions += 1;
        st.preempted_at = Some(now);
        self.stats.preemptions += 1;
        let class = st.class;
        // Re-enter the ready set with an unchanged rank (preemption
        // touches neither first_enqueue nor ready_time nor the deadline)
        // but a fresh seq — mid-plan re-entries stay invisible to the
        // pass that caused them (watermark). Tracked as a requeue, not a
        // fresh arrival, so queue stats don't double-count preempted
        // requests.
        let (family, rank) = self.policy.rank_key(st);
        if self.indexed {
            self.stats.planning_evals += 1;
        }
        self.ready.insert(id, family, rank, class, now, true);
        self.events.push(RequestEvent::Preempted { id, t: now });
    }

    /// Fail a request that can never be scheduled (prompt exceeds KV
    /// capacity under the current memory budget). The drop is surfaced:
    /// counted in `stats.dropped`, recorded as a failed outcome in
    /// [`Scheduler::report`], and emitted as [`RequestEvent::Dropped`].
    fn drop_request(&mut self, id: u64) {
        let now = self.now;
        self.ready.remove(id, now);
        self.running.remove(id);
        self.kv.free(id);
        self.engine.release(id);
        let st = self.states.get_mut(&id).unwrap();
        st.phase = Phase::Dropped;
        st.finish = Some(now);
        self.failed.push(id);
        self.stats.dropped += 1;
        self.events.push(RequestEvent::Dropped { id, t: now });
    }

    /// Drop every blocked waiting request (terminal starvation guard when
    /// no future events exist). Public so online callers can apply the
    /// same guard at shutdown that [`Scheduler::drain`] applies in batch.
    pub fn drop_blocked(&mut self) {
        let blocked: Vec<u64> = self.ready.iter().collect();
        for id in blocked {
            self.drop_request(id);
        }
    }

    // -----------------------------------------------------------------
    // results
    // -----------------------------------------------------------------

    fn apply_results(&mut self, plan: &StepPlan) {
        let now = self.now;
        for item in &plan.prefills {
            let st = self.states.get_mut(&item.req_id).unwrap();
            st.cached_rows += item.chunk_tokens;
            if item.last_chunk {
                debug_assert_eq!(st.cached_rows, st.prefill_target());
                st.phase = Phase::Decoding;
                if st.first_token.is_none() {
                    // the prefill-completing iteration computes the first
                    // token's logits: TTFT is measured here
                    st.first_token = Some(now);
                    st.decoded = 1;
                    self.events.push(RequestEvent::FirstToken { id: item.req_id, t: now });
                }
                if st.decoded >= st.req.output_tokens {
                    self.finish(item.req_id);
                }
            }
        }
        for item in &plan.decodes {
            let st = self.states.get_mut(&item.req_id).unwrap();
            st.decoded += 1;
            st.cached_rows += 1; // the input token's KV row was written
            if st.decoded >= st.req.output_tokens {
                self.finish(item.req_id);
            }
        }
    }

    fn finish(&mut self, id: u64) {
        let now = self.now;
        let st = self.states.get_mut(&id).unwrap();
        st.phase = Phase::Finished;
        st.finish = Some(now);
        self.kv.free(id);
        self.engine.release(id);
        self.running.remove(id);
        self.finished.push(id);
        self.events.push(RequestEvent::Finished { id, t: now });
    }

    /// Consistency invariants (exercised by property tests).
    pub fn check_invariants(&self) -> Result<(), InvariantViolation> {
        self.kv.check_invariants().map_err(InvariantViolation::Kv)?;
        self.ready
            .check_consistency()
            .map_err(|(structure, id)| InvariantViolation::IndexDesync { structure, id })?;
        self.running
            .check_consistency()
            .map_err(|(structure, id)| InvariantViolation::IndexDesync { structure, id })?;
        for id in self.ready.iter() {
            let phase = self.states[&id].phase;
            if phase != Phase::Waiting {
                return Err(InvariantViolation::PhaseMismatch { list: "waiting", id, phase });
            }
        }
        for id in self.running.iter() {
            let phase = self.states[&id].phase;
            if phase != Phase::Prefilling && phase != Phase::Decoding {
                return Err(InvariantViolation::PhaseMismatch { list: "running", id, phase });
            }
        }
        for &id in &self.finished {
            let phase = self.states[&id].phase;
            if phase != Phase::Finished {
                return Err(InvariantViolation::PhaseMismatch { list: "finished", id, phase });
            }
        }
        for &id in &self.failed {
            let phase = self.states[&id].phase;
            if phase != Phase::Dropped {
                return Err(InvariantViolation::PhaseMismatch { list: "failed", id, phase });
            }
        }
        for &id in &self.cancelled {
            let phase = self.states[&id].phase;
            if phase != Phase::Cancelled {
                return Err(InvariantViolation::PhaseMismatch { list: "cancelled", id, phase });
            }
            if self.ready.contains(id) || self.running.contains(id) {
                return Err(InvariantViolation::CancelledStillScheduled { id });
            }
        }
        if (self.cancelled.len() + self.retired_cancelled) as u64 != self.stats.cancelled {
            return Err(InvariantViolation::CancelAccounting {
                live: self.cancelled.len(),
                retired: self.retired_cancelled,
                counted: self.stats.cancelled,
            });
        }
        if (self.failed.len() + self.retired_failed) as u64 != self.stats.dropped {
            return Err(InvariantViolation::DropAccounting {
                live: self.failed.len(),
                retired: self.retired_failed,
                counted: self.stats.dropped,
            });
        }
        Ok(())
    }
}
