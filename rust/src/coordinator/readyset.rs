//! Indexed ready/run sets: the scheduler's core queue structures.
//!
//! [`ReadySet`] replaces the scheduler's old raw waiting-id vector and
//! the `QueueManager` it sat next to (paper §3.5): one structure owns
//! queue membership, per-class waiting statistics, *and* a rank index
//! that lets the planner walk waiting requests in policy order without
//! rescoring the whole queue each iteration. [`RunSet`] replaces the raw
//! running-id vector with the same O(log n) membership operations while
//! preserving the admission order the legacy vector encoded implicitly.
//!
//! # Determinism
//!
//! Both sets iterate in insertion (`seq`) order, which reproduces the
//! legacy `Vec` order exactly: `Vec::retain` preserved relative order
//! and `Vec::push` appended, so position order *was* seq order. The rank
//! index keys entries by `(family, rank, seq)` where `(family, rank)`
//! comes from [`crate::policies::Policy::rank_key`] — a time-invariant
//! decomposition of the policy's dynamic `order_key` (see that method's
//! contract). Float ranks are ordered by `f64::total_cmp` ([`TotalF64`]),
//! never `PartialOrd`, so a NaN rank cannot panic or introduce
//! platform-dependent order.

use crate::request::Class;
use std::collections::BTreeMap;
use std::ops::Bound;

/// An `f64` with the `total_cmp` total order (IEEE 754 totalOrder), so it
/// can key a `BTreeMap`. NaNs sort deterministically (negative NaN first,
/// positive NaN last) instead of panicking a comparator.
#[derive(Debug, Clone, Copy)]
pub struct TotalF64(pub f64);

impl PartialEq for TotalF64 {
    fn eq(&self, other: &Self) -> bool {
        self.0.total_cmp(&other.0).is_eq()
    }
}

impl Eq for TotalF64 {}

impl PartialOrd for TotalF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for TotalF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Running statistics for one class queue (absorbed from the retired
/// `QueueManager`; semantics unchanged).
#[derive(Debug, Clone, Default)]
pub struct QueueStats {
    /// Distinct requests enqueued on readiness (first entry only;
    /// re-enqueues after preemption are counted in `requeued`).
    pub enqueued: u64,
    /// Re-enqueues after preemption-by-recompute.
    pub requeued: u64,
    /// Distinct requests that left the queue (first dequeue only, so a
    /// preempted-and-readmitted request counts once).
    pub dequeued: u64,
    /// Sum of time-in-queue across *all* visits, including post-preemption
    /// requeues (avg_wait = sum / dequeued = average total queueing time
    /// per request).
    pub total_wait: f64,
    /// High-water mark of queue length.
    pub peak_len: usize,
}

impl QueueStats {
    /// Average total time-in-queue per request (all visits summed).
    pub fn avg_wait(&self) -> f64 {
        if self.dequeued == 0 {
            0.0
        } else {
            self.total_wait / self.dequeued as f64
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct EntryMeta {
    family: u8,
    rank: f64,
    seq: u64,
    class: Option<Class>,
    enqueue_time: f64,
    /// Re-enqueue after preemption (not a fresh arrival).
    requeue: bool,
}

/// The waiting set: indexed by insertion order (the legacy vec order) and
/// by `(family, rank, seq)` for policy-ordered traversal, with per-class
/// queue statistics. Insert, remove and cancel are O(log n).
#[derive(Debug, Default)]
pub struct ReadySet {
    by_rank: BTreeMap<(u8, TotalF64, u64), u64>,
    by_seq: BTreeMap<u64, u64>,
    index: BTreeMap<u64, EntryMeta>,
    family_len: BTreeMap<u8, usize>,
    next_seq: u64,
    class_len: [usize; 3],
    stats: [QueueStats; 3],
}

impl ReadySet {
    pub fn new() -> ReadySet {
        ReadySet::default()
    }

    /// Insert a request. `requeue = false` is a fresh enqueue on
    /// readiness (counted in `enqueued`); `requeue = true` is a
    /// post-preemption re-entry (counted in `requeued` so preemptions
    /// don't inflate arrival counts, while the renewed waiting time still
    /// accrues into `total_wait` at removal). `(family, rank)` must come
    /// from the active policy's `rank_key` for the request's current
    /// state.
    pub fn insert(
        &mut self,
        id: u64,
        family: u8,
        rank: f64,
        class: Option<Class>,
        now: f64,
        requeue: bool,
    ) {
        debug_assert!(!self.index.contains_key(&id), "ready-set double insert for {id}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.by_rank.insert((family, TotalF64(rank), seq), id);
        self.by_seq.insert(seq, id);
        self.index.insert(id, EntryMeta { family, rank, seq, class, enqueue_time: now, requeue });
        *self.family_len.entry(family).or_insert(0) += 1;
        if let Some(c) = class {
            let ci = c as usize;
            self.class_len[ci] += 1;
            let s = &mut self.stats[ci];
            if requeue {
                s.requeued += 1;
            } else {
                s.enqueued += 1;
            }
            s.peak_len = s.peak_len.max(self.class_len[ci]);
        }
    }

    /// Remove a request (admission, drop, or cancel — removal is
    /// rank-ordered in practice, never positional). Accrues its
    /// time-in-queue into the class stats. Returns `false` when absent.
    pub fn remove(&mut self, id: u64, now: f64) -> bool {
        let Some(meta) = self.index.remove(&id) else {
            return false;
        };
        self.by_rank.remove(&(meta.family, TotalF64(meta.rank), meta.seq));
        self.by_seq.remove(&meta.seq);
        if let Some(n) = self.family_len.get_mut(&meta.family) {
            *n -= 1;
            if *n == 0 {
                self.family_len.remove(&meta.family);
            }
        }
        if let Some(c) = meta.class {
            let ci = c as usize;
            self.class_len[ci] -= 1;
            let s = &mut self.stats[ci];
            if !meta.requeue {
                s.dequeued += 1;
            }
            s.total_wait += (now - meta.enqueue_time).max(0.0);
        }
        true
    }

    pub fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Ids in insertion order — exactly the legacy `waiting` vec order.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.by_seq.values().copied()
    }

    /// Current queue length for one class.
    pub fn class_len(&self, class: Class) -> usize {
        self.class_len[class as usize]
    }

    pub fn stats(&self, class: Class) -> &QueueStats {
        &self.stats[class as usize]
    }

    /// The next sequence number to be assigned. Entries with `seq >=
    /// watermark()` were inserted after the caller took the watermark —
    /// the planner uses this to exclude requests preempted *during* the
    /// current planning pass (the legacy snapshot excluded them by
    /// construction).
    pub fn watermark(&self) -> u64 {
        self.next_seq
    }

    /// Families currently present, ascending. At most one per
    /// `(class, SLO tier)` combination — bounded by the policy, not the
    /// queue depth.
    pub fn families(&self) -> impl Iterator<Item = u8> + '_ {
        self.family_len.keys().copied()
    }

    /// The first entry of `family` strictly after cursor position
    /// `after` (a `(rank, seq)` pair) with `seq < below_seq`, as
    /// `(rank, seq, id)`. Entries at or above the watermark are skipped
    /// but do not terminate the scan — a request preempted mid-plan
    /// re-enters with its old rank and a new seq, interleaved in rank
    /// order with older entries.
    pub fn next_in_family(
        &self,
        family: u8,
        after: Option<(f64, u64)>,
        below_seq: u64,
    ) -> Option<(f64, u64, u64)> {
        let lo = match after {
            Some((rank, seq)) => Bound::Excluded((family, TotalF64(rank), seq)),
            // total_cmp's minimum is the all-ones bit pattern (a negative
            // NaN), so this bound is inclusive of every possible rank
            None => Bound::Included((family, TotalF64(f64::from_bits(u64::MAX)), 0)),
        };
        self.by_rank
            .range((lo, Bound::Unbounded))
            .take_while(|(&(f, _, _), _)| f == family)
            .find(|(&(_, _, seq), _)| seq < below_seq)
            .map(|(&(_, rank, seq), &id)| (rank.0, seq, id))
    }

    /// Cross-index consistency (exercised by `check_invariants`): every
    /// entry must appear in all three maps with matching metadata.
    /// Returns the first desynced id with the structure name.
    pub fn check_consistency(&self) -> Result<(), (&'static str, u64)> {
        for (&id, meta) in &self.index {
            if self.by_rank.get(&(meta.family, TotalF64(meta.rank), meta.seq)) != Some(&id) {
                return Err(("ready-set rank", id));
            }
            if self.by_seq.get(&meta.seq) != Some(&id) {
                return Err(("ready-set seq", id));
            }
        }
        if self.by_rank.len() != self.index.len() || self.by_seq.len() != self.index.len() {
            let id = self.by_rank.values().chain(self.by_seq.values()).copied().next();
            return Err(("ready-set size", id.unwrap_or(0)));
        }
        Ok(())
    }
}

/// The running set: O(log n) membership keyed by admission order. The
/// planner's phase-1 sort, victim scans and trace dumps iterate it in
/// admission (`seq`) order, which is exactly the legacy `running` vec
/// order (retain preserved order, push appended).
#[derive(Debug, Default)]
pub struct RunSet {
    by_seq: BTreeMap<u64, u64>,
    index: BTreeMap<u64, u64>,
    next_seq: u64,
}

impl RunSet {
    pub fn new() -> RunSet {
        RunSet::default()
    }

    pub fn insert(&mut self, id: u64) {
        debug_assert!(!self.index.contains_key(&id), "run-set double insert for {id}");
        let seq = self.next_seq;
        self.next_seq += 1;
        self.by_seq.insert(seq, id);
        self.index.insert(id, seq);
    }

    pub fn remove(&mut self, id: u64) -> bool {
        match self.index.remove(&id) {
            Some(seq) => {
                self.by_seq.remove(&seq);
                true
            }
            None => false,
        }
    }

    pub fn contains(&self, id: u64) -> bool {
        self.index.contains_key(&id)
    }

    pub fn len(&self) -> usize {
        self.index.len()
    }

    pub fn is_empty(&self) -> bool {
        self.index.is_empty()
    }

    /// Ids in admission order — exactly the legacy `running` vec order.
    pub fn iter(&self) -> impl Iterator<Item = u64> + '_ {
        self.by_seq.values().copied()
    }

    /// Cross-index consistency; see [`ReadySet::check_consistency`].
    pub fn check_consistency(&self) -> Result<(), (&'static str, u64)> {
        for (&id, &seq) in &self.index {
            if self.by_seq.get(&seq) != Some(&id) {
                return Err(("run-set seq", id));
            }
        }
        if self.by_seq.len() != self.index.len() {
            let id = self.by_seq.values().copied().next();
            return Err(("run-set size", id.unwrap_or(0)));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Queue-stats semantics below are carried over verbatim from the
    // retired QueueManager's test suite: the absorption must not change
    // any counter's meaning.

    #[test]
    fn insertion_order_within_class() {
        let mut rs = ReadySet::new();
        rs.insert(1, 0, 0.0, Some(Class::Car), 0.0, false);
        rs.insert(2, 0, 1.0, Some(Class::Car), 1.0, false);
        rs.insert(3, 0, 0.5, Some(Class::Truck), 0.5, false);
        assert_eq!(rs.iter().collect::<Vec<_>>(), vec![1, 2, 3]);
        assert_eq!(rs.class_len(Class::Car), 2);
        assert_eq!(rs.class_len(Class::Truck), 1);
        assert_eq!(rs.class_len(Class::Motorcycle), 0);
    }

    #[test]
    fn remove_tracks_wait() {
        let mut rs = ReadySet::new();
        rs.insert(1, 0, 0.0, Some(Class::Motorcycle), 0.0, false);
        rs.insert(2, 0, 0.0, Some(Class::Motorcycle), 0.0, false);
        assert!(rs.remove(2, 4.0)); // out of order OK
        assert!(rs.remove(1, 6.0));
        assert!(!rs.remove(1, 7.0));
        let s = rs.stats(Class::Motorcycle);
        assert_eq!(s.enqueued, 2);
        assert_eq!(s.dequeued, 2);
        assert!((s.avg_wait() - 5.0).abs() < 1e-12);
    }

    #[test]
    fn requeues_tracked_separately_with_total_wait() {
        let mut rs = ReadySet::new();
        rs.insert(1, 0, 0.0, Some(Class::Car), 0.0, false);
        assert!(rs.remove(1, 2.0)); // admitted after 2 s
        rs.insert(1, 0, 0.0, Some(Class::Car), 3.0, true); // preempted, back in queue
        assert!(rs.remove(1, 5.0)); // readmitted after 2 more s
        let s = rs.stats(Class::Car);
        assert_eq!(s.enqueued, 1, "requeue must not count as a fresh enqueue");
        assert_eq!(s.requeued, 1);
        assert_eq!(s.dequeued, 1, "one distinct request left the queue");
        assert!((s.avg_wait() - 4.0).abs() < 1e-12, "total time-in-queue, not last visit");
    }

    #[test]
    fn peak_length_tracked() {
        let mut rs = ReadySet::new();
        for i in 0..5 {
            rs.insert(i, 0, 0.0, Some(Class::Truck), 0.0, false);
        }
        for i in 0..5 {
            rs.remove(i, 1.0);
        }
        assert_eq!(rs.stats(Class::Truck).peak_len, 5);
        assert!(rs.is_empty());
    }

    #[test]
    fn rank_traversal_is_family_then_rank_then_seq() {
        let mut rs = ReadySet::new();
        rs.insert(10, 1, 5.0, None, 0.0, false);
        rs.insert(11, 0, 9.0, None, 0.0, false);
        rs.insert(12, 0, 2.0, None, 0.0, false);
        rs.insert(13, 0, 2.0, None, 0.0, false); // rank tie → seq order
        assert_eq!(rs.families().collect::<Vec<_>>(), vec![0, 1]);
        let w = rs.watermark();
        let mut got = Vec::new();
        let mut after = None;
        while let Some((rank, seq, id)) = rs.next_in_family(0, after, w) {
            got.push(id);
            after = Some((rank, seq));
        }
        assert_eq!(got, vec![12, 13, 11]);
        assert_eq!(rs.next_in_family(1, None, w), Some((5.0, 0, 10)));
    }

    #[test]
    fn watermark_excludes_later_inserts_without_ending_scan() {
        let mut rs = ReadySet::new();
        rs.insert(1, 0, 3.0, None, 0.0, false);
        rs.insert(2, 0, 9.0, None, 0.0, false);
        let w = rs.watermark();
        // a mid-plan preemption re-enters with an *older* rank but a
        // newer seq — it must be skipped, and the scan must continue to
        // the entry behind it
        rs.insert(3, 0, 1.0, None, 0.0, true);
        rs.insert(4, 0, 5.0, None, 0.0, true);
        assert_eq!(rs.next_in_family(0, None, w).map(|(_, _, id)| id), Some(1));
        let (r1, s1, _) = rs.next_in_family(0, None, w).unwrap();
        assert_eq!(rs.next_in_family(0, Some((r1, s1)), w).map(|(_, _, id)| id), Some(2));
        // without the watermark both re-entries are visible, rank-ordered
        let all = rs.watermark();
        assert_eq!(rs.next_in_family(0, None, all).map(|(_, _, id)| id), Some(3));
    }

    #[test]
    fn run_set_preserves_admission_order() {
        let mut run = RunSet::new();
        for id in [7, 3, 9, 1] {
            run.insert(id);
        }
        assert!(run.remove(9));
        run.insert(9); // re-admitted: moves to the back, like Vec::push
        assert_eq!(run.iter().collect::<Vec<_>>(), vec![7, 3, 1, 9]);
        assert!(run.contains(9));
        assert!(!run.remove(42));
        assert_eq!(run.len(), 4);
        run.check_consistency().unwrap();
    }

    #[test]
    fn consistency_checks_pass_on_live_sets() {
        let mut rs = ReadySet::new();
        rs.insert(1, 2, 0.5, Some(Class::Car), 0.0, false);
        rs.insert(2, 0, -1.0, None, 0.0, true);
        rs.check_consistency().unwrap();
        rs.remove(1, 1.0);
        rs.check_consistency().unwrap();
    }
}
