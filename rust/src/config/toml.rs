//! A TOML-subset parser (serde/toml are unavailable offline).
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! string / float / integer / bool / homogeneous array values, `#`
//! comments, and blank lines. This covers every config file the project
//! ships; anything fancier is a parse error rather than silent
//! misinterpretation.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Float(f64),
    Int(i64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(v) => Some(v),
            _ => None,
        }
    }
}

/// Parsed document: dotted-path key → value (section headers are joined
/// with '.', e.g. `[scheduler] budget=1` → "scheduler.budget").
#[derive(Debug, Default, Clone)]
pub struct Doc {
    pub values: BTreeMap<String, Value>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl Doc {
    pub fn parse(text: &str) -> Result<Doc, TomlError> {
        let mut doc = Doc::default();
        let mut section = String::new();
        for (i, raw) in text.lines().enumerate() {
            let lineno = i + 1;
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(body) = line.strip_prefix('[') {
                let name = body.strip_suffix(']').ok_or(TomlError {
                    line: lineno,
                    msg: "unterminated section header".into(),
                })?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(TomlError { line: lineno, msg: "empty section name".into() });
                }
                section = name.to_string();
                continue;
            }
            let (key, val) = line.split_once('=').ok_or(TomlError {
                line: lineno,
                msg: format!("expected 'key = value', got '{line}'"),
            })?;
            let key = key.trim();
            if key.is_empty() {
                return Err(TomlError { line: lineno, msg: "empty key".into() });
            }
            let full_key = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            let value = parse_value(val.trim(), lineno)?;
            if doc.values.insert(full_key.clone(), value).is_some() {
                return Err(TomlError {
                    line: lineno,
                    msg: format!("duplicate key '{full_key}'"),
                });
            }
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<Doc, Box<dyn std::error::Error>> {
        let text = std::fs::read_to_string(path)?;
        Ok(Doc::parse(&text)?)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Value::as_str)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Value::as_f64)
    }

    pub fn get_i64(&self, key: &str) -> Option<i64> {
        self.get(key).and_then(Value::as_i64)
    }

    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Value::as_bool)
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string literal.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value, TomlError> {
    let err = |msg: String| TomlError { line: lineno, msg };
    if s.is_empty() {
        return Err(err("missing value".into()));
    }
    if let Some(body) = s.strip_prefix('"') {
        let body = body
            .strip_suffix('"')
            .ok_or_else(|| err("unterminated string".into()))?;
        if body.contains('"') {
            return Err(err("embedded quote in string".into()));
        }
        return Ok(Value::Str(body.to_string()));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(body) = s.strip_prefix('[') {
        let body = body
            .strip_suffix(']')
            .ok_or_else(|| err("unterminated array".into()))?;
        let mut items = Vec::new();
        for part in body.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            items.push(parse_value(part, lineno)?);
        }
        return Ok(Value::Array(items));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err(format!("cannot parse value '{s}'")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = Doc::parse(
            r#"
# top comment
title = "tcm"   # inline comment
[scheduler]
budget = 2048
aging = true
rate = 2.5
[scheduler.priority]
static = [0.1, 0.05, 0.0]
"#,
        )
        .unwrap();
        assert_eq!(doc.get_str("title"), Some("tcm"));
        assert_eq!(doc.get_i64("scheduler.budget"), Some(2048));
        assert_eq!(doc.get_bool("scheduler.aging"), Some(true));
        assert_eq!(doc.get_f64("scheduler.rate"), Some(2.5));
        let arr = doc.get("scheduler.priority.static").unwrap().as_array().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[0].as_f64(), Some(0.1));
    }

    #[test]
    fn int_coerces_to_f64() {
        let doc = Doc::parse("x = 3").unwrap();
        assert_eq!(doc.get_f64("x"), Some(3.0));
    }

    #[test]
    fn rejects_duplicates() {
        assert!(Doc::parse("a = 1\na = 2").is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Doc::parse("just words").is_err());
        assert!(Doc::parse("[unclosed").is_err());
        assert!(Doc::parse("x = \"unterminated").is_err());
        assert!(Doc::parse("x = [1, 2").is_err());
        assert!(Doc::parse("x = @wat").is_err());
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let doc = Doc::parse("x = \"a#b\"").unwrap();
        assert_eq!(doc.get_str("x"), Some("a#b"));
    }

    #[test]
    fn error_reports_line() {
        let e = Doc::parse("a = 1\nb = @").unwrap_err();
        assert_eq!(e.line, 2);
    }
}
