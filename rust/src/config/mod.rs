//! Typed configuration for the serving system: defaults follow the paper's
//! §4.1 experimental setup, overridable from a TOML file and/or CLI args.

// Parse paths handle untrusted input: every fallible conversion must
// surface a ConfigError, not panic. Mirrors simlint's `config-panic` rule
// (tests keep unwrap for brevity, hence not(test)).
#![cfg_attr(not(test), deny(clippy::unwrap_used, clippy::expect_used))]

pub mod toml;

use crate::request::Class;
use crate::util::cli::Args;
use toml::Doc;

/// Priority Regulator constants (paper §3.6 / §4.1):
/// `Priority_c = Static_c + (1 − e^{−k_c · wait^{p_c}})`.
#[derive(Debug, Clone, PartialEq)]
pub struct RegulatorConfig {
    /// StaticPriority per class [motorcycles, cars, trucks].
    pub static_priority: [f64; 3],
    /// Exponent p_c per class.
    pub p: [f64; 3],
    /// Rate k_c per class.
    pub k: [f64; 3],
    /// Disable to get the pure Static-Priority ablation (§3.4).
    pub aging_enabled: bool,
}

impl Default for RegulatorConfig {
    fn default() -> Self {
        RegulatorConfig {
            static_priority: [0.1, 0.05, 0.0],
            p: [3.5, 2.5, 1.1],
            k: [0.05, 0.003, 0.00075],
            aging_enabled: true,
        }
    }
}

impl RegulatorConfig {
    pub fn static_for(&self, c: Class) -> f64 {
        self.static_priority[c as usize]
    }

    pub fn k_for(&self, c: Class) -> f64 {
        self.k[c as usize]
    }

    pub fn p_for(&self, c: Class) -> f64 {
        self.p[c as usize]
    }
}

/// Continuous-batching scheduler knobs (vLLM-V1-style iteration loop).
#[derive(Debug, Clone, PartialEq)]
pub struct SchedulerConfig {
    /// Prefill token budget per iteration (chunked prefill chunk size).
    /// Default 512, the Sarathi-recommended chunk: it bounds the decode
    /// stall a single iteration can impose while keeping per-iteration
    /// launch overhead small.
    pub token_budget: u32,
    /// Maximum concurrently running sequences.
    pub max_running: usize,
    /// KV-cache page size in tokens (vLLM block size).
    pub kv_block_tokens: u32,
    /// CPU preprocess pool parallelism.
    pub preprocess_workers: usize,
    /// Require whole-prompt prefill in one chunk (the RealEngine's
    /// static-bucket artifacts do not support chunk resumption; the
    /// simulator supports both).
    pub atomic_prefill: bool,
    /// Use the indexed ready-set planner (default): waiting requests are
    /// kept pre-sorted per rank family and only visited heads are
    /// rescored, so per-iteration planning cost is near-constant in
    /// queue depth. `false` selects the original full-rescore oracle —
    /// O(n log n) per iteration — kept as an escape hatch; the two are
    /// proven bit-identical on events, reports and stats (minus
    /// `planning_evals`) by `tests/scheduler_properties.rs`.
    pub indexed: bool,
}

impl Default for SchedulerConfig {
    fn default() -> Self {
        SchedulerConfig {
            token_budget: 512,
            max_running: 256,
            kv_block_tokens: 16,
            preprocess_workers: 8,
            atomic_prefill: false,
            indexed: true,
        }
    }
}

/// Multi-replica cluster serving knobs (the `[cluster]` TOML section).
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Number of independent `Scheduler`+engine replicas. 1 keeps the
    /// single-engine behavior (a 1-replica round-robin cluster is
    /// bit-identical to a bare `Scheduler`, see `tests/cluster.rs`).
    pub replicas: usize,
    /// Router policy: round-robin | least-work | modality-partition.
    pub router: String,
    /// Run each replica's vision encoder concurrently with its
    /// prefill/decode pass (see `ModelProfile::encode_overlap`).
    pub encode_overlap: bool,
    /// Stream-sync penalty charged per overlapped iteration (seconds).
    pub overlap_penalty_s: f64,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            replicas: 1,
            router: "round-robin".into(),
            encode_overlap: false,
            overlap_penalty_s: 0.0005,
        }
    }
}

pub const ROUTERS: [&str; 3] = ["round-robin", "least-work", "modality-partition"];

/// Disaggregated encoder-pool knobs (the `[pool]` TOML section; see
/// `crate::cluster::pool`). Disabled by default: every pool-mode code
/// path is gated on `enabled`, keeping the cluster bit-identical to its
/// pre-pool behavior when off (proven in `tests/encoder_pool.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolConfig {
    /// Admit multimodal requests to a shared encoder pool instead of
    /// encoding inside each decode replica (`--encoder-pool`).
    pub enabled: bool,
    /// Encoder slots M in the pool (`--pool-slots`). Rocks are capped to
    /// ⌈M/2⌉ concurrent slots.
    pub slots: usize,
    /// A rock waiting longer than this outranks the pebble priority lane
    /// (`--pool-aging`), bounding rock encode-start delay.
    pub aging_deadline_s: f64,
    /// Embedding transfer cost in seconds per 1000 vision tokens, charged
    /// when the encode slot's host replica is not the late-bound decode
    /// replica (`--migration-cost`).
    pub migration_cost_s_per_ktok: f64,
    /// Pool-aware late binding (`--late-bind-epsilon`): at encode
    /// completion, ledger routers prefer the encode slot's host replica
    /// when its outstanding work is within this many seconds of the
    /// fleet minimum — a near-tie is not worth an embedding migration.
    /// 0.0 (the default) disables the preference entirely; the handoff
    /// path is then byte-identical to the plain ledger argmin.
    pub late_bind_epsilon_s: f64,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig {
            enabled: false,
            slots: 2,
            aging_deadline_s: 2.0,
            migration_cost_s_per_ktok: 0.002,
            late_bind_epsilon_s: 0.0,
        }
    }
}

/// Elastic control-plane knobs (the `[elastic]` TOML section; see
/// `crate::cluster::elastic`). Disabled by default: every elastic code
/// path is gated on `enabled`, keeping the cluster bit-identical to the
/// static partition router when off (proven in
/// `tests/elastic_properties.rs`).
#[derive(Debug, Clone, PartialEq)]
pub struct ElasticConfig {
    /// Run the controller (`--elastic`). Requires the
    /// modality-partition router, whose groups it re-partitions.
    pub enabled: bool,
    /// Controller evaluation period in virtual seconds
    /// (`--elastic-epoch`).
    pub epoch_s: f64,
    /// Dead band in replicas: a group's demand-driven target must
    /// deviate from its current size by more than this before a move
    /// starts (`--elastic-hysteresis`). Group sizes are integers, so a
    /// value >= 1 freezes re-partitioning entirely while keeping pool
    /// elasticity; the band is halved while any SLO class misses
    /// `attainment_floor`.
    pub hysteresis: f64,
    /// Controller epochs to stay quiet after a completed group flip or
    /// a pool resize (`--elastic-cooldown`).
    pub cooldown_epochs: u32,
    /// Encoder-pool slot floor under elastic shrink
    /// (`--elastic-slots-min`).
    pub slots_min: usize,
    /// Encoder-pool slot ceiling under elastic grow
    /// (`--elastic-slots-max`).
    pub slots_max: usize,
    /// Rolling TTFT-attainment floor per SLO class; dipping below it
    /// marks SLO pressure (faster controller reaction).
    pub attainment_floor: f64,
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig {
            enabled: false,
            epoch_s: 5.0,
            hysteresis: 0.25,
            cooldown_epochs: 2,
            slots_min: 1,
            slots_max: 8,
            attainment_floor: 0.9,
        }
    }
}

/// Observability knobs (the `[obs]` TOML section; see [`crate::obs`]).
/// All off by default: with no field set, no observer is attached and
/// backend behavior (events, reports, stats) is bit-identical to a
/// build without the obs module.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ObsConfig {
    /// Attach the observability decorator (`--obs`): record lifecycle
    /// spans and per-epoch telemetry even when no output path is set
    /// (the server exposes them via `metrics_text`/`telemetry_snapshot`).
    pub enabled: bool,
    /// Write a Chrome/Perfetto `trace_event` JSON file here at the end
    /// of the run (`--trace-out`). Implies `enabled`.
    pub trace_out: Option<String>,
    /// Write Prometheus-format telemetry text here at the end of the
    /// run (`--metrics-out`). Implies `enabled`.
    pub metrics_out: Option<String>,
}

impl ObsConfig {
    /// Whether any obs feature is requested (decorator attach point).
    pub fn active(&self) -> bool {
        self.enabled || self.trace_out.is_some() || self.metrics_out.is_some()
    }
}

/// Serving-front-end knobs (the `[server]` TOML section).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ServerConfig {
    /// Bounded admission (`--admission-limit`): the maximum outstanding
    /// (accepted but not yet terminal) requests the serving leader holds
    /// before answering new submissions with an immediate
    /// `ResponseEvent::Rejected` instead of buffering without bound.
    /// 0 (the default) keeps admission unbounded.
    pub admission_limit: usize,
}

/// Client-population workload knobs (the `[workload]` TOML section; see
/// [`crate::workload::population`]). The default engine ("poisson")
/// keeps trace generation bit-identical to the original `WorkloadGen`;
/// "population" selects the ServeGen-grade client-population engine.
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadConfig {
    /// Arrival engine: "poisson" (open-loop i.i.d., the original
    /// generator) | "population" (per-client MMPP / closed-loop /
    /// Poisson processes with multi-turn sessions).
    pub engine: String,
    /// Number of clients in the population (`--clients`).
    pub clients: usize,
    /// Unnormalized category weights [chat, agent, batch]; each client
    /// is deterministically assigned one category by position.
    pub category_weights: [f64; 3],
    /// MMPP duty cycle: fraction of time a chat client spends in its
    /// burst (on) phase (`--burst-duty`). Must be in (0, 1).
    pub burst_duty: f64,
    /// Burst intensity: on-phase session rate as a multiple of the
    /// client's mean rate (`--burst-boost`).
    pub burst_boost: f64,
    /// Mean burst (on-phase) length in seconds.
    pub burst_len_s: f64,
    /// Mean think time between session turns, seconds (`--think-time`).
    pub think_mean_s: f64,
    /// Mean turns per chat session, geometric (`--turns`).
    pub turns_mean: f64,
    /// Fraction of (prompt + output) carried into the next turn's
    /// context; 1.0 re-sends the full conversation.
    pub context_carry: f64,
    /// Piecewise-constant diurnal curve as flat (start_s, multiplier)
    /// pairs (`--diurnal "0:1,300:2.5"`); empty = flat 1.0.
    pub diurnal: Vec<f64>,
    /// Diurnal wrap period in seconds; 0 = no wrap (last segment holds).
    pub diurnal_period_s: f64,
    /// Mid-run traffic flip: sessions starting at/after this virtual
    /// time draw from `mix_flip_to` instead of the base mix
    /// (`--mix-flip-at`). Active only when `mix_flip_to` is set.
    pub mix_flip_at_s: f64,
    /// Mix name to flip to (T0|ML|MH|VH); empty = no flip.
    pub mix_flip_to: String,
    /// Trace scaling: tile + compress the generated trace to k× rate and
    /// k× request count with stable id remapping (`--scale-k`; 1 = off).
    pub scale_k: usize,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            engine: "poisson".into(),
            clients: 32,
            category_weights: [0.6, 0.25, 0.15],
            burst_duty: 0.25,
            burst_boost: 3.0,
            burst_len_s: 20.0,
            think_mean_s: 4.0,
            turns_mean: 3.0,
            context_carry: 1.0,
            diurnal: Vec::new(),
            diurnal_period_s: 0.0,
            mix_flip_at_s: 0.0,
            mix_flip_to: String::new(),
            scale_k: 1,
        }
    }
}

pub const WORKLOAD_ENGINES: [&str; 2] = ["poisson", "population"];

/// Top-level experiment/server configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Model profile name (Table 1) or "tiny-mllm" for the real engine.
    pub model: String,
    /// Workload mix: T0 | ML | MH | VH.
    pub mix: String,
    /// Poisson arrival rate (requests/second). Paper default: 2.
    pub rate: f64,
    /// Number of requests per experiment.
    pub num_requests: usize,
    pub seed: u64,
    /// Scheduling policy: fcfs | edf | naive-class | static-priority |
    /// naive-aging | tcm.
    pub policy: String,
    /// SLO = slo_scale × isolated end-to-end latency (paper: 5×).
    pub slo_scale: f64,
    /// Fraction of the profile's KV capacity available (memory pressure).
    pub memory_frac: f64,
    pub workload: WorkloadConfig,
    pub scheduler: SchedulerConfig,
    pub regulator: RegulatorConfig,
    pub cluster: ClusterConfig,
    pub pool: PoolConfig,
    pub elastic: ElasticConfig,
    pub server: ServerConfig,
    pub obs: ObsConfig,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "llava-7b".into(),
            mix: "MH".into(),
            rate: 2.0,
            num_requests: 1000,
            seed: 42,
            policy: "tcm".into(),
            slo_scale: 5.0,
            memory_frac: 1.0,
            workload: WorkloadConfig::default(),
            scheduler: SchedulerConfig::default(),
            regulator: RegulatorConfig::default(),
            cluster: ClusterConfig::default(),
            pool: PoolConfig::default(),
            elastic: ElasticConfig::default(),
            server: ServerConfig::default(),
            obs: ObsConfig::default(),
        }
    }
}

#[derive(Debug)]
pub struct ConfigError(pub String);

impl std::fmt::Display for ConfigError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config error: {}", self.0)
    }
}

impl std::error::Error for ConfigError {}

impl ServeConfig {
    /// The engine-side cost profile: the named model profile with the
    /// cluster's encode-overlap knob applied. Every simulated engine —
    /// single-scheduler `run_sim` and cluster replicas alike — must be
    /// built from this so `encode_overlap = true` means the same thing
    /// at any replica count.
    pub fn engine_profile(&self) -> crate::model::ModelProfile {
        #[allow(clippy::expect_used)]
        // simlint: allow(config-panic) — reached only after validate() checked the model name
        let profile = crate::model::by_name(&self.model).expect("validated model name");
        if self.cluster.encode_overlap {
            profile.with_encode_overlap(self.cluster.overlap_penalty_s)
        } else {
            profile
        }
    }

    /// Apply a parsed TOML document on top of the current values.
    pub fn apply_doc(&mut self, doc: &Doc) -> Result<(), ConfigError> {
        let known_prefixes = [
            "model", "mix", "rate", "num_requests", "seed", "policy", "slo_scale",
            "memory_frac", "workload.", "scheduler.", "regulator.", "cluster.", "pool.",
            "elastic.", "server.", "obs.",
        ];
        for key in doc.values.keys() {
            let known = known_prefixes.iter().any(|p| {
                if let Some(prefix) = p.strip_suffix('.') {
                    key == prefix || key.starts_with(p)
                } else {
                    key == p
                }
            });
            if !known {
                return Err(ConfigError(format!("unknown config key '{key}'")));
            }
        }
        if let Some(v) = doc.get_str("model") {
            self.model = v.to_string();
        }
        if let Some(v) = doc.get_str("mix") {
            self.mix = v.to_string();
        }
        if let Some(v) = doc.get_f64("rate") {
            self.rate = v;
        }
        if let Some(v) = doc.get_i64("num_requests") {
            self.num_requests = v as usize;
        }
        if let Some(v) = doc.get_i64("seed") {
            self.seed = v as u64;
        }
        if let Some(v) = doc.get_str("policy") {
            self.policy = v.to_string();
        }
        if let Some(v) = doc.get_f64("slo_scale") {
            self.slo_scale = v;
        }
        if let Some(v) = doc.get_f64("memory_frac") {
            self.memory_frac = v;
        }
        if let Some(v) = doc.get_str("workload.engine") {
            self.workload.engine = v.to_string();
        }
        if let Some(v) = doc.get_i64("workload.clients") {
            self.workload.clients = v as usize;
        }
        if let Some(val) = doc.get("workload.category_weights") {
            let arr = val
                .as_array()
                .ok_or_else(|| ConfigError("workload.category_weights must be an array".into()))?;
            if arr.len() != 3 {
                return Err(ConfigError(
                    "workload.category_weights must have 3 entries (chat, agent, batch)".into(),
                ));
            }
            let mut out = [0.0; 3];
            for (i, v) in arr.iter().enumerate() {
                out[i] = v.as_f64().ok_or_else(|| {
                    ConfigError(format!("workload.category_weights[{i}] must be numeric"))
                })?;
            }
            self.workload.category_weights = out;
        }
        if let Some(v) = doc.get_f64("workload.burst_duty") {
            self.workload.burst_duty = v;
        }
        if let Some(v) = doc.get_f64("workload.burst_boost") {
            self.workload.burst_boost = v;
        }
        if let Some(v) = doc.get_f64("workload.burst_len_s") {
            self.workload.burst_len_s = v;
        }
        if let Some(v) = doc.get_f64("workload.think_mean_s") {
            self.workload.think_mean_s = v;
        }
        if let Some(v) = doc.get_f64("workload.turns_mean") {
            self.workload.turns_mean = v;
        }
        if let Some(v) = doc.get_f64("workload.context_carry") {
            self.workload.context_carry = v;
        }
        if let Some(val) = doc.get("workload.diurnal") {
            let arr = val
                .as_array()
                .ok_or_else(|| ConfigError("workload.diurnal must be an array".into()))?;
            let mut out = Vec::with_capacity(arr.len());
            for (i, v) in arr.iter().enumerate() {
                out.push(v.as_f64().ok_or_else(|| {
                    ConfigError(format!("workload.diurnal[{i}] must be numeric"))
                })?);
            }
            self.workload.diurnal = out;
        }
        if let Some(v) = doc.get_f64("workload.diurnal_period_s") {
            self.workload.diurnal_period_s = v;
        }
        if let Some(v) = doc.get_f64("workload.mix_flip_at_s") {
            self.workload.mix_flip_at_s = v;
        }
        if let Some(v) = doc.get_str("workload.mix_flip_to") {
            self.workload.mix_flip_to = v.to_string();
        }
        if let Some(v) = doc.get_i64("workload.scale_k") {
            if v < 1 {
                return Err(ConfigError("workload.scale_k must be >= 1".into()));
            }
            self.workload.scale_k = v as usize;
        }
        if let Some(v) = doc.get_i64("scheduler.token_budget") {
            self.scheduler.token_budget = v as u32;
        }
        if let Some(v) = doc.get_i64("scheduler.max_running") {
            self.scheduler.max_running = v as usize;
        }
        if let Some(v) = doc.get_i64("scheduler.kv_block_tokens") {
            self.scheduler.kv_block_tokens = v as u32;
        }
        if let Some(v) = doc.get_i64("scheduler.preprocess_workers") {
            self.scheduler.preprocess_workers = v as usize;
        }
        if let Some(v) = doc.get_bool("scheduler.atomic_prefill") {
            self.scheduler.atomic_prefill = v;
        }
        if let Some(v) = doc.get_bool("scheduler.indexed") {
            self.scheduler.indexed = v;
        }
        if let Some(v) = doc.get_i64("cluster.replicas") {
            self.cluster.replicas = v as usize;
        }
        if let Some(v) = doc.get_str("cluster.router") {
            self.cluster.router = v.to_string();
        }
        if let Some(v) = doc.get_bool("cluster.encode_overlap") {
            self.cluster.encode_overlap = v;
        }
        if let Some(v) = doc.get_f64("cluster.overlap_penalty_s") {
            self.cluster.overlap_penalty_s = v;
        }
        if let Some(v) = doc.get_bool("pool.enabled") {
            self.pool.enabled = v;
        }
        if let Some(v) = doc.get_i64("pool.slots") {
            self.pool.slots = v as usize;
        }
        if let Some(v) = doc.get_f64("pool.aging_deadline_s") {
            self.pool.aging_deadline_s = v;
        }
        if let Some(v) = doc.get_f64("pool.migration_cost_s_per_ktok") {
            self.pool.migration_cost_s_per_ktok = v;
        }
        if let Some(v) = doc.get_f64("pool.late_bind_epsilon_s") {
            self.pool.late_bind_epsilon_s = v;
        }
        if let Some(v) = doc.get_bool("elastic.enabled") {
            self.elastic.enabled = v;
        }
        if let Some(v) = doc.get_f64("elastic.epoch_s") {
            self.elastic.epoch_s = v;
        }
        if let Some(v) = doc.get_f64("elastic.hysteresis") {
            self.elastic.hysteresis = v;
        }
        if let Some(v) = doc.get_i64("elastic.cooldown_epochs") {
            if !(0..=u32::MAX as i64).contains(&v) {
                return Err(ConfigError("elastic.cooldown_epochs must be >= 0".into()));
            }
            self.elastic.cooldown_epochs = v as u32;
        }
        if let Some(v) = doc.get_i64("elastic.slots_min") {
            self.elastic.slots_min = v as usize;
        }
        if let Some(v) = doc.get_i64("elastic.slots_max") {
            self.elastic.slots_max = v as usize;
        }
        if let Some(v) = doc.get_f64("elastic.attainment_floor") {
            self.elastic.attainment_floor = v;
        }
        if let Some(v) = doc.get_i64("server.admission_limit") {
            if v < 0 {
                return Err(ConfigError("server.admission_limit must be >= 0 (0 = off)".into()));
            }
            self.server.admission_limit = v as usize;
        }
        if let Some(v) = doc.get_bool("obs.enabled") {
            self.obs.enabled = v;
        }
        if let Some(v) = doc.get_str("obs.trace_out") {
            self.obs.trace_out = Some(v.to_string());
        }
        if let Some(v) = doc.get_str("obs.metrics_out") {
            self.obs.metrics_out = Some(v.to_string());
        }
        if let Some(v) = doc.get_bool("regulator.aging_enabled") {
            self.regulator.aging_enabled = v;
        }
        for (field, key) in [("static_priority", "regulator.static_priority"),
                             ("p", "regulator.p"), ("k", "regulator.k")] {
            if let Some(val) = doc.get(key) {
                let arr = val
                    .as_array()
                    .ok_or_else(|| ConfigError(format!("{key} must be an array")))?;
                if arr.len() != 3 {
                    return Err(ConfigError(format!("{key} must have 3 entries (M, C, T)")));
                }
                let mut out = [0.0; 3];
                for (i, v) in arr.iter().enumerate() {
                    out[i] = v
                        .as_f64()
                        .ok_or_else(|| ConfigError(format!("{key}[{i}] must be numeric")))?;
                }
                match field {
                    "static_priority" => self.regulator.static_priority = out,
                    "p" => self.regulator.p = out,
                    _ => self.regulator.k = out,
                }
            }
        }
        self.validate()
    }

    /// Apply CLI option overrides (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> Result<(), ConfigError> {
        let e = |s: crate::util::cli::CliError| ConfigError(s.0);
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        if let Some(v) = args.get("mix") {
            self.mix = v.to_string();
        }
        if let Some(v) = args.get("policy") {
            self.policy = v.to_string();
        }
        self.rate = args.get_f64("rate", self.rate).map_err(e)?;
        self.num_requests = args.get_usize("requests", self.num_requests).map_err(e)?;
        self.seed = args.get_u64("seed", self.seed).map_err(e)?;
        self.slo_scale = args.get_f64("slo-scale", self.slo_scale).map_err(e)?;
        self.memory_frac = args.get_f64("memory-frac", self.memory_frac).map_err(e)?;
        self.scheduler.token_budget =
            args.get_usize("token-budget", self.scheduler.token_budget as usize).map_err(e)?
                as u32;
        if let Some(v) = args.get("sched-indexed") {
            self.scheduler.indexed = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                other => {
                    return Err(ConfigError(format!(
                        "--sched-indexed expects true|false, got '{other}'"
                    )))
                }
            };
        }
        if let Some(v) = args.get("workload") {
            self.workload.engine = v.to_string();
        }
        self.workload.clients = args.get_usize("clients", self.workload.clients).map_err(e)?;
        self.workload.burst_duty =
            args.get_f64("burst-duty", self.workload.burst_duty).map_err(e)?;
        self.workload.burst_boost =
            args.get_f64("burst-boost", self.workload.burst_boost).map_err(e)?;
        self.workload.think_mean_s =
            args.get_f64("think-time", self.workload.think_mean_s).map_err(e)?;
        self.workload.turns_mean = args.get_f64("turns", self.workload.turns_mean).map_err(e)?;
        self.workload.mix_flip_at_s =
            args.get_f64("mix-flip-at", self.workload.mix_flip_at_s).map_err(e)?;
        if let Some(v) = args.get("mix-flip-to") {
            self.workload.mix_flip_to = v.to_string();
        }
        if let Some(v) = args.get("diurnal") {
            let mut out = Vec::new();
            for part in v.split(',') {
                let (t, m) = part.split_once(':').ok_or_else(|| {
                    ConfigError(format!("--diurnal expects start:mult pairs, got '{part}'"))
                })?;
                let t: f64 = t.trim().parse().map_err(|_| {
                    ConfigError(format!("--diurnal: bad start time '{}'", t.trim()))
                })?;
                let m: f64 = m.trim().parse().map_err(|_| {
                    ConfigError(format!("--diurnal: bad multiplier '{}'", m.trim()))
                })?;
                out.push(t);
                out.push(m);
            }
            self.workload.diurnal = out;
        }
        self.workload.scale_k = args.get_usize("scale-k", self.workload.scale_k).map_err(e)?;
        self.cluster.replicas = args.get_usize("replicas", self.cluster.replicas).map_err(e)?;
        if let Some(v) = args.get("router") {
            self.cluster.router = v.to_string();
        }
        if args.has_flag("encode-overlap") {
            self.cluster.encode_overlap = true;
        }
        self.cluster.overlap_penalty_s =
            args.get_f64("overlap-penalty", self.cluster.overlap_penalty_s).map_err(e)?;
        if args.has_flag("encoder-pool") {
            self.pool.enabled = true;
        }
        self.pool.slots = args.get_usize("pool-slots", self.pool.slots).map_err(e)?;
        self.pool.aging_deadline_s =
            args.get_f64("pool-aging", self.pool.aging_deadline_s).map_err(e)?;
        self.pool.migration_cost_s_per_ktok =
            args.get_f64("migration-cost", self.pool.migration_cost_s_per_ktok).map_err(e)?;
        self.pool.late_bind_epsilon_s =
            args.get_f64("late-bind-epsilon", self.pool.late_bind_epsilon_s).map_err(e)?;
        if args.has_flag("elastic") {
            self.elastic.enabled = true;
        }
        self.elastic.epoch_s = args.get_f64("elastic-epoch", self.elastic.epoch_s).map_err(e)?;
        self.elastic.hysteresis =
            args.get_f64("elastic-hysteresis", self.elastic.hysteresis).map_err(e)?;
        self.elastic.cooldown_epochs = args
            .get_usize("elastic-cooldown", self.elastic.cooldown_epochs as usize)
            .map_err(e)? as u32;
        self.elastic.slots_min =
            args.get_usize("elastic-slots-min", self.elastic.slots_min).map_err(e)?;
        self.elastic.slots_max =
            args.get_usize("elastic-slots-max", self.elastic.slots_max).map_err(e)?;
        self.server.admission_limit =
            args.get_usize("admission-limit", self.server.admission_limit).map_err(e)?;
        if args.has_flag("obs") {
            self.obs.enabled = true;
        }
        if let Some(v) = args.get("trace-out") {
            self.obs.trace_out = Some(v.to_string());
        }
        if let Some(v) = args.get("metrics-out") {
            self.obs.metrics_out = Some(v.to_string());
        }
        self.validate()
    }

    pub fn validate(&self) -> Result<(), ConfigError> {
        if crate::model::by_name(&self.model).is_none() {
            return Err(ConfigError(format!(
                "unknown model '{}' (expected one of {:?} or tiny-mllm)",
                self.model,
                crate::model::names()
            )));
        }
        if crate::workload::Mix::by_name(&self.mix).is_none() {
            return Err(ConfigError(format!("unknown mix '{}' (T0|ML|MH|VH)", self.mix)));
        }
        const POLICIES: [&str; 6] =
            ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"];
        if !POLICIES.contains(&self.policy.as_str()) {
            return Err(ConfigError(format!(
                "unknown policy '{}' (expected one of {POLICIES:?})",
                self.policy
            )));
        }
        if self.rate <= 0.0 {
            return Err(ConfigError("rate must be > 0".into()));
        }
        self.validate_workload()?;
        if !(0.0 < self.memory_frac && self.memory_frac <= 1.0) {
            return Err(ConfigError("memory_frac must be in (0, 1]".into()));
        }
        if self.scheduler.token_budget == 0 || self.scheduler.kv_block_tokens == 0 {
            return Err(ConfigError("scheduler token sizes must be > 0".into()));
        }
        if self.cluster.replicas == 0 || self.cluster.replicas > 256 {
            return Err(ConfigError("cluster.replicas must be in 1..=256".into()));
        }
        if !ROUTERS.contains(&self.cluster.router.as_str()) {
            return Err(ConfigError(format!(
                "unknown router '{}' (expected one of {ROUTERS:?})",
                self.cluster.router
            )));
        }
        if self.cluster.overlap_penalty_s < 0.0 {
            return Err(ConfigError("cluster.overlap_penalty_s must be >= 0".into()));
        }
        if self.pool.slots == 0 || self.pool.slots > 256 {
            return Err(ConfigError("pool.slots must be in 1..=256".into()));
        }
        if self.pool.aging_deadline_s < 0.0 {
            return Err(ConfigError("pool.aging_deadline_s must be >= 0".into()));
        }
        if self.pool.migration_cost_s_per_ktok < 0.0 {
            return Err(ConfigError("pool.migration_cost_s_per_ktok must be >= 0".into()));
        }
        if !self.pool.late_bind_epsilon_s.is_finite() || self.pool.late_bind_epsilon_s < 0.0 {
            return Err(ConfigError("pool.late_bind_epsilon_s must be finite and >= 0".into()));
        }
        if self.elastic.enabled {
            if self.cluster.router != "modality-partition" {
                return Err(ConfigError(format!(
                    "elastic.enabled requires cluster.router = \"modality-partition\" \
                     (the controller re-partitions its groups), got '{}'",
                    self.cluster.router
                )));
            }
            if !self.elastic.epoch_s.is_finite() || self.elastic.epoch_s <= 0.0 {
                return Err(ConfigError("elastic.epoch_s must be finite and > 0".into()));
            }
            if !self.elastic.hysteresis.is_finite() || self.elastic.hysteresis < 0.0 {
                return Err(ConfigError("elastic.hysteresis must be finite and >= 0".into()));
            }
            if self.elastic.slots_min == 0 || self.elastic.slots_max > 256 {
                return Err(ConfigError("elastic slot bounds must be in 1..=256".into()));
            }
            if self.elastic.slots_max < self.elastic.slots_min {
                return Err(ConfigError("elastic.slots_max must be >= elastic.slots_min".into()));
            }
            let floor = self.elastic.attainment_floor;
            if !floor.is_finite() || !(0.0..=1.0).contains(&floor) {
                return Err(ConfigError("elastic.attainment_floor must be in [0, 1]".into()));
            }
        }
        Ok(())
    }

    fn validate_workload(&self) -> Result<(), ConfigError> {
        let w = &self.workload;
        if !WORKLOAD_ENGINES.contains(&w.engine.as_str()) {
            return Err(ConfigError(format!(
                "unknown workload.engine '{}' (expected one of {WORKLOAD_ENGINES:?})",
                w.engine
            )));
        }
        if w.clients == 0 || w.clients > 100_000 {
            return Err(ConfigError("workload.clients must be in 1..=100000".into()));
        }
        let weights_ok = w.category_weights.iter().all(|x| x.is_finite() && *x >= 0.0)
            && w.category_weights.iter().sum::<f64>() > 0.0;
        if !weights_ok {
            return Err(ConfigError(
                "workload.category_weights must be finite, >= 0, with a positive sum".into(),
            ));
        }
        if !(w.burst_duty > 0.0 && w.burst_duty < 1.0) {
            return Err(ConfigError("workload.burst_duty must be in (0, 1)".into()));
        }
        if !w.burst_boost.is_finite() || w.burst_boost < 1.0 {
            return Err(ConfigError("workload.burst_boost must be finite and >= 1".into()));
        }
        if !w.burst_len_s.is_finite() || w.burst_len_s <= 0.0 {
            return Err(ConfigError("workload.burst_len_s must be finite and > 0".into()));
        }
        if !w.think_mean_s.is_finite() || w.think_mean_s <= 0.0 {
            return Err(ConfigError("workload.think_mean_s must be finite and > 0".into()));
        }
        if !w.turns_mean.is_finite() || w.turns_mean < 1.0 {
            return Err(ConfigError("workload.turns_mean must be finite and >= 1".into()));
        }
        if !w.context_carry.is_finite() || !(0.0..=1.0).contains(&w.context_carry) {
            return Err(ConfigError("workload.context_carry must be in [0, 1]".into()));
        }
        if w.diurnal.len() % 2 != 0 {
            return Err(ConfigError(
                "workload.diurnal must be flat (start_s, multiplier) pairs".into(),
            ));
        }
        if !w.diurnal.is_empty() {
            let mut last_t = f64::NEG_INFINITY;
            let mut any_positive = false;
            for pair in w.diurnal.chunks(2) {
                let (t, m) = (pair[0], pair[1]);
                if !t.is_finite() || !m.is_finite() || m < 0.0 {
                    return Err(ConfigError(
                        "workload.diurnal entries must be finite with multipliers >= 0".into(),
                    ));
                }
                if t <= last_t {
                    return Err(ConfigError(
                        "workload.diurnal start times must be strictly increasing".into(),
                    ));
                }
                last_t = t;
                any_positive |= m > 0.0;
            }
            if w.diurnal[0] != 0.0 {
                return Err(ConfigError("workload.diurnal must start at t = 0".into()));
            }
            if !any_positive {
                return Err(ConfigError(
                    "workload.diurnal needs at least one positive multiplier".into(),
                ));
            }
            if w.diurnal_period_s != 0.0
                && (!w.diurnal_period_s.is_finite() || w.diurnal_period_s <= last_t)
            {
                return Err(ConfigError(
                    "workload.diurnal_period_s must be 0 (no wrap) or beyond the last segment"
                        .into(),
                ));
            }
        }
        if !w.mix_flip_at_s.is_finite() || w.mix_flip_at_s < 0.0 {
            return Err(ConfigError("workload.mix_flip_at_s must be finite and >= 0".into()));
        }
        if !w.mix_flip_to.is_empty() && crate::workload::Mix::by_name(&w.mix_flip_to).is_none() {
            return Err(ConfigError(format!(
                "unknown workload.mix_flip_to '{}' (T0|ML|MH|VH)",
                w.mix_flip_to
            )));
        }
        if w.scale_k == 0 || w.scale_k > 1024 {
            return Err(ConfigError("workload.scale_k must be in 1..=1024".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper_setup() {
        let c = ServeConfig::default();
        assert_eq!(c.model, "llava-7b");
        assert_eq!(c.mix, "MH");
        assert_eq!(c.rate, 2.0);
        assert_eq!(c.slo_scale, 5.0);
        assert_eq!(c.regulator.static_priority, [0.1, 0.05, 0.0]);
        assert_eq!(c.regulator.p, [3.5, 2.5, 1.1]);
        assert_eq!(c.regulator.k, [0.05, 0.003, 0.00075]);
        c.validate().unwrap();
    }

    #[test]
    fn toml_overrides() {
        let mut c = ServeConfig::default();
        let doc = Doc::parse(
            r#"
model = "qwen-7b"
rate = 4.0
[scheduler]
token_budget = 1024
[regulator]
k = [0.1, 0.01, 0.001]
aging_enabled = false
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.model, "qwen-7b");
        assert_eq!(c.rate, 4.0);
        assert_eq!(c.scheduler.token_budget, 1024);
        assert_eq!(c.regulator.k, [0.1, 0.01, 0.001]);
        assert!(!c.regulator.aging_enabled);
    }

    #[test]
    fn rejects_unknown_key() {
        let mut c = ServeConfig::default();
        let doc = Doc::parse("modell = \"typo\"").unwrap();
        assert!(c.apply_doc(&doc).is_err());
    }

    #[test]
    fn rejects_bad_values() {
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("model = \"gpt-99\"").unwrap()).is_err());
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("rate = -1.0").unwrap()).is_err());
        let mut c = ServeConfig::default();
        assert!(c
            .apply_doc(&Doc::parse("[regulator]\nk = [0.1, 0.2]").unwrap())
            .is_err());
    }

    #[test]
    fn cluster_section_parses_and_validates() {
        let mut c = ServeConfig::default();
        assert_eq!(c.cluster, ClusterConfig::default());
        let doc = Doc::parse(
            r#"
[cluster]
replicas = 4
router = "modality-partition"
encode_overlap = true
overlap_penalty_s = 0.001
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.cluster.replicas, 4);
        assert_eq!(c.cluster.router, "modality-partition");
        assert!(c.cluster.encode_overlap);
        assert_eq!(c.cluster.overlap_penalty_s, 0.001);

        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[cluster]\nrouter = \"nope\"").unwrap()).is_err());
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[cluster]\nreplicas = 0").unwrap()).is_err());
    }

    #[test]
    fn pool_section_parses_and_validates() {
        let mut c = ServeConfig::default();
        assert_eq!(c.pool, PoolConfig::default());
        assert!(!c.pool.enabled, "the pool must be opt-in");
        let doc = Doc::parse(
            r#"
[pool]
enabled = true
slots = 6
aging_deadline_s = 1.5
migration_cost_s_per_ktok = 0.004
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert!(c.pool.enabled);
        assert_eq!(c.pool.slots, 6);
        assert_eq!(c.pool.aging_deadline_s, 1.5);
        assert_eq!(c.pool.migration_cost_s_per_ktok, 0.004);

        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[pool]\nslots = 0").unwrap()).is_err());
        let mut c = ServeConfig::default();
        assert!(c
            .apply_doc(&Doc::parse("[pool]\nmigration_cost_s_per_ktok = -1.0").unwrap())
            .is_err());
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[pool]\naging_deadline_s = -0.1").unwrap()).is_err());
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[pool]\nlate_bind_epsilon_s = -0.5").unwrap()).is_err());
    }

    #[test]
    fn elastic_section_parses_and_validates() {
        let mut c = ServeConfig::default();
        assert_eq!(c.elastic, ElasticConfig::default());
        assert!(!c.elastic.enabled, "the controller must be opt-in");
        let doc = Doc::parse(
            r#"
[cluster]
replicas = 4
router = "modality-partition"
[elastic]
enabled = true
epoch_s = 2.5
hysteresis = 0.5
cooldown_epochs = 3
slots_min = 2
slots_max = 12
attainment_floor = 0.8
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert!(c.elastic.enabled);
        assert_eq!(c.elastic.epoch_s, 2.5);
        assert_eq!(c.elastic.hysteresis, 0.5);
        assert_eq!(c.elastic.cooldown_epochs, 3);
        assert_eq!(c.elastic.slots_min, 2);
        assert_eq!(c.elastic.slots_max, 12);
        assert_eq!(c.elastic.attainment_floor, 0.8);
    }

    #[test]
    fn elastic_section_rejects_bad_values() {
        // enabling without the modality-partition router is an error —
        // the controller has no groups to re-partition
        let mut c = ServeConfig::default();
        assert!(c.apply_doc(&Doc::parse("[elastic]\nenabled = true").unwrap()).is_err());
        for bad in [
            "[elastic]\nenabled = true\nepoch_s = 0.0",
            "[elastic]\nenabled = true\nepoch_s = -1.0",
            "[elastic]\nenabled = true\nhysteresis = -0.1",
            "[elastic]\nenabled = true\ncooldown_epochs = -1",
            "[elastic]\nenabled = true\nslots_min = 0",
            "[elastic]\nenabled = true\nslots_min = 4\nslots_max = 2",
            "[elastic]\nenabled = true\nattainment_floor = 1.5",
        ] {
            let with_router = format!("[cluster]\nrouter = \"modality-partition\"\n{bad}");
            let mut c = ServeConfig::default();
            let doc = Doc::parse(&with_router).unwrap();
            assert!(c.apply_doc(&doc).is_err(), "accepted: {bad}");
        }
        // knobs without `enabled` never fail validation (inert)
        let mut c = ServeConfig::default();
        c.apply_doc(&Doc::parse("[elastic]\nepoch_s = -5.0").unwrap()).unwrap();
        assert!(!c.elastic.enabled);
    }

    #[test]
    fn server_section_and_late_bind_epsilon_parse() {
        let mut c = ServeConfig::default();
        assert_eq!(c.server, ServerConfig::default());
        assert_eq!(c.server.admission_limit, 0, "admission must default to unbounded");
        assert_eq!(c.pool.late_bind_epsilon_s, 0.0, "host preference must default off");
        let doc = Doc::parse(
            r#"
[server]
admission_limit = 128
[pool]
late_bind_epsilon_s = 0.25
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.server.admission_limit, 128);
        assert_eq!(c.pool.late_bind_epsilon_s, 0.25);

        let mut c = ServeConfig::default();
        assert!(
            c.apply_doc(&Doc::parse("[server]\nadmission_limit = -1").unwrap()).is_err(),
            "a negative limit must not wrap to unbounded"
        );
    }

    #[test]
    fn obs_section_and_flags_parse() {
        let mut c = ServeConfig::default();
        assert_eq!(c.obs, ObsConfig::default());
        assert!(!c.obs.active(), "obs must default to fully off");
        let doc = Doc::parse(
            r#"
[obs]
enabled = true
trace_out = "trace.json"
metrics_out = "metrics.prom"
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert!(c.obs.enabled);
        assert_eq!(c.obs.trace_out.as_deref(), Some("trace.json"));
        assert_eq!(c.obs.metrics_out.as_deref(), Some("metrics.prom"));
        assert!(c.obs.active());

        // any output path implies active() without the flag
        let c = ServeConfig {
            obs: ObsConfig { trace_out: Some("t.json".into()), ..ObsConfig::default() },
            ..ServeConfig::default()
        };
        assert!(c.obs.active());
    }

    #[test]
    fn workload_section_parses_and_validates() {
        let mut c = ServeConfig::default();
        assert_eq!(c.workload, WorkloadConfig::default());
        assert_eq!(c.workload.engine, "poisson", "default engine must stay bit-compatible");
        let doc = Doc::parse(
            r#"
[workload]
engine = "population"
clients = 64
category_weights = [0.5, 0.3, 0.2]
burst_duty = 0.2
burst_boost = 4.0
burst_len_s = 15.0
think_mean_s = 2.0
turns_mean = 4.0
context_carry = 0.8
diurnal = [0.0, 1.0, 300.0, 2.5]
diurnal_period_s = 600.0
mix_flip_at_s = 120.0
mix_flip_to = "T0"
scale_k = 4
"#,
        )
        .unwrap();
        c.apply_doc(&doc).unwrap();
        assert_eq!(c.workload.engine, "population");
        assert_eq!(c.workload.clients, 64);
        assert_eq!(c.workload.category_weights, [0.5, 0.3, 0.2]);
        assert_eq!(c.workload.burst_duty, 0.2);
        assert_eq!(c.workload.diurnal, vec![0.0, 1.0, 300.0, 2.5]);
        assert_eq!(c.workload.diurnal_period_s, 600.0);
        assert_eq!(c.workload.mix_flip_to, "T0");
        assert_eq!(c.workload.scale_k, 4);
    }

    #[test]
    fn workload_section_rejects_bad_values() {
        for bad in [
            "[workload]\nengine = \"quantum\"",
            "[workload]\nclients = 0",
            "[workload]\nburst_duty = 1.5",
            "[workload]\nburst_boost = 0.5",
            "[workload]\nturns_mean = 0.0",
            "[workload]\ncontext_carry = 2.0",
            "[workload]\ncategory_weights = [0.0, 0.0, 0.0]",
            "[workload]\ndiurnal = [0.0, 1.0, 300.0]",
            "[workload]\ndiurnal = [10.0, 1.0, 300.0, 2.0]",
            "[workload]\ndiurnal = [0.0, 1.0, 300.0, 2.0]\ndiurnal_period_s = 100.0",
            "[workload]\nmix_flip_to = \"XX\"",
            "[workload]\nscale_k = 0",
        ] {
            let mut c = ServeConfig::default();
            assert!(c.apply_doc(&Doc::parse(bad).unwrap()).is_err(), "accepted: {bad}");
        }
    }

    #[test]
    fn regulator_class_accessors() {
        use crate::request::Class;
        let r = RegulatorConfig::default();
        assert_eq!(r.static_for(Class::Motorcycle), 0.1);
        assert_eq!(r.k_for(Class::Truck), 0.00075);
        assert_eq!(r.p_for(Class::Car), 2.5);
    }
}
