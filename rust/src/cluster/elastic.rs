//! Elastic control plane: a deterministic, virtual-time control loop
//! that re-partitions the modality replica groups and resizes the
//! encoder pool as the traffic mix shifts (ElasticMM, arXiv 2507.10069).
//!
//! Every prior layer is a *mechanism*: the partition router confines
//! rocks, the pool caps concurrent video encodes, the workload engine
//! flips the mix mid-run. This module is the first *policy* layer that
//! composes them. It runs on `ServeBackend::step` epoch boundaries (a
//! fixed virtual-time grid, `elastic.epoch_s` apart), watches per-group
//! demand and the per-SLO-class TTFT-attainment windows of the PR-7
//! [`Telemetry`] ring, and emits three kinds of actions for the owning
//! [`super::Cluster`] to apply:
//!
//! * **repartition** — move one replica between the sand/pebble/rock
//!   groups via [`super::router::Router::set_groups`], but only through
//!   the drain-then-reassign protocol below;
//! * **pool resize** — grow/shrink [`super::EncoderPool`] slots between
//!   `elastic.slots_min` and `elastic.slots_max`;
//! * **nothing** — the common case: hysteresis and cooldowns keep the
//!   controller quiet while the static split is within tolerance.
//!
//! # Drain-then-reassign
//!
//! A replica is never moved while it owns work. The controller first
//! marks it *draining* ([`super::router::ReplicaView::draining`]): the
//! router stops sending it new work — including sand's idle-borrowing,
//! which would otherwise keep touching an idle-but-draining replica
//! forever — while everything it already owns finishes normally (or
//! migrates under the PR-4 cost model when the encoder pool late-binds
//! a handoff away from a draining host). Only when the replica reports
//! zero active requests *and* zero KV blocks does the group flip
//! happen, so no request is ever lost, double-owned, or torn mid-KV.
//! One drain is in flight at a time, and the donor group always keeps
//! at least one member.
//!
//! # Determinism
//!
//! The controller is part of the sim core (simlint-covered): decisions
//! are pure functions of virtual time, integer queue depths, and the
//! telemetry windows — no wall clock, no entropy, no hash iteration —
//! so elastic runs rerun bit-identically, and with `elastic.enabled =
//! false` the controller is never constructed and the cluster is
//! bit-identical to the static partition router
//! (`tests/elastic_properties.rs` pins both).

use crate::config::ElasticConfig;
use crate::metrics::Report;
use crate::obs::telemetry::Telemetry;
use crate::obs::Probe;

use super::router::partition_groups_with;

/// Rough engine-seconds per *queued request* of each modality (text,
/// image, video), used to convert observed queue depths into work
/// shares. The absolute scale cancels in the normalization; only the
/// ratios matter, and they mirror the paper's characterization: an
/// image costs a few text requests, a video costs tens (encode +
/// a multi-thousand-token prefill).
const DEMAND_WEIGHTS: [f64; 3] = [1.0, 4.0, 30.0];

/// Controller decision counters, surfaced in
/// [`super::ClusterReport::elastic`] and the CLI summary.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ElasticStats {
    /// Controller evaluations (epoch boundaries crossed).
    pub epochs: u64,
    /// Drains started (a replica marked draining toward a new group).
    pub drains_started: u64,
    /// Completed group flips (== repartitions applied to the router).
    pub repartitions: u64,
    /// Pool grow/shrink *intents* emitted; the pool's own
    /// `slot_grow_events`/`slot_shrink_events` count what actually
    /// happened (a shrink can be partially blocked by busy slots).
    pub slot_grows: u64,
    pub slot_shrinks: u64,
    /// Peak `active_requests()` observed on a replica at the instant it
    /// flipped groups. The drain protocol guarantees 0; the property
    /// suite asserts it.
    pub max_active_at_flip: usize,
    /// Peak KV blocks observed on a replica at the instant it flipped.
    pub max_kv_at_flip: u64,
}

/// Point-in-time controller description embedded in the cluster report.
#[derive(Debug, Clone)]
pub struct ElasticSnapshot {
    pub stats: ElasticStats,
    /// Final (sand, pebble, rock) partition.
    pub sand: Vec<usize>,
    pub pebble: Vec<usize>,
    pub rock: Vec<usize>,
    /// Rolling per-SLO-class TTFT attainment at snapshot time.
    pub ttft_attainment: [f64; 3],
}

/// Everything the controller reads at an epoch boundary. Assembled by
/// [`super::Cluster`]; plain data so the decision logic stays a pure
/// function.
pub struct EpochInputs<'a> {
    pub now: f64,
    /// Fleet-wide telemetry probe (summed queues, pool gauges).
    pub probe: Probe,
    /// Per-replica `(active_requests, kv_used_blocks)`.
    pub occupancy: &'a [(usize, u64)],
    /// Current router partition; `None` for group-free routers (the
    /// controller then only manages the pool).
    pub groups: Option<(Vec<usize>, Vec<usize>, Vec<usize>)>,
    /// `(slots, busy_slots, queue_depth)` when the pool exists.
    pub pool: Option<(usize, usize, usize)>,
}

/// Actions for the owning cluster to apply, in order.
#[derive(Debug, Clone, PartialEq)]
pub enum ElasticAction {
    /// Mark `replica` draining (no new work routed to it).
    StartDrain { replica: usize },
    /// Apply a completed drain: flip the drained replica's group via
    /// `Router::set_groups`.
    Repartition { sand: Vec<usize>, pebble: Vec<usize>, rock: Vec<usize> },
    /// Resize the encoder pool toward `target` slots.
    ResizePool { target: usize },
}

/// An in-flight drain: the replica being emptied and the partition that
/// takes effect once it is.
#[derive(Debug, Clone)]
struct DrainPlan {
    replica: usize,
    sand: Vec<usize>,
    pebble: Vec<usize>,
    rock: Vec<usize>,
}

/// The control loop. Owned by [`super::Cluster`] as `Option<_>`
/// (mirroring the pool: every elastic code path is gated on `Some`).
pub struct ElasticController {
    cfg: ElasticConfig,
    telemetry: Telemetry,
    next_epoch_t: f64,
    /// Controller evaluations to skip before the next repartition
    /// decision (set after every completed flip).
    cooldown: u32,
    pool_cooldown: u32,
    drain: Option<DrainPlan>,
    pub stats: ElasticStats,
}

impl ElasticController {
    pub fn new(cfg: ElasticConfig) -> ElasticController {
        let first = cfg.epoch_s;
        ElasticController {
            cfg,
            telemetry: Telemetry::new(),
            next_epoch_t: first,
            cooldown: 0,
            pool_cooldown: 0,
            drain: None,
            stats: ElasticStats::default(),
        }
    }

    /// The replica currently draining, if any (marks
    /// [`super::router::ReplicaView::draining`]).
    pub fn draining_replica(&self) -> Option<usize> {
        self.drain.as_ref().map(|d| d.replica)
    }

    /// Feed terminal outcomes into the TTFT-attainment windows (called
    /// from the cluster's reap path with each partial report).
    pub fn on_finished(&mut self, report: &Report) {
        self.telemetry.on_finished(report);
    }

    /// Has the virtual clock crossed the next epoch boundary?
    pub fn epoch_due(&self, now: f64) -> bool {
        now >= self.next_epoch_t
    }

    /// Evaluate one controller epoch. Multiple grid points crossed since
    /// the last call collapse into a single evaluation (the fleet state
    /// in between is gone); the next boundary is the first grid point
    /// strictly after `now`.
    pub fn step_epoch(&mut self, inputs: EpochInputs<'_>) -> Vec<ElasticAction> {
        debug_assert!(self.epoch_due(inputs.now));
        let epoch = self.cfg.epoch_s.max(f64::MIN_POSITIVE);
        while self.next_epoch_t <= inputs.now {
            self.next_epoch_t += epoch;
        }
        self.stats.epochs += 1;
        self.telemetry.push(inputs.probe);

        let mut actions = Vec::new();

        // SLO pressure: when any class with samples is missing its TTFT
        // budget, halve the hysteresis so the controller reacts sooner.
        let snap = self.telemetry.snapshot();
        let mut pressed = false;
        for (&att, &n) in snap.ttft_attainment.iter().zip(snap.ttft_samples.iter()) {
            if n > 0 && att < self.cfg.attainment_floor {
                pressed = true;
            }
        }
        let hysteresis = if pressed { self.cfg.hysteresis * 0.5 } else { self.cfg.hysteresis };

        self.repartition_epoch(&inputs, hysteresis, &mut actions);
        self.pool_epoch(&inputs, &mut actions);
        actions
    }

    /// Group-repartition half of the epoch: finish an in-flight drain,
    /// or look for a deficit/surplus pair worth moving a replica for.
    fn repartition_epoch(
        &mut self,
        inputs: &EpochInputs<'_>,
        hysteresis: f64,
        actions: &mut Vec<ElasticAction>,
    ) {
        // An in-flight drain blocks new repartition decisions until it
        // completes: one replica moves at a time.
        if let Some(draining) = self.drain.as_ref().map(|d| d.replica) {
            let (active, kv) = inputs.occupancy.get(draining).copied().unwrap_or((0, 0));
            if active == 0 && kv == 0 {
                let plan = self.drain.take().expect("drain checked above");
                self.stats.max_active_at_flip = self.stats.max_active_at_flip.max(active);
                self.stats.max_kv_at_flip = self.stats.max_kv_at_flip.max(kv);
                self.stats.repartitions += 1;
                self.cooldown = self.cfg.cooldown_epochs;
                actions.push(ElasticAction::Repartition {
                    sand: plan.sand,
                    pebble: plan.pebble,
                    rock: plan.rock,
                });
            }
            return;
        }
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return;
        }
        let Some((sand, pebble, rock)) = inputs.groups.clone() else {
            return;
        };
        let n = inputs.occupancy.len();
        if n < 3 {
            // 1- and 2-replica fleets share groups; nothing to move
            return;
        }

        // Observed per-modality demand (waiting + running), weighted
        // into engine-second shares.
        let p = &inputs.probe;
        let mut demand = [0.0f64; 3];
        let mut total = 0.0;
        for m in 0..3 {
            demand[m] =
                (p.waiting[m] as f64 + p.running[m] as f64) * DEMAND_WEIGHTS[m];
            total += demand[m];
        }
        // The pool queue is rock/pebble demand the replicas can't see
        // yet; attribute it to the heavier classes it holds.
        if let Some((_, _, queue)) = inputs.pool {
            demand[2] += queue as f64 * DEMAND_WEIGHTS[2] * 0.5;
            demand[1] += queue as f64 * DEMAND_WEIGHTS[1] * 0.5;
            total += queue as f64 * (DEMAND_WEIGHTS[2] + DEMAND_WEIGHTS[1]) * 0.5;
        }
        if total <= 0.0 {
            // quiet fleet: leave the partition alone
            return;
        }
        let shares = [demand[0] / total, demand[1] / total, demand[2] / total];
        let (tgt_sand, tgt_pebble, tgt_rock) = partition_groups_with(n, shares);
        let target = [tgt_sand.len() as f64, tgt_pebble.len() as f64, tgt_rock.len() as f64];
        let current = [sand.len() as f64, pebble.len() as f64, rock.len() as f64];

        // Largest deficit above the hysteresis band receives; the donor
        // is the group with the largest surplus (also above the band)
        // that can spare a member. Ties break toward the lower group
        // index — sand first — deterministically.
        let mut receiver: Option<(usize, f64)> = None;
        let mut donor: Option<(usize, f64)> = None;
        let groups_by_idx = [&sand, &pebble, &rock];
        for g in 0..3 {
            let deficit = target[g] - current[g];
            let better_recv = match receiver {
                None => true,
                Some((_, best)) => deficit > best,
            };
            if deficit > hysteresis && better_recv {
                receiver = Some((g, deficit));
            }
            let surplus = current[g] - target[g];
            let better_donor = match donor {
                None => true,
                Some((_, best)) => surplus > best,
            };
            if surplus > hysteresis && groups_by_idx[g].len() >= 2 && better_donor {
                donor = Some((g, surplus));
            }
        }
        let (Some((to, _)), Some((from, _))) = (receiver, donor) else {
            return;
        };
        if to == from {
            return;
        }
        // Donor replica: least active (drains fastest), ties to the
        // lowest id.
        let moved = groups_by_idx[from]
            .iter()
            .copied()
            .min_by_key(|&i| (inputs.occupancy.get(i).map_or(0, |o| o.0), i))
            .expect("donor group has >= 2 members");
        let mut next = [sand.clone(), pebble.clone(), rock.clone()];
        next[from].retain(|&i| i != moved);
        next[to].push(moved);
        next[to].sort_unstable();
        let [ns, np, nr] = next;
        self.stats.drains_started += 1;
        self.drain =
            Some(DrainPlan { replica: moved, sand: ns, pebble: np, rock: nr });
        actions.push(ElasticAction::StartDrain { replica: moved });
    }

    /// Pool half of the epoch: one slot per decision, with its own
    /// cooldown. Grow while work queues behind a saturated pool; shrink
    /// when the pool is quiet and holds more than one idle slot.
    fn pool_epoch(&mut self, inputs: &EpochInputs<'_>, actions: &mut Vec<ElasticAction>) {
        let Some((slots, busy, queue)) = inputs.pool else {
            return;
        };
        if self.pool_cooldown > 0 {
            self.pool_cooldown -= 1;
            return;
        }
        if queue > 0 && busy == slots && slots < self.cfg.slots_max {
            self.stats.slot_grows += 1;
            self.pool_cooldown = self.cfg.cooldown_epochs;
            actions.push(ElasticAction::ResizePool { target: slots + 1 });
        } else if queue == 0 && busy + 1 < slots && slots > self.cfg.slots_min {
            self.stats.slot_shrinks += 1;
            self.pool_cooldown = self.cfg.cooldown_epochs;
            actions.push(ElasticAction::ResizePool { target: slots - 1 });
        }
    }

    /// Snapshot for [`super::ClusterReport`]; `groups` is the router's
    /// current partition (the controller doesn't own it).
    pub fn snapshot(
        &self,
        groups: Option<(&[usize], &[usize], &[usize])>,
    ) -> ElasticSnapshot {
        let (sand, pebble, rock) = match groups {
            Some((s, p, r)) => (s.to_vec(), p.to_vec(), r.to_vec()),
            None => (Vec::new(), Vec::new(), Vec::new()),
        };
        ElasticSnapshot {
            stats: self.stats.clone(),
            sand,
            pebble,
            rock,
            ttft_attainment: self.telemetry.snapshot().ttft_attainment,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ElasticConfig {
        ElasticConfig {
            enabled: true,
            epoch_s: 5.0,
            hysteresis: 0.25,
            cooldown_epochs: 1,
            slots_min: 1,
            slots_max: 8,
            attainment_floor: 0.9,
        }
    }

    fn probe(waiting: [u32; 3], running: [u32; 3]) -> Probe {
        Probe { t: 5.0, waiting, running, ..Probe::default() }
    }

    fn groups4() -> Option<(Vec<usize>, Vec<usize>, Vec<usize>)> {
        let (s, p, r) = super::super::router::partition_groups(4);
        Some((s, p, r))
    }

    #[test]
    fn epoch_grid_is_virtual_time_only() {
        let mut c = ElasticController::new(cfg());
        assert!(!c.epoch_due(4.9));
        assert!(c.epoch_due(5.0));
        let occ = [(0usize, 0u64); 4];
        let _ = c.step_epoch(EpochInputs {
            now: 12.3,
            probe: probe([0; 3], [0; 3]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        // next boundary is the first grid point strictly after now
        assert!(!c.epoch_due(14.9));
        assert!(c.epoch_due(15.0));
        assert_eq!(c.stats.epochs, 1);
    }

    #[test]
    fn quiet_fleet_makes_no_moves() {
        let mut c = ElasticController::new(cfg());
        let occ = [(0usize, 0u64); 4];
        let acts = c.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([0; 3], [0; 3]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        assert!(acts.is_empty());
        assert!(c.draining_replica().is_none());
    }

    #[test]
    fn text_flood_drains_a_rock_then_flips_after_empty() {
        let mut c = ElasticController::new(cfg());
        // static split at n=4 is sand=[0], pebble=[1], rock=[2,3]; a pure
        // text flood wants sand=2 — a rock replica must be drained
        let occ = [(5usize, 10u64), (0, 0), (2, 4), (1, 2)];
        let acts = c.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([40, 0, 0], [4, 0, 0]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        // replica 3 is the least-active rock: it drains
        assert_eq!(acts, vec![ElasticAction::StartDrain { replica: 3 }]);
        assert_eq!(c.draining_replica(), Some(3));
        assert_eq!(c.stats.drains_started, 1);

        // still busy at the next epoch: no flip yet, and no second drain
        let occ_busy = [(5usize, 10u64), (0, 0), (2, 4), (1, 2)];
        let acts = c.step_epoch(EpochInputs {
            now: 10.0,
            probe: probe([40, 0, 0], [4, 0, 0]),
            occupancy: &occ_busy,
            groups: groups4(),
            pool: None,
        });
        assert!(acts.is_empty());
        assert_eq!(c.draining_replica(), Some(3));

        // empty: the flip lands, moving 3 into the sand group
        let occ_empty = [(5usize, 10u64), (0, 0), (2, 4), (0, 0)];
        let acts = c.step_epoch(EpochInputs {
            now: 15.0,
            probe: probe([40, 0, 0], [4, 0, 0]),
            occupancy: &occ_empty,
            groups: groups4(),
            pool: None,
        });
        assert_eq!(
            acts,
            vec![ElasticAction::Repartition {
                sand: vec![0, 3],
                pebble: vec![1],
                rock: vec![2]
            }]
        );
        assert!(c.draining_replica().is_none());
        assert_eq!(c.stats.repartitions, 1);
        assert_eq!(c.stats.max_active_at_flip, 0);
        assert_eq!(c.stats.max_kv_at_flip, 0);
    }

    #[test]
    fn video_heavy_matches_static_split_and_stays_put() {
        let mut c = ElasticController::new(cfg());
        let occ = [(1usize, 2u64); 4];
        let acts = c.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([2, 1, 6], [1, 1, 2]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        assert!(acts.is_empty(), "video-heavy demand matches the static split: {acts:?}");
    }

    #[test]
    fn minimal_fleets_never_repartition() {
        let mut c = ElasticController::new(cfg());
        // n=3 is the smallest fleet with distinct groups, and the sizing
        // clamps pin its target at (1,1,1) — a flood can never create a
        // deficit, so every group keeps its one member
        let (s, p, r) = super::super::router::partition_groups(3);
        let occ = [(5usize, 1u64), (0, 0), (0, 0)];
        let acts = c.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([50, 0, 0], [3, 0, 0]),
            occupancy: &occ,
            groups: Some((s, p, r)),
            pool: None,
        });
        assert!(acts.is_empty(), "n=3 targets are pinned at (1,1,1): {acts:?}");
        // n=2 shares groups outright; the controller refuses to touch it
        let occ2 = [(9usize, 9u64), (0, 0)];
        let acts = c.step_epoch(EpochInputs {
            now: 10.0,
            probe: probe([50, 0, 0], [3, 0, 0]),
            occupancy: &occ2,
            groups: Some((vec![0], vec![1], vec![1])),
            pool: None,
        });
        assert!(acts.is_empty(), "n<3 fleets must stay put: {acts:?}");
    }

    #[test]
    fn pool_grows_under_queue_and_shrinks_when_quiet() {
        let mut c = ElasticController::new(cfg());
        let occ = [(0usize, 0u64); 4];
        let mk = |now: f64, pool| EpochInputs {
            now,
            probe: probe([0; 3], [0; 3]),
            occupancy: &occ,
            groups: None,
            pool,
        };
        // saturated with a queue: grow one slot
        let acts = c.step_epoch(mk(5.0, Some((2, 2, 3))));
        assert_eq!(acts, vec![ElasticAction::ResizePool { target: 3 }]);
        // cooldown epoch: no action even though still saturated
        let acts = c.step_epoch(mk(10.0, Some((3, 3, 1))));
        assert!(acts.is_empty());
        // quiet with idle slots: shrink one
        let acts = c.step_epoch(mk(15.0, Some((3, 1, 0))));
        assert_eq!(acts, vec![ElasticAction::ResizePool { target: 2 }]);
        // at the floor: never below slots_min
        let mut c2 = ElasticController::new(cfg());
        let acts = c2.step_epoch(mk(5.0, Some((1, 0, 0))));
        assert!(acts.is_empty());
        // at the ceiling: never above slots_max
        let mut c3 = ElasticController::new(ElasticConfig { slots_max: 2, ..cfg() });
        let acts = c3.step_epoch(mk(5.0, Some((2, 2, 5))));
        assert!(acts.is_empty());
    }

    #[test]
    fn large_hysteresis_suppresses_a_unit_deficit() {
        // group-size deficits are integers, so a hysteresis band >= 1.0
        // freezes the partition no matter how skewed the demand gets —
        // the knob callers use to pin groups while keeping pool elasticity
        let frozen = ElasticConfig { hysteresis: 1.5, ..cfg() };
        let mut c = ElasticController::new(frozen);
        let occ = [(0usize, 0u64); 4];
        let acts = c.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([200, 0, 0], [4, 0, 0]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        assert!(acts.is_empty(), "hysteresis 1.5 must swallow a deficit of 1: {acts:?}");
        // the same demand under the default band moves a replica
        let mut c2 = ElasticController::new(cfg());
        let acts = c2.step_epoch(EpochInputs {
            now: 5.0,
            probe: probe([200, 0, 0], [4, 0, 0]),
            occupancy: &occ,
            groups: groups4(),
            pool: None,
        });
        assert_eq!(acts.len(), 1);
    }

    #[test]
    fn decisions_are_bit_deterministic() {
        let run = || {
            let mut c = ElasticController::new(cfg());
            let occ = [(3usize, 6u64), (1, 1), (2, 2), (0, 0)];
            let mut log = Vec::new();
            for k in 1..=6u32 {
                let t = 5.0 * k as f64;
                if c.epoch_due(t) {
                    log.push(c.step_epoch(EpochInputs {
                        now: t,
                        probe: probe([30, 2, 1], [3, 1, 1]),
                        occupancy: &occ,
                        groups: groups4(),
                        pool: Some((2, 2, 4)),
                    }));
                }
            }
            (log, c.stats.clone())
        };
        let (a, sa) = run();
        let (b, sb) = run();
        assert_eq!(a, b);
        assert_eq!(sa, sb);
    }
}
