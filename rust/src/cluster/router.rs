//! Modality-aware request routing across engine replicas.
//!
//! A [`Router`] picks the replica for each arriving request before the
//! per-replica scheduler ever sees it. This is the cluster-level analogue
//! of the paper's insight: a rock routed onto the replica serving sand
//! recreates head-of-line blocking one level up, no matter how good the
//! within-replica scheduler is (ElasticMM, arXiv 2507.10069, makes the
//! same observation with modality-decoupled instance groups).
//!
//! Three policies:
//! * [`RoundRobinRouter`] — the load-oblivious baseline;
//! * [`LeastWorkRouter`] — least outstanding *predicted* work, using the
//!   same [`ImpactEstimator`] the TCM policy classifies with: each routed
//!   request charges its predicted pre-first-token cost to its replica's
//!   ledger until the request finishes or is dropped;
//! * [`ModalityPartitionRouter`] — rocks/pebbles/sand partitioning with
//!   elastic spillover: replicas are split into sand (text), pebble
//!   (image) and rock (video) groups; sand may borrow *idle* pebble/rock
//!   replicas, images may borrow idle rock replicas, but rocks may never
//!   displace sand — a video is confined to the rock group even when
//!   every sand replica sits idle, because a single admitted video
//!   poisons that replica's latency for seconds.

use crate::config::ServeConfig;
use crate::coordinator::estimator::ImpactEstimator;
use crate::coordinator::profiler::Profiler;
use crate::model::ModelProfile;
use crate::request::{Modality, Request};
use std::collections::BTreeMap;

/// Snapshot of one replica at routing time. Index in the slice handed to
/// [`Router::route`] is the replica id.
#[derive(Debug, Clone, Copy)]
pub struct ReplicaView {
    /// The replica's virtual clock.
    pub now: f64,
    /// Requests the replica still owes work (pending arrivals,
    /// preprocessing, waiting, running). 0 means idle — borrowable.
    pub active: usize,
    pub waiting: usize,
    pub running: usize,
    /// KV-cache block utilization in `[0, 1]`.
    pub kv_utilization: f64,
    /// The elastic controller is draining this replica ahead of a group
    /// flip: stop routing new work to it (it finishes what it owns).
    /// Always `false` when the controller is off, so routing decisions
    /// are bit-identical to the static router.
    pub draining: bool,
}

/// Replica-selection policy. Implementations must be deterministic for a
/// fixed request/view sequence — cluster runs are reproduced bit-for-bit
/// from the workload seed.
pub trait Router: Send {
    fn name(&self) -> &'static str;

    /// Pick the replica for `req`. `views` has one entry per replica and
    /// is never empty; the returned index must be `< views.len()`.
    fn route(&mut self, req: &Request, views: &[ReplicaView]) -> usize;

    /// Late-binding hook for encoder-pool handoffs: called at *encode
    /// completion* time (not arrival) with the fleet views and
    /// outstanding-work ledger as they stand at that moment, so the
    /// decode replica is chosen against current load rather than the
    /// state when the request arrived. `host` is the replica co-hosted
    /// with the encode slot: binding anywhere else migrates the encoded
    /// embeddings, so ledger-keeping routers may prefer the host when
    /// its outstanding work is within their configured epsilon of the
    /// minimum (pool-aware late binding; epsilon 0 disables the
    /// preference entirely). Default: the same decision logic as
    /// [`Router::route`], ignoring `host`. Ledger-keeping routers also
    /// charge the handoff an *encode-free* predicted cost (the pool
    /// already ran the encode); `on_terminal` retires the entry
    /// whichever path assigned it.
    fn route_handoff(&mut self, req: &Request, views: &[ReplicaView], host: usize) -> usize {
        let _ = host;
        self.route(req, views)
    }

    /// Terminal notification (request finished or dropped) so stateful
    /// routers can retire ledger entries. Default: no-op.
    fn on_terminal(&mut self, _req_id: u64) {}

    /// Current (sand, pebble, rock) replica groups for routers that
    /// partition the fleet; `None` for group-free routers.
    fn groups(&self) -> Option<(&[usize], &[usize], &[usize])> {
        None
    }

    /// Elastic repartition hook: replace the modality groups wholesale.
    /// Returns `false` (and changes nothing) for group-free routers or
    /// when any group would be left empty — a modality must never become
    /// unroutable.
    fn set_groups(&mut self, sand: Vec<usize>, pebble: Vec<usize>, rock: Vec<usize>) -> bool {
        let _ = (sand, pebble, rock);
        false
    }
}

/// Outstanding predicted work per replica, retired on terminal events.
#[derive(Debug, Default)]
struct WorkLedger {
    outstanding: Vec<f64>,
    by_req: BTreeMap<u64, (usize, f64)>,
}

impl WorkLedger {
    fn new(replicas: usize) -> WorkLedger {
        WorkLedger { outstanding: vec![0.0; replicas], by_req: BTreeMap::new() }
    }

    fn assign(&mut self, req_id: u64, replica: usize, cost: f64) {
        if self.outstanding.len() <= replica {
            self.outstanding.resize(replica + 1, 0.0);
        }
        self.outstanding[replica] += cost;
        self.by_req.insert(req_id, (replica, cost));
    }

    fn retire(&mut self, req_id: u64) {
        if let Some((replica, cost)) = self.by_req.remove(&req_id) {
            // clamp: float cancellation must not leave a ledger negative
            self.outstanding[replica] = (self.outstanding[replica] - cost).max(0.0);
        }
    }

    fn of(&self, replica: usize) -> f64 {
        self.outstanding.get(replica).copied().unwrap_or(0.0)
    }

    /// Deterministic argmin over candidate replica ids: least outstanding
    /// work, ties to the lowest id.
    fn argmin(&self, candidates: impl Iterator<Item = usize>) -> Option<usize> {
        let mut best: Option<(f64, usize)> = None;
        for i in candidates {
            let w = self.of(i);
            let better = match best {
                None => true,
                Some((bw, bi)) => w < bw || (w == bw && i < bi),
            };
            if better {
                best = Some((w, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Argmin with a migration-aware preference: when `prefer` (the
    /// encode slot's host) is itself a candidate and its outstanding
    /// work is within `epsilon` seconds of the minimum, pick it instead
    /// — the ledger tie is not worth an embedding transfer. `epsilon`
    /// == 0.0 disables the preference (exact argmin, byte-identical to
    /// the epsilon-free path).
    fn argmin_prefer(
        &self,
        candidates: impl Iterator<Item = usize> + Clone,
        prefer: usize,
        epsilon: f64,
    ) -> Option<usize> {
        let best = self.argmin(candidates.clone())?;
        if epsilon > 0.0
            && candidates.clone().any(|i| i == prefer)
            && self.of(prefer) <= self.of(best) + epsilon
        {
            Some(prefer)
        } else {
            Some(best)
        }
    }
}

/// Load-oblivious baseline: cycle through replicas in submission order.
pub struct RoundRobinRouter {
    next: usize,
}

impl RoundRobinRouter {
    pub fn new() -> RoundRobinRouter {
        RoundRobinRouter { next: 0 }
    }
}

impl Default for RoundRobinRouter {
    fn default() -> Self {
        Self::new()
    }
}

impl Router for RoundRobinRouter {
    fn name(&self) -> &'static str {
        "round-robin"
    }

    fn route(&mut self, _req: &Request, views: &[ReplicaView]) -> usize {
        let i = self.next % views.len();
        self.next = (self.next + 1) % views.len();
        i
    }
}

/// Least outstanding predicted work, measured by the impact estimator's
/// pre-first-token cost prediction (§3.3).
pub struct LeastWorkRouter {
    est: ImpactEstimator,
    ledger: WorkLedger,
    /// Pool-aware late binding: prefer the encode slot's host replica on
    /// handoffs when its ledger is within this many seconds of the
    /// minimum (0.0 = plain argmin, the pre-epsilon behavior).
    handoff_epsilon_s: f64,
}

impl LeastWorkRouter {
    pub fn new(est: ImpactEstimator, replicas: usize) -> LeastWorkRouter {
        LeastWorkRouter { est, ledger: WorkLedger::new(replicas), handoff_epsilon_s: 0.0 }
    }

    /// Builder: set the host-preference epsilon for pool handoffs.
    pub fn with_handoff_epsilon(mut self, epsilon_s: f64) -> LeastWorkRouter {
        self.handoff_epsilon_s = epsilon_s;
        self
    }

    /// Ledger pick + charge shared by arrival routing and handoff
    /// binding; `prefer` is `Some(host)` for handoffs (see
    /// [`WorkLedger::argmin_prefer`]).
    fn route_with_cost(
        &mut self,
        req: &Request,
        views: &[ReplicaView],
        cost: f64,
        prefer: Option<usize>,
    ) -> usize {
        let i = match prefer {
            Some(host) => self.ledger.argmin_prefer(0..views.len(), host, self.handoff_epsilon_s),
            None => self.ledger.argmin(0..views.len()),
        }
        .expect("views non-empty");
        self.ledger.assign(req.id, i, cost);
        i
    }
}

impl Router for LeastWorkRouter {
    fn name(&self) -> &'static str {
        "least-work"
    }

    fn route(&mut self, req: &Request, views: &[ReplicaView]) -> usize {
        let cost = self.est.estimate(req).prefill_s;
        self.route_with_cost(req, views, cost, None)
    }

    fn route_handoff(&mut self, req: &Request, views: &[ReplicaView], host: usize) -> usize {
        // the pool already ran the encode: charge the ledger LLM prefill
        // only, or every video handoff would carry seconds of phantom
        // encode load until it finishes
        let cost = self.est.estimate_preencoded(req).prefill_s;
        self.route_with_cost(req, views, cost, Some(host))
    }

    fn on_terminal(&mut self, req_id: u64) {
        self.ledger.retire(req_id);
    }
}

/// The static (sand, pebble, rock) work shares `partition_groups` has
/// always used: rocks ~half the fleet, pebbles ~1/5, sand the rest.
/// Videos are a minority of requests but the large majority of
/// engine-seconds under multimodal mixes.
pub const STATIC_SHARES: [f64; 3] = [0.3, 0.2, 0.5];

/// Split `n` replica ids into (sand, pebble, rock) groups sized by an
/// explicit (sand, pebble, rock) work-share vector. This is the one
/// sizing function shared by the static partition router and the elastic
/// controller. Small clusters share: 1 replica serves all three roles,
/// 2 replicas give sand its own replica and fold pebbles into the rock
/// replica. From 3 replicas on, rock and pebble sizes are
/// `floor(n * share)` (normalized), each clamped so every group keeps at
/// least one replica; sand takes the remainder. With [`STATIC_SHARES`]
/// this reproduces the historical `n/2` / `n/5` splits exactly.
pub fn partition_groups_with(
    n: usize,
    shares: [f64; 3],
) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    match n {
        0 | 1 => (vec![0], vec![0], vec![0]),
        2 => (vec![0], vec![1], vec![1]),
        _ => {
            let total: f64 = shares.iter().filter(|s| s.is_finite() && **s > 0.0).sum();
            let frac = |s: f64| {
                if total > 0.0 && s.is_finite() && s > 0.0 {
                    s / total
                } else {
                    0.0
                }
            };
            let rock_n = ((n as f64 * frac(shares[2])).floor() as usize).clamp(1, n - 2);
            let pebble_n =
                ((n as f64 * frac(shares[1])).floor() as usize).clamp(1, n - 1 - rock_n);
            let sand_n = n - rock_n - pebble_n;
            let sand = (0..sand_n).collect();
            let pebble = (sand_n..sand_n + pebble_n).collect();
            let rock = (sand_n + pebble_n..n).collect();
            (sand, pebble, rock)
        }
    }
}

/// [`partition_groups_with`] at the historical static shares.
pub fn partition_groups(n: usize) -> (Vec<usize>, Vec<usize>, Vec<usize>) {
    partition_groups_with(n, STATIC_SHARES)
}

/// Rocks/pebbles/sand partitioning with elastic spillover (asymmetric by
/// design: light traffic borrows idle heavy replicas, never vice versa).
pub struct ModalityPartitionRouter {
    est: ImpactEstimator,
    ledger: WorkLedger,
    sand: Vec<usize>,
    pebble: Vec<usize>,
    rock: Vec<usize>,
    /// Pool-aware late binding epsilon (see [`LeastWorkRouter`]); the
    /// host preference only ever applies within the modality's group.
    handoff_epsilon_s: f64,
}

impl ModalityPartitionRouter {
    pub fn new(est: ImpactEstimator, replicas: usize) -> ModalityPartitionRouter {
        let (sand, pebble, rock) = partition_groups(replicas.max(1));
        ModalityPartitionRouter {
            est,
            ledger: WorkLedger::new(replicas.max(1)),
            sand,
            pebble,
            rock,
            handoff_epsilon_s: 0.0,
        }
    }

    /// Builder: set the host-preference epsilon for pool handoffs.
    pub fn with_handoff_epsilon(mut self, epsilon_s: f64) -> ModalityPartitionRouter {
        self.handoff_epsilon_s = epsilon_s;
        self
    }

    /// Group selection shared by arrival routing and handoff binding.
    /// `prefer` is `Some(host)` for handoffs: the host wins near-ledger
    /// ties *within the group the modality is allowed on* — a rock's
    /// embeddings never migrate onto a sand replica just because it
    /// hosted the encode slot.
    fn route_with_cost(
        &mut self,
        req: &Request,
        views: &[ReplicaView],
        cost: f64,
        prefer: Option<usize>,
    ) -> usize {
        // Candidate sets are tiny (≤ replicas); materializing keeps the
        // argmin/preference logic in one place (WorkLedger). A draining
        // replica (elastic controller emptying it ahead of a group flip)
        // takes no new work — in particular an idle-but-draining heavy
        // replica must not be borrowed, or the drain never completes.
        let open = |i: usize| !views[i].draining;
        let idle = |i: usize| views[i].active == 0 && !views[i].draining;
        let mut candidates: Vec<usize> = match req.modality {
            Modality::Text => {
                // sand flows through its own group and may borrow any
                // idle heavier replica
                self.sand
                    .iter()
                    .copied()
                    .filter(|&i| open(i))
                    .chain(
                        self.pebble
                            .iter()
                            .chain(self.rock.iter())
                            .copied()
                            .filter(|&i| idle(i)),
                    )
                    .collect()
            }
            Modality::Image => self
                .pebble
                .iter()
                .copied()
                .filter(|&i| open(i))
                .chain(self.rock.iter().copied().filter(|&i| idle(i)))
                .collect(),
            // rocks may not displace sand: videos stay in the rock group
            // even when sand replicas are idle
            Modality::Video => self.rock.iter().copied().filter(|&i| open(i)).collect(),
        };
        if candidates.is_empty() {
            // every replica in the home group is draining — the
            // controller never drains a group down to zero, but a routing
            // decision must exist regardless, so fall back to the
            // unfiltered home group rather than panic
            candidates = match req.modality {
                Modality::Text => self.sand.clone(),
                Modality::Image => self.pebble.clone(),
                Modality::Video => self.rock.clone(),
            };
        }
        let chosen = match prefer {
            Some(host) => self
                .ledger
                .argmin_prefer(candidates.iter().copied(), host, self.handoff_epsilon_s),
            None => self.ledger.argmin(candidates.iter().copied()),
        }
        .expect("every group holds at least one replica");
        self.ledger.assign(req.id, chosen, cost);
        chosen
    }
}

impl Router for ModalityPartitionRouter {
    fn name(&self) -> &'static str {
        "modality-partition"
    }

    fn route(&mut self, req: &Request, views: &[ReplicaView]) -> usize {
        let cost = self.est.estimate(req).prefill_s;
        self.route_with_cost(req, views, cost, None)
    }

    fn route_handoff(&mut self, req: &Request, views: &[ReplicaView], host: usize) -> usize {
        // pool handoffs owe LLM prefill only (encode already ran); the
        // group choice is unchanged — a pre-encoded video still carries a
        // rock-sized prefill and stays in the rock group
        let cost = self.est.estimate_preencoded(req).prefill_s;
        self.route_with_cost(req, views, cost, Some(host))
    }

    fn on_terminal(&mut self, req_id: u64) {
        self.ledger.retire(req_id);
    }

    fn groups(&self) -> Option<(&[usize], &[usize], &[usize])> {
        Some((&self.sand, &self.pebble, &self.rock))
    }

    fn set_groups(&mut self, sand: Vec<usize>, pebble: Vec<usize>, rock: Vec<usize>) -> bool {
        if sand.is_empty() || pebble.is_empty() || rock.is_empty() {
            return false;
        }
        self.sand = sand;
        self.pebble = pebble;
        self.rock = rock;
        true
    }
}

/// Train (if needed) and build the router named in the config. Stateful
/// routers share the estimator-training recipe with `build_policy`, so a
/// cluster run stays deterministic in the workload seed.
pub fn build_router(cfg: &ServeConfig, profile: &ModelProfile) -> Box<dyn Router> {
    let n = cfg.cluster.replicas.max(1);
    match cfg.cluster.router.as_str() {
        "round-robin" => Box::new(RoundRobinRouter::new()),
        name @ ("least-work" | "modality-partition") => {
            let data = Profiler::new(profile, cfg.seed ^ 0x7E57_AB1E).run(300);
            let est = ImpactEstimator::train(&data);
            let eps = cfg.pool.late_bind_epsilon_s;
            if name == "least-work" {
                Box::new(LeastWorkRouter::new(est, n).with_handoff_epsilon(eps))
            } else {
                Box::new(ModalityPartitionRouter::new(est, n).with_handoff_epsilon(eps))
            }
        }
        other => panic!("unknown router '{other}' (validate() should have caught this)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    fn estimator() -> ImpactEstimator {
        let data = Profiler::new(&by_name("llava-7b").unwrap(), 3).run(300);
        ImpactEstimator::train(&data)
    }

    fn views(n: usize) -> Vec<ReplicaView> {
        (0..n)
            .map(|_| ReplicaView {
                now: 0.0,
                active: 0,
                waiting: 0,
                running: 0,
                kv_utilization: 0.0,
                draining: false,
            })
            .collect()
    }

    fn req(id: u64, modality: Modality) -> Request {
        let mm = match modality {
            Modality::Text => 0,
            Modality::Image => 729,
            Modality::Video => 17_000,
        };
        Request {
            id,
            arrival: 0.0,
            modality,
            text_tokens: 40,
            mm_tokens: mm,
            video_duration_s: if modality == Modality::Video { 45.0 } else { 0.0 },
            output_tokens: 64,
            ..Request::default()
        }
    }

    #[test]
    fn round_robin_cycles() {
        let mut r = RoundRobinRouter::new();
        let v = views(3);
        let picks: Vec<usize> = (0..7).map(|i| r.route(&req(i, Modality::Text), &v)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2, 0]);
    }

    #[test]
    fn partition_groups_cover_all_replicas_disjointly() {
        for n in 3..=32 {
            let (sand, pebble, rock) = partition_groups(n);
            assert!(!sand.is_empty() && !pebble.is_empty() && !rock.is_empty(), "n={n}");
            let mut all: Vec<usize> =
                sand.iter().chain(&pebble).chain(&rock).copied().collect();
            all.sort_unstable();
            assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n}");
        }
        // shared small clusters
        assert_eq!(partition_groups(1), (vec![0], vec![0], vec![0]));
        assert_eq!(partition_groups(2), (vec![0], vec![1], vec![1]));
    }

    /// `partition_groups` is now a wrapper over the share-driven sizing
    /// function; pin that the static shares reproduce the historical
    /// `rock = (n/2).max(1)`, `pebble = (n/5).max(1)` splits exactly for
    /// every fleet size that has ever shipped.
    #[test]
    fn static_shares_pin_the_historical_splits() {
        for n in 1..=16usize {
            let legacy = match n {
                0 | 1 => (vec![0], vec![0], vec![0]),
                2 => (vec![0], vec![1], vec![1]),
                _ => {
                    let rock_n = (n / 2).max(1);
                    let pebble_n = (n / 5).max(1);
                    let sand_n = n - rock_n - pebble_n;
                    (
                        (0..sand_n).collect::<Vec<_>>(),
                        (sand_n..sand_n + pebble_n).collect::<Vec<_>>(),
                        (sand_n + pebble_n..n).collect::<Vec<_>>(),
                    )
                }
            };
            assert_eq!(partition_groups(n), legacy, "n={n}");
            assert_eq!(partition_groups_with(n, STATIC_SHARES), legacy, "n={n}");
        }
    }

    /// The share-driven sizing stays total and well-formed for skewed and
    /// hostile share vectors: disjoint cover, no empty group.
    #[test]
    fn share_driven_sizing_is_total_and_covers() {
        let vectors = [
            [0.8, 0.1, 0.1],
            [0.1, 0.1, 0.8],
            [0.0, 0.0, 1.0],
            [1.0, 0.0, 0.0],
            [0.0, 0.0, 0.0],
            [f64::NAN, 0.5, 0.5],
        ];
        for shares in vectors {
            for n in 3..=16usize {
                let (sand, pebble, rock) = partition_groups_with(n, shares);
                assert!(
                    !sand.is_empty() && !pebble.is_empty() && !rock.is_empty(),
                    "n={n} shares={shares:?}"
                );
                let mut all: Vec<usize> =
                    sand.iter().chain(&pebble).chain(&rock).copied().collect();
                all.sort_unstable();
                assert_eq!(all, (0..n).collect::<Vec<_>>(), "n={n} shares={shares:?}");
            }
        }
        // a sand-heavy vector actually moves replicas out of the rock group
        let (sand, _, rock) = partition_groups_with(8, [0.8, 0.1, 0.1]);
        let (s0, _, r0) = partition_groups(8);
        assert!(sand.len() > s0.len() && rock.len() < r0.len());
    }

    /// `set_groups` swaps the partition wholesale; empty groups and
    /// group-free routers refuse.
    #[test]
    fn set_groups_repartitions_and_refuses_empty() {
        let mut r = ModalityPartitionRouter::new(estimator(), 4);
        let (sand, pebble, rock) =
            r.groups().map(|(s, p, k)| (s.to_vec(), p.to_vec(), k.to_vec())).unwrap();
        assert_eq!((sand, pebble, rock), partition_groups(4));
        assert!(r.set_groups(vec![0, 1], vec![2], vec![3]));
        let v = views(4);
        // replica 3 is now the whole rock group
        for i in 0..4 {
            assert_eq!(r.route(&req(i, Modality::Video), &v), 3);
        }
        // an empty group is refused and the partition is untouched
        assert!(!r.set_groups(vec![0, 1, 2, 3], vec![], vec![]));
        assert_eq!(r.groups().unwrap().2, &[3]);
        // group-free routers refuse by default
        let mut rr = RoundRobinRouter::new();
        assert!(rr.groups().is_none());
        assert!(!rr.set_groups(vec![0], vec![0], vec![0]));
    }

    /// A draining replica takes no new work: not as a home-group member,
    /// and — the subtle one — not as an idle borrowable heavy replica.
    #[test]
    fn draining_replicas_are_not_routed_to() {
        let mut r = ModalityPartitionRouter::new(estimator(), 2); // sand=[0], rock=[1]
        let mut v = views(2);
        v[1].draining = true;
        // replica 1 is idle (active == 0) but draining: sand must not
        // borrow it, no matter how loaded the sand replica gets
        for i in 0..6 {
            assert_eq!(r.route(&req(i, Modality::Text), &v), 0, "borrowed a draining replica");
        }
        // once the drain flag clears, borrowing resumes
        v[1].draining = false;
        assert_eq!(r.route(&req(6, Modality::Text), &v), 1);

        // a fully-draining home group still routes (defensive fallback)
        let mut v2 = views(2);
        v2[1].draining = true;
        let pick = r.route(&req(7, Modality::Video), &v2);
        assert_eq!(pick, 1, "sole rock replica must still take videos while draining");
    }

    #[test]
    fn least_work_spreads_before_stacking() {
        let mut r = LeastWorkRouter::new(estimator(), 3);
        let v = views(3);
        // three equal-cost requests with no completions must land on
        // three distinct replicas
        let mut picks: Vec<usize> =
            (0..3).map(|i| r.route(&req(i, Modality::Image), &v)).collect();
        picks.sort_unstable();
        assert_eq!(picks, vec![0, 1, 2]);
        // retiring a request frees its replica for the next arrival
        r.on_terminal(0);
        let again = r.route(&req(9, Modality::Image), &v);
        assert_eq!(again, 0, "retired replica should be least-loaded again");
    }

    #[test]
    fn least_work_prefers_light_replica_over_video_loaded_one() {
        let mut r = LeastWorkRouter::new(estimator(), 2);
        let v = views(2);
        assert_eq!(r.route(&req(0, Modality::Video), &v), 0);
        // the video's predicted cost dwarfs a text request's: everything
        // light flows to replica 1 until the video retires
        for i in 1..5 {
            assert_eq!(r.route(&req(i, Modality::Text), &v), 1);
        }
    }

    /// Pool handoffs must charge the ledger LLM-prefill-only cost: the
    /// encode already ran in the pool, so a video handed off must not
    /// look as expensive as a video that still owes its encode.
    #[test]
    fn handoff_ledger_charge_excludes_encode() {
        let est = estimator();
        let v = req(0, Modality::Video);
        assert!(
            est.estimate_preencoded(&v).prefill_s < est.estimate(&v).prefill_s,
            "pre-encoded estimate must drop the encode component"
        );
        // two replicas: a video HANDOFF lands on 0 with its (small)
        // prefill-only charge, a fresh video ARRIVAL lands on 1 with the
        // full encode+prefill charge — the next sand request must prefer
        // the handoff replica, proving the phantom encode is gone
        let mut r = LeastWorkRouter::new(estimator(), 2);
        let views = views(2);
        assert_eq!(r.route_handoff(&req(0, Modality::Video), &views, 0), 0);
        assert_eq!(r.route(&req(1, Modality::Video), &views), 1);
        assert_eq!(
            r.route(&req(2, Modality::Text), &views),
            0,
            "replica holding only a pre-encoded video must look lighter"
        );
    }

    /// Pool-aware late binding: with a non-zero epsilon the slot's host
    /// wins near-ledger ties (no migration); with epsilon 0 the plain
    /// argmin runs and a loaded host loses the handoff.
    #[test]
    fn handoff_prefers_host_within_epsilon_only() {
        let v = views(3);
        // epsilon off: tie at zero ledgers goes to the lowest id, not
        // the host — bit-compatible with the pre-epsilon router
        let mut r0 = LeastWorkRouter::new(estimator(), 3);
        assert_eq!(r0.route_handoff(&req(0, Modality::Image), &v, 2), 0);

        // epsilon on: the same tie now goes to the host
        let mut r1 = LeastWorkRouter::new(estimator(), 3).with_handoff_epsilon(0.5);
        assert_eq!(r1.route_handoff(&req(0, Modality::Image), &v, 2), 2);

        // a host further than epsilon behind still loses
        let mut r2 = LeastWorkRouter::new(estimator(), 3).with_handoff_epsilon(0.5);
        // load replica 2's ledger well past epsilon with a video arrival
        for i in 0..3 {
            // fill replicas in id order so replica 2 ends up heaviest
            let _ = r2.route(&req(100 + i, Modality::Video), &v);
        }
        let _ = r2.route(&req(103, Modality::Video), &v); // replica 0 again
        assert_ne!(
            r2.route_handoff(&req(1, Modality::Image), &v, 0),
            0,
            "host more than epsilon behind the argmin must not win"
        );

        // partition router: the host preference never pulls a video out
        // of the rock group, even when the host is a sand replica
        let (sand, _, rock) = partition_groups(4);
        let mut rp = ModalityPartitionRouter::new(estimator(), 4).with_handoff_epsilon(10.0);
        let pick = rp.route_handoff(&req(2, Modality::Video), &views(4), sand[0]);
        assert!(rock.contains(&pick), "video handoff bound outside the rock group");
    }

    #[test]
    fn partition_confines_videos_to_rock_group() {
        let mut r = ModalityPartitionRouter::new(estimator(), 4);
        let (sand, _, rock) = partition_groups(4);
        let v = views(4); // everyone idle: still no video on sand
        for i in 0..8 {
            let pick = r.route(&req(i, Modality::Video), &v);
            assert!(rock.contains(&pick), "video routed to non-rock replica {pick}");
            assert!(!sand.contains(&pick));
        }
    }

    #[test]
    fn sand_borrows_idle_rock_but_not_busy_rock() {
        let mut r = ModalityPartitionRouter::new(estimator(), 2); // sand=[0], rock=[1]
        let mut v = views(2);
        // rock replica idle: after enough text load on sand, replica 1
        // (outstanding 0) wins the argmin
        let first = r.route(&req(0, Modality::Text), &v);
        assert_eq!(first, 0, "empty ledgers tie-break to the sand replica");
        let second = r.route(&req(1, Modality::Text), &v);
        assert_eq!(second, 1, "idle rock replica is borrowed once sand has work");
        // busy rock replica: no borrowing, everything stays on sand
        v[1].active = 3;
        for i in 2..6 {
            assert_eq!(r.route(&req(i, Modality::Text), &v), 0);
        }
    }

    #[test]
    fn factory_builds_every_router() {
        let profile = by_name("llava-7b").unwrap();
        for name in crate::config::ROUTERS {
            let mut cfg = ServeConfig::default();
            cfg.cluster.replicas = 2;
            cfg.cluster.router = name.into();
            let r = build_router(&cfg, &profile);
            assert_eq!(r.name(), name);
        }
    }
}
