//! Disaggregated encoder pool: a shared, elastic pool of vision-encoder
//! slots detached from the decode replicas (ElasticMM, arXiv 2507.10069;
//! GPU-internal multi-stage disaggregation, arXiv 2512.17574).
//!
//! PR 3's cluster pins one encoder inside each replica engine, so a rock
//! being encoded on replica k serializes with that replica's sand even
//! when another replica's encoder sits idle. In pool mode the cluster
//! admits multimodal requests here first; text (sand) bypasses the pool
//! entirely and is routed straight to a decode replica.
//!
//! Admission rules (modality-aware pool queue):
//! * **sand** — never enters the pool (no encoder work);
//! * **pebbles** (images) — priority lane: oldest pebble takes any free
//!   slot before un-aged rocks;
//! * **rocks** (videos) — capped to at most ⌈M/2⌉ concurrently encoding
//!   so a video burst cannot monopolize the pool, with *aging*: a rock
//!   waiting past `aging_deadline_s` outranks every pebble, so rocks
//!   never starve under a pebble flood (the bound is
//!   `wait ≤ deadline + max in-flight encode`, proven in
//!   `tests/encoder_pool.rs`).
//!
//! Each slot is co-hosted with decode replica `slot % N`. When an encode
//! completes, the cluster *late-binds* the decode replica through the
//! router ([`super::router::Router::route_handoff`]) using the
//! outstanding-work ledger at completion time; if the chosen replica is
//! not the slot's host, the encoded embeddings migrate at a configurable
//! transfer cost (`migration_cost_s_per_ktok` seconds per 1000 vision
//! tokens; bytes are reported at [`BYTES_PER_MM_TOKEN`] per token — a
//! 1024-dim fp16 embedding row).
//!
//! The pool is a deterministic discrete-event machine: its only event
//! source is slot completions (queue admissions happen at enqueue or
//! completion instants), so for a fixed enqueue sequence the handoff
//! sequence is bit-reproducible — the property the pool-mode determinism
//! and stepped-equals-batch tests in `tests/encoder_pool.rs` pin down.

use crate::model::ModelProfile;
use crate::request::{Modality, Request};
use std::collections::VecDeque;

/// Accounting bytes per migrated vision token: one 1024-dim fp16
/// embedding row (2 bytes/element).
pub const BYTES_PER_MM_TOKEN: u64 = 2048;

/// A completed encode ready to be handed to a decode replica.
#[derive(Debug, Clone)]
pub struct Handoff {
    pub req: Request,
    /// Pool-clock time the encode finished.
    pub done_at: f64,
    /// Replica co-hosted with the slot that ran the encode; migration is
    /// charged iff the router binds a different replica.
    pub host: usize,
    /// Slot index that ran the encode (observability: slot occupancy
    /// timelines in the Perfetto export).
    pub slot: usize,
    /// Pool-clock time the encode started on its slot.
    pub started: f64,
}

/// Aggregate pool counters (surfaced in
/// [`super::ClusterReport::pool`]).
#[derive(Debug, Clone, Default)]
pub struct PoolStats {
    pub enqueued_pebbles: u64,
    pub enqueued_rocks: u64,
    /// Encodes completed (== handoffs delivered: the pool never drops).
    pub encodes: u64,
    /// Virtual seconds of encode work across all slots.
    pub busy_time_s: f64,
    /// Longest single encode started so far (the starvation-bound term).
    pub max_encode_s: f64,
    pub pebble_wait_max_s: f64,
    pub rock_wait_max_s: f64,
    /// Rocks admitted past the aging deadline while pebbles were still
    /// waiting — each one is an exercised anti-starvation promotion.
    pub aged_promotions: u64,
    /// Encodes cancelled while queued or in flight ([`EncoderPool::cancel`]).
    pub cancelled: u64,
    pub rock_in_flight_peak: usize,
    /// Handoffs whose bound replica differed from the slot host.
    pub migrations: u64,
    pub migrated_mm_tokens: u64,
    pub migrated_bytes: u64,
    /// Elastic resizes that grew the pool ([`EncoderPool::resize`]).
    pub slot_grow_events: u64,
    /// Elastic resizes that shrank the pool.
    pub slot_shrink_events: u64,
    /// Peak slot count ever held (== configured slots when static).
    pub max_concurrent_slots: usize,
}

/// Point-in-time pool description embedded in the cluster report.
#[derive(Debug, Clone)]
pub struct PoolSnapshot {
    pub slots: usize,
    pub rock_cap: usize,
    /// Slot-resize accounting, mirrored from [`PoolStats`] so controller
    /// actions are readable without digging into the stats blob.
    pub slot_grow_events: u64,
    pub slot_shrink_events: u64,
    pub max_concurrent_slots: usize,
    pub stats: PoolStats,
}

#[derive(Debug)]
struct Queued {
    req: Request,
    enqueued: f64,
}

#[derive(Debug)]
struct Slot {
    host: usize,
    busy_until: f64,
    /// When the in-flight encode started (valid while `current` is set).
    started: f64,
    /// In-flight request and whether it occupies a rock-cap slot.
    current: Option<(Request, bool)>,
}

/// The shared encoder pool: M slots, two modality lanes, rock cap with
/// aging. Time is virtual and driven by the owning [`super::Cluster`].
pub struct EncoderPool {
    profile: ModelProfile,
    slots: Vec<Slot>,
    /// Decode replica count; new slots keep the `i % replicas` host cycle.
    replicas: usize,
    rock_cap: usize,
    aging_deadline_s: f64,
    pebbles: VecDeque<Queued>,
    rocks: VecDeque<Queued>,
    rocks_in_flight: usize,
    clock: f64,
    pub stats: PoolStats,
}

impl EncoderPool {
    /// Build a pool of `slots` encoder slots over `replicas` decode
    /// replicas; slot `i` is co-hosted with replica `i % replicas`.
    pub fn new(
        profile: &ModelProfile,
        slots: usize,
        replicas: usize,
        aging_deadline_s: f64,
    ) -> EncoderPool {
        let slots = slots.max(1);
        let replicas = replicas.max(1);
        EncoderPool {
            profile: profile.clone(),
            slots: (0..slots)
                .map(|i| Slot { host: i % replicas, busy_until: 0.0, started: 0.0, current: None })
                .collect(),
            replicas,
            rock_cap: slots.div_ceil(2),
            aging_deadline_s,
            pebbles: VecDeque::new(),
            rocks: VecDeque::new(),
            rocks_in_flight: 0,
            clock: 0.0,
            stats: PoolStats { max_concurrent_slots: slots, ..PoolStats::default() },
        }
    }

    /// Resize the pool toward `target` slots (the elastic controller's
    /// hook). Growth appends fresh slots continuing the `i % replicas`
    /// host cycle and immediately admits queued work. Shrinking removes
    /// trailing *idle* slots only — an in-flight encode is never killed —
    /// and never lets the rock cap (⌈M/2⌉) fall below the rocks already
    /// in flight; a blocked shrink stops early and the controller retries
    /// next epoch. Returns the resulting slot count.
    pub fn resize(&mut self, target: usize) -> usize {
        let target = target.max(1);
        let before = self.slots.len();
        while self.slots.len() < target {
            let i = self.slots.len();
            self.slots.push(Slot {
                host: i % self.replicas,
                busy_until: 0.0,
                started: 0.0,
                current: None,
            });
        }
        while self.slots.len() > target
            && self.slots.last().is_some_and(|s| s.current.is_none())
            && (self.slots.len() - 1).div_ceil(2) >= self.rocks_in_flight
        {
            self.slots.pop();
        }
        let after = self.slots.len();
        self.rock_cap = after.div_ceil(2);
        if after > before {
            self.stats.slot_grow_events += 1;
            self.stats.max_concurrent_slots = self.stats.max_concurrent_slots.max(after);
            self.fill_slots();
        } else if after < before {
            self.stats.slot_shrink_events += 1;
        }
        after
    }

    pub fn slot_count(&self) -> usize {
        self.slots.len()
    }

    /// Slots with an encode in flight right now (telemetry gauge).
    pub fn busy_slots(&self) -> usize {
        self.slots.iter().filter(|s| s.current.is_some()).count()
    }

    /// Requests waiting in either lane (telemetry gauge).
    pub fn queue_depth(&self) -> usize {
        self.pebbles.len() + self.rocks.len()
    }

    pub fn rock_cap(&self) -> usize {
        self.rock_cap
    }

    /// Nothing queued and nothing encoding.
    pub fn is_idle(&self) -> bool {
        self.pebbles.is_empty()
            && self.rocks.is_empty()
            && self.slots.iter().all(|s| s.current.is_none())
    }

    /// Earliest in-flight completion, if any. Queued-but-unstarted work
    /// only starts at enqueue or completion instants, so this is the
    /// pool's only event source.
    pub fn next_event_time(&self) -> Option<f64> {
        self.slots
            .iter()
            .filter(|s| s.current.is_some())
            .map(|s| s.busy_until)
            .fold(None, |m: Option<f64>, t| Some(m.map_or(t, |m| m.min(t))))
    }

    /// Admit a multimodal request to the pool at time `t`. The owning
    /// cluster processes completions in global time order, so every
    /// completion earlier than `t` has already been popped.
    pub fn enqueue(&mut self, req: Request, t: f64) {
        debug_assert!(req.mm_tokens > 0, "sand bypasses the pool");
        debug_assert!(
            self.next_event_time().map_or(true, |tc| tc >= t - 1e-9),
            "enqueue at {t} with completion pending at {:?}",
            self.next_event_time()
        );
        if t > self.clock {
            self.clock = t;
        }
        let is_rock = req.modality == Modality::Video;
        if is_rock {
            self.stats.enqueued_rocks += 1;
            self.rocks.push_back(Queued { req, enqueued: t });
        } else {
            self.stats.enqueued_pebbles += 1;
            self.pebbles.push_back(Queued { req, enqueued: t });
        }
        self.fill_slots();
    }

    /// Complete the earliest in-flight encode (ties break to the lowest
    /// slot index), refill freed capacity from the queues, and return the
    /// handoff. `None` when nothing is encoding.
    pub fn pop_completion(&mut self) -> Option<Handoff> {
        let i = self
            .slots
            .iter()
            .enumerate()
            .filter(|(_, s)| s.current.is_some())
            .min_by(|(ai, a), (bi, b)| {
                a.busy_until.total_cmp(&b.busy_until).then(ai.cmp(bi))
            })
            .map(|(i, _)| i)?;
        let done_at = self.slots[i].busy_until;
        if done_at > self.clock {
            self.clock = done_at;
        }
        let (req, was_rock) = self.slots[i].current.take().expect("selected slot is busy");
        if was_rock {
            self.rocks_in_flight -= 1;
        }
        self.stats.encodes += 1;
        let host = self.slots[i].host;
        let started = self.slots[i].started;
        self.fill_slots();
        Some(Handoff { req, done_at, host, slot: i, started })
    }

    /// Cancel a queued or in-flight encode at pool time `t`. A queued
    /// entry is removed outright; an in-flight encode frees its slot
    /// immediately — the unspent tail of the encode is refunded from
    /// `busy_time_s` and the freed capacity refills from the lanes at
    /// `max(clock, t)`. Returns the request (so the owning cluster can
    /// record the cancelled outcome); `None` when `id` is not here. The
    /// caller must have delivered completions due before `t` first
    /// (the cluster's `process_due` contract).
    pub fn cancel(&mut self, id: u64, t: f64) -> Option<Request> {
        if let Some(pos) = self.pebbles.iter().position(|q| q.req.id == id) {
            self.stats.cancelled += 1;
            return self.pebbles.remove(pos).map(|q| q.req);
        }
        if let Some(pos) = self.rocks.iter().position(|q| q.req.id == id) {
            self.stats.cancelled += 1;
            return self.rocks.remove(pos).map(|q| q.req);
        }
        let slot = self
            .slots
            .iter()
            .position(|s| matches!(&s.current, Some((r, _)) if r.id == id))?;
        if t > self.clock {
            self.clock = t;
        }
        let (req, was_rock) = self.slots[slot].current.take().expect("matched in-flight slot");
        if was_rock {
            self.rocks_in_flight -= 1;
        }
        let refund = (self.slots[slot].busy_until - self.clock).max(0.0);
        self.stats.busy_time_s -= refund.min(self.stats.busy_time_s);
        self.stats.cancelled += 1;
        self.fill_slots();
        Some(req)
    }

    /// Requests currently queued or encoding (occupancy view for
    /// backends and drain checks).
    pub fn active(&self) -> usize {
        self.pebbles.len()
            + self.rocks.len()
            + self.slots.iter().filter(|s| s.current.is_some()).count()
    }

    /// Record a handoff that actually crossed hosts; returns the transfer
    /// time for `migration_cost_s_per_ktok` seconds per 1000 vision
    /// tokens.
    pub fn charge_migration(&mut self, req: &Request, cost_s_per_ktok: f64) -> f64 {
        self.stats.migrations += 1;
        self.stats.migrated_mm_tokens += req.mm_tokens as u64;
        self.stats.migrated_bytes += req.mm_tokens as u64 * BYTES_PER_MM_TOKEN;
        cost_s_per_ktok * (req.mm_tokens as f64 / 1000.0)
    }

    pub fn snapshot(&self) -> PoolSnapshot {
        PoolSnapshot {
            slots: self.slots.len(),
            rock_cap: self.rock_cap,
            slot_grow_events: self.stats.slot_grow_events,
            slot_shrink_events: self.stats.slot_shrink_events,
            max_concurrent_slots: self.stats.max_concurrent_slots,
            stats: self.stats.clone(),
        }
    }

    /// Serialized pool-side encode cost: CPU preprocess (image decode /
    /// frame extraction) plus the encoder pass. The amortized/overlapped
    /// charging of replica-local encoding does not apply — a pool slot is
    /// a dedicated encoder instance.
    fn encode_duration(&self, req: &Request) -> f64 {
        self.profile.preprocess_time(req) + self.profile.encode_time(req)
    }

    /// Start encodes on free slots until no admissible work remains.
    /// Admission order at time `now`:
    /// 1. the oldest rock older than the aging deadline (anti-starvation),
    ///    subject to the rock cap;
    /// 2. the oldest pebble;
    /// 3. the oldest rock, subject to the rock cap.
    fn fill_slots(&mut self) {
        let now = self.clock;
        loop {
            let Some(slot) = self.slots.iter().position(|s| s.current.is_none()) else {
                break;
            };
            let rock_ok = self.rocks_in_flight < self.rock_cap;
            let rock_aged = rock_ok
                && self
                    .rocks
                    .front()
                    .is_some_and(|q| now - q.enqueued >= self.aging_deadline_s);
            let q = if rock_aged {
                if !self.pebbles.is_empty() {
                    self.stats.aged_promotions += 1;
                }
                self.rocks.pop_front().expect("aged rock present")
            } else if let Some(q) = self.pebbles.pop_front() {
                q
            } else if rock_ok {
                match self.rocks.pop_front() {
                    Some(q) => q,
                    None => break,
                }
            } else {
                break;
            };
            let is_rock = q.req.modality == Modality::Video;
            let wait = (now - q.enqueued).max(0.0);
            if is_rock {
                self.rocks_in_flight += 1;
                self.stats.rock_in_flight_peak =
                    self.stats.rock_in_flight_peak.max(self.rocks_in_flight);
                self.stats.rock_wait_max_s = self.stats.rock_wait_max_s.max(wait);
            } else {
                self.stats.pebble_wait_max_s = self.stats.pebble_wait_max_s.max(wait);
            }
            let dur = self.encode_duration(&q.req);
            self.stats.busy_time_s += dur;
            self.stats.max_encode_s = self.stats.max_encode_s.max(dur);
            self.slots[slot].busy_until = now + dur;
            self.slots[slot].started = now;
            self.slots[slot].current = Some((q.req, is_rock));
        }
    }

    /// Structural invariants (exercised by the pool property suite).
    pub fn check_invariants(&self) -> Result<(), crate::backend::InvariantViolation> {
        use crate::backend::InvariantViolation;
        let in_flight = self.slots.iter().filter(|s| matches!(s.current, Some((_, true)))).count();
        if in_flight != self.rocks_in_flight {
            return Err(InvariantViolation::RockCounterMismatch {
                counter: self.rocks_in_flight,
                recount: in_flight,
            });
        }
        if self.rocks_in_flight > self.rock_cap {
            return Err(InvariantViolation::RockCapExceeded {
                in_flight: self.rocks_in_flight,
                cap: self.rock_cap,
            });
        }
        for (i, s) in self.slots.iter().enumerate() {
            if s.current.is_some() && s.busy_until < self.clock - 1e-9 {
                return Err(InvariantViolation::SlotBehindClock {
                    slot: i,
                    busy_until: s.busy_until,
                    clock: self.clock,
                });
            }
        }
        // work conservation: a free slot may coexist only with an empty
        // pebble lane and a rock lane blocked by the cap
        let free = self.slots.iter().any(|s| s.current.is_none());
        if free && !self.pebbles.is_empty() {
            return Err(InvariantViolation::IdleSlotWithPebbles);
        }
        if free && !self.rocks.is_empty() && self.rocks_in_flight < self.rock_cap {
            return Err(InvariantViolation::IdleSlotWithAdmissibleRock);
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    fn image(id: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            modality: Modality::Image,
            text_tokens: 40,
            mm_tokens: 729,
            video_duration_s: 0.0,
            output_tokens: 8,
            ..Request::default()
        }
    }

    fn video(id: u64) -> Request {
        Request {
            id,
            arrival: 0.0,
            modality: Modality::Video,
            text_tokens: 40,
            mm_tokens: 17_640,
            video_duration_s: 45.0,
            output_tokens: 8,
            ..Request::default()
        }
    }

    fn pool(slots: usize) -> EncoderPool {
        EncoderPool::new(&by_name("llava-7b").unwrap(), slots, 2, 1.0)
    }

    #[test]
    fn rock_cap_is_half_the_slots_rounded_up() {
        assert_eq!(pool(1).rock_cap(), 1);
        assert_eq!(pool(2).rock_cap(), 1);
        assert_eq!(pool(4).rock_cap(), 2);
        assert_eq!(pool(5).rock_cap(), 3);
    }

    #[test]
    fn completions_pop_in_time_order_and_conserve_requests() {
        let mut p = pool(2);
        p.enqueue(image(0), 0.0);
        p.enqueue(video(1), 0.0);
        p.enqueue(image(2), 0.0); // queued: both slots busy
        p.check_invariants().unwrap();
        let a = p.pop_completion().unwrap();
        assert_eq!(a.req.id, 0, "image encodes faster than the video");
        let b = p.pop_completion().unwrap();
        assert_eq!(b.req.id, 2, "queued pebble started when the image slot freed");
        let c = p.pop_completion().unwrap();
        assert_eq!(c.req.id, 1);
        assert!(a.done_at <= b.done_at && b.done_at <= c.done_at);
        assert!(p.pop_completion().is_none());
        assert!(p.is_idle());
        assert_eq!(p.stats.encodes, 3);
        p.check_invariants().unwrap();
    }

    #[test]
    fn rocks_capped_while_pebbles_flow() {
        let mut p = pool(4); // cap 2
        for id in 0..4 {
            p.enqueue(video(id), 0.0);
        }
        assert_eq!(p.rocks_in_flight, 2, "only ⌈M/2⌉ rocks encode concurrently");
        p.enqueue(image(10), 0.0);
        p.enqueue(image(11), 0.0);
        // pebbles take the two slots the cap reserved away from rocks
        assert!(p.slots.iter().all(|s| s.current.is_some()));
        p.check_invariants().unwrap();
        let mut order = Vec::new();
        while let Some(h) = p.pop_completion() {
            order.push(h.req.id);
        }
        assert_eq!(order.len(), 6);
        assert!(p.is_idle());
    }

    #[test]
    fn aged_rock_outranks_pebbles() {
        let mut p = pool(1); // cap 1, deadline 1.0
        p.enqueue(image(0), 0.0);
        p.enqueue(video(1), 0.0);
        for id in 2..10 {
            p.enqueue(image(id), 0.01);
        }
        // image 0 completes ~0.16s: rock not yet aged, next pebble wins
        let h = p.pop_completion().unwrap();
        assert_eq!(h.req.id, 0);
        assert_eq!(p.slots[0].current.as_ref().unwrap().0.id, 2);
        // keep completing: once the rock's wait crosses 1.0s it must win
        // the next free slot even though pebbles still queue
        let mut rock_started_at = None;
        while let Some(_h) = p.pop_completion() {
            if let Some((req, _)) = &p.slots[0].current {
                if req.modality == Modality::Video && rock_started_at.is_none() {
                    rock_started_at = Some(p.clock);
                }
            }
        }
        let started = rock_started_at.expect("rock must eventually start");
        assert!(started >= 1.0, "rock started before aging at {started}");
        assert!(
            started <= 1.0 + p.stats.max_encode_s + 1e-9,
            "rock start {started} exceeds deadline + max encode"
        );
        assert!(p.stats.aged_promotions >= 1, "aging was never exercised");
    }

    #[test]
    fn cancel_frees_queued_and_in_flight_encodes() {
        let mut p = pool(1);
        p.enqueue(image(0), 0.0); // takes the slot
        p.enqueue(image(1), 0.0); // queued behind it
        p.enqueue(video(2), 0.0); // queued in the rock lane
        assert_eq!(p.active(), 3);

        // queued cancels remove the entry without touching the slot
        assert_eq!(p.cancel(1, 0.0).map(|r| r.id), Some(1));
        assert_eq!(p.cancel(1, 0.0).map(|r| r.id), None, "already gone");
        assert_eq!(p.active(), 2);
        p.check_invariants().unwrap();

        // cancelling the in-flight image frees the slot mid-encode: the
        // queued rock starts immediately and busy time is refunded
        let busy_before = p.stats.busy_time_s;
        assert_eq!(p.cancel(0, 0.05).map(|r| r.id), Some(0));
        assert!(p.stats.busy_time_s < busy_before, "unspent encode tail refunded");
        let (next, is_rock) = p.slots[0].current.as_ref().expect("rock backfilled the slot");
        assert_eq!(next.id, 2);
        assert!(*is_rock);
        p.check_invariants().unwrap();
        assert_eq!(p.stats.cancelled, 2);

        let h = p.pop_completion().unwrap();
        assert_eq!(h.req.id, 2);
        assert!(p.is_idle());
        assert_eq!(p.active(), 0, "occupancy returns to zero after cancels + drain");
    }

    #[test]
    fn migration_accounting_is_token_and_byte_conserving() {
        let mut p = pool(2);
        let v = video(0);
        let dt = p.charge_migration(&v, 0.002);
        assert!((dt - 0.002 * 17.640).abs() < 1e-12);
        assert_eq!(p.stats.migrations, 1);
        assert_eq!(p.stats.migrated_mm_tokens, 17_640);
        assert_eq!(p.stats.migrated_bytes, 17_640 * BYTES_PER_MM_TOKEN);
        assert_eq!(p.charge_migration(&v, 0.0), 0.0);
    }

    #[test]
    fn hosts_cycle_over_replicas() {
        let p = EncoderPool::new(&by_name("llava-7b").unwrap(), 4, 3, 1.0);
        let hosts: Vec<usize> = p.slots.iter().map(|s| s.host).collect();
        assert_eq!(hosts, vec![0, 1, 2, 0]);
    }

    #[test]
    fn resize_grows_admits_queued_work_and_keeps_host_cycle() {
        let mut p = EncoderPool::new(&by_name("llava-7b").unwrap(), 1, 2, 1.0);
        p.enqueue(image(0), 0.0); // takes the only slot
        p.enqueue(image(1), 0.0); // queued
        assert_eq!(p.queue_depth(), 1);
        assert_eq!(p.resize(3), 3);
        // growth admits the queued pebble immediately (work conservation)
        assert_eq!(p.queue_depth(), 0);
        assert_eq!(p.rock_cap(), 2);
        let hosts: Vec<usize> = p.slots.iter().map(|s| s.host).collect();
        assert_eq!(hosts, vec![0, 1, 0], "new slots continue the host cycle");
        assert_eq!(p.stats.slot_grow_events, 1);
        assert_eq!(p.stats.max_concurrent_slots, 3);
        p.check_invariants().unwrap();
        let snap = p.snapshot();
        assert_eq!(snap.slot_grow_events, 1);
        assert_eq!(snap.max_concurrent_slots, 3);
    }

    #[test]
    fn resize_shrink_spares_busy_slots_and_rock_cap() {
        let mut p = pool(4); // cap 2
        p.enqueue(video(0), 0.0);
        p.enqueue(video(1), 0.0);
        assert_eq!(p.rocks_in_flight, 2);
        // slots 0 and 1 are busy with rocks; shrinking to 1 must stop at
        // 3 slots: cap ⌈3/2⌉ = 2 still covers both in-flight rocks, but
        // ⌈2/2⌉ = 1 would not
        assert_eq!(p.resize(1), 3);
        assert_eq!(p.rock_cap(), 2);
        assert_eq!(p.stats.slot_shrink_events, 1);
        p.check_invariants().unwrap();
        // drain, then the shrink completes
        while p.pop_completion().is_some() {}
        assert_eq!(p.resize(1), 1);
        assert_eq!(p.rock_cap(), 1);
        assert_eq!(p.stats.slot_shrink_events, 2);
        assert_eq!(p.stats.max_concurrent_slots, 4, "peak is sticky");
        p.check_invariants().unwrap();
        // floor: a pool never shrinks to zero slots
        assert_eq!(p.resize(0), 1);
    }

    #[test]
    fn resize_noop_counts_nothing() {
        let mut p = pool(2);
        assert_eq!(p.resize(2), 2);
        assert_eq!(p.stats.slot_grow_events, 0);
        assert_eq!(p.stats.slot_shrink_events, 0);
        assert_eq!(p.stats.max_concurrent_slots, 2);
    }
}
