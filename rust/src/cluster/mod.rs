//! Multi-replica cluster serving: N independent [`Scheduler`]+engine
//! replicas behind one modality-aware [`Router`].
//!
//! The paper's single-engine scheduler keeps sand flowing through rocks;
//! this layer keeps that true at fleet scale. Each replica is a complete
//! scheduler+engine pair driven through the stepping API
//! ([`Scheduler::inject`] / [`Scheduler::step`] / [`Scheduler::advance_to`]),
//! so the cluster composes with everything the stepping refactor enabled:
//! online injection, per-iteration events, incremental retirement
//! ([`Scheduler::take_finished`]). Replicas do not share state — the only
//! cross-replica decision is the router's, made per arrival from
//! [`ReplicaView`] snapshots — which is what makes cluster runs
//! deterministic and a 1-replica round-robin cluster bit-identical to a
//! bare scheduler (proven in `tests/cluster.rs`).
//!
//! Virtual time: every replica carries its own clock. The batch driver
//! ([`Cluster::run`]) advances each replica to an arrival's timestamp
//! before routing it, so load-aware routers observe the fleet as it
//! would look at that moment; [`Cluster::drain`] then interleaves
//! replicas exactly like [`Scheduler::drain`] interleaves iterations.
//!
//! Encode/prefill overlap: building the cluster with
//! `cluster.encode_overlap = true` flips each replica engine's profile to
//! [`crate::model::ModelProfile::encode_overlap`] mode, where vision
//! encode runs concurrently with the iteration's prefill/decode pass
//! (RServe, arXiv 2509.24381) — `max(encode, prefill+decode) + penalty`
//! instead of the serialized sum.
//!
//! # Encoder-pool mode (`[pool] enabled = true` / `--encoder-pool`)
//!
//! With the disaggregated [`pool::EncoderPool`] enabled, the cluster
//! becomes a two-stage system. Injection no longer routes immediately:
//! requests enter a cluster-level ingress timeline; at their arrival
//! time, sand (text) is routed to a decode replica as before while
//! multimodal requests are admitted to the shared encoder pool (pebble
//! priority lanes, rock cap + aging — see `pool.rs`). When an encode
//! completes, the decode replica is *late-bound* through
//! [`Router::route_handoff`] using the outstanding-work ledger at that
//! moment, migration cost is charged if the slot host differs from the
//! bound replica, and the request is handed to the replica pre-encoded
//! ([`Scheduler::inject_preencoded`]) — it skips CPU preprocessing and
//! the local admission encode, and its prefill chunks carry no encoder
//! work. Preemption-by-recompute re-encodes locally, preserving the
//! `encodes == 1 + preemptions` invariant across the handoff boundary.
//! With the pool disabled, none of these paths run: the cluster is
//! bit-identical to its pre-pool (PR 3) behavior, which
//! `tests/encoder_pool.rs` pins for every router.
//!
//! # Elastic mode (`[elastic] enabled = true` / `--elastic`)
//!
//! With the [`elastic::ElasticController`] attached, the cluster runs a
//! closed control loop on epoch boundaries of the virtual clock:
//! demand-driven re-partitioning of the sand/pebble/rock groups
//! (drain-then-reassign via [`Router::set_groups`]) and encoder-pool
//! slot scaling ([`EncoderPool::resize`]). Every elastic code path is
//! gated on the controller being `Some`, so elastic-off clusters are
//! bit-identical to static ones (`tests/elastic_properties.rs`).

pub mod elastic;
pub mod pool;
pub mod router;

pub use elastic::{ElasticAction, ElasticController, ElasticSnapshot, ElasticStats};
pub use pool::{EncoderPool, PoolSnapshot, PoolStats};
pub use router::{build_router, partition_groups, partition_groups_with, ReplicaView, Router};

use crate::config::ServeConfig;
use crate::coordinator::{RequestEvent, Scheduler, StepOutcome};
use crate::engine::sim_engine::SimEngine;
use crate::metrics::Report;
use crate::policies::build_policy;
use crate::request::Request;
use crate::sim::EventQueue;

/// Per-replica counters for the merged report (utilization/imbalance).
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub replica: usize,
    /// Requests the router sent here.
    pub routed: usize,
    pub iterations: u64,
    pub preemptions: u64,
    pub dropped: u64,
    /// Requests cancelled after being routed to this replica (cluster- or
    /// pool-level cancellations are not attributed to any replica).
    pub cancelled: u64,
    /// Virtual seconds the replica's engine was busy.
    pub busy_time_s: f64,
    /// Order/victim-key evaluations the replica's planner performed
    /// (deterministic planning-cost proxy — see `SchedStats`).
    pub planning_evals: u64,
    /// The replica's final virtual clock.
    pub clock: f64,
}

/// Cluster-level result: one merged [`Report`] (global TTFT percentiles,
/// SLO attainment across the whole fleet) plus per-replica statistics.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    /// All outcomes across replicas, sorted by request id.
    pub report: Report,
    pub per_replica: Vec<ReplicaStats>,
    /// Largest replica clock — the fleet-wide makespan.
    pub makespan: f64,
    /// Encoder-pool counters (slots, waits, aging promotions, migration
    /// count/tokens/bytes); `None` when the pool is disabled.
    pub pool: Option<PoolSnapshot>,
    /// Elastic-controller decisions and the final group partition;
    /// `None` when the controller is off.
    pub elastic: Option<ElasticSnapshot>,
}

impl ClusterReport {
    /// Fraction of the fleet makespan one replica's engine was busy.
    pub fn utilization(&self, replica: usize) -> f64 {
        if self.makespan <= 0.0 {
            0.0
        } else {
            self.per_replica[replica].busy_time_s / self.makespan
        }
    }

    /// Load imbalance: max over mean per-replica busy time. 1.0 is a
    /// perfectly balanced fleet; N means one replica did all the work.
    pub fn imbalance(&self) -> f64 {
        if self.per_replica.is_empty() {
            return 1.0;
        }
        let mut max = 0.0f64;
        let mut sum = 0.0f64;
        for r in &self.per_replica {
            max = max.max(r.busy_time_s);
            sum += r.busy_time_s;
        }
        let mean = sum / self.per_replica.len() as f64;
        if mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    /// Fraction of `slots × makespan` the encoder pool spent encoding
    /// (0.0 when the pool is disabled).
    pub fn pool_utilization(&self) -> f64 {
        match &self.pool {
            Some(p) if self.makespan > 0.0 && p.slots > 0 => {
                p.stats.busy_time_s / (p.slots as f64 * self.makespan)
            }
            _ => 0.0,
        }
    }
}

/// N scheduler+engine replicas behind a router, driven through the same
/// stepping verbs as a single [`Scheduler`].
pub struct Cluster {
    replicas: Vec<Scheduler>,
    router: Box<dyn Router>,
    routed: Vec<usize>,
    /// Terminal outcomes reaped from replicas via `take_finished` — the
    /// cluster retires per-replica state continuously, so replica memory
    /// stays bounded regardless of how many requests flow through.
    collected: Report,
    events: Vec<RequestEvent>,
    /// Disaggregated encoder pool (`None` = PR-3 per-replica encoding;
    /// every pool-mode code path is gated on this being `Some`).
    pool: Option<EncoderPool>,
    /// Pool-mode ingress timeline: injected requests waiting for their
    /// arrival instant, at which they are routed (sand) or pool-admitted
    /// (pebbles/rocks) with the fleet advanced to that moment.
    ingress: EventQueue<Request>,
    migration_cost_s_per_ktok: f64,
    /// Observation enabled (see [`crate::obs`]): buffer cluster-level
    /// [`crate::obs::ObsEvent`]s and retain `events` across batch drains.
    obs: bool,
    obs_events: Vec<crate::obs::ObsEvent>,
    /// Elastic control loop (`None` = static partition + fixed pool;
    /// every elastic code path is gated on this being `Some`, mirroring
    /// the pool field).
    elastic: Option<ElasticController>,
}

impl Cluster {
    /// Build `cfg.cluster.replicas` simulated replicas plus the
    /// configured router. Policy training and router training are
    /// seeded from `cfg.seed`, so construction is deterministic.
    pub fn new(cfg: &ServeConfig) -> Cluster {
        let profile = crate::model::by_name(&cfg.model).expect("validated model name");
        let engine_profile = cfg.engine_profile();
        let n = cfg.cluster.replicas.max(1);
        let mut replicas = Vec::with_capacity(n);
        for _ in 0..n {
            let policy = build_policy(cfg, &profile);
            let engine = Box::new(SimEngine::new(&engine_profile));
            replicas.push(Scheduler::new(cfg.clone(), policy, engine));
        }
        let router = build_router(cfg, &profile);
        let pool = if cfg.pool.enabled {
            Some(EncoderPool::new(&profile, cfg.pool.slots, n, cfg.pool.aging_deadline_s))
        } else {
            None
        };
        let elastic = if cfg.elastic.enabled {
            Some(ElasticController::new(cfg.elastic.clone()))
        } else {
            None
        };
        Cluster {
            replicas,
            router,
            routed: vec![0; n],
            collected: Report::default(),
            events: Vec::new(),
            pool,
            ingress: EventQueue::new(),
            migration_cost_s_per_ktok: cfg.pool.migration_cost_s_per_ktok,
            obs: false,
            obs_events: Vec::new(),
            elastic,
        }
    }

    /// Enable/disable observation cluster-wide (replicas included).
    pub fn set_obs(&mut self, enabled: bool) {
        self.obs = enabled;
        for r in &mut self.replicas {
            r.set_obs(enabled);
        }
    }

    /// Drain cluster-level and per-replica obs events. Ordering is
    /// deterministic (cluster buffer, then replicas in index order);
    /// consumers sort per-request by time, so feed order is not
    /// semantic.
    pub fn take_obs_events(&mut self) -> Vec<crate::obs::ObsEvent> {
        let mut out = std::mem::take(&mut self.obs_events);
        for r in &mut self.replicas {
            out.extend(r.take_obs_events());
        }
        out
    }

    /// Fleet-wide telemetry sample: replica probes summed (KV
    /// utilization averaged) plus encoder-pool occupancy.
    pub fn probe(&self) -> crate::obs::Probe {
        let mut p = crate::obs::Probe { t: Cluster::now(self), ..crate::obs::Probe::default() };
        for r in &self.replicas {
            let rp = r.probe();
            for i in 0..3 {
                p.waiting[i] += rp.waiting[i];
                p.running[i] += rp.running[i];
            }
            p.kv_utilization += rp.kv_utilization;
            p.planning_evals += rp.planning_evals;
        }
        if !self.replicas.is_empty() {
            p.kv_utilization /= self.replicas.len() as f64;
        }
        if let Some(pool) = &self.pool {
            p.pool_busy_slots = pool.busy_slots() as u32;
            p.pool_total_slots = pool.slot_count() as u32;
            p.pool_queue_depth = pool.queue_depth() as u32;
            p.pool_aged_promotions = pool.stats.aged_promotions;
        }
        if let Some((sand, pebble, rock)) = self.router.groups() {
            p.group_sizes = [sand.len() as u32, pebble.len() as u32, rock.len() as u32];
        }
        p
    }

    /// Encoder-pool mode active?
    pub fn pool_enabled(&self) -> bool {
        self.pool.is_some()
    }

    pub fn replica_count(&self) -> usize {
        self.replicas.len()
    }

    pub fn router_name(&self) -> &'static str {
        self.router.name()
    }

    /// Requests routed to each replica so far.
    pub fn routed(&self) -> &[usize] {
        &self.routed
    }

    /// Latest replica clock (the fleet-wide "now").
    pub fn now(&self) -> f64 {
        self.replicas.iter().map(|r| r.now()).fold(0.0, f64::max)
    }

    /// Routing-time snapshot of every replica. `active` costs a scan of
    /// the replica's request table; everything else is O(1). A replica
    /// mid-drain (elastic group move) is flagged so the router stops
    /// sending it new work; the flag is always `false` with the
    /// controller off.
    pub fn views(&self) -> Vec<ReplicaView> {
        let draining = self.elastic.as_ref().and_then(|c| c.draining_replica());
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaView {
                now: r.now(),
                active: r.active_requests(),
                waiting: r.waiting_len(),
                running: r.running_len(),
                kv_utilization: r.kv().utilization(),
                draining: Some(i) == draining,
            })
            .collect()
    }

    /// Hand a request to the cluster (stepping-API ingress). Without the
    /// pool it is routed immediately; in pool mode it joins the ingress
    /// timeline and is dispatched (sand → replica, multimodal → pool)
    /// when the fleet reaches its arrival instant.
    pub fn inject(&mut self, req: Request) {
        // Sanitize before routing: the router's cost estimates read the
        // same untrusted floats the scheduler does (see Request::sanitize).
        let req = req.sanitize();
        if self.pool.is_some() {
            let due = req.arrival.max(self.ingress.now());
            self.ingress.schedule(due, req);
        } else {
            let views = self.views();
            let i = self.router.route(&req, &views);
            self.dispatch_to_replica(i, req);
        }
    }

    /// Hand the cluster a request whose vision encode already ran
    /// *outside* the fleet (an upstream encode tier, or a migrating
    /// peer cluster): a decode replica is late-bound with the ledger as
    /// it stands, charged the encode-free predicted cost, and admitted
    /// pre-encoded at `ready_at`. There is no co-hosted slot, so no
    /// migration-avoidance host preference applies (the out-of-range
    /// host can never match a candidate).
    pub fn inject_preencoded(&mut self, req: Request, ready_at: f64) {
        let req = req.sanitize();
        let views = self.views();
        let i = self.checked_replica(self.router.route_handoff(&req, &views, usize::MAX));
        self.routed[i] += 1;
        self.replicas[i].inject_preencoded(req, ready_at);
    }

    /// Validate a router's pick: out-of-range is a router bug (debug
    /// assert); release builds clamp rather than skewing onto a panic
    /// path. Shared by arrival routing and handoff late binding so both
    /// paths catch the same bugs.
    fn checked_replica(&self, i: usize) -> usize {
        debug_assert!(
            i < self.replicas.len(),
            "router {} returned out-of-range replica {i}",
            self.router.name()
        );
        i.min(self.replicas.len() - 1)
    }

    /// Hand the request to a (validated) replica pick.
    fn dispatch_to_replica(&mut self, i: usize, req: Request) {
        let i = self.checked_replica(i);
        self.routed[i] += 1;
        self.replicas[i].inject(req);
    }

    /// Advance every replica clock to `t` (monotone, like
    /// [`Scheduler::advance_to`]). In pool mode, ingress and encoder-pool
    /// events due up to `t` are processed first, in global time order.
    /// Elastic epochs that became due by `t` are evaluated after the
    /// fleet reaches it, so the controller observes the state at the
    /// boundary, not before.
    pub fn advance_to(&mut self, t: f64) {
        if self.pool.is_some() {
            self.process_due(t);
        }
        for r in &mut self.replicas {
            r.advance_to(t);
        }
        self.run_elastic_epochs();
    }

    /// Pool mode: deliver every ingress arrival and encoder-pool
    /// completion due at or before `horizon`, in global time order (ties
    /// go to ingress — an arrival precedes a completion at the same
    /// instant, mirroring the batch driver's arrival boundaries). Each
    /// event first advances the whole fleet to its timestamp so routing
    /// decisions — including late binding at encode completion — observe
    /// the replicas as they stand at that moment. Returns the number of
    /// events delivered.
    fn process_due(&mut self, horizon: f64) -> usize {
        let mut delivered = 0;
        loop {
            let next_ing = self.ingress.peek_time();
            let next_pool = self.pool.as_ref().and_then(|p| p.next_event_time());
            let ingress_first = match (next_ing, next_pool) {
                (Some(ti), _) if ti > horizon => false,
                (Some(ti), Some(tp)) => ti <= tp,
                (Some(_), None) => true,
                (None, _) => false,
            };
            if ingress_first {
                let (t, req) = self.ingress.pop().expect("peeked ingress event");
                for i in 0..self.replicas.len() {
                    self.advance_replica_to(i, t);
                }
                self.reap_finished();
                if req.mm_tokens == 0 {
                    // sand bypasses the pool entirely
                    let views = self.views();
                    let i = self.router.route(&req, &views);
                    self.dispatch_to_replica(i, req);
                } else {
                    if self.obs {
                        self.obs_events
                            .push(crate::obs::ObsEvent::PoolEnqueued { id: req.id, t });
                    }
                    self.pool.as_mut().expect("pool mode").enqueue(req, t);
                }
                delivered += 1;
                continue;
            }
            match next_pool {
                Some(tp) if tp <= horizon => {
                    for i in 0..self.replicas.len() {
                        self.advance_replica_to(i, tp);
                    }
                    self.reap_finished();
                    let h = self
                        .pool
                        .as_mut()
                        .expect("pool mode")
                        .pop_completion()
                        .expect("completion was due");
                    // late binding: pick the decode replica NOW, from the
                    // outstanding-work ledger at encode completion; the
                    // slot host wins near-ledger ties when the router's
                    // pool-aware epsilon is armed (migration avoidance)
                    let views = self.views();
                    let i = self.checked_replica(self.router.route_handoff(&h.req, &views, h.host));
                    let migration = if i == h.host {
                        0.0
                    } else {
                        self.pool
                            .as_mut()
                            .expect("pool mode")
                            .charge_migration(&h.req, self.migration_cost_s_per_ktok)
                    };
                    self.events.push(RequestEvent::Encoded { id: h.req.id, t: h.done_at });
                    if self.obs {
                        self.obs_events.push(crate::obs::ObsEvent::PoolEncode {
                            id: h.req.id,
                            slot: h.slot,
                            start: h.started,
                            end: h.done_at,
                        });
                        if migration > 0.0 {
                            self.obs_events.push(crate::obs::ObsEvent::Migration {
                                id: h.req.id,
                                start: h.done_at,
                                end: h.done_at + migration,
                            });
                        }
                    }
                    self.routed[i] += 1;
                    self.replicas[i].inject_preencoded(h.req, h.done_at + migration);
                    delivered += 1;
                }
                _ => break,
            }
        }
        delivered
    }

    /// Step every replica once and aggregate: `Executed` if any replica
    /// executed work (dt = the largest step), otherwise the earliest
    /// internal wake-up across replicas, `Blocked { None }` when nothing
    /// can ever run without new input, `Drained` when the whole fleet is
    /// empty. Also reaps terminal state into the merged report and feeds
    /// terminal events to the router's ledger.
    pub fn step(&mut self) -> StepOutcome {
        self.run_elastic_epochs();
        if self.pool.is_some() {
            self.process_due(self.now());
        }
        let mut executed: Option<f64> = None;
        let mut next_event: Option<f64> = None;
        let mut all_drained = true;
        for i in 0..self.replicas.len() {
            let out = self.replicas[i].step();
            self.collect_events(i);
            match out {
                StepOutcome::Executed { dt } => {
                    all_drained = false;
                    executed = Some(executed.map_or(dt, |m| m.max(dt)));
                }
                StepOutcome::Idle { next_event: t } => {
                    all_drained = false;
                    next_event = Some(next_event.map_or(t, |m| m.min(t)));
                }
                StepOutcome::Blocked { next_event: t } => {
                    all_drained = false;
                    if let Some(t) = t {
                        next_event = Some(next_event.map_or(t, |m| m.min(t)));
                    }
                }
                StepOutcome::Drained => {}
            }
        }
        self.reap_finished();
        // Pool mode: replica clocks moved during the step — deliver any
        // ingress/pool events that became due, and fold the remaining
        // (strictly future) pool/ingress timeline into the aggregate so
        // the fleet never reports Drained while encodes are queued or in
        // flight.
        let mut delivered_now = 0;
        if self.pool.is_some() {
            delivered_now = self.process_due(self.now());
            let pending =
                [self.ingress.peek_time(), self.pool.as_ref().and_then(|p| p.next_event_time())];
            for t in pending.into_iter().flatten() {
                all_drained = false;
                next_event = Some(next_event.map_or(t, |m| m.min(t)));
            }
        }
        if let Some(dt) = executed {
            return StepOutcome::Executed { dt };
        }
        if delivered_now > 0 {
            // arrivals/handoffs just landed at (or before) the current
            // clocks: there is runnable work — step again immediately
            return StepOutcome::Executed { dt: 0.0 };
        }
        if all_drained {
            return StepOutcome::Drained;
        }
        match next_event {
            Some(t) => StepOutcome::Idle { next_event: t },
            None => StepOutcome::Blocked { next_event: None },
        }
    }

    /// Drain the request events emitted since the last call (merged
    /// across replicas; request ids are cluster-unique).
    pub fn take_events(&mut self) -> Vec<RequestEvent> {
        std::mem::take(&mut self.events)
    }

    /// Cancel a request anywhere in the fleet: still on the pool-mode
    /// ingress timeline, queued or encoding in the encoder pool, or on
    /// whichever replica it was routed/bound to. Emits exactly one
    /// [`RequestEvent::Cancelled`] and records the cancelled outcome;
    /// requests cancelled before being routed never count in `routed`.
    /// Returns `false` when the id is unknown or already terminal.
    pub fn cancel(&mut self, id: u64) -> bool {
        let t = self.now();
        // not yet dispatched (pool-mode ingress): never routed anywhere
        if let Some((_, req)) = self.ingress.remove_where(|r| r.id == id) {
            self.record_cluster_cancel(req, t);
            return true;
        }
        // queued or encoding in the pool: never bound to a replica. The
        // pool's event contract requires completions due before `t` to
        // be delivered first — process them exactly like `advance_to`.
        if self.pool.is_some() {
            self.process_due(t);
            if let Some(req) = self.pool.as_mut().expect("pool mode").cancel(id, t) {
                self.record_cluster_cancel(req, t);
                return true;
            }
        }
        // Raise every replica clock to the fleet max before trying the
        // replicas, so a replica-owned cancel is stamped at the same
        // fleet time the ingress/pool paths use (a lagging replica's
        // local clock would otherwise under-report `cancelled_at` and
        // let a Cancelled event time-travel behind already-emitted
        // events). Clock raise only — due work still runs at its step.
        for r in &mut self.replicas {
            r.advance_to(t);
        }
        for i in 0..self.replicas.len() {
            if self.replicas[i].cancel(id) {
                self.collect_events(i);
                self.reap_finished();
                return true;
            }
        }
        false
    }

    /// Record a cancellation that happened before any replica owned the
    /// request (ingress timeline or encoder pool): the outcome goes
    /// straight into the merged report with no class (it was never
    /// classified), and the terminal event is emitted here.
    fn record_cluster_cancel(&mut self, req: Request, t: f64) {
        self.collected.cancelled.push(crate::metrics::CancelledOutcome {
            id: req.id,
            modality: req.modality,
            class: None,
            arrival: req.arrival,
            cancelled_at: t,
        });
        self.events.push(RequestEvent::Cancelled { id: req.id, t });
    }

    /// Terminal outcomes accumulated since the last call (the merged,
    /// incrementally-reaped view — the cluster analogue of
    /// [`Scheduler::take_finished`]). The batch [`Cluster::report`]
    /// covers only what has not been taken.
    pub fn take_finished(&mut self) -> Report {
        self.reap_finished();
        std::mem::take(&mut self.collected)
    }

    /// Requests the fleet still owes work: undispatched ingress arrivals,
    /// pool occupancy, and every replica's active set.
    pub fn active_requests(&self) -> usize {
        self.ingress.len()
            + self.pool.as_ref().map_or(0, |p| p.active())
            + self.replicas.iter().map(|r| r.active_requests()).sum::<usize>()
    }

    /// KV blocks currently reserved across the fleet (drain/cancel
    /// occupancy checks: must return to zero once everything terminal).
    pub fn kv_blocks_in_use(&self) -> u64 {
        self.replicas.iter().map(|r| r.kv().used_blocks()).sum()
    }

    /// Encoder-pool occupancy (0 when the pool is disabled or idle).
    pub fn pool_active(&self) -> usize {
        self.pool.as_ref().map_or(0, |p| p.active())
    }

    /// Drop terminally blocked requests on every replica (shutdown /
    /// batch-drain guard, mirroring [`Scheduler::drop_blocked`]).
    pub fn drop_blocked(&mut self) {
        for i in 0..self.replicas.len() {
            self.replicas[i].drop_blocked();
            self.collect_events(i);
        }
        self.reap_finished();
    }

    /// Step until the whole fleet is drained, then report — the cluster
    /// analogue of [`Scheduler::drain`].
    pub fn drain(&mut self) -> ClusterReport {
        loop {
            // with an observer attached, retain events so it can harvest
            // the full stream after the batch drive completes
            if !self.obs {
                self.events.clear();
            }
            match self.step() {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event } => self.advance_to(next_event),
                StepOutcome::Blocked { next_event: Some(t) } => self.advance_to(t),
                StepOutcome::Blocked { next_event: None } => self.drop_blocked(),
                StepOutcome::Drained => break,
            }
        }
        if !self.obs {
            self.events.clear();
        }
        self.report()
    }

    /// Run a full trace: requests are routed in arrival order, with every
    /// replica first advanced to the arrival's timestamp so load-aware
    /// routers see the fleet state at that moment (a request arriving at
    /// `t` must not be placed by looking at queues that will only exist
    /// later).
    pub fn run(&mut self, trace: Vec<Request>) -> ClusterReport {
        let mut trace = trace;
        trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        if self.pool.is_some() {
            // Pool mode already dispatches from a global ingress timeline
            // (every arrival advances the fleet to its instant before
            // being routed or pool-admitted), so the batch driver is
            // exactly inject-everything + drain — the same machine the
            // stepping callers drive, proven bit-identical in
            // `tests/encoder_pool.rs`.
            for req in trace {
                self.inject(req);
            }
            return self.drain();
        }
        for req in trace {
            let t = req.arrival;
            for i in 0..self.replicas.len() {
                self.advance_replica_to(i, t);
            }
            self.reap_finished();
            // the batch arrival loop never calls `step()`, so elastic
            // epochs that became due must fire here, before routing the
            // arrival against the (possibly re-partitioned) groups
            self.run_elastic_epochs();
            if !self.obs {
                self.events.clear();
            }
            self.inject(req);
        }
        self.drain()
    }

    /// Per-replica statistics as they stand (read-only; no reaping).
    pub fn replica_stats(&self) -> Vec<ReplicaStats> {
        self.replicas
            .iter()
            .enumerate()
            .map(|(i, r)| ReplicaStats {
                replica: i,
                routed: self.routed[i],
                iterations: r.stats.iterations,
                preemptions: r.stats.preemptions,
                dropped: r.stats.dropped,
                cancelled: r.stats.cancelled,
                busy_time_s: r.stats.busy_time_s,
                planning_evals: r.stats.planning_evals,
                clock: r.now(),
            })
            .collect()
    }

    /// Encoder-pool counters (`None` when the pool is disabled).
    pub fn pool_snapshot(&self) -> Option<PoolSnapshot> {
        self.pool.as_ref().map(|p| p.snapshot())
    }

    /// Elastic-controller state (`None` when the controller is off).
    pub fn elastic_snapshot(&self) -> Option<ElasticSnapshot> {
        self.elastic.as_ref().map(|c| c.snapshot(self.router.groups()))
    }

    /// Elastic control loop active?
    pub fn elastic_enabled(&self) -> bool {
        self.elastic.is_some()
    }

    /// The router's current (sand, pebble, rock) partition, if it keeps
    /// one — test/diagnostic surface for repartition conservation.
    pub fn router_groups(&self) -> Option<(Vec<usize>, Vec<usize>, Vec<usize>)> {
        let (s, p, r) = self.router.groups()?;
        Some((s.to_vec(), p.to_vec(), r.to_vec()))
    }

    /// `(slots, busy, queued)` pool gauges for the controller's inputs.
    fn pool_gauges(&self) -> Option<(usize, usize, usize)> {
        let p = self.pool.as_ref()?;
        Some((p.slot_count(), p.busy_slots(), p.queue_depth()))
    }

    /// Merged report plus per-replica stats at this moment (reaps any
    /// not-yet-collected terminal state first).
    pub fn report(&mut self) -> ClusterReport {
        self.reap_finished();
        let mut merged = self.collected.clone();
        merged.sort_by_id();
        ClusterReport {
            report: merged,
            per_replica: self.replica_stats(),
            makespan: self.now(),
            pool: self.pool_snapshot(),
            elastic: self.elastic_snapshot(),
        }
    }

    /// Per-replica scheduler invariants plus encoder-pool structural
    /// invariants (property tests).
    pub fn check_invariants(&self) -> Result<(), crate::backend::InvariantViolation> {
        use crate::backend::InvariantViolation;
        for (i, r) in self.replicas.iter().enumerate() {
            r.check_invariants()
                .map_err(|e| InvariantViolation::Replica { index: i, source: Box::new(e) })?;
        }
        if let Some(p) = &self.pool {
            p.check_invariants().map_err(|e| InvariantViolation::Pool(Box::new(e)))?;
        }
        Ok(())
    }

    /// Process replica `i`'s work up to time `t`: execute iterations
    /// whose inputs are ready, jump across idle gaps, and stop once the
    /// replica's clock reaches `t` (or it cannot progress without new
    /// input). Exactly the `drain` loop, bounded by a horizon.
    fn advance_replica_to(&mut self, i: usize, t: f64) {
        while self.replicas[i].now() < t {
            let out = self.replicas[i].step();
            self.collect_events(i);
            match out {
                StepOutcome::Executed { .. } => {}
                StepOutcome::Idle { next_event }
                | StepOutcome::Blocked { next_event: Some(next_event) } => {
                    if next_event >= t {
                        self.replicas[i].advance_to(t);
                        return;
                    }
                    self.replicas[i].advance_to(next_event);
                }
                StepOutcome::Blocked { next_event: None } | StepOutcome::Drained => {
                    self.replicas[i].advance_to(t);
                    return;
                }
            }
        }
    }

    /// Pull replica `i`'s fresh events into the cluster buffer, retiring
    /// terminal requests from the router's ledger.
    fn collect_events(&mut self, i: usize) {
        for ev in self.replicas[i].take_events() {
            if let RequestEvent::Finished { id, .. }
            | RequestEvent::Dropped { id, .. }
            | RequestEvent::Cancelled { id, .. } = ev
            {
                self.router.on_terminal(id);
            }
            self.events.push(ev);
        }
    }

    /// Merge every replica's newly terminal outcomes into the cluster
    /// report, reclaiming replica-side state. With the controller
    /// attached, every partial report also feeds its TTFT-attainment
    /// windows before merging.
    fn reap_finished(&mut self) {
        for r in &mut self.replicas {
            let part = r.take_finished();
            if part.total() > 0 {
                if let Some(ctrl) = self.elastic.as_mut() {
                    ctrl.on_finished(&part);
                }
                self.collected.merge(part);
            }
        }
    }

    /// Evaluate the elastic controller if a virtual-time epoch boundary
    /// has been crossed, and apply whatever it decides: group
    /// repartitions land on the router, pool resizes on the encoder
    /// pool, drain starts only mark state (the router sees the draining
    /// flag through [`Cluster::views`]). No-op with the controller off —
    /// the gate every bit-identity proof leans on.
    fn run_elastic_epochs(&mut self) {
        let now = self.now();
        match &self.elastic {
            Some(ctrl) if ctrl.epoch_due(now) => {}
            _ => return,
        }
        let probe = self.probe();
        let mut occupancy = Vec::with_capacity(self.replicas.len());
        for r in &self.replicas {
            occupancy.push((r.active_requests(), r.kv().used_blocks()));
        }
        let groups = self.router_groups();
        let pool = self.pool_gauges();
        let inputs = elastic::EpochInputs { now, probe, occupancy: &occupancy, groups, pool };
        let ctrl = self.elastic.as_mut().expect("elastic checked above");
        let actions = ctrl.step_epoch(inputs);
        for action in actions {
            match action {
                ElasticAction::StartDrain { .. } => {}
                ElasticAction::Repartition { sand, pebble, rock } => {
                    let applied = self.router.set_groups(sand, pebble, rock);
                    debug_assert!(applied, "controller repartition refused by the router");
                }
                ElasticAction::ResizePool { target } => {
                    if let Some(p) = self.pool.as_mut() {
                        p.resize(target);
                    }
                }
            }
        }
    }
}
