//! Deterministic observability: lifecycle spans, Perfetto trace export,
//! and per-epoch telemetry — all in virtual time.
//!
//! Three layers, all opt-in and all pure functions of the simulation's
//! event stream (no wall clock, no hash iteration, no entropy):
//!
//! 1. [`SpanRecorder`] ([`span`]) folds [`RequestEvent`]s plus the
//!    obs-only [`ObsEvent`] side-channel into per-request span trees
//!    whose segments exactly partition `[arrival, terminal]`.
//! 2. [`trace::trace_json`] serializes spans + telemetry into
//!    Chrome/Perfetto `trace_event` JSON (`--trace-out`).
//! 3. [`Telemetry`] ([`telemetry`]) samples a [`Probe`] of backend state
//!    on step epochs into a decimating ring, tracks rolling TTFT
//!    attainment per SLO class, and renders Prometheus text
//!    (`--metrics-out`, `ServerHandle::metrics_text`).
//!
//! The integration point is [`ObsBackend`], a decorator over any
//! [`ServeBackend`]. With the recorder disabled (no decorator), the
//! backends skip every obs hook and their event streams, reports, and
//! stats are bit-identical to a build without this module — enforced by
//! `tests/spans.rs`.

pub mod span;
pub mod telemetry;
pub mod trace;

pub use span::{RequestSpans, Segment, SpanKind, SpanRecorder, Terminal};
pub use telemetry::{prometheus_text, Telemetry, TelemetrySnapshot};

use crate::backend::ServeBackend;
use crate::coordinator::{RequestEvent, StepOutcome};
use crate::metrics::Report;
use crate::request::Request;

/// Obs-only lifecycle facts the public [`RequestEvent`] stream doesn't
/// carry: admissions, pool queueing, slot occupancy, and KV migration
/// intervals. Backends buffer these only when observation is enabled
/// via [`ServeBackend::set_obs`], so the disabled path allocates
/// nothing.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ObsEvent {
    /// Request entered the running batch at `t`.
    Admitted { id: u64, t: f64 },
    /// Request was queued behind the disaggregated encoder pool.
    PoolEnqueued { id: u64, t: f64 },
    /// Request occupied encoder slot `slot` over `[start, end]`.
    PoolEncode { id: u64, slot: usize, start: f64, end: f64 },
    /// Encoded KV migrated from the encode host to the serving replica
    /// over `[start, end]`.
    Migration { id: u64, start: f64, end: f64 },
}

/// Point-in-time backend state sampled on a step epoch. Modality-indexed
/// arrays follow [`crate::request::Modality`] discriminant order
/// (text, image, video).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Probe {
    /// Virtual time of the sample.
    pub t: f64,
    pub waiting: [u32; 3],
    pub running: [u32; 3],
    /// KV utilization in [0,1] (replica mean for clusters).
    pub kv_utilization: f64,
    pub planning_evals: u64,
    pub pool_busy_slots: u32,
    pub pool_total_slots: u32,
    pub pool_queue_depth: u32,
    pub pool_aged_promotions: u64,
    /// Current (sand, pebble, rock) replica-group sizes; all zero when
    /// the backend's router keeps no modality partition.
    pub group_sizes: [u32; 3],
}

/// Decorator that observes any [`ServeBackend`] without changing its
/// scheduling decisions: every verb passes through, the event stream is
/// returned unchanged, and reports are bit-identical to the undecorated
/// backend. Constructing it flips the inner backend's obs tap on so the
/// [`ObsEvent`] side-channel flows.
pub struct ObsBackend {
    inner: Box<dyn ServeBackend>,
    recorder: SpanRecorder,
    telemetry: Telemetry,
}

impl ObsBackend {
    pub fn new(mut inner: Box<dyn ServeBackend>) -> ObsBackend {
        inner.set_obs(true);
        ObsBackend { inner, recorder: SpanRecorder::new(), telemetry: Telemetry::new() }
    }

    fn drain_obs(&mut self) {
        for ev in self.inner.take_obs_events() {
            self.recorder.observe_obs(&ev);
        }
    }

    /// Harvest everything still buffered and reconstruct span trees.
    /// Consumes pending [`RequestEvent`]s (they are observed first), so
    /// callers interleaving with `take_events` should call this after
    /// their own drain.
    pub fn spans(&mut self) -> Vec<RequestSpans> {
        for ev in self.inner.take_events() {
            self.recorder.observe(&ev);
        }
        self.drain_obs();
        self.recorder.finalize()
    }

    /// Render the Perfetto JSON trace for everything observed so far.
    pub fn trace(&mut self) -> String {
        let spans = self.spans();
        trace::trace_json(&spans, self.telemetry.samples())
    }

    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }
}

impl ServeBackend for ObsBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn inject(&mut self, req: Request) {
        self.recorder.on_request(&req);
        self.inner.inject(req);
    }

    fn inject_preencoded(&mut self, req: Request, ready_at: f64) {
        self.recorder.on_request(&req);
        self.inner.inject_preencoded(req, ready_at);
    }

    fn cancel(&mut self, id: u64) -> bool {
        self.inner.cancel(id)
    }

    fn step(&mut self) -> StepOutcome {
        let out = self.inner.step();
        self.drain_obs();
        // probe() walks live request state, so skip it entirely on
        // epochs the decimating ring would not retain
        if self.telemetry.wants_sample() {
            match self.inner.probe() {
                Some(p) => self.telemetry.push(p),
                None => self.telemetry.tick(),
            }
        } else {
            self.telemetry.tick();
        }
        out
    }

    fn advance_to(&mut self, t: f64) {
        self.inner.advance_to(t);
    }

    fn take_events(&mut self) -> Vec<RequestEvent> {
        let events = self.inner.take_events();
        for ev in &events {
            self.recorder.observe(ev);
        }
        self.drain_obs();
        events
    }

    fn take_finished(&mut self) -> Report {
        let report = self.inner.take_finished();
        self.telemetry.on_finished(&report);
        report
    }

    fn drop_blocked(&mut self) {
        self.inner.drop_blocked();
    }

    fn now(&self) -> f64 {
        self.inner.now()
    }

    fn active_requests(&self) -> usize {
        self.inner.active_requests()
    }

    fn check_invariants(&self) -> Result<(), crate::backend::InvariantViolation> {
        self.inner.check_invariants()
    }

    fn run_trace(&mut self, trace: Vec<Request>) -> Report {
        for req in &trace {
            self.recorder.on_request(req);
        }
        if self.inner.name() == "cluster" {
            // the cluster's batch driver has arrival-faithful semantics
            // (replicas advance to each arrival before routing) that the
            // public stepping verbs cannot reproduce, so delegate and
            // harvest the accumulated streams afterwards — with obs on,
            // the cluster retains its events instead of clearing them.
            // Telemetry degrades to a single final probe on this path;
            // step-driven use (the server) samples every epoch.
            let report = self.inner.run_trace(trace);
            for ev in self.inner.take_events() {
                self.recorder.observe(&ev);
            }
            self.drain_obs();
            self.telemetry.on_finished(&report);
            if let Some(p) = self.inner.probe() {
                self.telemetry.push(p);
            }
            report
        } else {
            // single scheduler: inject + drain through our own stepping
            // wrappers (the trait's documented equivalence), sampling
            // telemetry on every epoch along the way
            let mut trace = trace;
            trace.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
            for req in trace {
                self.inner.inject(req);
            }
            self.drain_report()
        }
    }

    fn summary_lines(&self) -> Vec<String> {
        let mut lines = self.inner.summary_lines();
        lines.extend(self.telemetry.summary_lines());
        lines
    }

    fn set_obs(&mut self, _enabled: bool) {
        // already observing; nesting decorators is a no-op
    }

    fn take_obs_events(&mut self) -> Vec<ObsEvent> {
        // consumed internally by the recorder
        Vec::new()
    }

    fn probe(&self) -> Option<Probe> {
        self.inner.probe()
    }

    fn telemetry_snapshot(&self) -> Option<TelemetrySnapshot> {
        Some(self.telemetry.snapshot())
    }

    fn trace_json(&mut self) -> Option<String> {
        Some(self.trace())
    }
}
