//! Chrome/Perfetto `trace_event` JSON export.
//!
//! Hand-rolled serializer (zero dependencies) emitting the legacy JSON
//! trace format that both `chrome://tracing` and [ui.perfetto.dev]
//! ingest. Layout:
//!
//! - pid 1 — requests: one thread per request (named via `M` metadata
//!   events), one `X` complete event per lifecycle [`Segment`].
//! - pid 2 — encoder pool: one thread per slot, `X` slices for each
//!   pool encode occupancy.
//! - pid 3 — telemetry: `C` counter events per retained [`Probe`].
//!
//! Timestamps are microseconds of virtual time; all floats are written
//! with fixed precision so the output is byte-deterministic.
//!
//! [ui.perfetto.dev]: https://ui.perfetto.dev

use super::span::{RequestSpans, SpanKind};
use super::Probe;

/// Seconds of virtual time -> trace microseconds, clamped finite.
fn us(t: f64) -> f64 {
    if t.is_finite() {
        t * 1e6
    } else {
        0.0
    }
}

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

struct TraceWriter {
    buf: String,
    first: bool,
}

impl TraceWriter {
    fn new() -> Self {
        TraceWriter { buf: String::from("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["), first: true }
    }

    fn push(&mut self, event: String) {
        if !self.first {
            self.buf.push(',');
        }
        self.first = false;
        self.buf.push('\n');
        self.buf.push_str(&event);
    }

    fn meta_name(&mut self, pid: u32, tid: u64, which: &str, name: &str) {
        self.push(format!(
            "{{\"ph\":\"M\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{which}\",\
             \"args\":{{\"name\":\"{}\"}}}}",
            esc(name)
        ));
    }

    fn complete(&mut self, pid: u32, tid: u64, name: &str, ts: f64, dur: f64, args: Option<String>) {
        let args = args.map(|a| format!(",\"args\":{{{a}}}")).unwrap_or_default();
        self.push(format!(
            "{{\"ph\":\"X\",\"pid\":{pid},\"tid\":{tid},\"name\":\"{}\",\
             \"ts\":{:.3},\"dur\":{:.3}{args}}}",
            esc(name),
            us(ts),
            us(dur.max(0.0)),
        ));
    }

    fn counter(&mut self, pid: u32, name: &str, ts: f64, series: &[(&str, f64)]) {
        let args = series
            .iter()
            .map(|(k, v)| format!("\"{}\":{:.6}", esc(k), if v.is_finite() { *v } else { 0.0 }))
            .collect::<Vec<_>>()
            .join(",");
        self.push(format!(
            "{{\"ph\":\"C\",\"pid\":{pid},\"tid\":0,\"name\":\"{}\",\"ts\":{:.3},\
             \"args\":{{{args}}}}}",
            esc(name),
            us(ts),
        ));
    }

    fn finish(mut self) -> String {
        self.buf.push_str("\n]}\n");
        self.buf
    }
}

/// Serialize spans and telemetry probes into a Perfetto-loadable JSON
/// trace. Output is a pure function of the inputs.
pub fn trace_json(spans: &[RequestSpans], samples: &[Probe]) -> String {
    let mut w = TraceWriter::new();

    w.meta_name(1, 0, "process_name", "requests");
    w.meta_name(2, 0, "process_name", "encoder pool");
    w.meta_name(3, 0, "process_name", "telemetry");

    // pid 1: one thread per request, one slice per segment
    let mut pool_slices: Vec<(usize, f64, f64, u64)> = Vec::new();
    for s in spans {
        w.meta_name(1, s.id, "thread_name", &format!("req {} ({})", s.id, s.modality.name()));
        for seg in &s.segments {
            let args = seg.slot.map(|slot| format!("\"slot\":{slot}"));
            w.complete(1, s.id, seg.kind.name(), seg.start, seg.end - seg.start, args);
            if seg.kind == SpanKind::Encode {
                if let Some(slot) = seg.slot {
                    pool_slices.push((slot, seg.start, seg.end, s.id));
                }
            }
        }
    }

    // pid 2: encoder slot occupancy, ordered by (slot, start)
    pool_slices.sort_by(|a, b| a.0.cmp(&b.0).then(a.1.total_cmp(&b.1)));
    let mut named: Option<usize> = None;
    for (slot, start, end, id) in pool_slices {
        if named != Some(slot) {
            // slots arrive sorted, so each thread is named exactly once
            w.meta_name(2, slot as u64, "thread_name", &format!("slot {slot}"));
            named = Some(slot);
        }
        w.complete(2, slot as u64, &format!("encode req {id}"), start, end - start, None);
    }

    // pid 3: counters per retained probe
    for p in samples {
        w.counter(
            3,
            "waiting",
            p.t,
            &[
                ("text", p.waiting[0] as f64),
                ("image", p.waiting[1] as f64),
                ("video", p.waiting[2] as f64),
            ],
        );
        w.counter(
            3,
            "running",
            p.t,
            &[
                ("text", p.running[0] as f64),
                ("image", p.running[1] as f64),
                ("video", p.running[2] as f64),
            ],
        );
        w.counter(3, "kv_utilization", p.t, &[("kv", p.kv_utilization)]);
        w.counter(
            3,
            "encoder_pool",
            p.t,
            &[
                ("busy", p.pool_busy_slots as f64),
                ("queued", p.pool_queue_depth as f64),
                ("total", p.pool_total_slots as f64),
            ],
        );
        // replica-group sizes (elastic control plane); omitted entirely
        // for backends without a modality partition so their traces are
        // unchanged
        if p.group_sizes.iter().any(|&g| g > 0) {
            w.counter(
                3,
                "groups",
                p.t,
                &[
                    ("sand", p.group_sizes[0] as f64),
                    ("pebble", p.group_sizes[1] as f64),
                    ("rock", p.group_sizes[2] as f64),
                ],
            );
        }
    }

    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::span::{Segment, SpanKind, Terminal};
    use crate::request::Modality;

    fn spans_fixture() -> Vec<RequestSpans> {
        vec![RequestSpans {
            id: 7,
            modality: Modality::Image,
            multimodal: true,
            arrival: 0.0,
            end: 2.0,
            terminal: Some(Terminal::Finished),
            segments: vec![
                Segment { kind: SpanKind::PoolQueue, start: 0.0, end: 0.5, slot: None },
                Segment { kind: SpanKind::Encode, start: 0.5, end: 1.0, slot: Some(2) },
                Segment { kind: SpanKind::Prefill, start: 1.0, end: 1.5, slot: None },
                Segment { kind: SpanKind::Decode, start: 1.5, end: 2.0, slot: None },
            ],
        }]
    }

    #[test]
    fn trace_is_valid_shape_and_deterministic() {
        let probes =
            vec![Probe { t: 0.5, waiting: [1, 0, 0], running: [0, 1, 0], ..Probe::default() }];
        let a = trace_json(&spans_fixture(), &probes);
        let b = trace_json(&spans_fixture(), &probes);
        assert_eq!(a, b);
        assert!(a.starts_with("{\"displayTimeUnit\":\"ms\",\"traceEvents\":["));
        assert!(a.trim_end().ends_with("]}"));
        assert!(a.contains("\"ph\":\"X\""));
        assert!(a.contains("\"ph\":\"C\""));
        assert!(a.contains("req 7 (image)"));
        assert!(a.contains("encode req 7"));
        assert!(a.contains("\"slot\":2"));
        // braces balance (cheap structural sanity; CI runs the real
        // validator in tools/trace_check.py)
        let open = a.matches('{').count();
        let close = a.matches('}').count();
        assert_eq!(open, close);
    }

    #[test]
    fn non_finite_inputs_are_clamped() {
        let mut spans = spans_fixture();
        spans[0].segments[0].end = 0.5;
        let probes = vec![Probe { t: 1.0, kv_utilization: f64::NAN, ..Probe::default() }];
        let json = trace_json(&spans, &probes);
        assert!(!json.contains("NaN"));
        assert!(!json.contains("inf"));
    }
}
