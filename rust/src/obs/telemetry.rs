//! Per-epoch telemetry: a bounded ring of [`Probe`] samples plus
//! rolling TTFT-attainment windows per SLO class.
//!
//! The ring decimates rather than truncates: when it reaches capacity
//! it drops every other sample and doubles the sampling stride, so a
//! long run keeps uniform coverage of its whole history in bounded
//! memory — and the retained set is a pure function of the epoch
//! sequence (no clocks, no randomness).

use std::collections::VecDeque;

use crate::metrics::Report;

use super::Probe;

/// Ring capacity before decimation kicks in.
const RING_CAP: usize = 4096;
/// Rolling window length for per-class TTFT attainment.
const TTFT_WINDOW: usize = 64;

const SLO_CLASSES: [&str; 3] = ["critical", "standard", "best-effort"];
const MODALITIES: [&str; 3] = ["text", "image", "video"];

/// Point-in-time aggregate of the telemetry state.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    pub epochs: u64,
    /// Virtual time of the most recent retained probe (0.0 if none).
    pub t: f64,
    pub waiting: [u32; 3],
    pub running: [u32; 3],
    pub kv_utilization: f64,
    pub planning_evals: u64,
    pub pool_busy_slots: u32,
    pub pool_total_slots: u32,
    pub pool_queue_depth: u32,
    pub pool_aged_promotions: u64,
    pub finished: u64,
    pub dropped: u64,
    pub cancelled: u64,
    /// Fraction of the rolling window that met its TTFT budget, per
    /// SLO class (1.0 when the window is empty).
    pub ttft_attainment: [f64; 3],
    /// Number of samples currently in each rolling window.
    pub ttft_samples: [u32; 3],
}

/// Accumulates probes and terminal outcomes across a run.
#[derive(Debug, Default)]
pub struct Telemetry {
    epochs: u64,
    stride: u64,
    samples: Vec<Probe>,
    finished: u64,
    dropped: u64,
    cancelled: u64,
    ttft_ok: [VecDeque<bool>; 3],
}

impl Telemetry {
    pub fn new() -> Self {
        Telemetry { stride: 1, ..Telemetry::default() }
    }

    /// Whether the upcoming epoch's probe would be retained — callers
    /// should skip the (O(requests)) probe entirely when it wouldn't.
    pub fn wants_sample(&self) -> bool {
        self.epochs % self.stride.max(1) == 0
    }

    /// Advance the epoch counter without recording a sample.
    pub fn tick(&mut self) {
        self.epochs += 1;
    }

    /// Record a probe for this epoch and advance.
    pub fn push(&mut self, p: Probe) {
        self.samples.push(p);
        self.epochs += 1;
        if self.samples.len() >= RING_CAP {
            // decimate: keep the 1st, 3rd, 5th, ... samples
            let mut keep = false;
            self.samples.retain(|_| {
                keep = !keep;
                keep
            });
            self.stride = self.stride.max(1) * 2;
        }
    }

    /// Fold a finished run's terminal outcomes into the counters and
    /// TTFT windows. Safe to call per drained report chunk.
    pub fn on_finished(&mut self, report: &Report) {
        for o in &report.outcomes {
            self.finished += 1;
            let idx = o.slo_class.unwrap_or_default() as usize;
            let win = &mut self.ttft_ok[idx];
            win.push_back(o.ttft() <= o.slo_latency);
            while win.len() > TTFT_WINDOW {
                win.pop_front();
            }
        }
        self.dropped += report.failed.len() as u64;
        self.cancelled += report.cancelled.len() as u64;
    }

    pub fn epochs(&self) -> u64 {
        self.epochs
    }

    /// The retained probe ring, oldest first.
    pub fn samples(&self) -> &[Probe] {
        &self.samples
    }

    pub fn snapshot(&self) -> TelemetrySnapshot {
        let last = self.samples.last().copied().unwrap_or_default();
        let mut ttft_attainment = [1.0f64; 3];
        let mut ttft_samples = [0u32; 3];
        for (i, win) in self.ttft_ok.iter().enumerate() {
            ttft_samples[i] = win.len() as u32;
            if !win.is_empty() {
                let ok = win.iter().filter(|&&b| b).count();
                ttft_attainment[i] = ok as f64 / win.len() as f64;
            }
        }
        TelemetrySnapshot {
            epochs: self.epochs,
            t: last.t,
            waiting: last.waiting,
            running: last.running,
            kv_utilization: last.kv_utilization,
            planning_evals: last.planning_evals,
            pool_busy_slots: last.pool_busy_slots,
            pool_total_slots: last.pool_total_slots,
            pool_queue_depth: last.pool_queue_depth,
            pool_aged_promotions: last.pool_aged_promotions,
            finished: self.finished,
            dropped: self.dropped,
            cancelled: self.cancelled,
            ttft_attainment,
            ttft_samples,
        }
    }

    /// Human-readable lines appended to a backend's summary output.
    pub fn summary_lines(&self) -> Vec<String> {
        let s = self.snapshot();
        let mut out = vec![
            format!(
                "obs: {} epochs, {} samples retained (stride {})",
                s.epochs,
                self.samples.len(),
                self.stride.max(1)
            ),
            format!(
                "obs: terminal counts finished={} dropped={} cancelled={}",
                s.finished, s.dropped, s.cancelled
            ),
        ];
        for (i, name) in SLO_CLASSES.iter().enumerate() {
            if s.ttft_samples[i] > 0 {
                out.push(format!(
                    "obs: ttft attainment [{name}] {:.3} over {} finished",
                    s.ttft_attainment[i], s.ttft_samples[i]
                ));
            }
        }
        out
    }
}

fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "0.000000".into()
    }
}

/// Render a snapshot in Prometheus text exposition format. Output is
/// deterministic: fixed metric order, fixed label order, `{:.6}`
/// floats.
pub fn prometheus_text(s: &TelemetrySnapshot) -> String {
    let mut out = String::with_capacity(2048);
    let mut metric = |help: &str, ty: &str, name: &str, lines: &[(String, String)]| {
        out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {ty}\n"));
        for (labels, value) in lines {
            if labels.is_empty() {
                out.push_str(&format!("{name} {value}\n"));
            } else {
                out.push_str(&format!("{name}{{{labels}}} {value}\n"));
            }
        }
    };

    metric(
        "Scheduler epochs (steps) observed.",
        "counter",
        "tcm_obs_epochs",
        &[(String::new(), s.epochs.to_string())],
    );
    metric(
        "Virtual clock of the most recent probe, seconds.",
        "gauge",
        "tcm_obs_clock_seconds",
        &[(String::new(), fmt_f64(s.t))],
    );
    let by_modality = |vals: &[u32; 3]| -> Vec<(String, String)> {
        MODALITIES
            .iter()
            .enumerate()
            .map(|(i, m)| (format!("modality=\"{m}\""), vals[i].to_string()))
            .collect()
    };
    metric(
        "Requests waiting for admission, by modality.",
        "gauge",
        "tcm_obs_waiting",
        &by_modality(&s.waiting),
    );
    metric(
        "Requests in the running batch, by modality.",
        "gauge",
        "tcm_obs_running",
        &by_modality(&s.running),
    );
    metric(
        "KV cache utilization in [0,1].",
        "gauge",
        "tcm_obs_kv_utilization",
        &[(String::new(), fmt_f64(s.kv_utilization))],
    );
    metric(
        "Cumulative admission-planning evaluations.",
        "counter",
        "tcm_obs_planning_evals",
        &[(String::new(), s.planning_evals.to_string())],
    );
    metric(
        "Busy encoder pool slots.",
        "gauge",
        "tcm_obs_pool_busy_slots",
        &[(String::new(), s.pool_busy_slots.to_string())],
    );
    metric(
        "Total encoder pool slots.",
        "gauge",
        "tcm_obs_pool_total_slots",
        &[(String::new(), s.pool_total_slots.to_string())],
    );
    metric(
        "Requests queued behind the encoder pool.",
        "gauge",
        "tcm_obs_pool_queue_depth",
        &[(String::new(), s.pool_queue_depth.to_string())],
    );
    metric(
        "Cumulative aged pebble-to-rock promotions in the pool.",
        "counter",
        "tcm_obs_pool_aged_promotions",
        &[(String::new(), s.pool_aged_promotions.to_string())],
    );
    metric(
        "Requests finished.",
        "counter",
        "tcm_obs_finished_total",
        &[(String::new(), s.finished.to_string())],
    );
    metric(
        "Requests dropped.",
        "counter",
        "tcm_obs_dropped_total",
        &[(String::new(), s.dropped.to_string())],
    );
    metric(
        "Requests cancelled.",
        "counter",
        "tcm_obs_cancelled_total",
        &[(String::new(), s.cancelled.to_string())],
    );
    let by_class = |vals: &[f64; 3]| -> Vec<(String, String)> {
        SLO_CLASSES
            .iter()
            .enumerate()
            .map(|(i, c)| (format!("slo_class=\"{c}\""), fmt_f64(vals[i])))
            .collect()
    };
    metric(
        "Rolling TTFT attainment per SLO class (1.0 when no samples).",
        "gauge",
        "tcm_obs_ttft_attainment",
        &by_class(&s.ttft_attainment),
    );
    metric(
        "Samples in each rolling TTFT window.",
        "gauge",
        "tcm_obs_ttft_window",
        &SLO_CLASSES
            .iter()
            .enumerate()
            .map(|(i, c)| (format!("slo_class=\"{c}\""), s.ttft_samples[i].to_string()))
            .collect::<Vec<_>>(),
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Outcome;
    use crate::request::{Modality, SloClass};

    fn probe(t: f64) -> Probe {
        Probe { t, waiting: [1, 2, 3], running: [4, 5, 6], kv_utilization: 0.5, ..Probe::default() }
    }

    #[test]
    fn decimation_bounds_memory_and_doubles_stride() {
        let mut tel = Telemetry::new();
        for i in 0..20_000u64 {
            if tel.wants_sample() {
                tel.push(probe(i as f64));
            } else {
                tel.tick();
            }
        }
        assert!(tel.samples().len() < RING_CAP);
        assert_eq!(tel.epochs(), 20_000);
        // samples must remain strictly time-ordered after decimation
        for w in tel.samples().windows(2) {
            assert!(w[0].t < w[1].t);
        }
    }

    #[test]
    fn ttft_windows_track_slo_class() {
        let mut tel = Telemetry::new();
        let mut report = Report::default();
        report.outcomes.push(Outcome {
            id: 1,
            modality: Modality::Text,
            class: None,
            arrival: 0.0,
            first_token: 0.5,
            finish: 1.0,
            output_tokens: 8,
            slo_latency: 1.0,
            preemptions: 0,
            preempted_time: 0.0,
            slo_class: Some(SloClass::Critical),
        });
        report.outcomes.push(Outcome {
            id: 2,
            modality: Modality::Text,
            class: None,
            arrival: 0.0,
            first_token: 5.0,
            finish: 6.0,
            output_tokens: 8,
            slo_latency: 1.0,
            preemptions: 0,
            preempted_time: 0.0,
            slo_class: None, // defaults to standard
        });
        tel.on_finished(&report);
        let s = tel.snapshot();
        assert_eq!(s.finished, 2);
        assert_eq!(s.ttft_samples, [1, 1, 0]);
        assert_eq!(s.ttft_attainment[0], 1.0);
        assert_eq!(s.ttft_attainment[1], 0.0);
        assert_eq!(s.ttft_attainment[2], 1.0, "empty window reads 1.0");
    }

    #[test]
    fn prometheus_text_is_deterministic_and_labeled() {
        let mut tel = Telemetry::new();
        tel.push(probe(1.25));
        let a = prometheus_text(&tel.snapshot());
        let b = prometheus_text(&tel.snapshot());
        assert_eq!(a, b);
        assert!(a.contains("tcm_obs_epochs 1"));
        assert!(a.contains("tcm_obs_waiting{modality=\"image\"} 2"));
        assert!(a.contains("tcm_obs_ttft_attainment{slo_class=\"critical\"} 1.000000"));
        assert!(a.contains("# TYPE tcm_obs_kv_utilization gauge"));
    }
}
