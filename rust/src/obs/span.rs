//! Per-request lifecycle span reconstruction.
//!
//! [`SpanRecorder`] folds the public [`RequestEvent`] stream plus the
//! obs-only [`ObsEvent`] side-channel into one span tree per request: a
//! flat, time-ordered list of [`Segment`]s that exactly partitions the
//! interval `[arrival, terminal]`. The recorder never consults a wall
//! clock and never iterates a hash-ordered container; everything is
//! keyed by `BTreeMap` and ordered by virtual time, so its output is a
//! pure function of the event stream.
//!
//! Conservation invariant (checked by [`RequestSpans::check_conservation`]):
//! the first segment starts bit-exactly at `arrival`, adjacent segments
//! are bit-contiguous, and the last segment ends bit-exactly at the
//! terminal timestamp. Preempted time reported by the scheduler equals
//! the sum of `PreemptedGap` segments bit-for-bit for finished requests.

use std::collections::BTreeMap;

use crate::coordinator::RequestEvent;
use crate::request::{Modality, Request};

use super::ObsEvent;

/// What a request was doing during a [`Segment`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Between arrival and the scheduler/cluster seeing it as ready.
    Preprocess,
    /// Queued behind the disaggregated encoder pool.
    PoolQueue,
    /// Occupying an encoder slot (pool) or the inline encode instant
    /// (local encode, zero-length marker).
    Encode,
    /// KV migration from the encode host to the serving replica.
    Migration,
    /// Admissible but not yet admitted to the running batch.
    Waiting,
    /// Admitted, before the first token.
    Prefill,
    /// Admitted, after the first token.
    Decode,
    /// Evicted from the batch, waiting to be re-admitted.
    PreemptedGap,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Preprocess => "preprocess",
            SpanKind::PoolQueue => "pool_queue",
            SpanKind::Encode => "encode",
            SpanKind::Migration => "migration",
            SpanKind::Waiting => "waiting",
            SpanKind::Prefill => "prefill",
            SpanKind::Decode => "decode",
            SpanKind::PreemptedGap => "preempted_gap",
        }
    }
}

/// One contiguous interval of a request's lifetime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Segment {
    pub kind: SpanKind,
    pub start: f64,
    pub end: f64,
    /// Encoder slot index for pool `Encode` segments, `None` otherwise.
    pub slot: Option<usize>,
}

/// How a request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Terminal {
    Finished,
    Dropped,
    Cancelled,
}

/// The reconstructed lifecycle of one request.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestSpans {
    pub id: u64,
    pub modality: Modality,
    pub multimodal: bool,
    pub arrival: f64,
    pub end: f64,
    pub terminal: Option<Terminal>,
    pub segments: Vec<Segment>,
}

impl RequestSpans {
    /// Total time spent in `PreemptedGap` segments.
    pub fn gap_total(&self) -> f64 {
        self.segments
            .iter()
            .filter(|s| s.kind == SpanKind::PreemptedGap)
            .map(|s| s.end - s.start)
            .sum()
    }

    /// Number of `Encode` segments (pool slot occupancy or local
    /// zero-length markers).
    pub fn encode_count(&self) -> usize {
        self.segments.iter().filter(|s| s.kind == SpanKind::Encode).count()
    }

    /// Verify the conservation invariant: segments exactly partition
    /// `[arrival, end]` with bit-exact contiguity.
    pub fn check_conservation(&self) -> Result<(), String> {
        if self.segments.is_empty() {
            if self.end.to_bits() != self.arrival.to_bits() {
                return Err(format!(
                    "req {}: no segments but end {} != arrival {}",
                    self.id, self.end, self.arrival
                ));
            }
            return Ok(());
        }
        let first = &self.segments[0];
        if first.start.to_bits() != self.arrival.to_bits() {
            return Err(format!(
                "req {}: first segment starts at {} but arrival is {}",
                self.id, first.start, self.arrival
            ));
        }
        let mut cursor = self.arrival;
        for (i, s) in self.segments.iter().enumerate() {
            if !s.start.is_finite() || !s.end.is_finite() {
                return Err(format!("req {}: segment {i} non-finite", self.id));
            }
            if s.start.to_bits() != cursor.to_bits() {
                return Err(format!(
                    "req {}: segment {i} ({:?}) starts at {} but cursor is {}",
                    self.id, s.kind, s.start, cursor
                ));
            }
            if s.end < s.start {
                return Err(format!(
                    "req {}: segment {i} ({:?}) ends before it starts ({} < {})",
                    self.id, s.kind, s.end, s.start
                ));
            }
            cursor = s.end;
        }
        if cursor.to_bits() != self.end.to_bits() {
            return Err(format!(
                "req {}: last segment ends at {cursor} but terminal is {}",
                self.id, self.end
            ));
        }
        Ok(())
    }
}

/// Internal normalized event, ranked for stable same-instant ordering.
#[derive(Debug, Clone, Copy, PartialEq)]
enum RawEv {
    Ready(f64),
    PoolEnqueued(f64),
    PoolEncode { slot: usize, start: f64, end: f64 },
    Migration { start: f64, end: f64 },
    Requeued(f64),
    Admitted(f64),
    EncodedLocal(f64),
    First(f64),
    Preempted(f64),
    Terminal(f64, Terminal),
}

impl RawEv {
    fn time(&self) -> f64 {
        match *self {
            RawEv::Ready(t)
            | RawEv::PoolEnqueued(t)
            | RawEv::Requeued(t)
            | RawEv::Admitted(t)
            | RawEv::EncodedLocal(t)
            | RawEv::First(t)
            | RawEv::Preempted(t)
            | RawEv::Terminal(t, _) => t,
            RawEv::PoolEncode { start, .. } => start,
            RawEv::Migration { start, .. } => start,
        }
    }

    /// Tie-break rank for events sharing a timestamp: lifecycle order.
    fn rank(&self) -> u8 {
        match self {
            RawEv::Ready(_) => 0,
            RawEv::PoolEnqueued(_) => 0,
            RawEv::PoolEncode { .. } => 1,
            RawEv::Migration { .. } => 2,
            RawEv::Requeued(_) => 3,
            RawEv::Admitted(_) => 4,
            RawEv::EncodedLocal(_) => 5,
            RawEv::First(_) => 6,
            RawEv::Preempted(_) => 7,
            RawEv::Terminal(..) => 8,
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct Meta {
    arrival: f64,
    modality: Modality,
    multimodal: bool,
}

/// Folds request/obs events into per-request span trees.
///
/// Feed it every injected [`Request`] via [`SpanRecorder::on_request`],
/// every [`RequestEvent`] via [`SpanRecorder::observe`], and every
/// [`ObsEvent`] via [`SpanRecorder::observe_obs`]; then call
/// [`SpanRecorder::finalize`] for the reconstructed spans. `finalize`
/// is non-consuming, so it can be called repeatedly as a run proceeds.
#[derive(Debug, Default)]
pub struct SpanRecorder {
    meta: BTreeMap<u64, Meta>,
    events: BTreeMap<u64, Vec<RawEv>>,
}

impl SpanRecorder {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register a request's identity before (or as) it is injected.
    pub fn on_request(&mut self, req: &Request) {
        self.meta.entry(req.id).or_insert(Meta {
            arrival: req.arrival,
            modality: req.modality,
            multimodal: req.mm_tokens > 0,
        });
    }

    /// Fold one public lifecycle event.
    pub fn observe(&mut self, ev: &RequestEvent) {
        let (id, raw) = match *ev {
            RequestEvent::Ready { id, t } => (id, RawEv::Ready(t)),
            RequestEvent::Encoded { id, t } => (id, RawEv::EncodedLocal(t)),
            RequestEvent::Requeued { id, t } => (id, RawEv::Requeued(t)),
            RequestEvent::FirstToken { id, t } => (id, RawEv::First(t)),
            RequestEvent::Preempted { id, t } => (id, RawEv::Preempted(t)),
            RequestEvent::Finished { id, t } => (id, RawEv::Terminal(t, Terminal::Finished)),
            RequestEvent::Dropped { id, t } => (id, RawEv::Terminal(t, Terminal::Dropped)),
            RequestEvent::Cancelled { id, t } => (id, RawEv::Terminal(t, Terminal::Cancelled)),
        };
        self.events.entry(id).or_default().push(raw);
    }

    /// Fold one obs-only side-channel event.
    pub fn observe_obs(&mut self, ev: &ObsEvent) {
        let (id, raw) = match *ev {
            ObsEvent::Admitted { id, t } => (id, RawEv::Admitted(t)),
            ObsEvent::PoolEnqueued { id, t } => (id, RawEv::PoolEnqueued(t)),
            ObsEvent::PoolEncode { id, slot, start, end } => {
                (id, RawEv::PoolEncode { slot, start, end })
            }
            ObsEvent::Migration { id, start, end } => (id, RawEv::Migration { start, end }),
        };
        self.events.entry(id).or_default().push(raw);
    }

    /// Number of requests with registered metadata.
    pub fn len(&self) -> usize {
        self.meta.len()
    }

    pub fn is_empty(&self) -> bool {
        self.meta.is_empty()
    }

    /// Reconstruct span trees for every known request, in id order.
    pub fn finalize(&self) -> Vec<RequestSpans> {
        let mut out = Vec::with_capacity(self.meta.len());
        for (&id, meta) in &self.meta {
            let mut evs = self.events.get(&id).cloned().unwrap_or_default();
            evs.sort_by(|a, b| a.time().total_cmp(&b.time()).then(a.rank().cmp(&b.rank())));
            dedup_pool_encoded(&mut evs);
            out.push(build_spans(id, meta, &evs));
        }
        out
    }
}

/// A pool handoff produces both an obs `PoolEncode` (with slot/timing)
/// and a public `Encoded` event at the same completion instant; remove
/// the redundant local marker so encode segments aren't double-counted.
fn dedup_pool_encoded(evs: &mut Vec<RawEv>) {
    let ends: Vec<u64> = evs
        .iter()
        .filter_map(|e| match e {
            RawEv::PoolEncode { end, .. } => Some(end.to_bits()),
            _ => None,
        })
        .collect();
    for end_bits in ends {
        if let Some(pos) = evs
            .iter()
            .position(|e| matches!(e, RawEv::EncodedLocal(t) if t.to_bits() == end_bits))
        {
            evs.remove(pos);
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    Pre,
    PoolQueue,
    Waiting,
    Running,
    Gap,
}

struct Builder {
    segments: Vec<Segment>,
    cursor: f64,
    state: St,
    first_seen: bool,
}

impl Builder {
    fn state_kind(&self) -> SpanKind {
        match self.state {
            St::Pre => SpanKind::Preprocess,
            St::PoolQueue => SpanKind::PoolQueue,
            St::Waiting => SpanKind::Waiting,
            St::Running => {
                if self.first_seen {
                    SpanKind::Decode
                } else {
                    SpanKind::Prefill
                }
            }
            St::Gap => SpanKind::PreemptedGap,
        }
    }

    /// Fill `[cursor, t]` with the current state's kind and advance.
    fn close(&mut self, t: f64) {
        let t = t.max(self.cursor);
        if t > self.cursor {
            self.segments.push(Segment {
                kind: self.state_kind(),
                start: self.cursor,
                end: t,
                slot: None,
            });
        }
        self.cursor = t;
    }

    /// Close `[cursor, t]` as running time (prefill before the first
    /// token, decode after), regardless of what the state machine
    /// currently believes — events that imply the request was running
    /// (FirstToken, Preempted, Finished) are authoritative and this
    /// self-heals same-instant preempt/requeue/admit scrambles.
    fn close_running(&mut self, t: f64) {
        let t = t.max(self.cursor);
        if t > self.cursor {
            let kind = if self.first_seen { SpanKind::Decode } else { SpanKind::Prefill };
            self.segments.push(Segment { kind, start: self.cursor, end: t, slot: None });
        }
        self.cursor = t;
    }
}

fn build_spans(id: u64, meta: &Meta, evs: &[RawEv]) -> RequestSpans {
    let mut b = Builder {
        segments: Vec::new(),
        cursor: meta.arrival,
        state: St::Pre,
        first_seen: false,
    };
    let mut terminal = None;
    for ev in evs {
        match *ev {
            RawEv::Ready(t) => {
                // the pool handoff path re-announces readiness on the
                // serving replica; only the first Ready ends Preprocess
                if b.state == St::Pre {
                    b.close(t);
                    b.state = St::Waiting;
                }
            }
            RawEv::PoolEnqueued(_) => {
                b.state = St::PoolQueue;
            }
            RawEv::PoolEncode { slot, start, end } => {
                b.close(start);
                let start = b.cursor;
                let end = end.max(start);
                b.segments.push(Segment { kind: SpanKind::Encode, start, end, slot: Some(slot) });
                b.cursor = end;
                b.state = St::Waiting;
            }
            RawEv::Migration { start, end } => {
                b.close(start);
                let start = b.cursor;
                let end = end.max(start);
                b.segments.push(Segment { kind: SpanKind::Migration, start, end, slot: None });
                b.cursor = end;
                b.state = St::Waiting;
            }
            RawEv::Requeued(t) => {
                b.close(t);
                b.state = St::Waiting;
            }
            RawEv::Admitted(t) => {
                b.close(t);
                b.state = St::Running;
            }
            RawEv::EncodedLocal(t) => {
                b.close(t);
                // inline encode is instantaneous in virtual time:
                // leave a zero-length marker so encode_count() sees it
                b.segments.push(Segment {
                    kind: SpanKind::Encode,
                    start: b.cursor,
                    end: b.cursor,
                    slot: None,
                });
            }
            RawEv::First(t) => {
                b.close_running(t);
                b.first_seen = true;
                b.state = St::Running;
            }
            RawEv::Preempted(t) => {
                b.close_running(t);
                b.state = St::Gap;
            }
            RawEv::Terminal(t, term) => {
                match term {
                    Terminal::Finished => b.close_running(t),
                    Terminal::Dropped | Terminal::Cancelled => b.close(t),
                }
                terminal = Some(term);
            }
        }
    }
    // zero-length markers at the very start can precede arrival only if
    // events were malformed; conservation checking will surface that.
    RequestSpans {
        id,
        modality: meta.modality,
        multimodal: meta.multimodal,
        arrival: meta.arrival,
        end: b.cursor,
        terminal,
        segments: b.segments,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, arrival: f64, mm: u32) -> Request {
        Request {
            id,
            arrival,
            modality: if mm > 0 { Modality::Image } else { Modality::Text },
            text_tokens: 32,
            mm_tokens: mm,
            output_tokens: 8,
            ..Request::default()
        }
    }

    #[test]
    fn simple_text_lifecycle() {
        let mut rec = SpanRecorder::new();
        rec.on_request(&req(1, 0.0, 0));
        rec.observe(&RequestEvent::Ready { id: 1, t: 0.0 });
        rec.observe_obs(&ObsEvent::Admitted { id: 1, t: 0.5 });
        rec.observe(&RequestEvent::FirstToken { id: 1, t: 1.0 });
        rec.observe(&RequestEvent::Finished { id: 1, t: 2.0 });
        let spans = rec.finalize();
        assert_eq!(spans.len(), 1);
        let s = &spans[0];
        s.check_conservation().unwrap();
        assert_eq!(s.terminal, Some(Terminal::Finished));
        let kinds: Vec<_> = s.segments.iter().map(|x| x.kind).collect();
        assert_eq!(kinds, vec![SpanKind::Waiting, SpanKind::Prefill, SpanKind::Decode]);
    }

    #[test]
    fn pool_lifecycle_with_migration_dedups_encoded() {
        let mut rec = SpanRecorder::new();
        rec.on_request(&req(2, 1.0, 128));
        rec.observe(&RequestEvent::Ready { id: 2, t: 1.0 });
        rec.observe_obs(&ObsEvent::PoolEnqueued { id: 2, t: 1.0 });
        rec.observe_obs(&ObsEvent::PoolEncode { id: 2, slot: 3, start: 1.5, end: 2.5 });
        // the cluster also emits a public Encoded at done_at
        rec.observe(&RequestEvent::Encoded { id: 2, t: 2.5 });
        rec.observe_obs(&ObsEvent::Migration { id: 2, start: 2.5, end: 2.75 });
        rec.observe_obs(&ObsEvent::Admitted { id: 2, t: 3.0 });
        rec.observe(&RequestEvent::FirstToken { id: 2, t: 3.5 });
        rec.observe(&RequestEvent::Finished { id: 2, t: 4.0 });
        let spans = rec.finalize();
        let s = &spans[0];
        s.check_conservation().unwrap();
        assert_eq!(s.encode_count(), 1, "public Encoded must be deduped against PoolEncode");
        let kinds: Vec<_> = s.segments.iter().map(|x| x.kind).collect();
        assert_eq!(
            kinds,
            vec![
                SpanKind::PoolQueue,
                SpanKind::Encode,
                SpanKind::Migration,
                SpanKind::Waiting,
                SpanKind::Prefill,
                SpanKind::Decode,
            ]
        );
        assert_eq!(s.segments[1].slot, Some(3));
    }

    #[test]
    fn preemption_gap_is_conserved() {
        let mut rec = SpanRecorder::new();
        rec.on_request(&req(3, 0.0, 0));
        rec.observe(&RequestEvent::Ready { id: 3, t: 0.0 });
        rec.observe_obs(&ObsEvent::Admitted { id: 3, t: 0.0 });
        rec.observe(&RequestEvent::FirstToken { id: 3, t: 1.0 });
        rec.observe(&RequestEvent::Preempted { id: 3, t: 2.0 });
        rec.observe(&RequestEvent::Requeued { id: 3, t: 2.0 });
        rec.observe_obs(&ObsEvent::Admitted { id: 3, t: 3.0 });
        rec.observe(&RequestEvent::Finished { id: 3, t: 5.0 });
        let spans = rec.finalize();
        let s = &spans[0];
        s.check_conservation().unwrap();
        assert!((s.gap_total() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn same_instant_scramble_stays_conserved() {
        // preempt, requeue, and re-admit all at t=2.0, then run on
        let mut rec = SpanRecorder::new();
        rec.on_request(&req(4, 0.0, 0));
        rec.observe(&RequestEvent::Ready { id: 4, t: 0.0 });
        rec.observe_obs(&ObsEvent::Admitted { id: 4, t: 0.0 });
        rec.observe(&RequestEvent::FirstToken { id: 4, t: 1.0 });
        rec.observe(&RequestEvent::Preempted { id: 4, t: 2.0 });
        rec.observe(&RequestEvent::Requeued { id: 4, t: 2.0 });
        rec.observe_obs(&ObsEvent::Admitted { id: 4, t: 2.0 });
        rec.observe(&RequestEvent::Finished { id: 4, t: 3.0 });
        let spans = rec.finalize();
        let s = &spans[0];
        s.check_conservation().unwrap();
        assert_eq!(s.gap_total(), 0.0, "zero-length scramble must leave no gap");
    }

    #[test]
    fn dropped_request_conserves_to_drop_instant() {
        let mut rec = SpanRecorder::new();
        rec.on_request(&req(5, 0.0, 0));
        rec.observe(&RequestEvent::Ready { id: 5, t: 0.0 });
        rec.observe(&RequestEvent::Dropped { id: 5, t: 4.0 });
        let spans = rec.finalize();
        let s = &spans[0];
        s.check_conservation().unwrap();
        assert_eq!(s.terminal, Some(Terminal::Dropped));
        assert_eq!(s.segments.len(), 1);
        assert_eq!(s.segments[0].kind, SpanKind::Waiting);
    }
}
