//! Scheduling policies: TCM-Serve and every baseline in the paper's
//! evaluation (Fig 8, Fig 10).
//!
//! A policy plugs into the shared continuous-batching scheduler
//! ([`crate::coordinator::scheduler`]) through three decisions:
//!
//! 1. **admit** — classify an arriving request (class + impact estimate);
//! 2. **order_key** — a per-iteration sort key over waiting/running
//!    requests (lower runs first);
//! 3. **preemption** — whether admission may preempt, and which victim
//!    to evict (the scheduler proposes the max-key running request).
//!
//! | policy            | order                        | classify | preempt-for-admission |
//! |-------------------|------------------------------|----------|-----------------------|
//! | `fcfs` (vLLM)     | arrival (ready) time         | no       | no (growth only)      |
//! | `edf`             | absolute deadline            | no       | yes                   |
//! | `naive-class`     | static prio, naive classes   | naive    | yes                   |
//! | `static-priority` | static prio, smart classes   | smart    | yes                   |
//! | `naive-aging`     | pure age (oldest first)      | no       | yes                   |
//! | `tcm`             | regulator score (aging+class)| smart    | yes                   |

use crate::config::ServeConfig;
use crate::coordinator::classifier::{Classifier, NaiveClassifier, SmartClassifier};
use crate::coordinator::estimator::{Impact, ImpactEstimator};
use crate::coordinator::priority::PriorityRegulator;
use crate::coordinator::profiler::Profiler;
use crate::coordinator::state::ReqState;
use crate::model::ModelProfile;
use crate::request::{Class, Request, SloClass};

/// Score shift applied per client-declared [`SloClass`] tier in the
/// class-priority family: `ln 4`, i.e. a `Critical` request schedules as
/// if its regulator priority were 4× (and `BestEffort` as if ×1/4).
/// Scores are `−log(priority)`, so a constant priority *factor* is a
/// constant score *shift* — aging dynamics within a tier are unchanged,
/// and `Standard`/undeclared is bit-identical to the pre-lifecycle score.
pub const SLO_CLASS_LN_SHIFT: f64 = 1.3862943611198906;

/// The score adjustment for a request's declared SLO class (0.0 for
/// `Standard`/undeclared — callers on that path stay bit-identical).
#[inline]
pub fn slo_class_shift(slo_class: Option<SloClass>) -> f64 {
    match slo_class {
        None | Some(SloClass::Standard) => 0.0,
        Some(SloClass::Critical) => -SLO_CLASS_LN_SHIFT,
        Some(SloClass::BestEffort) => SLO_CLASS_LN_SHIFT,
    }
}

/// Scheduling sort key, compared lexicographically: `(score, tie)` —
/// the policy's score first, then a tie-break (class policies use the
/// ready time so equal scores stay FCFS). A tuple rather than a weighted
/// f64 blend because a blend leaks into the score magnitude: at
/// `ready_time ≳ 1e8` virtual seconds an ε-weighted tie-break exceeds
/// real score gaps and inverts class order.
pub type OrderKey = (f64, f64);

/// Victim-selection key: class rank first (trucks evicted before cars
/// before motorcycles), then the order key.
pub type VictimKey = (u8, OrderKey);

/// Total lexicographic order over [`OrderKey`]s. The scheduler's sorts
/// and victim scans must never go through `PartialOrd` + `unwrap()`: a
/// single NaN score (adversarial input, estimator edge case) would panic
/// the leader loop. `total_cmp` orders NaN deterministically instead
/// (after +inf), so a poisoned request sorts last and gets served or
/// preempted like any other — enforced tree-wide by
/// `simlint`'s `partial-cmp-unwrap` rule.
#[inline]
pub fn cmp_order_key(a: &OrderKey, b: &OrderKey) -> std::cmp::Ordering {
    a.0.total_cmp(&b.0).then_with(|| a.1.total_cmp(&b.1))
}

/// Total lexicographic order over [`VictimKey`]s (see [`cmp_order_key`]).
#[inline]
pub fn cmp_victim_key(a: &VictimKey, b: &VictimKey) -> std::cmp::Ordering {
    a.0.cmp(&b.0).then_with(|| cmp_order_key(&a.1, &b.1))
}

/// Decision interface between the scheduler and a policy.
pub trait Policy: Send {
    fn name(&self) -> &'static str;

    /// Classify an arriving request. Returns (class, impact) — `None`s
    /// for baselines without classifier/estimator.
    fn admit(&mut self, req: &Request) -> (Option<Class>, Option<Impact>);

    /// Sort key at time `now`: lexicographically lower = scheduled
    /// earlier.
    fn order_key(&self, rs: &ReqState, now: f64) -> OrderKey;

    /// Victim-selection key, compared lexicographically: the *highest*
    /// value is evicted first when KV memory runs out. Defaults to
    /// `(0, order_key)` (evict the least urgent). Class-aware policies
    /// put the class rank in the first component so trucks are evicted
    /// before cars before motorcycles regardless of aging — the mechanism
    /// behind the paper's "TCM eliminates preemptions for motorcycles"
    /// (Fig 11). A tuple (not a weighted f64 sum) because the second
    /// component's resolution must survive: collapsing both into one
    /// float ties all same-class victims and the strict preemption gate
    /// then live-locks on self-preemption.
    fn victim_key(&self, rs: &ReqState, now: f64) -> VictimKey {
        (0, self.order_key(rs, now))
    }

    /// Time-invariant rank for the indexed ready set
    /// ([`crate::coordinator::readyset::ReadySet`]): a `(family, rank)`
    /// pair such that among waiting requests **of the same family**, the
    /// dynamic `order_key(·, now)` ranks them in ascending `rank` order
    /// (ties by insertion order) for *every* `now`. Families partition
    /// the queue so that cross-family order may drift with time (aging
    /// moves whole classes against each other) while within-family order
    /// cannot — which is what lets the planner keep requests pre-sorted
    /// across iterations and evaluate only one key per family head
    /// instead of one per waiting request.
    ///
    /// The contract holds because every policy's score is monotone
    /// non-decreasing in the chosen rank at any fixed `now`, and score
    /// plateaus (aging saturation, static ablations) fall through to the
    /// `ready_time` tie-break, which equals the rank on those paths.
    /// `rank_key` is evaluated on state transitions only (enqueue,
    /// preemption re-queue) — the incremental rescore counted in
    /// `planning_evals` by the indexed scheduler.
    fn rank_key(&self, rs: &ReqState) -> (u8, f64);

    /// May a waiting request preempt a running one to be admitted?
    fn preempt_for_admission(&self) -> bool;

    /// Skip memory-blocked waiting requests and try later (smaller) ones?
    /// vLLM's FCFS keeps strict order (head-of-line blocks); priority
    /// policies let motorcycles flow past blocked trucks.
    fn skip_blocked(&self) -> bool;
}

// ---------------------------------------------------------------------
// vLLM baseline: FCFS + chunked prefill
// ---------------------------------------------------------------------

/// First-come-first-served (vLLM default). Preempts only for KV growth
/// (the scheduler's recompute path), choosing the most recent arrival.
pub struct FcfsPolicy;

impl Policy for FcfsPolicy {
    fn name(&self) -> &'static str {
        "fcfs"
    }

    fn admit(&mut self, _req: &Request) -> (Option<Class>, Option<Impact>) {
        (None, None)
    }

    fn order_key(&self, rs: &ReqState, _now: f64) -> OrderKey {
        (rs.ready_time, 0.0)
    }

    fn rank_key(&self, rs: &ReqState) -> (u8, f64) {
        // the order key is already time-invariant: one family
        (0, rs.ready_time)
    }

    fn preempt_for_admission(&self) -> bool {
        false
    }

    fn skip_blocked(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------
// EDF baseline
// ---------------------------------------------------------------------

/// Earliest-deadline-first. Assumes deadline knowledge (§4.1: EDF "assumes
/// knowledge of each request's deadline or relies on prediction models") —
/// we grant it the true SLO deadline.
pub struct EdfPolicy;

impl Policy for EdfPolicy {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn admit(&mut self, _req: &Request) -> (Option<Class>, Option<Impact>) {
        (None, None)
    }

    fn order_key(&self, rs: &ReqState, _now: f64) -> OrderKey {
        (rs.deadline(), 0.0)
    }

    fn rank_key(&self, rs: &ReqState) -> (u8, f64) {
        // deadlines are fixed at arrival: one family, ranked by deadline
        (0, rs.deadline())
    }

    fn preempt_for_admission(&self) -> bool {
        true
    }

    fn skip_blocked(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// Naive aging baseline (Fig 8): oldest-first, no classes
// ---------------------------------------------------------------------

/// Pure age priority: the older the request, the higher its priority,
/// ignoring the motorcycles/cars/trucks hierarchy.
pub struct NaiveAgingPolicy;

impl Policy for NaiveAgingPolicy {
    fn name(&self) -> &'static str {
        "naive-aging"
    }

    fn admit(&mut self, _req: &Request) -> (Option<Class>, Option<Impact>) {
        (None, None)
    }

    fn order_key(&self, rs: &ReqState, now: f64) -> OrderKey {
        (-rs.waiting_time(now), 0.0)
    }

    fn rank_key(&self, rs: &ReqState) -> (u8, f64) {
        // −waiting_time(now) = first_enqueue − now: at any fixed `now`
        // the oldest-first order is the first_enqueue order
        (0, rs.first_enqueue)
    }

    fn preempt_for_admission(&self) -> bool {
        true
    }

    fn skip_blocked(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// Classifier + priority family: naive-class, static-priority, tcm
// ---------------------------------------------------------------------

/// Class-priority policy: a classifier (naive or smart) plus the Priority
/// Regulator (aging optional). Instantiates three of the paper's systems:
/// * `naive-class`    = NaiveClassifier + static priorities,
/// * `static-priority`= SmartClassifier + static priorities,
/// * `tcm`            = SmartClassifier + full regulator (the paper).
pub struct ClassPriorityPolicy<C: Classifier> {
    name: &'static str,
    classifier: C,
    estimator: ImpactEstimator,
    regulator: PriorityRegulator,
}

impl<C: Classifier> ClassPriorityPolicy<C> {
    pub fn new(
        name: &'static str,
        classifier: C,
        estimator: ImpactEstimator,
        regulator: PriorityRegulator,
    ) -> Self {
        ClassPriorityPolicy { name, classifier, estimator, regulator }
    }
}

impl<C: Classifier + Send> Policy for ClassPriorityPolicy<C> {
    fn name(&self) -> &'static str {
        self.name
    }

    fn admit(&mut self, req: &Request) -> (Option<Class>, Option<Impact>) {
        let impact = self.estimator.estimate(req);
        let class = self.classifier.classify(req, &impact);
        (Some(class), Some(impact))
    }

    fn order_key(&self, rs: &ReqState, now: f64) -> OrderKey {
        // Score = −log(priority); FCFS within class follows from score
        // monotonicity in waiting time. Lexicographic tie-break on ready
        // time keeps equal scores (e.g. static ablation) FCFS without
        // perturbing the score itself — an ε-weighted blend inverts class
        // order once ready_time grows past the score gaps. A declared
        // SLO class shifts the score by a constant (zero for Standard —
        // that path is bit-identical to an undeclared class).
        let class = rs.class.unwrap_or(Class::Truck);
        let score =
            self.regulator.score(class, rs.waiting_time(now)) + slo_class_shift(rs.req.slo_class);
        (score, rs.ready_time)
    }

    fn victim_key(&self, rs: &ReqState, now: f64) -> VictimKey {
        // Strict class hierarchy for eviction: trucks first, then cars;
        // motorcycles only as a last resort. Within a class, evict the
        // least-priority (highest-score) request.
        let class = rs.class.unwrap_or(Class::Truck);
        (class as u8, self.order_key(rs, now))
    }

    fn rank_key(&self, rs: &ReqState) -> (u8, f64) {
        // One family per (class, SLO tier): the regulator score is
        // `−ln((static_c + 1 − e^{−k_c·w^{p_c}}).max(1e-9)) + shift(tier)`
        // with `w = (now − first_enqueue).max(0)` — within a fixed
        // (class, tier) the score is monotone non-decreasing in
        // `first_enqueue` at every `now` (older waits more, so it scores
        // lower), and score plateaus (aging saturation, the max-clamp,
        // aging disabled) fall through to the `ready_time` tie-break,
        // which equals `first_enqueue` (both are set only in
        // `mark_ready`). So `first_enqueue` ranks the family for all
        // time. Cross-family order is what aging changes — those streams
        // are merged per-iteration by the planner.
        let class = rs.class.unwrap_or(Class::Truck);
        let tier = match rs.req.slo_class {
            Some(crate::request::SloClass::Critical) => 0u8,
            None | Some(crate::request::SloClass::Standard) => 1,
            Some(crate::request::SloClass::BestEffort) => 2,
        };
        (class as u8 * 3 + tier, rs.first_enqueue)
    }

    fn preempt_for_admission(&self) -> bool {
        true
    }

    fn skip_blocked(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------
// Factory
// ---------------------------------------------------------------------

/// Train (if needed) and build the policy named in the config.
/// Profiling/training happens once here — the paper's offline phase.
pub fn build_policy(cfg: &ServeConfig, profile: &ModelProfile) -> Box<dyn Policy> {
    match cfg.policy.as_str() {
        "fcfs" => Box::new(FcfsPolicy),
        "edf" => Box::new(EdfPolicy),
        "naive-aging" => Box::new(NaiveAgingPolicy),
        name @ ("naive-class" | "static-priority" | "tcm") => {
            let data = Profiler::new(profile, cfg.seed ^ 0x0FF1CE).run(300);
            let estimator = ImpactEstimator::train(&data);
            let mut reg_cfg = cfg.regulator.clone();
            // The ablation variants use static priorities only.
            if name != "tcm" {
                reg_cfg.aging_enabled = false;
            }
            let regulator = PriorityRegulator::new(reg_cfg);
            match name {
                "naive-class" => Box::new(ClassPriorityPolicy::new(
                    "naive-class",
                    NaiveClassifier,
                    estimator,
                    regulator,
                )),
                "static-priority" => Box::new(ClassPriorityPolicy::new(
                    "static-priority",
                    SmartClassifier::train(&data, &estimator, cfg.seed),
                    estimator,
                    regulator,
                )),
                _ => Box::new(ClassPriorityPolicy::new(
                    "tcm",
                    SmartClassifier::train(&data, &estimator, cfg.seed),
                    estimator,
                    regulator,
                )),
            }
        }
        other => panic!("unknown policy '{other}' (validate() should have caught this)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::request::Modality;

    fn rs(arrival: f64, ready: f64, slo: f64) -> ReqState {
        let mut s = ReqState::new(
            Request {
                id: 1,
                arrival,
                modality: Modality::Text,
                text_tokens: 50,
                mm_tokens: 0,
                video_duration_s: 0.0,
                output_tokens: 10,
                ..Request::default()
            },
            slo,
        );
        s.ready_time = ready;
        s.first_enqueue = ready;
        s
    }

    #[test]
    fn fcfs_orders_by_ready_time() {
        let p = FcfsPolicy;
        assert!(p.order_key(&rs(0.0, 1.0, 5.0), 10.0) < p.order_key(&rs(0.5, 2.0, 5.0), 10.0));
        assert!(!p.preempt_for_admission());
        assert!(!p.skip_blocked());
    }

    #[test]
    fn edf_orders_by_deadline() {
        let p = EdfPolicy;
        // arrival 0 + slo 3 = deadline 3 beats arrival 1 + slo 5 = 6
        assert!(p.order_key(&rs(0.0, 0.1, 3.0), 2.0) < p.order_key(&rs(1.0, 1.1, 5.0), 2.0));
    }

    #[test]
    fn naive_aging_prefers_oldest() {
        let p = NaiveAgingPolicy;
        assert!(p.order_key(&rs(0.0, 0.0, 5.0), 10.0) < p.order_key(&rs(0.0, 8.0, 5.0), 10.0));
    }

    #[test]
    fn factory_builds_every_policy() {
        let profile = by_name("llava-7b").unwrap();
        for name in ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"] {
            let mut cfg = ServeConfig::default();
            cfg.policy = name.into();
            cfg.num_requests = 1;
            let p = build_policy(&cfg, &profile);
            assert_eq!(p.name(), name);
        }
    }

    #[test]
    fn class_order_survives_large_ready_times() {
        // Regression: the old tie-break (`score + ready_time * 1e-9`)
        // leaked into the score magnitude — at ready_time ≥ ~1e9 virtual
        // seconds the perturbation exceeded the M/C static score gap
        // (−ln 0.05 − (−ln 0.1) ≈ 0.69) and inverted class order. The
        // lexicographic key must keep a fresh motorcycle ahead of a car
        // no matter how late it became ready.
        let profile = by_name("llava-7b").unwrap();
        let mut cfg = ServeConfig::default();
        cfg.policy = "static-priority".into(); // aging off: scores constant
        let p = build_policy(&cfg, &profile);

        let now = 1.0e9;
        let mut m = rs(now, now, 5.0); // motorcycle ready very late
        m.class = Some(Class::Motorcycle);
        let mut c = rs(0.0, 0.0, 5.0); // car ready at time zero
        c.class = Some(Class::Car);

        assert!(
            p.order_key(&m, now) < p.order_key(&c, now),
            "motorcycle must outrank car regardless of ready-time magnitude: {:?} vs {:?}",
            p.order_key(&m, now),
            p.order_key(&c, now)
        );
        // and the tie-break still keeps equal scores FCFS
        let mut m2 = rs(now, now - 1.0, 5.0);
        m2.class = Some(Class::Motorcycle);
        let mut m3 = m2.clone();
        m3.ready_time = now;
        m3.first_enqueue = m2.first_enqueue; // same waiting time → same score
        assert!(p.order_key(&m2, now) < p.order_key(&m3, now));
    }

    #[test]
    fn slo_class_shifts_order_within_and_across_classes() {
        let profile = by_name("llava-7b").unwrap();
        let mut cfg = ServeConfig::default();
        cfg.policy = "tcm".into();
        let p = build_policy(&cfg, &profile);

        // same class, same wait: Critical < Standard < BestEffort
        let mut std_m = rs(0.0, 0.0, 5.0);
        std_m.class = Some(Class::Motorcycle);
        let mut crit_m = std_m.clone();
        crit_m.req.slo_class = Some(SloClass::Critical);
        let mut be_m = std_m.clone();
        be_m.req.slo_class = Some(SloClass::BestEffort);
        assert!(p.order_key(&crit_m, 1.0) < p.order_key(&std_m, 1.0));
        assert!(p.order_key(&std_m, 1.0) < p.order_key(&be_m, 1.0));

        // an undeclared class is bit-identical to Standard
        let mut none_m = std_m.clone();
        none_m.req.slo_class = None;
        std_m.req.slo_class = Some(SloClass::Standard);
        assert_eq!(p.order_key(&none_m, 1.0), p.order_key(&std_m, 1.0));

        // a critical car outranks a fresh standard motorcycle: the ln 4
        // boost exceeds the M/C static gap (ln 0.1 − ln 0.05 ≈ 0.69)
        let mut crit_c = rs(0.0, 0.0, 5.0);
        crit_c.class = Some(Class::Car);
        crit_c.req.slo_class = Some(SloClass::Critical);
        assert!(p.order_key(&crit_c, 0.0) < p.order_key(&none_m, 0.0));
    }

    #[test]
    fn tcm_motorcycle_outranks_truck_until_aged() {
        let profile = by_name("llava-7b").unwrap();
        let mut cfg = ServeConfig::default();
        cfg.policy = "tcm".into();
        let mut p = build_policy(&cfg, &profile);

        let mut m = rs(0.0, 0.0, 5.0);
        let (c, i) = p.admit(&m.req);
        m.class = c;
        m.impact = i;
        assert_eq!(m.class, Some(Class::Motorcycle));

        let mut t = rs(0.0, 0.0, 60.0);
        t.req.modality = Modality::Video;
        t.req.mm_tokens = 6272;
        t.req.video_duration_s = 120.0;
        let (c, i) = p.admit(&t.req);
        t.class = c;
        t.impact = i;
        assert_eq!(t.class, Some(Class::Truck));

        // fresh: motorcycle first
        assert!(p.order_key(&m, 0.0) < p.order_key(&t, 0.0));
        // after the truck waits a very long time, it outranks a fresh
        // motorcycle (anti-starvation)
        let mut fresh_m = m.clone();
        fresh_m.first_enqueue = 3000.0;
        fresh_m.ready_time = 3000.0;
        assert!(p.order_key(&t, 3000.0) < p.order_key(&fresh_m, 3000.0));
    }
}
