//! Thread-based serving front end.
//!
//! tokio is unavailable in the offline crate set, so the leader loop uses
//! std threads + mpsc channels — the same topology as vLLM's single-
//! threaded engine core behind an ingress queue. Clients submit requests
//! through a [`ServerHandle`] and receive streamed events (first token /
//! completion) on a per-request channel.
//!
//! This front end drives the *real* engine in wall-clock time; simulation
//! experiments use [`crate::experiments`] directly (virtual time cannot
//! be driven by external threads).

use crate::config::ServeConfig;
use crate::coordinator::Scheduler;
use crate::engine::Engine;
use crate::metrics::Report;
use crate::policies::build_policy;
use crate::request::Request;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Events streamed back to a client.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseEvent {
    FirstToken { req_id: u64, ttft_s: f64 },
    Finished { req_id: u64, e2e_s: f64, output_tokens: u32 },
}

enum ServerMsg {
    Submit(Request, mpsc::Sender<ResponseEvent>),
    Shutdown,
}

/// Client-side handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
}

impl ServerHandle {
    /// Submit a request; events arrive on the returned receiver.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<ResponseEvent> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(ServerMsg::Submit(req, tx)).expect("server gone");
        rx
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
    }
}

/// A serving leader running a scheduler over an engine on its own thread.
pub struct Server {
    handle: ServerHandle,
    join: JoinHandle<Report>,
}

impl Server {
    /// Spawn the leader thread. The engine must be Send (both engines are).
    pub fn spawn(cfg: ServeConfig, engine: Box<dyn Engine + Send>) -> Server {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let join = std::thread::spawn(move || leader_loop(cfg, engine, rx));
        Server { handle: ServerHandle { tx }, join }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shut down and collect the final report.
    pub fn finish(self) -> Report {
        self.handle.shutdown();
        self.join.join().expect("leader thread panicked")
    }
}

/// The leader: drain ingress, run the scheduler to completion over the
/// accumulated batch, stream events. Wall-clock arrivals are mapped onto
/// the scheduler's clock by stamping each request's arrival with the
/// leader's elapsed time.
fn leader_loop(
    cfg: ServeConfig,
    engine: Box<dyn Engine + Send>,
    rx: mpsc::Receiver<ServerMsg>,
) -> Report {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let policy = build_policy(&cfg, &profile);
    let mut sched = Scheduler::new(cfg, policy, engine);

    let t0 = std::time::Instant::now();
    let mut pending: Vec<Request> = Vec::new();
    let mut subscribers: std::collections::HashMap<u64, mpsc::Sender<ResponseEvent>> =
        std::collections::HashMap::new();

    // Ingress: accept until shutdown. Requests carry their true submit
    // time so queueing before the batch runs is accounted for.
    loop {
        match rx.recv() {
            Ok(ServerMsg::Submit(mut req, sub)) => {
                req.arrival = t0.elapsed().as_secs_f64();
                subscribers.insert(req.id, sub);
                pending.push(req);
            }
            Ok(ServerMsg::Shutdown) | Err(_) => break,
        }
    }

    let report = sched.run(pending);
    for o in &report.outcomes {
        if let Some(sub) = subscribers.get(&o.id) {
            let _ = sub.send(ResponseEvent::FirstToken { req_id: o.id, ttft_s: o.ttft() });
            let _ = sub.send(ResponseEvent::Finished {
                req_id: o.id,
                e2e_s: o.e2e(),
                output_tokens: o.output_tokens,
            });
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim_engine::SimEngine;
    use crate::request::Modality;

    #[test]
    fn serve_roundtrip_with_sim_engine() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.num_requests = 4;
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let server = Server::spawn(cfg, Box::new(SimEngine::new(&profile)));
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..4u64 {
            rxs.push(h.submit(Request {
                id,
                arrival: 0.0,
                modality: Modality::Text,
                text_tokens: 64,
                mm_tokens: 0,
                video_duration_s: 0.0,
                output_tokens: 4,
            }));
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 4);
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }
}
