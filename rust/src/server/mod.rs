//! Thread-based serving front end.
//!
//! tokio is unavailable in the offline crate set, so the leader loop uses
//! std threads + mpsc channels — the same topology as vLLM's single-
//! threaded engine core behind an ingress queue. Clients submit requests
//! through a [`ServerHandle`] and receive streamed events (first token /
//! completion / drop / cancel / rejection) on a per-request channel.
//!
//! There is exactly **one** leader loop, generic over
//! [`ServeBackend`](crate::backend::ServeBackend): a bare scheduler and a
//! multi-replica cluster (with or without the encoder pool) are served by
//! the same code. Backends may hold non-Send engines, so [`Server::spawn`]
//! takes a Send *factory* and builds the backend inside the leader thread.
//!
//! The leader is *truly online*: it interleaves channel ingress with
//! backend iterations via the stepping API — a request submitted while
//! others are in flight is scheduled between their iterations, and its
//! `FirstToken` event is delivered at the iteration that produces it,
//! not after the batch drains. Wall-clock time maps onto the backend
//! clock continuously (`advance_to` with the leader's elapsed time
//! before every step).
//!
//! # Request lifecycle
//!
//! * **Deadlines / SLO classes** — [`ServerHandle::submit_with`] attaches
//!   [`SubmitOptions`]: an explicit end-to-end deadline (feeds EDF and
//!   SLO accounting) and/or an [`SloClass`] tier (shifts the
//!   class-priority score).
//! * **Cancellation** — [`ServerHandle::cancel`] aborts a request in any
//!   state; the client receives [`ResponseEvent::Cancelled`] as its
//!   terminal event and the backend frees KV/encoder resources.
//! * **Admission backpressure** — with `cfg.server.admission_limit > 0`
//!   the leader answers over-limit submissions with an immediate
//!   [`ResponseEvent::Rejected`] instead of buffering without bound; a
//!   saturated fleet fails fast.
//!
//! This front end drives the *real* engine in wall-clock time; pure
//! virtual-time experiments use [`crate::experiments`] directly. A
//! simulated engine still works behind the server (the tests do exactly
//! that), with the caveat that its virtual iteration costs accumulate
//! into the backend clock on top of the wall mapping, so event
//! timestamps run ahead of wall time.

use crate::backend::{self, ServeBackend};
use crate::config::ServeConfig;
use crate::coordinator::{RequestEvent, StepOutcome};
use crate::engine::Engine;
use crate::metrics::Report;
use crate::request::{Request, SloClass};
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Events streamed back to a client.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseEvent {
    FirstToken { req_id: u64, ttft_s: f64 },
    Finished { req_id: u64, e2e_s: f64, output_tokens: u32 },
    /// The scheduler gave up on the request (prompt can never fit, or
    /// terminally blocked at shutdown).
    Dropped { req_id: u64 },
    /// The request was cancelled via [`ServerHandle::cancel`]; terminal.
    Cancelled { req_id: u64 },
    /// Bounded admission refused the request before it reached the
    /// backend (`cfg.server.admission_limit`); terminal, and the only
    /// event the request will ever produce. Resubmit later or shed load.
    Rejected { req_id: u64 },
}

/// Client-attached lifecycle options for [`ServerHandle::submit_with`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct SubmitOptions {
    /// End-to-end deadline, seconds after submission. Becomes the
    /// request's SLO latency (EDF orders by it; SLO accounting measures
    /// against it). `None` = the configured `slo_scale` default; a
    /// non-finite or non-positive value is treated as `None` (client
    /// input must not poison scheduler order keys).
    pub deadline_s: Option<f64>,
    /// Latency tier; `None` behaves as [`SloClass::Standard`].
    pub slo_class: Option<SloClass>,
}

/// The server is gone: the leader thread has exited (shutdown raced the
/// call) or was never reachable. Submissions and cancels return this
/// instead of panicking so client threads survive shutdown races.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerGone;

impl std::fmt::Display for ServerGone {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("server gone: the leader thread has exited")
    }
}

impl std::error::Error for ServerGone {}

enum ServerMsg {
    Submit(Request, mpsc::Sender<ResponseEvent>),
    Cancel(u64),
    /// Telemetry scrape: reply with Prometheus text (see
    /// [`ServerHandle::metrics_text`]).
    Metrics(mpsc::Sender<String>),
    Shutdown,
}

/// Client-side handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
}

impl ServerHandle {
    /// Submit a request; events arrive on the returned receiver. Errs
    /// with [`ServerGone`] when the leader has already exited (instead
    /// of panicking — submission legitimately races shutdown).
    pub fn submit(&self, req: Request) -> Result<mpsc::Receiver<ResponseEvent>, ServerGone> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(ServerMsg::Submit(req, tx)).map_err(|_| ServerGone)?;
        Ok(rx)
    }

    /// Submit with lifecycle options (deadline, SLO class).
    pub fn submit_with(
        &self,
        mut req: Request,
        opts: SubmitOptions,
    ) -> Result<mpsc::Receiver<ResponseEvent>, ServerGone> {
        req.deadline_s = opts.deadline_s;
        req.slo_class = opts.slo_class;
        self.submit(req)
    }

    /// Cancel a previously submitted request. Works in any state (queued
    /// at an encoder pool, waiting, running); the client's receiver gets
    /// [`ResponseEvent::Cancelled`] as its terminal event. A cancel that
    /// races completion loses quietly (the terminal event already sent
    /// stands). Errs only when the leader has exited.
    pub fn cancel(&self, req_id: u64) -> Result<(), ServerGone> {
        self.tx.send(ServerMsg::Cancel(req_id)).map_err(|_| ServerGone)
    }

    /// Scrape current telemetry as Prometheus text (the `/metrics`
    /// endpoint a real deployment would expose). Requires the backend to
    /// have been spawned with `cfg.obs` active; otherwise returns a
    /// comment line saying telemetry is disabled. Errs with
    /// [`ServerGone`] when the leader has exited.
    pub fn metrics_text(&self) -> Result<String, ServerGone> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(ServerMsg::Metrics(tx)).map_err(|_| ServerGone)?;
        rx.recv().map_err(|_| ServerGone)
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
    }
}

/// A serving leader running a backend on its own thread.
pub struct Server {
    handle: ServerHandle,
    join: JoinHandle<Report>,
}

impl Server {
    /// Spawn the leader thread over any [`ServeBackend`]. The factory
    /// runs *inside* the leader thread (backends may hold non-Send
    /// engines — only the factory crosses the boundary), receiving the
    /// config it should build from.
    pub fn spawn<F>(cfg: ServeConfig, make_backend: F) -> Server
    where
        F: FnOnce(&ServeConfig) -> Box<dyn ServeBackend> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let join = std::thread::spawn(move || {
            let backend = make_backend(&cfg);
            leader_loop(&cfg, backend, rx)
        });
        Server { handle: ServerHandle { tx }, join }
    }

    /// Spawn over the backend the config describes — a bare scheduler
    /// with a simulated engine, or a cluster when `cfg.cluster.replicas
    /// > 1` / the encoder pool is enabled (see [`backend::build`]).
    pub fn spawn_sim(cfg: ServeConfig) -> Server {
        Server::spawn(cfg, backend::build)
    }

    /// Spawn a single-scheduler server over an explicit engine (the real
    /// PJRT engine, a throttled test engine). The engine must be Send to
    /// reach the leader thread; it is boxed into the scheduler there.
    pub fn spawn_engine(cfg: ServeConfig, engine: Box<dyn Engine + Send>) -> Server {
        Server::spawn(cfg, move |cfg| backend::scheduler_backend(cfg, engine))
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shut down and collect the final report.
    pub fn finish(self) -> Report {
        self.handle.shutdown();
        self.join.join().expect("leader thread panicked")
    }
}

/// Per-request client bookkeeping on the leader side.
struct Subscriber {
    tx: mpsc::Sender<ResponseEvent>,
    arrival: f64,
    output_tokens: u32,
}

/// Receive the next pending channel message. `block` bounds the wait to
/// one 25 ms timeout slice (the leader re-checks backend state after).
/// `Err(())` means every handle is gone — treat as shutdown.
fn recv_msg(rx: &mpsc::Receiver<ServerMsg>, block: bool) -> Result<Option<ServerMsg>, ()> {
    if block {
        match rx.recv_timeout(Duration::from_millis(25)) {
            Ok(m) => Ok(Some(m)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(()),
        }
    } else {
        match rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(()),
        }
    }
}

/// The one generic leader: interleave ingress with backend steps. Each
/// loop turn drains every pending channel message (injecting new
/// requests, applying cancels, rejecting over-limit submissions), maps
/// wall-clock onto the backend clock, runs one iteration, streams the
/// iteration's events to subscribers, and retires terminal backend
/// state (`take_finished`) so backend-side memory stays flat over an
/// unbounded request stream (the accumulated outcome history returned
/// at shutdown still grows, a few dozen bytes per request). When there
/// is nothing runnable it blocks on the channel instead of spinning.
fn leader_loop(
    cfg: &ServeConfig,
    mut backend: Box<dyn ServeBackend>,
    rx: mpsc::Receiver<ServerMsg>,
) -> Report {
    let admission_limit = cfg.server.admission_limit;
    let t0 = Instant::now();
    let mut subscribers: HashMap<u64, Subscriber> = HashMap::new();
    let mut collected = Report::default();
    let mut shutdown = false;
    // Block on the channel (instead of polling) on the next turn; set
    // whenever the backend reports nothing can run until new input.
    let mut block_for_msg = false;

    loop {
        // 1. ingest: drain everything available; block once when idle
        loop {
            let block = block_for_msg && !shutdown;
            block_for_msg = false;
            match recv_msg(&rx, block) {
                Ok(Some(ServerMsg::Submit(mut req, tx))) => {
                    // bounded admission: outstanding = accepted requests
                    // whose terminal event has not been delivered yet
                    if admission_limit > 0 && subscribers.len() >= admission_limit {
                        collected.rejected += 1;
                        let _ = tx.send(ResponseEvent::Rejected { req_id: req.id });
                        // dropping tx closes the client's channel after
                        // the rejection — its event stream terminates
                        continue;
                    }
                    // stamp the true submit time so queueing before the
                    // first iteration is accounted for
                    req.arrival = t0.elapsed().as_secs_f64();
                    subscribers.insert(
                        req.id,
                        Subscriber { tx, arrival: req.arrival, output_tokens: req.output_tokens },
                    );
                    backend.inject(req);
                }
                Ok(Some(ServerMsg::Cancel(id))) => {
                    // the backend emits Cancelled as the terminal event;
                    // deliver() retires the subscriber when it streams
                    backend.cancel(id);
                }
                Ok(Some(ServerMsg::Metrics(tx))) => {
                    let text = backend
                        .telemetry_snapshot()
                        .map(|s| crate::obs::prometheus_text(&s))
                        .unwrap_or_else(|| "# telemetry disabled (spawn with --obs)\n".into());
                    let _ = tx.send(text);
                }
                Ok(Some(ServerMsg::Shutdown)) => shutdown = true,
                Ok(None) => break,
                Err(()) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // 2. wall-clock → backend clock (monotone; never rewinds)
        backend.advance_to(t0.elapsed().as_secs_f64());

        // 3. one scheduling iteration
        let outcome = backend.step();

        // 4. stream this iteration's events as they happen, then retire
        //    the iteration's terminal state into the running report
        for ev in backend.take_events() {
            deliver(&mut subscribers, ev);
        }
        collected.merge(backend.take_finished());

        match outcome {
            StepOutcome::Executed { .. } => {}
            // Nothing runnable until an internal event (preprocess
            // completion / pending arrival): jump the backend clock to
            // it. For the real engine that time is at/near wall time; for
            // a simulated engine it is virtual and there is no point
            // waiting wall-clock for it.
            StepOutcome::Idle { next_event } => backend.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => backend.advance_to(t),
            StepOutcome::Blocked { next_event: None } => {
                if shutdown {
                    // same terminal guard the batch drain applies: these
                    // can never run; fail them so clients are notified
                    backend.drop_blocked();
                } else {
                    block_for_msg = true;
                }
            }
            StepOutcome::Drained => {
                if shutdown {
                    break;
                }
                block_for_msg = true;
            }
        }
    }

    // deliver anything emitted by a final drop_blocked
    for ev in backend.take_events() {
        deliver(&mut subscribers, ev);
    }
    collected.merge(backend.take_finished());
    // observability: flush the Perfetto trace at shutdown when requested
    if let Some(path) = &cfg.obs.trace_out {
        if let Some(json) = backend.trace_json() {
            if let Err(e) = std::fs::write(path, json) {
                eprintln!("failed to write trace {path}: {e}");
            }
        }
    }
    collected.sort_by_id();
    collected
}

/// Route one backend event to its subscriber. Terminal events
/// (`Finished`/`Dropped`/`Cancelled`) retire the subscriber entry — the
/// map must not grow with total requests served, and dropping the
/// retained `Sender` closes the per-request channel so clients iterating
/// their receiver terminate without waiting for server shutdown.
fn deliver(subscribers: &mut HashMap<u64, Subscriber>, ev: RequestEvent) {
    match ev {
        RequestEvent::FirstToken { id, t } => {
            if let Some(s) = subscribers.get(&id) {
                let _ = s.tx.send(ResponseEvent::FirstToken { req_id: id, ttft_s: t - s.arrival });
            }
        }
        RequestEvent::Finished { id, t } => {
            if let Some(s) = subscribers.remove(&id) {
                let _ = s.tx.send(ResponseEvent::Finished {
                    req_id: id,
                    e2e_s: t - s.arrival,
                    output_tokens: s.output_tokens,
                });
            }
        }
        RequestEvent::Dropped { id, .. } => {
            if let Some(s) = subscribers.remove(&id) {
                let _ = s.tx.send(ResponseEvent::Dropped { req_id: id });
            }
        }
        RequestEvent::Cancelled { id, .. } => {
            if let Some(s) = subscribers.remove(&id) {
                let _ = s.tx.send(ResponseEvent::Cancelled { req_id: id });
            }
        }
        // internal lifecycle events, not client-visible
        RequestEvent::Ready { .. }
        | RequestEvent::Encoded { .. }
        | RequestEvent::Preempted { .. }
        | RequestEvent::Requeued { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim_engine::SimEngine;
    use crate::engine::StepPlan;
    use crate::request::Modality;

    fn text_req(id: u64, text_tokens: u32, output_tokens: u32) -> Request {
        Request { id, text_tokens, output_tokens, ..Request::default() }
    }

    #[test]
    fn serve_roundtrip_with_sim_engine() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.num_requests = 4;
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let server = Server::spawn_engine(cfg, Box::new(SimEngine::new(&profile)));
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..4u64 {
            rxs.push(h.submit(text_req(id, 64, 4)).unwrap());
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 4);
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    #[test]
    fn cluster_server_roundtrip() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.cluster.replicas = 2;
        cfg.cluster.router = "round-robin".into();
        let server = Server::spawn_sim(cfg);
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..6u64 {
            rxs.push(h.submit(text_req(id, 64, 4)).unwrap());
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 6, "both replicas served their share");
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    /// The pool-aware leader: multimodal submissions flow through the
    /// encoder pool and still come back finished — nothing is stranded in
    /// the pool at shutdown, and sand streams alongside. The generic
    /// leader never branches: the cluster backend hides the pool.
    #[test]
    fn cluster_server_roundtrip_with_encoder_pool() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.cluster.replicas = 2;
        cfg.cluster.router = "round-robin".into();
        cfg.pool.enabled = true;
        cfg.pool.slots = 2;
        let server = Server::spawn_sim(cfg);
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            rxs.push(h.submit(text_req(id, 64, 4)).unwrap());
        }
        for id in 3..6u64 {
            let mut req = text_req(id, 40, 4);
            req.modality = Modality::Image;
            req.mm_tokens = 729;
            rxs.push(h.submit(req).unwrap());
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 6, "pool handoffs all completed");
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    /// An observed server: `metrics_text` scrapes live Prometheus
    /// telemetry mid-run, and the observer never perturbs results.
    #[test]
    fn server_metrics_scrape_with_obs() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.obs.enabled = true;
        let server = Server::spawn_sim(cfg);
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..4u64 {
            rxs.push(h.submit(text_req(id, 64, 4)).unwrap());
        }
        let text = h.metrics_text().unwrap();
        assert!(text.contains("tcm_obs_epochs"), "scrape must expose telemetry: {text}");
        assert!(text.contains("tcm_obs_waiting{modality=\"text\"}"));
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 4);
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
        }
    }

    /// Without obs, a scrape answers with the disabled comment rather
    /// than hanging or panicking.
    #[test]
    fn server_metrics_scrape_without_obs() {
        let cfg = ServeConfig::default();
        let server = Server::spawn_sim(cfg);
        let h = server.handle();
        let text = h.metrics_text().unwrap();
        assert!(text.starts_with("# telemetry disabled"), "got: {text}");
        server.finish();
    }

    /// A sim engine that takes real wall time per iteration, so tests can
    /// observe streaming while work is genuinely in flight.
    struct ThrottledEngine {
        inner: SimEngine,
        delay: Duration,
    }

    impl Engine for ThrottledEngine {
        fn execute(&mut self, plan: &StepPlan) -> f64 {
            std::thread::sleep(self.delay);
            self.inner.execute(plan)
        }

        fn release(&mut self, req_id: u64) {
            self.inner.release(req_id);
        }

        fn name(&self) -> &'static str {
            "throttled-sim"
        }
    }

    fn throttled(cfg: &ServeConfig, delay_ms: u64) -> Box<dyn Engine + Send> {
        let profile = crate::model::by_name(&cfg.model).unwrap();
        Box::new(ThrottledEngine {
            inner: SimEngine::new(&profile),
            delay: Duration::from_millis(delay_ms),
        })
    }

    /// The online-serving acceptance test: a request submitted first gets
    /// its FirstToken event while a later-submitted request is still
    /// unfinished — events stream per iteration, not batch-then-flush at
    /// shutdown (the pre-refactor leader buffered everything until
    /// `Shutdown` and only then ran the scheduler).
    #[test]
    fn first_token_streams_while_later_request_in_flight() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        let engine = throttled(&cfg, 2);
        let server = Server::spawn_engine(cfg, engine);
        let h = server.handle();

        // A: tiny prompt — first token within the first few iterations.
        let rx_a = h.submit(text_req(0, 32, 8)).unwrap();
        // B: giant prompt — ~100 chunked-prefill iterations (≈200 ms at
        // 2 ms per iteration) before ITS first token.
        let rx_b = h.submit(text_req(1, 50_000, 4)).unwrap();

        // No shutdown has been sent: a FirstToken arriving here proves
        // per-iteration streaming (the old leader would block forever
        // until Shutdown, timing this recv out).
        let first = rx_a
            .recv_timeout(Duration::from_secs(30))
            .expect("first token must stream before shutdown");
        assert!(
            matches!(first, ResponseEvent::FirstToken { req_id: 0, .. }),
            "expected FirstToken for request 0, got {first:?}"
        );
        // ... and the later submission must still be in flight.
        assert!(
            matches!(rx_b.try_recv(), Err(mpsc::TryRecvError::Empty)),
            "the giant request must not have produced events when the tiny one's \
             first token streams"
        );

        let report = server.finish();
        assert_eq!(report.total(), 2, "both requests accounted for");
        assert_eq!(report.outcomes.len(), 2);
        // A's full event stream arrived, in order
        let events_a: Vec<_> = rx_a.iter().collect();
        assert!(matches!(events_a.last(), Some(ResponseEvent::Finished { req_id: 0, .. })));
        let events_b: Vec<_> = rx_b.iter().collect();
        assert_eq!(events_b.len(), 2);
        assert!(matches!(events_b[0], ResponseEvent::FirstToken { req_id: 1, .. }));
    }

    /// Requests submitted *after* earlier ones already started executing
    /// must still be served (the old leader only scheduled the batch
    /// accumulated before Shutdown — late submissions during execution
    /// were impossible by construction).
    #[test]
    fn late_submission_joins_running_schedule() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        let engine = throttled(&cfg, 2);
        let server = Server::spawn_engine(cfg, engine);
        let h = server.handle();

        let rx_long = h.submit(text_req(0, 20_000, 4)).unwrap();
        // wait until the long request is demonstrably being worked on
        std::thread::sleep(Duration::from_millis(20));
        let rx_late = h.submit(text_req(1, 16, 2)).unwrap();
        let ev = rx_late
            .recv_timeout(Duration::from_secs(30))
            .expect("late request must be scheduled while the first still runs");
        assert!(matches!(ev, ResponseEvent::FirstToken { req_id: 1, .. }));

        let report = server.finish();
        assert_eq!(report.outcomes.len(), 2);
        let _ = rx_long.iter().count(); // drain
    }

    /// Satellite regression: submitting after the leader exited must
    /// return Err(ServerGone), not panic the client thread.
    #[test]
    fn submit_after_shutdown_returns_err_instead_of_panicking() {
        let cfg = ServeConfig::default();
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let server = Server::spawn_engine(cfg, Box::new(SimEngine::new(&profile)));
        let h = server.handle();
        let _ = server.finish(); // leader exits; rx dropped
        assert_eq!(h.submit(text_req(9, 16, 2)).unwrap_err(), ServerGone);
        assert_eq!(h.cancel(9).unwrap_err(), ServerGone);
    }

    /// Cancel mid-stream: a long request is cancelled while running; the
    /// client receives Cancelled as its terminal event and the final
    /// report conserves (finished + cancelled == submitted).
    #[test]
    fn cancel_mid_stream_terminates_the_request() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        let engine = throttled(&cfg, 2);
        let server = Server::spawn_engine(cfg, engine);
        let h = server.handle();

        // long request: ~40 chunked-prefill iterations before its first
        // token, then thousands of decode steps
        let rx_long = h.submit(text_req(0, 20_000, 5_000)).unwrap();
        let rx_short = h.submit(text_req(1, 16, 2)).unwrap();
        // wait until the short one finished — the long one is mid-flight
        let short_events: Vec<_> = rx_short.iter().take(2).collect();
        assert!(matches!(short_events[1], ResponseEvent::Finished { req_id: 1, .. }));

        h.cancel(0).unwrap();
        let terminal = rx_long
            .iter()
            .find(|ev| {
                matches!(
                    ev,
                    ResponseEvent::Cancelled { .. }
                        | ResponseEvent::Finished { .. }
                        | ResponseEvent::Dropped { .. }
                )
            })
            .expect("cancelled request must get a terminal event");
        assert!(
            matches!(terminal, ResponseEvent::Cancelled { req_id: 0 }),
            "expected Cancelled, got {terminal:?}"
        );

        let report = server.finish();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.cancelled.len(), 1);
        assert_eq!(report.cancelled[0].id, 0);
        assert_eq!(report.total(), 2, "finished + cancelled == submitted");
    }

    /// Bounded admission: with admission_limit = 2 and a slow engine, a
    /// third concurrent submission is rejected immediately — no
    /// unbounded buffering — and the final report counts it.
    #[test]
    fn over_limit_submission_is_rejected_immediately() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.server.admission_limit = 2;
        let engine = throttled(&cfg, 5);
        let server = Server::spawn_engine(cfg, engine);
        let h = server.handle();

        // two big requests occupy the leader's outstanding budget
        let rx_a = h.submit(text_req(0, 30_000, 2_000)).unwrap();
        let rx_b = h.submit(text_req(1, 30_000, 2_000)).unwrap();
        let rx_c = h.submit(text_req(2, 16, 2)).unwrap();
        let ev = rx_c
            .recv_timeout(Duration::from_secs(30))
            .expect("over-limit submission must be answered, not buffered");
        assert_eq!(ev, ResponseEvent::Rejected { req_id: 2 });
        assert!(
            rx_c.iter().next().is_none(),
            "a rejected request's stream terminates after the rejection"
        );

        // free capacity by cancelling both giants, then resubmit: accepted
        h.cancel(0).unwrap();
        h.cancel(1).unwrap();
        assert!(rx_a.iter().any(|e| matches!(e, ResponseEvent::Cancelled { .. })));
        assert!(rx_b.iter().any(|e| matches!(e, ResponseEvent::Cancelled { .. })));
        let rx_d = h.submit(text_req(3, 16, 2)).unwrap();
        let events_d: Vec<_> = rx_d.iter().collect();
        assert!(
            matches!(events_d.last(), Some(ResponseEvent::Finished { req_id: 3, .. })),
            "capacity freed by cancels must admit new work, got {events_d:?}"
        );

        let report = server.finish();
        assert_eq!(report.rejected, 1);
        assert_eq!(report.cancelled.len(), 2);
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.total() as u64 + report.rejected, 4, "serving-layer conservation");
    }

    /// Deadlines attach end-to-end: an explicit tight deadline makes the
    /// outcome's SLO latency exactly the requested budget.
    #[test]
    fn submit_with_deadline_feeds_slo_accounting() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "edf".into();
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let server = Server::spawn_engine(cfg, Box::new(SimEngine::new(&profile)));
        let h = server.handle();
        let opts = SubmitOptions { deadline_s: Some(0.75), slo_class: Some(SloClass::Critical) };
        let rx = h.submit_with(text_req(0, 64, 4), opts).unwrap();
        let report = server.finish();
        let _ = rx.iter().count();
        assert_eq!(report.outcomes.len(), 1);
        assert_eq!(report.outcomes[0].slo_latency, 0.75, "deadline plumbed into the outcome");
    }
}
