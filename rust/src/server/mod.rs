//! Thread-based serving front end.
//!
//! tokio is unavailable in the offline crate set, so the leader loop uses
//! std threads + mpsc channels — the same topology as vLLM's single-
//! threaded engine core behind an ingress queue. Clients submit requests
//! through a [`ServerHandle`] and receive streamed events (first token /
//! completion / drop) on a per-request channel.
//!
//! The leader is *truly online*: it interleaves channel ingress with
//! scheduler iterations via the stepping API
//! ([`Scheduler::inject`] / [`Scheduler::step`]) — a request submitted
//! while others are in flight is scheduled between their iterations, and
//! its `FirstToken` event is delivered at the iteration that produces it,
//! not after the batch drains. Wall-clock time maps onto the scheduler
//! clock continuously ([`Scheduler::advance_to`] with the leader's
//! elapsed time before every step).
//!
//! This front end drives the *real* engine in wall-clock time; pure
//! virtual-time experiments use [`crate::experiments`] directly. A
//! simulated engine still works behind the server (the tests do exactly
//! that), with the caveat that its virtual iteration costs accumulate
//! into the scheduler clock on top of the wall mapping, so event
//! timestamps run ahead of wall time.

use crate::cluster::Cluster;
use crate::config::ServeConfig;
use crate::coordinator::{RequestEvent, Scheduler, StepOutcome};
use crate::engine::Engine;
use crate::metrics::Report;
use crate::policies::build_policy;
use crate::request::Request;
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Events streamed back to a client.
#[derive(Debug, Clone, PartialEq)]
pub enum ResponseEvent {
    FirstToken { req_id: u64, ttft_s: f64 },
    Finished { req_id: u64, e2e_s: f64, output_tokens: u32 },
    /// The scheduler gave up on the request (prompt can never fit, or
    /// terminally blocked at shutdown).
    Dropped { req_id: u64 },
}

enum ServerMsg {
    Submit(Request, mpsc::Sender<ResponseEvent>),
    Shutdown,
}

/// Client-side handle to a running server.
#[derive(Clone)]
pub struct ServerHandle {
    tx: mpsc::Sender<ServerMsg>,
}

impl ServerHandle {
    /// Submit a request; events arrive on the returned receiver.
    pub fn submit(&self, req: Request) -> mpsc::Receiver<ResponseEvent> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(ServerMsg::Submit(req, tx)).expect("server gone");
        rx
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(ServerMsg::Shutdown);
    }
}

/// A serving leader running a scheduler over an engine on its own thread.
pub struct Server {
    handle: ServerHandle,
    join: JoinHandle<Report>,
}

impl Server {
    /// Spawn the leader thread. The engine must be Send (both engines are).
    pub fn spawn(cfg: ServeConfig, engine: Box<dyn Engine + Send>) -> Server {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let join = std::thread::spawn(move || leader_loop(cfg, engine, rx));
        Server { handle: ServerHandle { tx }, join }
    }

    /// Spawn a multi-replica leader: `cfg.cluster.replicas` simulated
    /// engine replicas behind the configured modality-aware router, all
    /// driven by one leader thread through the cluster stepping API. The
    /// replicas are built inside the leader thread (a [`Cluster`] holds
    /// non-Send trait objects), so only the config crosses the boundary.
    /// With `cfg.pool.enabled` the leader serves through the
    /// disaggregated encoder pool: multimodal submissions queue at the
    /// pool and are late-bound to a decode replica at encode completion;
    /// the cluster stepping verbs hide all of it, so the leader loop is
    /// unchanged (the fleet never reports `Drained` while encodes are
    /// queued or in flight, so shutdown still drains every request).
    pub fn spawn_cluster(cfg: ServeConfig) -> Server {
        let (tx, rx) = mpsc::channel::<ServerMsg>();
        let join = std::thread::spawn(move || cluster_leader_loop(cfg, rx));
        Server { handle: ServerHandle { tx }, join }
    }

    pub fn handle(&self) -> ServerHandle {
        self.handle.clone()
    }

    /// Shut down and collect the final report.
    pub fn finish(self) -> Report {
        self.handle.shutdown();
        self.join.join().expect("leader thread panicked")
    }
}

/// Per-request client bookkeeping on the leader side.
struct Subscriber {
    tx: mpsc::Sender<ResponseEvent>,
    arrival: f64,
    output_tokens: u32,
}

/// Receive the next pending channel message. `block` bounds the wait to
/// one 25 ms timeout slice (the leader re-checks scheduler state after).
/// `Err(())` means every handle is gone — treat as shutdown.
fn recv_msg(rx: &mpsc::Receiver<ServerMsg>, block: bool) -> Result<Option<ServerMsg>, ()> {
    if block {
        match rx.recv_timeout(Duration::from_millis(25)) {
            Ok(m) => Ok(Some(m)),
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => Err(()),
        }
    } else {
        match rx.try_recv() {
            Ok(m) => Ok(Some(m)),
            Err(mpsc::TryRecvError::Empty) => Ok(None),
            Err(mpsc::TryRecvError::Disconnected) => Err(()),
        }
    }
}

/// The leader: interleave ingress with scheduler steps. Each loop turn
/// drains every pending channel message (injecting new requests), maps
/// wall-clock onto the scheduler clock, runs one iteration, streams the
/// iteration's events to subscribers, and retires terminal scheduler
/// state ([`Scheduler::take_finished`]) so scheduler-side memory stays
/// flat over an unbounded request stream (the accumulated outcome
/// history returned at shutdown still grows, a few dozen bytes per
/// request). When there is nothing runnable it blocks on the channel
/// instead of spinning.
fn leader_loop(
    cfg: ServeConfig,
    engine: Box<dyn Engine + Send>,
    rx: mpsc::Receiver<ServerMsg>,
) -> Report {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let policy = build_policy(&cfg, &profile);
    let mut sched = Scheduler::new(cfg, policy, engine);

    let t0 = Instant::now();
    let mut subscribers: HashMap<u64, Subscriber> = HashMap::new();
    let mut collected = Report::default();
    let mut shutdown = false;
    // Block on the channel (instead of polling) on the next turn; set
    // whenever the scheduler reports nothing can run until new input.
    let mut block_for_msg = false;

    loop {
        // 1. ingest: drain everything available; block once when idle
        loop {
            let block = block_for_msg && !shutdown;
            block_for_msg = false;
            match recv_msg(&rx, block) {
                Ok(Some(ServerMsg::Submit(mut req, tx))) => {
                    // stamp the true submit time so queueing before the
                    // first iteration is accounted for
                    req.arrival = t0.elapsed().as_secs_f64();
                    subscribers.insert(
                        req.id,
                        Subscriber { tx, arrival: req.arrival, output_tokens: req.output_tokens },
                    );
                    sched.inject(req);
                }
                Ok(Some(ServerMsg::Shutdown)) => shutdown = true,
                Ok(None) => break,
                Err(()) => {
                    shutdown = true;
                    break;
                }
            }
        }

        // 2. wall-clock → scheduler clock (monotone; never rewinds)
        sched.advance_to(t0.elapsed().as_secs_f64());

        // 3. one scheduling iteration
        let outcome = sched.step();

        // 4. stream this iteration's events as they happen, then retire
        //    the iteration's terminal state into the running report
        for ev in sched.take_events() {
            deliver(&mut subscribers, ev);
        }
        collected.merge(sched.take_finished());

        match outcome {
            StepOutcome::Executed { .. } => {}
            // Nothing runnable until an internal event (preprocess
            // completion / pending arrival): jump the scheduler clock to
            // it. For the real engine that time is at/near wall time; for
            // a simulated engine it is virtual and there is no point
            // waiting wall-clock for it.
            StepOutcome::Idle { next_event } => sched.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => sched.advance_to(t),
            StepOutcome::Blocked { next_event: None } => {
                if shutdown {
                    // same terminal guard the batch drain applies: these
                    // can never run; fail them so clients are notified
                    sched.drop_blocked();
                } else {
                    block_for_msg = true;
                }
            }
            StepOutcome::Drained => {
                if shutdown {
                    break;
                }
                block_for_msg = true;
            }
        }
    }

    // deliver anything emitted by a final drop_blocked
    for ev in sched.take_events() {
        deliver(&mut subscribers, ev);
    }
    collected.merge(sched.take_finished());
    collected.sort_by_id();
    collected
}

/// The multi-replica leader: identical ingress/step/stream topology, but
/// requests are dispatched through the cluster's router and every
/// replica advances per turn. The cluster retires terminal replica state
/// internally, so replica-side memory stays flat; only the merged
/// outcome history (returned from [`Server::finish`]) grows with
/// requests served.
fn cluster_leader_loop(cfg: ServeConfig, rx: mpsc::Receiver<ServerMsg>) -> Report {
    let mut cluster = Cluster::new(&cfg);

    let t0 = Instant::now();
    let mut subscribers: HashMap<u64, Subscriber> = HashMap::new();
    let mut shutdown = false;
    let mut block_for_msg = false;

    loop {
        loop {
            let block = block_for_msg && !shutdown;
            block_for_msg = false;
            match recv_msg(&rx, block) {
                Ok(Some(ServerMsg::Submit(mut req, tx))) => {
                    req.arrival = t0.elapsed().as_secs_f64();
                    subscribers.insert(
                        req.id,
                        Subscriber { tx, arrival: req.arrival, output_tokens: req.output_tokens },
                    );
                    cluster.inject(req);
                }
                Ok(Some(ServerMsg::Shutdown)) => shutdown = true,
                Ok(None) => break,
                Err(()) => {
                    shutdown = true;
                    break;
                }
            }
        }

        cluster.advance_to(t0.elapsed().as_secs_f64());
        let outcome = cluster.step();
        for ev in cluster.take_events() {
            deliver(&mut subscribers, ev);
        }

        match outcome {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => cluster.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => cluster.advance_to(t),
            StepOutcome::Blocked { next_event: None } => {
                if shutdown {
                    cluster.drop_blocked();
                } else {
                    block_for_msg = true;
                }
            }
            StepOutcome::Drained => {
                if shutdown {
                    break;
                }
                block_for_msg = true;
            }
        }
    }

    for ev in cluster.take_events() {
        deliver(&mut subscribers, ev);
    }
    cluster.report().report
}

/// Route one scheduler event to its subscriber. Terminal events
/// (`Finished`/`Dropped`) retire the subscriber entry — the map must not
/// grow with total requests served, and dropping the retained `Sender`
/// closes the per-request channel so clients iterating their receiver
/// terminate without waiting for server shutdown.
fn deliver(subscribers: &mut HashMap<u64, Subscriber>, ev: RequestEvent) {
    match ev {
        RequestEvent::FirstToken { id, t } => {
            if let Some(s) = subscribers.get(&id) {
                let _ = s.tx.send(ResponseEvent::FirstToken { req_id: id, ttft_s: t - s.arrival });
            }
        }
        RequestEvent::Finished { id, t } => {
            if let Some(s) = subscribers.remove(&id) {
                let _ = s.tx.send(ResponseEvent::Finished {
                    req_id: id,
                    e2e_s: t - s.arrival,
                    output_tokens: s.output_tokens,
                });
            }
        }
        RequestEvent::Dropped { id, .. } => {
            if let Some(s) = subscribers.remove(&id) {
                let _ = s.tx.send(ResponseEvent::Dropped { req_id: id });
            }
        }
        // internal lifecycle events, not client-visible
        RequestEvent::Ready { .. }
        | RequestEvent::Encoded { .. }
        | RequestEvent::Preempted { .. } => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim_engine::SimEngine;
    use crate::engine::StepPlan;
    use crate::request::Modality;

    fn text_req(id: u64, text_tokens: u32, output_tokens: u32) -> Request {
        Request {
            id,
            arrival: 0.0,
            modality: Modality::Text,
            text_tokens,
            mm_tokens: 0,
            video_duration_s: 0.0,
            output_tokens,
        }
    }

    #[test]
    fn serve_roundtrip_with_sim_engine() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.num_requests = 4;
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let server = Server::spawn(cfg, Box::new(SimEngine::new(&profile)));
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..4u64 {
            rxs.push(h.submit(text_req(id, 64, 4)));
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 4);
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    #[test]
    fn cluster_server_roundtrip() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.cluster.replicas = 2;
        cfg.cluster.router = "round-robin".into();
        let server = Server::spawn_cluster(cfg);
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..6u64 {
            rxs.push(h.submit(text_req(id, 64, 4)));
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 6, "both replicas served their share");
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    /// The pool-aware leader: multimodal submissions flow through the
    /// encoder pool and still come back finished — nothing is stranded in
    /// the pool at shutdown, and sand streams alongside.
    #[test]
    fn cluster_server_roundtrip_with_encoder_pool() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        cfg.cluster.replicas = 2;
        cfg.cluster.router = "round-robin".into();
        cfg.pool.enabled = true;
        cfg.pool.slots = 2;
        let server = Server::spawn_cluster(cfg);
        let h = server.handle();
        let mut rxs = Vec::new();
        for id in 0..3u64 {
            rxs.push(h.submit(text_req(id, 64, 4)));
        }
        for id in 3..6u64 {
            let mut req = text_req(id, 40, 4);
            req.modality = Modality::Image;
            req.mm_tokens = 729;
            rxs.push(h.submit(req));
        }
        let report = server.finish();
        assert_eq!(report.outcomes.len(), 6, "pool handoffs all completed");
        for rx in rxs {
            let events: Vec<_> = rx.iter().collect();
            assert_eq!(events.len(), 2);
            assert!(matches!(events[0], ResponseEvent::FirstToken { .. }));
            assert!(matches!(events[1], ResponseEvent::Finished { .. }));
        }
    }

    /// A sim engine that takes real wall time per iteration, so tests can
    /// observe streaming while work is genuinely in flight.
    struct ThrottledEngine {
        inner: SimEngine,
        delay: Duration,
    }

    impl Engine for ThrottledEngine {
        fn execute(&mut self, plan: &StepPlan) -> f64 {
            std::thread::sleep(self.delay);
            self.inner.execute(plan)
        }

        fn release(&mut self, req_id: u64) {
            self.inner.release(req_id);
        }

        fn name(&self) -> &'static str {
            "throttled-sim"
        }
    }

    /// The online-serving acceptance test: a request submitted first gets
    /// its FirstToken event while a later-submitted request is still
    /// unfinished — events stream per iteration, not batch-then-flush at
    /// shutdown (the pre-refactor leader buffered everything until
    /// `Shutdown` and only then ran the scheduler).
    #[test]
    fn first_token_streams_while_later_request_in_flight() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let engine = ThrottledEngine {
            inner: SimEngine::new(&profile),
            delay: Duration::from_millis(2),
        };
        let server = Server::spawn(cfg, Box::new(engine));
        let h = server.handle();

        // A: tiny prompt — first token within the first few iterations.
        let rx_a = h.submit(text_req(0, 32, 8));
        // B: giant prompt — ~100 chunked-prefill iterations (≈200 ms at
        // 2 ms per iteration) before ITS first token.
        let rx_b = h.submit(text_req(1, 50_000, 4));

        // No shutdown has been sent: a FirstToken arriving here proves
        // per-iteration streaming (the old leader would block forever
        // until Shutdown, timing this recv out).
        let first = rx_a
            .recv_timeout(Duration::from_secs(30))
            .expect("first token must stream before shutdown");
        assert!(
            matches!(first, ResponseEvent::FirstToken { req_id: 0, .. }),
            "expected FirstToken for request 0, got {first:?}"
        );
        // ... and the later submission must still be in flight.
        assert!(
            matches!(rx_b.try_recv(), Err(mpsc::TryRecvError::Empty)),
            "the giant request must not have produced events when the tiny one's \
             first token streams"
        );

        let report = server.finish();
        assert_eq!(report.total(), 2, "both requests accounted for");
        assert_eq!(report.outcomes.len(), 2);
        // A's full event stream arrived, in order
        let events_a: Vec<_> = rx_a.iter().collect();
        assert!(matches!(events_a.last(), Some(ResponseEvent::Finished { req_id: 0, .. })));
        let events_b: Vec<_> = rx_b.iter().collect();
        assert_eq!(events_b.len(), 2);
        assert!(matches!(events_b[0], ResponseEvent::FirstToken { req_id: 1, .. }));
    }

    /// Requests submitted *after* earlier ones already started executing
    /// must still be served (the old leader only scheduled the batch
    /// accumulated before Shutdown — late submissions during execution
    /// were impossible by construction).
    #[test]
    fn late_submission_joins_running_schedule() {
        let mut cfg = ServeConfig::default();
        cfg.policy = "fcfs".into();
        let profile = crate::model::by_name(&cfg.model).unwrap();
        let engine = ThrottledEngine {
            inner: SimEngine::new(&profile),
            delay: Duration::from_millis(2),
        };
        let server = Server::spawn(cfg, Box::new(engine));
        let h = server.handle();

        let rx_long = h.submit(text_req(0, 20_000, 4));
        // wait until the long request is demonstrably being worked on
        std::thread::sleep(Duration::from_millis(20));
        let rx_late = h.submit(text_req(1, 16, 2));
        let ev = rx_late
            .recv_timeout(Duration::from_secs(30))
            .expect("late request must be scheduled while the first still runs");
        assert!(matches!(ev, ResponseEvent::FirstToken { req_id: 1, .. }));

        let report = server.finish();
        assert_eq!(report.outcomes.len(), 2);
        let _ = rx_long.iter().count(); // drain
    }
}
