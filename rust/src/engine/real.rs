//! Real execution engine: drives the TinyMLLM AOT artifacts through PJRT.
//!
//! Proves the three-layer contract end-to-end: the *same coordinator and
//! policies* that run on the simulator produce [`StepPlan`]s that this
//! engine executes against actual compiled HLO (whose attention is the L1
//! Pallas kernel). Iteration durations are measured wall time.
//!
//! Static-shape bucketing (DESIGN.md §5): prompts pad to the enclosing
//! prefill bucket, vision patches to the enclosing encoder bucket, decode
//! batches to the enclosing batch bucket. Synthetic prompt content (token
//! ids / pixel patches) derives deterministically from the request id —
//! the workload model specifies only token *counts*.
//!
//! Chunked prefill note: the TinyMLLM prefill artifact processes a whole
//! prompt (≤ 512 tokens) in one call, so the coordinator must be run with
//! a token budget ≥ the longest tiny-model prompt. Chunked prefill
//! semantics are exercised on the simulator, whose cost model charges
//! per-chunk.

use super::{Engine, StepPlan};
use crate::runtime::{literal_f32, Input, Runtime};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;

/// Per-request device-path state.
struct ReqExec {
    /// Prompt embeddings [prefill_tokens, d_model] (vision prefix + text).
    embeds: Vec<f32>,
    /// Vision embedding rows already computed (encode ran).
    vision_rows: usize,
    /// KV cache [n_layers, 2, n_heads, max_seq, head_dim] after prefill.
    kv: Option<Vec<f32>>,
    /// Tokens cached so far (prompt + decoded).
    length: usize,
    /// Last emitted token (input to the next decode step).
    last_token: i32,
    /// All generated tokens (observability; greedy argmax).
    generated: Vec<i32>,
}

/// PJRT-backed engine over the artifacts in `artifacts/`.
pub struct RealEngine {
    rt: Runtime,
    reqs: BTreeMap<u64, ReqExec>,
    d_model: usize,
    /// Emitted tokens per request, exposed for tests/examples.
    pub outputs: BTreeMap<u64, Vec<i32>>,
}

impl RealEngine {
    pub fn new(rt: Runtime) -> RealEngine {
        let d_model = rt.manifest.hparams.d_model;
        RealEngine { rt, reqs: BTreeMap::new(), d_model, outputs: BTreeMap::new() }
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    fn state(&mut self, id: u64) -> &mut ReqExec {
        self.reqs.entry(id).or_insert_with(|| ReqExec {
            embeds: Vec::new(),
            vision_rows: 0,
            kv: None,
            length: 0,
            last_token: 0,
            generated: Vec::new(),
        })
    }

    /// Deterministic synthetic pixel patches for a request.
    fn synth_patches(id: u64, n: usize, patch_dim: usize) -> Vec<f32> {
        let mut rng = Rng::new(id.wrapping_mul(0x9E3779B97F4A7C15) ^ 0x1A6E);
        (0..n * patch_dim).map(|_| rng.normal() as f32 * 0.5).collect()
    }

    /// Deterministic synthetic text token ids for a request.
    fn synth_text(id: u64, n: usize, vocab: usize) -> Vec<i32> {
        let mut rng = Rng::new(id.wrapping_mul(0xD1B54A32D192ED03) ^ 0x7E47);
        (0..n).map(|_| rng.below(vocab as u64) as i32).collect()
    }

    fn run_encode(&mut self, item: &super::EncodeItem) -> Result<()> {
        let hp = self.rt.manifest.hparams.clone();
        let n = item.mm_tokens as usize;
        let bucket = Runtime::bucket_for(&hp.encoder_buckets, n)
            .ok_or_else(|| anyhow!("mm_tokens {n} exceeds encoder buckets"))?;
        let mut pixels = Self::synth_patches(item.req_id, n, hp.patch_dim);
        pixels.resize(bucket * hp.patch_dim, 0.0);
        let out = self
            .rt
            .execute(&format!("encoder_{bucket}"), &[Input::F32(&pixels, vec![bucket, hp.patch_dim])])
            .context("encoder")?;
        let rows = literal_f32(&out[0])?;
        let st = self.state(item.req_id);
        st.embeds.clear();
        st.embeds.extend_from_slice(&rows[..n * hp.d_model]);
        st.vision_rows = n;
        Ok(())
    }

    fn run_prefill(&mut self, item: &super::PrefillItem) -> Result<()> {
        if !item.last_chunk || item.ctx_before != 0 {
            bail!(
                "RealEngine requires single-chunk prefill (req {}: ctx_before={} last={})",
                item.req_id,
                item.ctx_before,
                item.last_chunk
            );
        }
        let hp = self.rt.manifest.hparams.clone();
        let total = item.chunk_tokens as usize;
        let text_n = item.text_tokens as usize;
        let bucket = Runtime::bucket_for(&hp.prefill_buckets, total)
            .ok_or_else(|| anyhow!("prompt {total} exceeds prefill buckets"))?;

        // Text embeddings via the embed artifact (padded ids).
        let mut ids = Self::synth_text(item.req_id, text_n, hp.vocab);
        ids.resize(bucket, 0);
        let out = self
            .rt
            .execute(&format!("embed_{bucket}"), &[Input::I32(&ids, vec![bucket])])
            .context("embed")?;
        let text_emb = literal_f32(&out[0])?;

        let d = self.d_model;
        let st = self.state(item.req_id);
        let vision_rows = st.vision_rows;
        if vision_rows + text_n != total {
            bail!(
                "req {}: vision {} + text {} != prompt {}",
                item.req_id,
                vision_rows,
                text_n,
                total
            );
        }
        // Prompt buffer = vision prefix ++ text rows, padded to bucket.
        let mut embeds = st.embeds.clone();
        embeds.extend_from_slice(&text_emb[..text_n * d]);
        embeds.resize(bucket * d, 0.0);

        let out = self
            .rt
            .execute(
                &format!("prefill_{bucket}"),
                &[Input::F32(&embeds, vec![bucket, d]), Input::ScalarI32(total as i32)],
            )
            .context("prefill")?;
        let logits = literal_f32(&out[0])?;
        let kv = literal_f32(&out[1])?;
        let tok = argmax(&logits) as i32;

        let st = self.state(item.req_id);
        st.kv = Some(kv);
        st.length = total;
        st.last_token = tok;
        st.generated.push(tok);
        st.embeds = Vec::new(); // prompt embeddings no longer needed
        self.outputs.entry(item.req_id).or_default().push(tok);
        Ok(())
    }

    fn run_decodes(&mut self, items: &[super::DecodeItem]) -> Result<()> {
        if items.is_empty() {
            return Ok(());
        }
        let hp = self.rt.manifest.hparams.clone();
        let kv_elems = hp.kv_elems();
        // Split the decode set into bucket-sized groups.
        for group in items.chunks(*hp.decode_buckets.iter().max().unwrap()) {
            let bucket = Runtime::bucket_for(&hp.decode_buckets, group.len())
                .ok_or_else(|| anyhow!("decode group {} exceeds buckets", group.len()))?;
            let mut ids = vec![0i32; bucket];
            let mut lengths = vec![0i32; bucket];
            let mut kv = vec![0f32; bucket * kv_elems];
            for (slot, it) in group.iter().enumerate() {
                let st = self
                    .reqs
                    .get(&it.req_id)
                    .ok_or_else(|| anyhow!("decode for unknown req {}", it.req_id))?;
                let st_kv = st
                    .kv
                    .as_ref()
                    .ok_or_else(|| anyhow!("decode before prefill (req {})", it.req_id))?;
                ids[slot] = st.last_token;
                lengths[slot] = st.length as i32;
                kv[slot * kv_elems..(slot + 1) * kv_elems].copy_from_slice(st_kv);
            }
            let kv_dims = vec![bucket, hp.n_layers, 2, hp.n_heads, hp.max_seq, hp.head_dim];
            let out = self
                .rt
                .execute(
                    &format!("decode_{bucket}"),
                    &[
                        Input::I32(&ids, vec![bucket]),
                        Input::F32(&kv, kv_dims),
                        Input::I32(&lengths, vec![bucket]),
                    ],
                )
                .context("decode")?;
            let logits = literal_f32(&out[0])?;
            let new_kv = literal_f32(&out[1])?;
            for (slot, it) in group.iter().enumerate() {
                let tok = argmax(&logits[slot * hp.vocab..(slot + 1) * hp.vocab]) as i32;
                let st = self.reqs.get_mut(&it.req_id).unwrap();
                st.kv
                    .as_mut()
                    .unwrap()
                    .copy_from_slice(&new_kv[slot * kv_elems..(slot + 1) * kv_elems]);
                st.length += 1;
                st.last_token = tok;
                st.generated.push(tok);
                self.outputs.entry(it.req_id).or_default().push(tok);
            }
        }
        Ok(())
    }

    /// Fallible step execution (Engine::execute unwraps; examples may call
    /// this directly for error reporting).
    pub fn try_execute(&mut self, plan: &StepPlan) -> Result<f64> {
        // simlint: allow(wall-clock) — real-hardware engine: iteration duration IS wall time
        let t0 = std::time::Instant::now();
        for e in &plan.encodes {
            self.run_encode(e)?;
        }
        for p in &plan.prefills {
            self.run_prefill(p)?;
        }
        self.run_decodes(&plan.decodes)?;
        Ok(t0.elapsed().as_secs_f64())
    }

    /// Generated tokens of a request so far.
    pub fn generated(&self, id: u64) -> Option<&[i32]> {
        self.reqs.get(&id).map(|r| r.generated.as_slice())
    }
}

impl Engine for RealEngine {
    fn execute(&mut self, plan: &StepPlan) -> f64 {
        self.try_execute(plan).expect("RealEngine step failed")
    }

    fn release(&mut self, req_id: u64) {
        self.reqs.remove(&req_id);
    }

    fn name(&self) -> &'static str {
        "real-pjrt"
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_picks_largest() {
        assert_eq!(argmax(&[0.1, 3.0, 2.0]), 1);
        assert_eq!(argmax(&[5.0]), 0);
        assert_eq!(argmax(&[1.0, 1.0]), 0); // first wins ties
    }

    #[test]
    fn synth_inputs_deterministic() {
        let a = RealEngine::synth_text(7, 16, 512);
        let b = RealEngine::synth_text(7, 16, 512);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| (0..512).contains(&t)));
        let c = RealEngine::synth_text(8, 16, 512);
        assert_ne!(a, c);

        let p = RealEngine::synth_patches(7, 4, 48);
        assert_eq!(p.len(), 4 * 48);
        assert_eq!(p, RealEngine::synth_patches(7, 4, 48));
    }
}
