//! Execution engines: the device abstraction under the coordinator.
//!
//! The coordinator emits one [`StepPlan`] per scheduling iteration
//! (vLLM-V1-style continuous batching: encode + prefill chunks + decode
//! batch) and the engine reports how long the iteration took:
//!
//! * [`sim_engine::SimEngine`] — charges the calibrated cost model of a
//!   [`crate::model::ModelProfile`] in virtual time; this is what all
//!   paper-scale experiments run on.
//! * [`real::RealEngine`] — executes the TinyMLLM's AOT artifacts through
//!   PJRT (see `crate::runtime`) and reports wall time; this proves the
//!   identical coordinator drives real model execution.

pub mod kv_cache;
#[cfg(pjrt_runtime)]
pub mod real;
pub mod sim_engine;

use crate::request::Modality;

/// Vision-encoder work for a request being admitted this iteration.
#[derive(Debug, Clone)]
pub struct EncodeItem {
    pub req_id: u64,
    pub modality: Modality,
    pub mm_tokens: u32,
    pub video_duration_s: f64,
}

/// One chunk of prefill work (chunked prefill: `ctx_before` tokens are
/// already cached, this iteration processes `chunk_tokens` more).
#[derive(Debug, Clone)]
pub struct PrefillItem {
    pub req_id: u64,
    pub ctx_before: u32,
    pub chunk_tokens: u32,
    /// True when this chunk completes the prompt — the iteration emits
    /// the request's first token.
    pub last_chunk: bool,
    /// Text tokens of the prompt (the suffix after any vision tokens);
    /// the real engine needs the split to build embeddings.
    pub text_tokens: u32,
    /// Vision tokens the *local* encoder still owes for this prompt (0
    /// for text). The simulator amortizes the encoder's throughput cost
    /// across prefill chunks in proportion to
    /// `chunk_tokens / prefill_total` — modeling vLLM V1's per-iteration
    /// encoder budget, which tiles multimodal encoding alongside chunked
    /// prefill instead of blocking a whole iteration. Requests encoded
    /// elsewhere (the cluster's encoder pool) carry 0 here even though
    /// their prompt contains vision rows: the embeddings already exist,
    /// so prefill charges LLM work only. The scheduler restores the real
    /// count after a preemption-by-recompute (the re-encode is local).
    pub mm_tokens: u32,
    /// Total prompt tokens (the amortization denominator).
    pub prefill_total: u32,
}

/// One running sequence decoding a single token this iteration.
#[derive(Debug, Clone)]
pub struct DecodeItem {
    pub req_id: u64,
    /// Tokens in the KV cache before this step.
    pub ctx_tokens: u32,
}

/// Work selected for one scheduling iteration.
#[derive(Debug, Clone, Default)]
pub struct StepPlan {
    pub encodes: Vec<EncodeItem>,
    pub prefills: Vec<PrefillItem>,
    pub decodes: Vec<DecodeItem>,
}

impl StepPlan {
    /// Empty the item lists, keeping their allocations — the scheduler
    /// recycles one plan across iterations.
    pub fn clear(&mut self) {
        self.encodes.clear();
        self.prefills.clear();
        self.decodes.clear();
    }

    pub fn is_empty(&self) -> bool {
        self.encodes.is_empty() && self.prefills.is_empty() && self.decodes.is_empty()
    }

    /// Total new tokens processed (budget accounting).
    pub fn token_count(&self) -> u64 {
        self.prefills.iter().map(|p| p.chunk_tokens as u64).sum::<u64>()
            + self.decodes.len() as u64
    }
}

/// A device executing iteration plans.
pub trait Engine {
    /// Execute the plan; return the iteration duration in seconds
    /// (virtual for simulation, wall-clock for real execution).
    fn execute(&mut self, plan: &StepPlan) -> f64;

    /// Called when a request finishes or is preempted-by-recompute so the
    /// engine can drop per-request state (KV literals etc.).
    fn release(&mut self, req_id: u64);

    fn name(&self) -> &'static str;
}
