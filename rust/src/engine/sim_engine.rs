//! Cost-model execution engine (virtual time).
//!
//! Charges the calibrated [`ModelProfile`] for each iteration component,
//! mirroring how a single-GPU vLLM engine serializes work per iteration:
//! vision encodes, then prefill chunks, then one fused decode step for the
//! whole decode batch. Optional multiplicative noise models run-to-run
//! variance (used by the Workload Profiler to make estimator fitting
//! non-trivial, Fig 7).

use super::{Engine, StepPlan};
use crate::model::ModelProfile;
use crate::util::rng::Rng;

#[derive(Debug)]
pub struct SimEngine {
    profile: ModelProfile,
    /// Multiplicative lognormal noise sigma on each component (0 = exact).
    noise_sigma: f64,
    rng: Rng,
    /// Cumulative busy time (utilization reporting).
    pub busy_time: f64,
    pub iterations: u64,
}

impl SimEngine {
    pub fn new(profile: &ModelProfile) -> SimEngine {
        SimEngine {
            profile: profile.clone(),
            noise_sigma: 0.0,
            rng: Rng::new(0),
            busy_time: 0.0,
            iterations: 0,
        }
    }

    /// Enable measurement-like noise (profiling runs).
    pub fn with_noise(profile: &ModelProfile, sigma: f64, seed: u64) -> SimEngine {
        SimEngine {
            profile: profile.clone(),
            noise_sigma: sigma,
            rng: Rng::new(seed),
            busy_time: 0.0,
            iterations: 0,
        }
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    fn jitter(&mut self, t: f64) -> f64 {
        if self.noise_sigma == 0.0 {
            t
        } else {
            t * self.rng.lognormal(0.0, self.noise_sigma)
        }
    }

    /// Component costs for one plan (exposed for the profiler's TTFT
    /// breakdown, Fig 6).
    ///
    /// Encoder accounting: the per-request launch overhead is charged on
    /// the EncodeItem (admission iteration); the throughput cost
    /// (mm_tokens / encode rate) is amortized over the request's prefill
    /// chunks, modeling vLLM V1's per-iteration encoder budget.
    ///
    /// Decode fusion (Sarathi / chunked prefill): decode tokens are
    /// piggybacked onto the prefill chunk's batched forward pass, so a
    /// mixed iteration charges only the per-sequence memory-bandwidth
    /// term for decodes; the decode launch cost applies to pure-decode
    /// iterations. Prefill launch overhead is charged once per iteration
    /// (one fused kernel), with per-chunk linear + quadratic terms.
    pub fn plan_cost(&mut self, plan: &StepPlan) -> (f64, f64, f64) {
        let p = self.profile.clone();
        let mut encode: f64 = plan.encodes.len() as f64 * p.encode_base_s;
        for c in &plan.prefills {
            if c.mm_tokens > 0 && c.prefill_total > 0 {
                let share = c.chunk_tokens as f64 / c.prefill_total as f64;
                encode += share * c.mm_tokens as f64 / p.encode_tok_per_s;
            }
        }
        let mut prefill: f64 = plan
            .prefills
            .iter()
            .map(|c| p.prefill_chunk_time(c.ctx_before, c.chunk_tokens) - p.prefill_base_s)
            .sum();
        if !plan.prefills.is_empty() {
            prefill += p.prefill_base_s; // one fused launch per iteration
        }
        let n = plan.decodes.len();
        let decode = if n == 0 {
            0.0
        } else if plan.prefills.is_empty() {
            p.decode_step_time(n)
        } else {
            p.decode_per_seq_s * n as f64 // piggybacked on the prefill pass
        };
        (
            self.jitter(encode),
            self.jitter(prefill),
            self.jitter(decode),
        )
    }
}

impl SimEngine {
    /// Combine component costs into the iteration duration. Serialized
    /// mode (the default) charges the sum, mirroring a single-stream
    /// engine. With [`crate::model::ModelProfile::encode_overlap`] set,
    /// the encoder runs on its own stream concurrent with the LLM pass:
    /// the iteration costs `max(encode, prefill+decode) + penalty`,
    /// clamped to never exceed the serialized sum (a real engine
    /// serializes when overlap is unprofitable).
    pub fn iteration_time(&self, encode: f64, prefill: f64, decode: f64) -> f64 {
        let gpu = prefill + decode;
        let serial = encode + gpu;
        if self.profile.encode_overlap && encode > 0.0 && gpu > 0.0 {
            serial.min(encode.max(gpu) + self.profile.encode_overlap_penalty_s)
        } else {
            serial
        }
    }
}

impl Engine for SimEngine {
    fn execute(&mut self, plan: &StepPlan) -> f64 {
        let (e, pf, d) = self.plan_cost(plan);
        let dt = self.iteration_time(e, pf, d);
        self.busy_time += dt;
        self.iterations += 1;
        dt
    }

    fn release(&mut self, _req_id: u64) {}

    fn name(&self) -> &'static str {
        "sim"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{DecodeItem, EncodeItem, PrefillItem};
    use crate::model::by_name;
    use crate::request::Modality;

    fn plan() -> StepPlan {
        StepPlan {
            encodes: vec![EncodeItem {
                req_id: 1,
                modality: Modality::Image,
                mm_tokens: 729,
                video_duration_s: 0.0,
            }],
            prefills: vec![PrefillItem {
                req_id: 1,
                ctx_before: 0,
                chunk_tokens: 769,
                last_chunk: true,
                text_tokens: 40,
                mm_tokens: 729,
                prefill_total: 769,
            }],
            decodes: vec![
                DecodeItem { req_id: 2, ctx_tokens: 100 },
                DecodeItem { req_id: 3, ctx_tokens: 200 },
            ],
        }
    }

    #[test]
    fn charges_all_components() {
        let p = by_name("llava-7b").unwrap();
        let mut e = SimEngine::new(&p);
        let dt = e.execute(&plan());
        let expected = {
            let r = crate::request::Request {
                id: 1,
                arrival: 0.0,
                modality: Modality::Image,
                text_tokens: 0,
                mm_tokens: 729,
                video_duration_s: 0.0,
                output_tokens: 0,
                ..Request::default()
            };
            // fused iteration: encode + prefill chunk + piggybacked decodes
            p.encode_time(&r) + p.prefill_chunk_time(0, 769) + 2.0 * p.decode_per_seq_s
        };
        assert!((dt - expected).abs() < 1e-12);
        assert_eq!(e.iterations, 1);
        assert!((e.busy_time - dt).abs() < 1e-12);
    }

    #[test]
    fn empty_plan_costs_nothing() {
        let p = by_name("llava-7b").unwrap();
        let mut e = SimEngine::new(&p);
        assert_eq!(e.execute(&StepPlan::default()), 0.0);
    }

    #[test]
    fn noise_is_multiplicative_and_seeded() {
        let p = by_name("llava-7b").unwrap();
        let base = SimEngine::new(&p).execute(&plan());
        let mut a = SimEngine::with_noise(&p, 0.1, 7);
        let mut b = SimEngine::with_noise(&p, 0.1, 7);
        let da = a.execute(&plan());
        assert_eq!(da, b.execute(&plan()));
        assert!(da != base);
        assert!((da / base - 1.0).abs() < 0.5);
    }

    #[test]
    fn plan_token_count() {
        assert_eq!(plan().token_count(), 769 + 2);
    }

    #[test]
    fn overlap_charges_max_plus_penalty() {
        let serial_p = by_name("llava-7b").unwrap();
        let overlap_p = serial_p.clone().with_encode_overlap(0.0005);
        let mut serial = SimEngine::new(&serial_p);
        let mut overlap = SimEngine::new(&overlap_p);
        let (e, pf, d) = serial.plan_cost(&plan());
        let dt_serial = serial.execute(&plan());
        let dt_overlap = overlap.execute(&plan());
        assert!((dt_serial - (e + pf + d)).abs() < 1e-12);
        let expect = (e + pf + d).min(e.max(pf + d) + 0.0005);
        assert!((dt_overlap - expect).abs() < 1e-12);
        assert!(dt_overlap < dt_serial, "{dt_overlap} !< {dt_serial}");
    }

    #[test]
    fn overlap_never_exceeds_serialized() {
        // when the penalty dwarfs the smaller component, fall back to
        // the serialized sum rather than charging overlap at a loss
        let p = by_name("llava-7b").unwrap().with_encode_overlap(10.0);
        let mut overlap = SimEngine::new(&p);
        let mut serial = SimEngine::new(&by_name("llava-7b").unwrap());
        assert_eq!(overlap.execute(&plan()), serial.execute(&plan()));
    }

    /// Pool-mode contract: a prefill item whose encode ran elsewhere
    /// (`mm_tokens: 0`, no EncodeItem) charges exactly the text-equivalent
    /// LLM cost — the encoder component is split out of `execute` and
    /// billed at the pool instead.
    #[test]
    fn preencoded_prefill_charges_no_encoder_work() {
        let p = by_name("llava-7b").unwrap();
        let item = |mm: u32| StepPlan {
            encodes: vec![],
            prefills: vec![PrefillItem {
                req_id: 1,
                ctx_before: 0,
                chunk_tokens: 769,
                last_chunk: true,
                text_tokens: 40,
                mm_tokens: mm,
                prefill_total: 769,
            }],
            decodes: vec![],
        };
        let mut e = SimEngine::new(&p);
        let (enc, pf, _) = e.plan_cost(&item(0));
        assert_eq!(enc, 0.0, "no encoder charge for a pool-encoded prompt");
        assert!((pf - p.prefill_chunk_time(0, 769)).abs() < 1e-12);
        // the same prompt with a live local encode owes the amortized
        // encoder throughput on top
        let (enc_local, pf_local, _) = e.plan_cost(&item(729));
        assert!((enc_local - 729.0 / p.encode_tok_per_s).abs() < 1e-12);
        assert_eq!(pf, pf_local, "LLM-side prefill cost is identical");
    }

    #[test]
    fn overlap_is_noop_for_pure_text_or_pure_encode_iterations() {
        let p = by_name("llava-7b").unwrap().with_encode_overlap(0.0005);
        let mut e = SimEngine::new(&p);
        let text_only = StepPlan {
            encodes: vec![],
            prefills: vec![PrefillItem {
                req_id: 1,
                ctx_before: 0,
                chunk_tokens: 100,
                last_chunk: true,
                text_tokens: 100,
                mm_tokens: 0,
                prefill_total: 100,
            }],
            decodes: vec![],
        };
        let mut serial = SimEngine::new(&by_name("llava-7b").unwrap());
        assert_eq!(e.execute(&text_only), serial.execute(&text_only));
    }
}
