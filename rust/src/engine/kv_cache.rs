//! Paged KV-cache manager (vLLM-style block allocator).
//!
//! The GPU KV cache is divided into fixed-size blocks of
//! `block_tokens` tokens; each request owns ceil(tokens / block_tokens)
//! blocks. The scheduler reserves capacity *before* scheduling prefill
//! chunks or decode steps and preempts (frees) requests when reservation
//! fails — exactly the resource the paper's trucks monopolize under
//! memory pressure (§2.4).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Alloc {
    blocks: u64,
    tokens: u32,
}

/// Block-granular KV-cache accounting for one device.
#[derive(Debug)]
pub struct KvCache {
    block_tokens: u32,
    total_blocks: u64,
    free_blocks: u64,
    allocs: BTreeMap<u64, Alloc>,
    /// High-water mark of used blocks (for reporting).
    peak_used_blocks: u64,
}

impl KvCache {
    /// `capacity_tokens` rounds *down* to whole blocks (a partial block is
    /// unusable).
    pub fn new(capacity_tokens: u64, block_tokens: u32) -> KvCache {
        assert!(block_tokens > 0);
        KvCache {
            block_tokens,
            total_blocks: capacity_tokens / block_tokens as u64,
            free_blocks: capacity_tokens / block_tokens as u64,
            allocs: BTreeMap::new(),
            peak_used_blocks: 0,
        }
    }

    fn blocks_for(&self, tokens: u32) -> u64 {
        (tokens as u64).div_ceil(self.block_tokens as u64)
    }

    /// Grow (or create) request `id`'s allocation to cover `tokens` total
    /// tokens. Returns false (and changes nothing) if the cache lacks
    /// free blocks. Shrinking is not supported (KV never shrinks while a
    /// request lives).
    pub fn try_reserve(&mut self, id: u64, tokens: u32) -> bool {
        let cur = self.allocs.get(&id).copied().unwrap_or(Alloc { blocks: 0, tokens: 0 });
        let need = self.blocks_for(tokens.max(cur.tokens));
        let extra = need.saturating_sub(cur.blocks);
        if extra > self.free_blocks {
            return false;
        }
        self.free_blocks -= extra;
        self.allocs.insert(id, Alloc { blocks: need, tokens: tokens.max(cur.tokens) });
        let used = self.total_blocks - self.free_blocks;
        self.peak_used_blocks = self.peak_used_blocks.max(used);
        true
    }

    /// Whether `tokens` total for request `id` would fit right now.
    pub fn can_reserve(&self, id: u64, tokens: u32) -> bool {
        let cur = self.allocs.get(&id).copied().unwrap_or(Alloc { blocks: 0, tokens: 0 });
        let need = self.blocks_for(tokens.max(cur.tokens));
        need.saturating_sub(cur.blocks) <= self.free_blocks
    }

    /// Release all blocks of request `id` (completion or preemption-by-
    /// recompute). No-op if unknown.
    pub fn free(&mut self, id: u64) {
        if let Some(a) = self.allocs.remove(&id) {
            self.free_blocks += a.blocks;
        }
    }

    pub fn tokens_of(&self, id: u64) -> u32 {
        self.allocs.get(&id).map(|a| a.tokens).unwrap_or(0)
    }

    pub fn used_blocks(&self) -> u64 {
        self.total_blocks - self.free_blocks
    }

    pub fn free_tokens(&self) -> u64 {
        self.free_blocks * self.block_tokens as u64
    }

    pub fn total_blocks(&self) -> u64 {
        self.total_blocks
    }

    /// Usable capacity in tokens (whole blocks).
    pub fn capacity_tokens(&self) -> u64 {
        self.total_blocks * self.block_tokens as u64
    }

    pub fn utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.used_blocks() as f64 / self.total_blocks as f64
    }

    pub fn peak_utilization(&self) -> f64 {
        if self.total_blocks == 0 {
            return 1.0;
        }
        self.peak_used_blocks as f64 / self.total_blocks as f64
    }

    /// Internal consistency: free + Σ per-request blocks == total.
    pub fn check_invariants(&self) -> Result<(), String> {
        let owned: u64 = self.allocs.values().map(|a| a.blocks).sum();
        if owned + self.free_blocks != self.total_blocks {
            return Err(format!(
                "block leak: owned={owned} free={} total={}",
                self.free_blocks, self.total_blocks
            ));
        }
        for (id, a) in &self.allocs {
            if self.blocks_for(a.tokens) != a.blocks {
                return Err(format!(
                    "req {id}: tokens={} needs {} blocks but owns {}",
                    a.tokens,
                    self.blocks_for(a.tokens),
                    a.blocks
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest_lite as pt;

    #[test]
    fn reserve_and_free_roundtrip() {
        let mut kv = KvCache::new(1600, 16); // 100 blocks
        assert!(kv.try_reserve(1, 100)); // 7 blocks
        assert_eq!(kv.used_blocks(), 7);
        assert!(kv.try_reserve(1, 200)); // grow to 13 blocks
        assert_eq!(kv.used_blocks(), 13);
        kv.free(1);
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn reserve_fails_without_side_effects() {
        let mut kv = KvCache::new(160, 16); // 10 blocks
        assert!(kv.try_reserve(1, 100)); // 7 blocks
        assert!(!kv.try_reserve(2, 100)); // needs 7, only 3 free
        assert_eq!(kv.used_blocks(), 7);
        assert!(kv.try_reserve(2, 48)); // 3 blocks fits exactly
        assert_eq!(kv.used_blocks(), 10);
        kv.check_invariants().unwrap();
    }

    #[test]
    fn growth_within_block_is_free() {
        let mut kv = KvCache::new(160, 16);
        assert!(kv.try_reserve(1, 1));
        assert_eq!(kv.used_blocks(), 1);
        assert!(kv.try_reserve(1, 16)); // same block
        assert_eq!(kv.used_blocks(), 1);
        assert!(kv.try_reserve(1, 17)); // second block
        assert_eq!(kv.used_blocks(), 2);
    }

    #[test]
    fn shrink_requests_keep_allocation() {
        let mut kv = KvCache::new(160, 16);
        assert!(kv.try_reserve(1, 64));
        assert!(kv.try_reserve(1, 32)); // no shrink
        assert_eq!(kv.tokens_of(1), 64);
    }

    #[test]
    fn partial_trailing_capacity_is_unusable() {
        let kv = KvCache::new(100, 16); // 6 blocks, 4 tokens wasted
        assert_eq!(kv.total_blocks(), 6);
        assert_eq!(kv.free_tokens(), 96);
    }

    #[test]
    fn peak_tracks_high_water() {
        let mut kv = KvCache::new(160, 16);
        kv.try_reserve(1, 96);
        kv.free(1);
        kv.try_reserve(2, 16);
        assert_eq!(kv.used_blocks(), 1);
        assert!((kv.peak_utilization() - 0.6).abs() < 1e-12);
    }

    #[test]
    fn property_no_leaks_under_random_ops() {
        pt::run(150, |g| {
            let mut kv = KvCache::new(g.u64_in(64, 4096), 16);
            let mut live: Vec<u64> = Vec::new();
            for step in 0..g.usize_in(1, 120) {
                if g.bool() || live.is_empty() {
                    let id = step as u64;
                    if kv.try_reserve(id, g.u64_in(1, 800) as u32) {
                        live.push(id);
                    }
                } else {
                    let idx = g.usize_in(0, live.len() - 1);
                    let id = live.swap_remove(idx);
                    if g.bool() {
                        // grow before free sometimes
                        let t = kv.tokens_of(id);
                        let _ = kv.try_reserve(id, t + g.u64_in(1, 64) as u32);
                    }
                    kv.free(id);
                }
                kv.check_invariants().map_err(|e| format!("step {step}: {e}"))?;
            }
            for id in live {
                kv.free(id);
            }
            if kv.used_blocks() != 0 {
                return Err("blocks leaked after freeing everything".into());
            }
            kv.check_invariants()
        });
    }
}
