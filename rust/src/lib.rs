//! # TCM-Serve
//!
//! A modality-aware scheduling framework for multimodal LLM inference —
//! a full-system reproduction of *"Rocks, Pebbles and Sand: Modality-aware
//! Scheduling for Multimodal Large Language Model Inference"* (TCM-Serve).
//!
//! The paper's insight: multimodal requests differ by orders of magnitude
//! in prefill time and KV-cache footprint — videos behave like *trucks*,
//! images like *cars*, text like *motorcycles*. TCM-Serve classifies
//! requests by estimated resource impact, queues them per class, and
//! schedules with dynamic priorities (static class order + aging) so
//! motorcycles flow through without starving trucks.
//!
//! Architecture (three layers, Python never on the request path):
//! * **L3 (this crate)** — coordinator: classifier, queues, priority
//!   regulator, chunked-prefill continuous batching, paged KV cache,
//!   plus every baseline the paper evaluates against.
//! * **L2 (python/compile/model.py)** — a tiny-but-real MLLM in JAX,
//!   AOT-lowered to HLO text artifacts at build time.
//! * **L1 (python/compile/kernels/)** — Pallas flash-attention kernel
//!   (the prefill hot spot), interpret-mode lowered into the same HLO.
//!
//! Entry points: [`coordinator::Coordinator`] drives an [`engine::Engine`]
//! (simulated cost-model engine or the PJRT-backed real engine) over a
//! [`workload::WorkloadGen`] stream under a [`config::ServeConfig`].

pub mod backend;
pub mod bench_harness;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod policies;
pub mod report;
pub mod request;
/// PJRT runtime: needs the external `xla` + `anyhow` crates, which are
/// not in the offline crate set — compile-gated behind
/// `RUSTFLAGS="--cfg pjrt_runtime"` (see README.md).
#[cfg(pjrt_runtime)]
pub mod runtime;
pub mod server;
pub mod sim;
pub mod util;
pub mod workload;

pub mod experiments;
