//! Serving metrics: exactly the quantities the paper's figures report.
//!
//! * normalized latency — end-to-end seconds per output token (Fig 3/4/8…)
//! * TTFT — time to first token, average and P90 (Fig 2b, 10, 12)
//! * SLO violations — rate, and severity = mean delay beyond the SLO
//!   among violators (Fig 3/4/13/14/15)
//! * preemptions — count and aggregate preempted time (Fig 11)
//! * goodput — max sustainable rate meeting the SLO (Fig 15)

use crate::request::{Class, Modality, SloClass};

/// Everything recorded about one completed request.
#[derive(Debug, Clone)]
pub struct Outcome {
    pub id: u64,
    pub modality: Modality,
    /// Class assigned by the active classifier (None for baselines that
    /// do not classify; grouped reports then fall back to modality).
    pub class: Option<Class>,
    pub arrival: f64,
    /// Absolute time the first output token was emitted.
    pub first_token: f64,
    /// Absolute completion time.
    pub finish: f64,
    pub output_tokens: u32,
    /// Absolute SLO deadline for end-to-end latency (seconds of latency,
    /// not an absolute timestamp): slo_scale × isolated E2E.
    pub slo_latency: f64,
    pub preemptions: u32,
    /// Aggregate time spent preempted (evicted and waiting to re-run).
    pub preempted_time: f64,
    /// Client-declared latency class (`None` behaves as Standard) —
    /// telemetry groups rolling TTFT attainment by this.
    pub slo_class: Option<SloClass>,
}

impl Outcome {
    #[inline]
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }

    #[inline]
    pub fn e2e(&self) -> f64 {
        self.finish - self.arrival
    }

    /// Seconds per output token (the paper's "normalized latency").
    #[inline]
    pub fn normalized_latency(&self) -> f64 {
        self.e2e() / self.output_tokens.max(1) as f64
    }

    #[inline]
    pub fn violates_slo(&self) -> bool {
        self.e2e() > self.slo_latency
    }

    /// Delay beyond the SLO (0 when met).
    #[inline]
    pub fn severity(&self) -> f64 {
        (self.e2e() - self.slo_latency).max(0.0)
    }
}

/// Aggregated statistics over a set of outcomes.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    pub n: usize,
    pub avg_norm_latency: f64,
    pub avg_ttft: f64,
    pub p50_ttft: f64,
    pub p90_ttft: f64,
    pub p99_ttft: f64,
    pub slo_violation_rate: f64,
    /// Mean delay beyond SLO among violators (the paper's "severity").
    pub violation_severity: f64,
    pub preemptions: u64,
    pub preempted_time: f64,
    pub avg_e2e: f64,
    pub throughput_tok_per_s: f64,
}

impl Summary {
    pub fn of(outcomes: &[&Outcome]) -> Summary {
        if outcomes.is_empty() {
            return Summary::default();
        }
        let n = outcomes.len();
        let mut ttfts: Vec<f64> = outcomes.iter().map(|o| o.ttft()).collect();
        ttfts.sort_by(|a, b| a.total_cmp(b));
        let violators: Vec<&&Outcome> = outcomes.iter().filter(|o| o.violates_slo()).collect();
        let severity = if violators.is_empty() {
            0.0
        } else {
            violators.iter().map(|o| o.severity()).sum::<f64>() / violators.len() as f64
        };
        let t_start = outcomes.iter().map(|o| o.arrival).fold(f64::INFINITY, f64::min);
        let t_end = outcomes.iter().map(|o| o.finish).fold(0.0f64, f64::max);
        let total_tokens: u64 = outcomes.iter().map(|o| o.output_tokens as u64).sum();
        Summary {
            n,
            avg_norm_latency: outcomes.iter().map(|o| o.normalized_latency()).sum::<f64>()
                / n as f64,
            avg_ttft: ttfts.iter().sum::<f64>() / n as f64,
            p50_ttft: crate::util::stats::percentile_sorted(&ttfts, 50.0),
            p90_ttft: crate::util::stats::percentile_sorted(&ttfts, 90.0),
            p99_ttft: crate::util::stats::percentile_sorted(&ttfts, 99.0),
            slo_violation_rate: violators.len() as f64 / n as f64,
            violation_severity: severity,
            preemptions: outcomes.iter().map(|o| o.preemptions as u64).sum(),
            preempted_time: outcomes.iter().map(|o| o.preempted_time).sum(),
            avg_e2e: outcomes.iter().map(|o| o.e2e()).sum::<f64>() / n as f64,
            throughput_tok_per_s: if t_end > t_start {
                total_tokens as f64 / (t_end - t_start)
            } else {
                0.0
            },
        }
    }
}

/// A request the scheduler gave up on: the prompt can never fit under the
/// memory budget, or the request was terminally blocked at drain. Kept
/// distinct from [`Outcome`] because there is no first token or finish to
/// measure — but reports must still account for it (a silently vanished
/// request overcounts SLO attainment and goodput).
#[derive(Debug, Clone)]
pub struct FailedOutcome {
    pub id: u64,
    pub modality: Modality,
    pub class: Option<Class>,
    pub arrival: f64,
    /// Scheduler time at which the request was dropped.
    pub dropped_at: f64,
}

/// A request cancelled by the client before completing (any state:
/// pending, preprocessing, queued at an encoder pool, waiting, running).
/// Distinct from [`FailedOutcome`]: a drop is the *scheduler* giving up,
/// a cancellation is the *client* abandoning — it must not count against
/// SLO attainment, but conservation still has to see it
/// (`finished + failed + cancelled == submitted`).
#[derive(Debug, Clone)]
pub struct CancelledOutcome {
    pub id: u64,
    pub modality: Modality,
    /// Class at cancellation time (None when cancelled before
    /// classification — pending, preprocessing, or pool-queued).
    pub class: Option<Class>,
    pub arrival: f64,
    /// Scheduler/cluster time at which the cancel took effect.
    pub cancelled_at: f64,
}

/// A full experiment result: all outcomes plus grouped views.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub outcomes: Vec<Outcome>,
    /// Requests dropped without completing. SLO accounting counts these
    /// as violations; conservation holds as
    /// `outcomes.len() + failed.len() + cancelled.len() == submitted`.
    pub failed: Vec<FailedOutcome>,
    /// Requests cancelled by the client (see [`CancelledOutcome`]).
    pub cancelled: Vec<CancelledOutcome>,
    /// Submissions refused at admission by a bounded serving front end
    /// (`server.admission_limit`). Rejected requests never reach a
    /// scheduler, so they are a counter, not outcomes: serving-layer
    /// conservation is `total() + rejected == submissions offered`.
    pub rejected: u64,
}

impl Report {
    pub fn new(outcomes: Vec<Outcome>) -> Report {
        Report { outcomes, ..Report::default() }
    }

    pub fn with_failed(outcomes: Vec<Outcome>, failed: Vec<FailedOutcome>) -> Report {
        Report { outcomes, failed, ..Report::default() }
    }

    /// Every request the scheduler was handed: completed + dropped +
    /// cancelled (rejected submissions never reached it).
    pub fn total(&self) -> usize {
        self.outcomes.len() + self.failed.len() + self.cancelled.len()
    }

    /// Absorb another (partial) report: used by incremental retirement
    /// (`Scheduler::take_finished`) and by the cluster layer merging
    /// per-replica reports into one global view.
    pub fn merge(&mut self, other: Report) {
        self.outcomes.extend(other.outcomes);
        self.failed.extend(other.failed);
        self.cancelled.extend(other.cancelled);
        self.rejected += other.rejected;
    }

    /// Canonical ordering for cross-run comparison: merged reports
    /// accumulate outcomes in completion order, which depends on replica
    /// interleaving; sorting by request id makes equality checks and
    /// diffs deterministic.
    pub fn sort_by_id(&mut self) {
        self.outcomes.sort_by_key(|o| o.id);
        self.failed.sort_by_key(|f| f.id);
        self.cancelled.sort_by_key(|c| c.id);
    }

    /// Fraction of completed-or-dropped requests that met their SLO; a
    /// dropped request counts as a violation. Cancelled requests are
    /// excluded from both sides — the client walked away, so neither the
    /// server's success nor its failure can be measured.
    pub fn slo_attainment(&self) -> f64 {
        let denom = self.outcomes.len() + self.failed.len();
        if denom == 0 {
            return 1.0;
        }
        let ok = self.outcomes.iter().filter(|o| !o.violates_slo()).count();
        ok as f64 / denom as f64
    }

    pub fn overall(&self) -> Summary {
        Summary::of(&self.outcomes.iter().collect::<Vec<_>>())
    }

    pub fn by_modality(&self, m: Modality) -> Summary {
        Summary::of(&self.outcomes.iter().filter(|o| o.modality == m).collect::<Vec<_>>())
    }

    /// Group by assigned class, falling back to the naive modality mapping
    /// for outcomes without a class (baselines): text→M, image→C, video→T.
    pub fn by_class(&self, c: Class) -> Summary {
        let fallback = |o: &Outcome| match o.modality {
            Modality::Text => Class::Motorcycle,
            Modality::Image => Class::Car,
            Modality::Video => Class::Truck,
        };
        Summary::of(
            &self
                .outcomes
                .iter()
                .filter(|o| o.class.unwrap_or_else(|| fallback(o)) == c)
                .collect::<Vec<_>>(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(ttft: f64, e2e: f64, slo: f64, out: u32) -> Outcome {
        Outcome {
            id: 0,
            modality: Modality::Text,
            class: None,
            arrival: 10.0,
            first_token: 10.0 + ttft,
            finish: 10.0 + e2e,
            output_tokens: out,
            slo_latency: slo,
            preemptions: 0,
            preempted_time: 0.0,
            slo_class: None,
        }
    }

    #[test]
    fn outcome_derived_metrics() {
        let o = outcome(0.5, 4.0, 3.0, 8);
        assert!((o.ttft() - 0.5).abs() < 1e-12);
        assert!((o.e2e() - 4.0).abs() < 1e-12);
        assert!((o.normalized_latency() - 0.5).abs() < 1e-12);
        assert!(o.violates_slo());
        assert!((o.severity() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn meeting_slo_has_zero_severity() {
        let o = outcome(0.1, 2.0, 3.0, 10);
        assert!(!o.violates_slo());
        assert_eq!(o.severity(), 0.0);
    }

    #[test]
    fn summary_aggregates() {
        let a = outcome(0.1, 1.0, 5.0, 10);
        let b = outcome(0.3, 6.0, 5.0, 10);
        let s = Summary::of(&[&a, &b]);
        assert_eq!(s.n, 2);
        assert!((s.avg_ttft - 0.2).abs() < 1e-12);
        assert!((s.slo_violation_rate - 0.5).abs() < 1e-12);
        assert!((s.violation_severity - 1.0).abs() < 1e-12);
        assert!((s.avg_norm_latency - 0.35).abs() < 1e-12);
    }

    #[test]
    fn empty_summary_is_zeroes() {
        let s = Summary::of(&[]);
        assert_eq!(s.n, 0);
        assert_eq!(s.avg_ttft, 0.0);
    }

    #[test]
    fn report_class_fallback_uses_modality() {
        let mut o1 = outcome(0.1, 1.0, 5.0, 10);
        o1.modality = Modality::Video;
        let mut o2 = outcome(0.1, 1.0, 5.0, 10);
        o2.modality = Modality::Text;
        o2.class = Some(Class::Truck); // classifier overrides modality
        let r = Report::new(vec![o1, o2]);
        assert_eq!(r.by_class(Class::Truck).n, 2);
        assert_eq!(r.by_class(Class::Motorcycle).n, 0);
    }

    #[test]
    fn dropped_requests_count_against_attainment() {
        let ok = outcome(0.1, 1.0, 5.0, 10); // meets SLO
        let failed = FailedOutcome {
            id: 9,
            modality: Modality::Video,
            class: Some(Class::Truck),
            arrival: 0.0,
            dropped_at: 3.0,
        };
        let r = Report::with_failed(vec![ok], vec![failed]);
        assert_eq!(r.total(), 2);
        assert!((r.slo_attainment() - 0.5).abs() < 1e-12, "a drop is a violation");
        // grouped summaries still cover completed outcomes only
        assert_eq!(r.overall().n, 1);
    }

    #[test]
    fn merge_and_sort_by_id() {
        let mut a = Report::new(vec![]);
        let mut o1 = outcome(0.1, 1.0, 5.0, 10);
        o1.id = 7;
        let mut o2 = outcome(0.2, 1.0, 5.0, 10);
        o2.id = 3;
        a.merge(Report::new(vec![o1]));
        a.merge(Report::with_failed(
            vec![o2],
            vec![FailedOutcome {
                id: 5,
                modality: Modality::Text,
                class: None,
                arrival: 0.0,
                dropped_at: 1.0,
            }],
        ));
        assert_eq!(a.total(), 3);
        a.sort_by_id();
        assert_eq!(a.outcomes[0].id, 3);
        assert_eq!(a.outcomes[1].id, 7);
    }

    #[test]
    fn cancelled_requests_conserve_but_do_not_skew_slo() {
        let ok = outcome(0.1, 1.0, 5.0, 10); // meets SLO
        let r = Report {
            outcomes: vec![ok],
            failed: vec![],
            cancelled: vec![CancelledOutcome {
                id: 4,
                modality: Modality::Image,
                class: None,
                arrival: 0.0,
                cancelled_at: 2.0,
            }],
            rejected: 3,
        };
        assert_eq!(r.total(), 2, "cancellations count toward conservation");
        assert!((r.slo_attainment() - 1.0).abs() < 1e-12, "cancellation is not a violation");

        let mut merged = Report::default();
        merged.merge(r.clone());
        merged.merge(r);
        assert_eq!(merged.total(), 4);
        assert_eq!(merged.cancelled.len(), 2);
        assert_eq!(merged.rejected, 6, "rejection counters add up across partials");
    }

    #[test]
    fn p90_ordering() {
        let outs: Vec<Outcome> =
            (0..100).map(|i| outcome(i as f64 / 100.0, 1.0, 5.0, 10)).collect();
        let s = Summary::of(&outs.iter().collect::<Vec<_>>());
        assert!(s.p50_ttft < s.p90_ttft);
        assert!(s.p90_ttft < s.p99_ttft);
        assert!((s.p90_ttft - 0.891).abs() < 0.01);
    }
}
