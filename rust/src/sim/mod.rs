//! Discrete-event simulation substrate: a virtual clock and event queue.
//!
//! The serving simulator is *iteration-driven* (the coordinator's `step`
//! loop pulls time forward by executing engine steps), but several side
//! processes need scheduled events: injected request arrivals (the
//! scheduler's online ingress queue), preprocess-stage completions, and
//! timeout probes. This module provides the minimal deterministic event
//! queue those share; determinism (ties break by insertion order) is what
//! makes the stepped and batch scheduler paths bit-identical.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at a virtual time, carrying a payload.
#[derive(Debug, Clone)]
struct Event<T> {
    time: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Event<T> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}

impl<T> Eq for Event<T> {}

impl<T> Ord for Event<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // min-heap on (time, seq): reverse the natural (max-heap) order.
        // total_cmp so even a (sanitized-away) NaN time would order
        // deterministically instead of silently tying.
        other.time.total_cmp(&self.time).then_with(|| other.seq.cmp(&self.seq))
    }
}

impl<T> PartialOrd for Event<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic event queue: ties in time break by insertion order.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Event<T>>,
    next_seq: u64,
    now: f64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        EventQueue { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }

    /// Current virtual time (the time of the last popped event, or the
    /// last explicit `advance_to`).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Schedule `payload` at absolute virtual time `time`.
    pub fn schedule(&mut self, time: f64, payload: T) {
        debug_assert!(time >= self.now, "scheduling into the past: {time} < {}", self.now);
        self.heap.push(Event { time, seq: self.next_seq, payload });
        self.next_seq += 1;
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let e = self.heap.pop()?;
        self.now = e.time;
        Some((e.time, e.payload))
    }

    /// Pop the earliest event only if it is at or before `time`.
    pub fn pop_until(&mut self, time: f64) -> Option<(f64, T)> {
        if self.peek_time()? <= time {
            self.pop()
        } else {
            None
        }
    }

    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|e| e.time)
    }

    /// Remove and return the earliest-scheduled event whose payload
    /// matches `pred`. O(n) heap rebuild — used by rare control-plane
    /// operations (request cancellation), never on the hot path.
    pub fn remove_where(&mut self, mut pred: impl FnMut(&T) -> bool) -> Option<(f64, T)> {
        let mut found: Option<Event<T>> = None;
        let mut rest = Vec::with_capacity(self.heap.len());
        for e in std::mem::take(&mut self.heap).into_vec() {
            if pred(&e.payload) {
                // keep the earliest match; (time, seq) orders duplicates
                match &found {
                    Some(f) if (f.time, f.seq) <= (e.time, e.seq) => rest.push(e),
                    _ => {
                        if let Some(prev) = found.replace(e) {
                            rest.push(prev);
                        }
                    }
                }
            } else {
                rest.push(e);
            }
        }
        self.heap = BinaryHeap::from(rest);
        found.map(|e| (e.time, e.payload))
    }

    /// Manually advance the clock (iteration-driven progress).
    pub fn advance_to(&mut self, time: f64) {
        if time > self.now {
            self.now = time;
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule(3.0, "c");
        q.schedule(1.0, "a");
        q.schedule(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 1);
        q.schedule(1.0, 2);
        q.schedule(1.0, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn clock_advances_on_pop() {
        let mut q = EventQueue::new();
        q.schedule(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop();
        assert_eq!(q.now(), 5.0);
    }

    #[test]
    fn pop_until_respects_horizon() {
        let mut q = EventQueue::new();
        q.schedule(1.0, "a");
        q.schedule(10.0, "b");
        assert_eq!(q.pop_until(5.0), Some((1.0, "a")));
        assert_eq!(q.pop_until(5.0), None);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn remove_where_pulls_one_event_and_preserves_order() {
        let mut q = EventQueue::new();
        q.schedule(1.0, 10u64);
        q.schedule(2.0, 20);
        q.schedule(3.0, 10);
        assert_eq!(q.remove_where(|&x| x == 10), Some((1.0, 10)), "earliest match wins");
        assert_eq!(q.remove_where(|&x| x == 99), None);
        assert_eq!(q.len(), 2);
        assert_eq!(q.pop(), Some((2.0, 20)));
        assert_eq!(q.pop(), Some((3.0, 10)));
    }

    #[test]
    fn advance_to_is_monotone() {
        let mut q: EventQueue<()> = EventQueue::new();
        q.advance_to(4.0);
        q.advance_to(2.0); // no-op backwards
        assert_eq!(q.now(), 4.0);
    }
}
