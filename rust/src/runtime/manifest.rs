//! Parser for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! Line format:
//!   `hparam <key> <value>`
//!   `weight <name> <d0,d1,..|scalar> <offset> <size>`   (f32 values)
//!   `artifact <name> <file> <sha256-prefix>`
//!   `weights_file weights.bin <total-f32-count>`

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

#[derive(Debug, Clone)]
pub struct WeightEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Offset into weights.bin in f32 units.
    pub offset: usize,
    pub size: usize,
}

#[derive(Debug, Clone)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: String,
    pub digest: String,
}

/// TinyMLLM hyperparameters the Rust side needs for shape bookkeeping.
#[derive(Debug, Clone)]
pub struct Hparams {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub head_dim: usize,
    pub max_seq: usize,
    pub patch_dim: usize,
    pub prefill_buckets: Vec<usize>,
    pub decode_buckets: Vec<usize>,
    pub encoder_buckets: Vec<usize>,
}

impl Hparams {
    /// Flattened KV-cache element count per request:
    /// [n_layers, 2, n_heads, max_seq, head_dim].
    pub fn kv_elems(&self) -> usize {
        self.n_layers * 2 * self.n_heads * self.max_seq * self.head_dim
    }
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub hparams: Hparams,
    pub weights: Vec<WeightEntry>,
    pub artifacts: Vec<ArtifactEntry>,
    /// Total f32 count of weights.bin.
    pub weights_total: usize,
}

impl Manifest {
    pub fn load(path: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let mut hp: BTreeMap<String, String> = BTreeMap::new();
        let mut weights = Vec::new();
        let mut artifacts = Vec::new();
        let mut weights_total = None;

        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            let ctx = || format!("manifest line {}: '{line}'", i + 1);
            match fields[0] {
                "hparam" if fields.len() == 3 => {
                    hp.insert(fields[1].to_string(), fields[2].to_string());
                }
                "weight" if fields.len() == 5 => {
                    let shape = if fields[2] == "scalar" {
                        vec![]
                    } else {
                        fields[2]
                            .split(',')
                            .map(|d| d.parse::<usize>().with_context(ctx))
                            .collect::<Result<Vec<_>>>()?
                    };
                    weights.push(WeightEntry {
                        name: fields[1].to_string(),
                        shape,
                        offset: fields[3].parse().with_context(ctx)?,
                        size: fields[4].parse().with_context(ctx)?,
                    });
                }
                "artifact" if fields.len() == 4 => {
                    artifacts.push(ArtifactEntry {
                        name: fields[1].to_string(),
                        file: fields[2].to_string(),
                        digest: fields[3].to_string(),
                    });
                }
                "weights_file" if fields.len() == 3 => {
                    weights_total = Some(fields[2].parse().with_context(ctx)?);
                }
                _ => bail!("unrecognized manifest line {}: '{line}'", i + 1),
            }
        }

        let get = |k: &str| -> Result<usize> {
            hp.get(k)
                .with_context(|| format!("manifest missing hparam '{k}'"))?
                .parse::<usize>()
                .with_context(|| format!("hparam '{k}' not an integer"))
        };
        let get_list = |k: &str| -> Result<Vec<usize>> {
            hp.get(k)
                .with_context(|| format!("manifest missing hparam '{k}'"))?
                .split(',')
                .map(|s| s.parse::<usize>().with_context(|| format!("hparam '{k}'")))
                .collect()
        };

        let hparams = Hparams {
            vocab: get("vocab")?,
            d_model: get("d_model")?,
            n_layers: get("n_layers")?,
            n_heads: get("n_heads")?,
            head_dim: get("head_dim")?,
            max_seq: get("max_seq")?,
            patch_dim: get("patch_dim")?,
            prefill_buckets: get_list("prefill_buckets")?,
            decode_buckets: get_list("decode_buckets")?,
            encoder_buckets: get_list("encoder_buckets")?,
        };

        // Validate weight layout: contiguous, non-overlapping, sizes match.
        let mut sorted = weights.clone();
        sorted.sort_by_key(|w| w.offset);
        let mut expect = 0usize;
        for w in &sorted {
            if w.offset != expect {
                bail!("weight '{}' at offset {} (expected {expect})", w.name, w.offset);
            }
            let n: usize = w.shape.iter().product::<usize>().max(1);
            if n != w.size {
                bail!("weight '{}': shape {:?} != size {}", w.name, w.shape, w.size);
            }
            expect += w.size;
        }
        let weights_total =
            weights_total.with_context(|| "manifest missing weights_file line")?;
        if expect != weights_total {
            bail!("weights sum to {expect} but weights_file says {weights_total}");
        }

        Ok(Manifest { hparams, weights, artifacts, weights_total })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
# comment
hparam vocab 512
hparam d_model 128
hparam n_layers 2
hparam n_heads 4
hparam head_dim 32
hparam max_seq 640
hparam patch_dim 48
hparam prefill_buckets 32,64
hparam decode_buckets 1,2
hparam encoder_buckets 16
weight a.x 128 0 128
weight b.y 2,64 128 128
weights_file weights.bin 256
artifact prefill_32 prefill_32.hlo.txt abcd1234
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.hparams.vocab, 512);
        assert_eq!(m.hparams.prefill_buckets, vec![32, 64]);
        assert_eq!(m.weights.len(), 2);
        assert_eq!(m.weights[1].shape, vec![2, 64]);
        assert_eq!(m.artifacts.len(), 1);
        assert_eq!(m.weights_total, 256);
        assert_eq!(m.hparams.kv_elems(), 2 * 2 * 4 * 640 * 32);
    }

    #[test]
    fn rejects_gap_in_weights() {
        let bad = SAMPLE.replace("weight b.y 2,64 128 128", "weight b.y 2,64 200 128");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_shape_size_mismatch() {
        let bad = SAMPLE.replace("weight b.y 2,64 128 128", "weight b.y 2,65 128 128");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_missing_hparam() {
        let bad = SAMPLE.replace("hparam vocab 512\n", "");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn rejects_unknown_line() {
        let bad = format!("{SAMPLE}wat is this\n");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn parses_real_manifest_if_built() {
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert_eq!(m.hparams.d_model, 128);
            assert!(m.artifacts.len() >= 17);
            assert!(m.weights.len() >= 40);
        }
    }
}
