//! PJRT runtime: loads the AOT artifacts produced by `make artifacts` and
//! executes them on the request path. Python never runs here.
//!
//! * `manifest.txt` describes the model hparams, the weight layout inside
//!   `weights.bin`, and one HLO-text file per (entry point, shape bucket).
//! * HLO *text* is the interchange format (jax ≥ 0.5 emits 64-bit
//!   instruction ids that xla_extension 0.5.1 rejects in proto form; the
//!   text parser reassigns ids — see python/compile/aot.py).
//! * Weights are uploaded to the device **once** as `PjRtBuffer`s; every
//!   `execute` call prepends them (the HLO entry signature is
//!   `[weight leaves..., inputs...]`, matching pytree-flatten order).

pub mod manifest;

use crate::runtime::manifest::Manifest;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Typed input for an artifact call.
pub enum Input<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
    /// Scalar i32 (rank-0).
    ScalarI32(i32),
}

/// A loaded PJRT runtime: compiled executables + device-resident weights.
pub struct Runtime {
    client: xla::PjRtClient,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    weight_buffers: Vec<xla::PjRtBuffer>,
    pub manifest: Manifest,
    dir: PathBuf,
    /// Wall time spent inside PJRT execute (perf accounting).
    pub execute_time: std::time::Duration,
    pub execute_calls: u64,
}

impl Runtime {
    /// Load manifest + weights and compile every artifact eagerly.
    pub fn load(dir: &Path) -> Result<Runtime> {
        Self::load_filtered(dir, |_| true)
    }

    /// Load, compiling only artifacts accepted by `keep` (tests use this
    /// to avoid compiling all 17 buckets).
    pub fn load_filtered(dir: &Path, keep: impl Fn(&str) -> bool) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.txt"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;

        // Upload weights once.
        let raw = std::fs::read(dir.join("weights.bin")).context("reading weights.bin")?;
        if raw.len() != manifest.weights_total * 4 {
            bail!(
                "weights.bin is {} bytes, manifest says {} f32 values",
                raw.len(),
                manifest.weights_total
            );
        }
        let floats: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
            .collect();
        let mut weight_buffers = Vec::with_capacity(manifest.weights.len());
        for w in &manifest.weights {
            let slice = &floats[w.offset..w.offset + w.size];
            let buf = client
                .buffer_from_host_buffer(slice, &w.shape, None)
                .map_err(|e| anyhow!("uploading weight {}: {e:?}", w.name))?;
            weight_buffers.push(buf);
        }

        // Compile artifacts.
        let mut executables = HashMap::new();
        for a in &manifest.artifacts {
            if !keep(&a.name) {
                continue;
            }
            let path = dir.join(&a.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", a.name))?;
            executables.insert(a.name.clone(), exe);
        }

        Ok(Runtime {
            client,
            executables,
            weight_buffers,
            manifest,
            dir: dir.to_path_buf(),
            execute_time: std::time::Duration::ZERO,
            execute_calls: 0,
        })
    }

    pub fn has(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.executables.keys().map(|s| s.as_str()).collect()
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Execute artifact `name` with the given inputs (weights prepended
    /// automatically). Returns the flattened output tuple as literals.
    pub fn execute(&mut self, name: &str, inputs: &[Input]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not loaded"))?;

        let mut bufs: Vec<&xla::PjRtBuffer> = self.weight_buffers.iter().collect();
        let mut owned: Vec<xla::PjRtBuffer> = Vec::with_capacity(inputs.len());
        for (i, input) in inputs.iter().enumerate() {
            let buf = match input {
                Input::F32(data, dims) => self
                    .client
                    .buffer_from_host_buffer(data, dims, None)
                    .map_err(|e| anyhow!("{name} input {i} (f32): {e:?}"))?,
                Input::I32(data, dims) => self
                    .client
                    .buffer_from_host_buffer(data, dims, None)
                    .map_err(|e| anyhow!("{name} input {i} (i32): {e:?}"))?,
                Input::ScalarI32(v) => self
                    .client
                    .buffer_from_host_buffer(&[*v], &[], None)
                    .map_err(|e| anyhow!("{name} input {i} (scalar): {e:?}"))?,
            };
            owned.push(buf);
        }
        for b in &owned {
            bufs.push(b);
        }

        // simlint: allow(wall-clock) — PJRT device timing: measures actual execution
        let t0 = std::time::Instant::now();
        let result = exe
            .execute_b(&bufs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        self.execute_time += t0.elapsed();
        self.execute_calls += 1;

        let out = result
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{name}: no replica output"))?
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("{name}: empty output"))?;
        let literal = out
            .to_literal_sync()
            .map_err(|e| anyhow!("{name}: to_literal: {e:?}"))?;
        // aot.py lowers with return_tuple=True: unwrap the tuple.
        literal.to_tuple().map_err(|e| anyhow!("{name}: to_tuple: {e:?}"))
    }

    /// Pick the smallest bucket >= n from a bucket list.
    pub fn bucket_for(buckets: &[usize], n: usize) -> Option<usize> {
        buckets.iter().copied().filter(|&b| b >= n).min()
    }
}

/// Read an f32 literal into a Vec.
pub fn literal_f32(l: &xla::Literal) -> Result<Vec<f32>> {
    l.to_vec::<f32>().map_err(|e| anyhow!("literal to f32: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        let b = [32usize, 64, 128, 256, 512];
        assert_eq!(Runtime::bucket_for(&b, 1), Some(32));
        assert_eq!(Runtime::bucket_for(&b, 32), Some(32));
        assert_eq!(Runtime::bucket_for(&b, 33), Some(64));
        assert_eq!(Runtime::bucket_for(&b, 512), Some(512));
        assert_eq!(Runtime::bucket_for(&b, 513), None);
    }
}
