//! Reusable experiment drivers: every figure bench, the CLI and the
//! examples run simulations through these helpers so setups are identical
//! (and reproducible from the seeds recorded in EXPERIMENTS.md).

use crate::backend::ServeBackend as _;
use crate::cluster::{Cluster, ClusterReport};
use crate::config::ServeConfig;
use crate::coordinator::{SchedStats, Scheduler};
use crate::engine::sim_engine::SimEngine;
use crate::metrics::Report;
use crate::model::ModelProfile;
use crate::policies::build_policy;
use crate::request::Request;
use crate::workload::{scale_trace, Mix, PopulationGen, WorkloadGen, WorkloadSpec};

/// Outcome of one simulated serving run.
pub struct RunResult {
    pub report: Report,
    pub stats: SchedStats,
    /// Virtual seconds the run spanned.
    pub makespan: f64,
}

/// Generate the trace a config describes (same seed ⇒ same trace, so
/// policies compete on identical arrival sequences). Dispatches on
/// `cfg.workload.engine`: "poisson" keeps the original open-loop
/// generator bit-identical; "population" runs the client-population
/// engine ([`crate::workload::population`]). With `workload.scale_k`
/// > 1 the generated trace is additionally tiled + compressed to k×
/// rate and k×`num_requests` requests via [`scale_trace`].
pub fn make_trace(cfg: &ServeConfig, profile: &ModelProfile) -> Vec<Request> {
    let mix = Mix::by_name(&cfg.mix).expect("validated mix");
    let trace = if cfg.workload.engine == "population" {
        let spec = WorkloadSpec::from_config(&cfg.workload, mix, cfg.rate);
        PopulationGen::new(profile, spec, cfg.seed).generate(cfg.num_requests)
    } else {
        WorkloadGen::new(profile, mix, cfg.rate, cfg.seed).generate(cfg.num_requests)
    };
    if cfg.workload.scale_k > 1 {
        scale_trace(&trace, cfg.workload.scale_k)
    } else {
        trace
    }
}

/// Run one simulated serving experiment under `cfg`.
pub fn run_sim(cfg: &ServeConfig) -> RunResult {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let trace = make_trace(cfg, &profile);
    run_sim_with_trace(cfg, trace)
}

/// Run a simulation over an explicit trace (A/B policy comparisons).
/// The engine honors `cfg.cluster.encode_overlap` even at a single
/// scheduler, so overlap A/Bs don't silently require a cluster.
pub fn run_sim_with_trace(cfg: &ServeConfig, trace: Vec<Request>) -> RunResult {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let policy = build_policy(cfg, &profile);
    let engine = Box::new(SimEngine::new(&cfg.engine_profile()));
    let mut sched = Scheduler::new(cfg.clone(), policy, engine);
    let report = sched.run(trace);
    RunResult { makespan: sched.now(), stats: sched.stats.clone(), report }
}

/// Run a multi-replica cluster experiment under `cfg` (replica count,
/// router policy and encode-overlap mode come from `cfg.cluster`). The
/// trace is identical to the single-engine one for the same seed, so
/// router policies compete on identical arrival sequences.
pub fn run_cluster(cfg: &ServeConfig) -> ClusterReport {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let trace = make_trace(cfg, &profile);
    run_cluster_with_trace(cfg, trace)
}

/// Cluster run over an explicit trace (A/B router comparisons).
pub fn run_cluster_with_trace(cfg: &ServeConfig, trace: Vec<Request>) -> ClusterReport {
    Cluster::new(cfg).run(trace)
}

/// Run whatever backend the config describes — a bare scheduler or a
/// cluster ([`crate::backend::build`]) — over its generated trace and
/// return the merged, id-sorted report. This is the de-branched driver:
/// callers that only need a [`Report`] (goodput search, sweeps, the
/// CLI's generic paths) stop caring about the topology. Use
/// [`run_sim`]/[`run_cluster`] when scheduler stats or per-replica
/// detail are needed.
pub fn run_serve(cfg: &ServeConfig) -> Report {
    let profile = crate::model::by_name(&cfg.model).expect("validated model");
    let trace = make_trace(cfg, &profile);
    run_serve_with_trace(cfg, trace)
}

/// Backend-generic run over an explicit trace (see [`run_serve`]).
pub fn run_serve_with_trace(cfg: &ServeConfig, trace: Vec<Request>) -> Report {
    crate::backend::build(cfg).run_trace(trace)
}

/// Goodput (Fig 15): the maximum request rate sustaining
/// `attainment` SLO compliance (DistServe-style, default 0.9), found by
/// doubling + bisection over simulated runs. Backend-generic: a cluster
/// config searches fleet goodput through the same code path.
pub fn goodput(base: &ServeConfig, attainment: f64, n_requests: usize) -> f64 {
    let meets = |rate: f64| -> bool {
        let mut cfg = base.clone();
        cfg.rate = rate;
        cfg.num_requests = n_requests;
        let report = run_serve(&cfg);
        if report.outcomes.is_empty() {
            return false;
        }
        // dropped requests surface in `report.failed` and count as
        // violations
        report.slo_attainment() >= attainment
    };

    // exponential search for an upper bound
    let mut lo = 0.0;
    let mut hi = 0.25;
    while meets(hi) {
        lo = hi;
        hi *= 2.0;
        if hi > 64.0 {
            return hi; // effectively unbounded at this scale
        }
    }
    // bisect
    for _ in 0..7 {
        let mid = 0.5 * (lo + hi);
        if meets(mid) {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::request::Modality;

    fn cfg(policy: &str) -> ServeConfig {
        let mut c = ServeConfig::default();
        c.policy = policy.into();
        c.num_requests = 150;
        c.rate = 2.0;
        c.seed = 7;
        c
    }

    #[test]
    fn fcfs_completes_all_requests() {
        let r = run_sim(&cfg("fcfs"));
        assert_eq!(r.report.outcomes.len() + r.stats.dropped as usize, 150);
        assert_eq!(r.report.failed.len(), r.stats.dropped as usize, "drops surface in report");
        assert_eq!(r.report.total(), 150);
        assert!(r.stats.dropped <= 2);
        assert!(r.makespan > 0.0);
        // every outcome well-formed
        for o in &r.report.outcomes {
            assert!(o.first_token >= o.arrival, "ttft before arrival");
            assert!(o.finish >= o.first_token);
        }
    }

    #[test]
    fn all_policies_run_same_trace() {
        for p in ["fcfs", "edf", "naive-class", "static-priority", "naive-aging", "tcm"] {
            let r = run_sim(&cfg(p));
            assert!(
                r.report.outcomes.len() + r.stats.dropped as usize == 150,
                "{p}: {} + {}",
                r.report.outcomes.len(),
                r.stats.dropped
            );
        }
    }

    #[test]
    fn tcm_beats_fcfs_on_text_ttft_under_mh() {
        // the paper's headline direction (Fig 10)
        let fcfs = run_sim(&cfg("fcfs"));
        let tcm = run_sim(&cfg("tcm"));
        let f = fcfs.report.by_modality(Modality::Text).avg_ttft;
        let t = tcm.report.by_modality(Modality::Text).avg_ttft;
        assert!(t < f, "tcm text ttft {t} !< fcfs {f}");
    }

    #[test]
    fn t0_workload_is_fast_for_everyone() {
        let mut c = cfg("fcfs");
        c.mix = "T0".into();
        let r = run_sim(&c);
        let s = r.report.overall();
        assert!(s.slo_violation_rate < 0.05, "{}", s.slo_violation_rate);
        assert!(s.avg_ttft < 1.0, "{}", s.avg_ttft);
    }

    #[test]
    fn run_serve_matches_run_sim_for_single_replica() {
        // the de-branched driver must not change single-scheduler results
        let c = cfg("tcm");
        let a = run_sim(&c);
        let mut a_report = a.report.clone();
        a_report.sort_by_id();
        let b = run_serve(&c);
        assert_eq!(a_report.outcomes.len(), b.outcomes.len());
        assert_eq!(a_report.failed.len(), b.failed.len());
        for (x, y) in a_report.outcomes.iter().zip(&b.outcomes) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.first_token.to_bits(), y.first_token.to_bits());
            assert_eq!(x.finish.to_bits(), y.finish.to_bits());
        }
    }

    #[test]
    fn population_engine_and_scale_k_run_end_to_end() {
        let mut c = cfg("tcm");
        c.workload.engine = "population".into();
        c.workload.mix_flip_to = "T0".into();
        c.workload.mix_flip_at_s = 30.0;
        c.num_requests = 120;
        let r = run_sim(&c);
        assert_eq!(r.report.total(), 120);
        // scale_k multiplies the trace deterministically
        c.workload.scale_k = 2;
        let profile = crate::model::by_name(&c.model).unwrap();
        let t = make_trace(&c, &profile);
        assert_eq!(t.len(), 240);
        let t2 = make_trace(&c, &profile);
        for (a, b) in t.iter().zip(&t2) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
    }

    #[test]
    fn deterministic_runs() {
        let a = run_sim(&cfg("tcm"));
        let b = run_sim(&cfg("tcm"));
        assert_eq!(a.report.outcomes.len(), b.report.outcomes.len());
        assert_eq!(a.makespan, b.makespan);
        for (x, y) in a.report.outcomes.iter().zip(&b.report.outcomes) {
            assert_eq!(x.first_token, y.first_token);
        }
    }
}
