//! tcm-serve — the launcher.
//!
//! Subcommands:
//!   simulate   run a simulated serving experiment and print the report
//!   serve      drive the RealEngine (PJRT, TinyMLLM artifacts) over a
//!              generated workload and report wall-clock metrics
//!   profile    run the offline Workload Profiler for a model
//!   goodput    search the max sustainable rate at 90% SLO attainment
//!   trace      generate a workload trace file for later replay
//!
//! Config precedence: defaults (paper §4.1) < --config file.toml < flags.

use tcm_serve::backend::ServeBackend as _;
use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::profiler::Profiler;
use tcm_serve::experiments;
use tcm_serve::report;
use tcm_serve::request::Modality;
use tcm_serve::util::cli::Parser;

fn parser() -> Parser {
    Parser::new("tcm-serve", "modality-aware scheduling for multimodal LLM inference")
        .subcommand("simulate", "simulated serving experiment (cost-model engine)")
        .subcommand("serve", "real serving over the PJRT TinyMLLM artifacts")
        .subcommand("profile", "offline workload profiling for a model")
        .subcommand("goodput", "max sustainable rate at 90% SLO attainment")
        .subcommand("trace", "generate a workload trace file")
        .option("config", "TOML config file")
        .option("model", "model profile (Table 1 name or tiny-mllm)")
        .option("mix", "workload mix: T0 | ML | MH | VH")
        .option("policy", "fcfs | edf | naive-class | static-priority | naive-aging | tcm")
        .option("rate", "Poisson arrival rate, req/s")
        .option("requests", "number of requests")
        .option("seed", "workload seed")
        .option("slo-scale", "SLO = scale x isolated e2e latency")
        .option("workload", "arrival engine: poisson (default) | population")
        .option("clients", "client-population size (population engine)")
        .option("burst-duty", "MMPP burst duty cycle in (0,1) for chat clients")
        .option("burst-boost", "burst intensity as a multiple of the mean rate (>= 1)")
        .option("think-time", "mean think time between session turns, seconds")
        .option("turns", "mean turns per chat session (geometric)")
        .option("mix-flip-at", "flip the traffic mix at this virtual time, seconds")
        .option("mix-flip-to", "mix to flip to: T0 | ML | MH | VH")
        .option("diurnal", "piecewise rate curve, start:mult pairs e.g. \"0:1,300:2.5\"")
        .option("scale-k", "replay the generated trace at k x rate with k x requests")
        .option("memory-frac", "fraction of KV capacity available")
        .option("token-budget", "chunked-prefill token budget per iteration")
        .option("sched-indexed", "indexed ready-set planner: true (default) | false (full-rescore)")
        .option("replicas", "engine replicas (cluster serving; 1 = single engine)")
        .option("router", "round-robin | least-work | modality-partition")
        .option("overlap-penalty", "encode-overlap sync penalty, seconds")
        .flag("encode-overlap", "overlap vision encode with prefill/decode")
        .flag("encoder-pool", "disaggregated encoder pool (multimodal encodes leave the replicas)")
        .option("pool-slots", "encoder slots in the pool (rocks capped to half)")
        .option("pool-aging", "rock aging deadline in the pool queue, seconds")
        .option("migration-cost", "embedding transfer cost, seconds per 1000 vision tokens")
        .option(
            "late-bind-epsilon",
            "prefer the encode slot's host on handoff within this ledger gap, s (0 = off)",
        )
        .flag("elastic", "elastic control plane: re-partition groups + resize pool slots per epoch")
        .option("elastic-epoch", "controller evaluation period, virtual seconds")
        .option("elastic-hysteresis", "dead band in replicas before a group move starts")
        .option("elastic-cooldown", "controller epochs to stay quiet after an action")
        .option("elastic-slots-min", "encoder-pool slot floor under elastic shrink")
        .option("elastic-slots-max", "encoder-pool slot ceiling under elastic grow")
        .option("admission-limit", "max outstanding requests before the server rejects (0 = off)")
        .flag("obs", "record lifecycle spans and per-epoch telemetry (deterministic, virtual-time)")
        .option("trace-out", "write a Chrome/Perfetto trace_event JSON file (implies --obs)")
        .option("metrics-out", "write Prometheus-format telemetry text (implies --obs)")
        .option("out", "output path (trace subcommand)")
        .option("artifacts", "artifacts directory (serve subcommand)")
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match parser().parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(2);
        }
    };

    let mut cfg = ServeConfig::default();
    if let Some(path) = args.get("config") {
        let doc = match tcm_serve::config::toml::Doc::load(std::path::Path::new(path)) {
            Ok(d) => d,
            Err(e) => {
                eprintln!("failed to read config {path}: {e}");
                std::process::exit(2);
            }
        };
        if let Err(e) = cfg.apply_doc(&doc) {
            eprintln!("{e}");
            std::process::exit(2);
        }
    }
    if let Err(e) = cfg.apply_args(&args) {
        eprintln!("{e}");
        std::process::exit(2);
    }

    match args.subcommand.as_deref() {
        Some("simulate") | None => cmd_simulate(&cfg),
        Some("serve") => cmd_serve(&mut cfg, args.get("artifacts")),
        Some("profile") => cmd_profile(&cfg),
        Some("goodput") => cmd_goodput(&cfg),
        Some("trace") => cmd_trace(&cfg, args.get_or("out", "workload.trace")),
        Some(other) => {
            eprintln!("unknown subcommand {other}");
            std::process::exit(2);
        }
    }
}

/// The de-branched simulate driver: one code path for every topology.
/// `backend::build` picks scheduler vs cluster from the config; the
/// backend's own `summary_lines` carry the per-replica / pool detail the
/// old cluster-only branch printed.
fn cmd_simulate(cfg: &ServeConfig) {
    println!(
        "simulate: model={} mix={} policy={} rate={} requests={} seed={} slo={}x mem={:.0}%",
        cfg.model,
        cfg.mix,
        cfg.policy,
        cfg.rate,
        cfg.num_requests,
        cfg.seed,
        cfg.slo_scale,
        cfg.memory_frac * 100.0
    );
    if cfg.workload.engine != "poisson" || cfg.workload.scale_k > 1 {
        let flip = if cfg.workload.mix_flip_to.is_empty() {
            "off".to_string()
        } else {
            format!("{}@{}s", cfg.workload.mix_flip_to, cfg.workload.mix_flip_at_s)
        };
        println!(
            "workload: engine={} clients={} mix_flip={} scale_k={}",
            cfg.workload.engine, cfg.workload.clients, flip, cfg.workload.scale_k
        );
    }
    let mut backend = tcm_serve::backend::build(cfg);
    println!(
        "backend: {} (replicas={} router={} encode_overlap={} encoder_pool={} elastic={})",
        backend.name(),
        cfg.cluster.replicas,
        cfg.cluster.router,
        cfg.cluster.encode_overlap,
        if cfg.pool.enabled { format!("{} slots", cfg.pool.slots) } else { "off".into() },
        if cfg.elastic.enabled {
            format!("epoch {}s", cfg.elastic.epoch_s)
        } else {
            "off".into()
        }
    );
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = experiments::make_trace(cfg, &profile);
    let r = backend.run_trace(trace);
    report::header("results by class");
    report::mcto_rows(&cfg.policy, &r);
    report::header("results by modality");
    report::modality_rows(&cfg.policy, &r);
    println!();
    for line in backend.summary_lines() {
        println!("{line}");
    }
    println!(
        "slo_attainment={:.1}% cancelled={} rejected={}",
        r.slo_attainment() * 100.0,
        r.cancelled.len(),
        r.rejected
    );
    if let Some(path) = &cfg.obs.trace_out {
        match backend.trace_json() {
            Some(json) => match std::fs::write(path, json) {
                Ok(()) => println!("wrote perfetto trace to {path}"),
                Err(e) => eprintln!("failed to write trace {path}: {e}"),
            },
            None => eprintln!("trace-out set but no observer attached (internal error)"),
        }
    }
    if let Some(path) = &cfg.obs.metrics_out {
        match backend.telemetry_snapshot() {
            Some(snap) => {
                let text = tcm_serve::obs::prometheus_text(&snap);
                match std::fs::write(path, text) {
                    Ok(()) => println!("wrote telemetry to {path}"),
                    Err(e) => eprintln!("failed to write metrics {path}: {e}"),
                }
            }
            None => eprintln!("metrics-out set but no observer attached (internal error)"),
        }
    }
}

#[cfg(pjrt_runtime)]
fn cmd_serve(cfg: &mut ServeConfig, artifacts: Option<&str>) {
    use tcm_serve::coordinator::Scheduler;
    use tcm_serve::policies::build_policy;

    let dir = artifacts
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"));
    if !dir.join("manifest.txt").exists() {
        eprintln!("artifacts missing at {} — run `make artifacts`", dir.display());
        std::process::exit(1);
    }
    cfg.model = "tiny-mllm".into();
    cfg.scheduler.atomic_prefill = true;
    cfg.scheduler.max_running = cfg.scheduler.max_running.min(8);

    println!("loading artifacts from {} ...", dir.display());
    let rt = tcm_serve::runtime::Runtime::load(&dir).expect("runtime load");
    let engine = Box::new(tcm_serve::engine::real::RealEngine::new(rt));
    let profile = tcm_serve::model::by_name("tiny-mllm").unwrap();
    let trace = experiments::make_trace(cfg, &profile);
    let policy = build_policy(cfg, &profile);
    let mut sched = Scheduler::new(cfg.clone(), policy, engine);

    let wall = std::time::Instant::now();
    let rep = sched.run(trace);
    let wall = wall.elapsed().as_secs_f64();
    report::header("real-engine report (wall-clock)");
    report::mcto_rows(&cfg.policy, &rep);
    let tokens: u64 = rep.outcomes.iter().map(|o| o.output_tokens as u64).sum();
    println!(
        "\n{} requests, wall {:.1}s, {:.1} tok/s, {} iterations",
        rep.outcomes.len(),
        wall,
        tokens as f64 / wall,
        sched.stats.iterations
    );
}

#[cfg(not(pjrt_runtime))]
fn cmd_serve(_cfg: &mut ServeConfig, _artifacts: Option<&str>) {
    eprintln!(
        "the real PJRT engine is not compiled into this binary; rebuild with \
         RUSTFLAGS=\"--cfg pjrt_runtime\" (requires the xla + anyhow crates, \
         see rust/README.md)"
    );
    std::process::exit(1);
}

fn cmd_profile(cfg: &ServeConfig) {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let data = Profiler::new(&profile, cfg.seed).run(cfg.num_requests.max(100));
    report::header(&format!("workload profile — {}", cfg.model));
    for m in Modality::ALL {
        let ss = data.of_modality(m);
        let ttfts: Vec<f64> = ss.iter().map(|s| s.ttft()).collect();
        let kv: Vec<f64> = ss.iter().map(|s| s.kv_tokens as f64).collect();
        report::cdf_deciles(&format!("{m} ttft(s)"), &ttfts);
        report::cdf_deciles(&format!("{m} kv(tok)"), &kv);
    }
    println!("median output tokens: {:.0}", data.median_output_tokens());
}

fn cmd_goodput(cfg: &ServeConfig) {
    println!("searching goodput for policy={} slo={}x ...", cfg.policy, cfg.slo_scale);
    let g = experiments::goodput(cfg, 0.9, cfg.num_requests.min(200));
    println!("goodput ≈ {g:.2} req/s at 90% SLO attainment");
}

fn cmd_trace(cfg: &ServeConfig, out: &str) {
    let profile = tcm_serve::model::by_name(&cfg.model).unwrap();
    let trace = experiments::make_trace(cfg, &profile);
    tcm_serve::workload::save_trace(std::path::Path::new(out), &trace).expect("write trace");
    println!("wrote {} requests to {out}", trace.len());
}
