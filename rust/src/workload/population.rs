//! Client-population workload engine: ServeGen-grade traffic synthesis.
//!
//! The original [`WorkloadGen`](crate::workload::WorkloadGen) draws
//! i.i.d. requests from one open Poisson process — the textbook null
//! model. ServeGen's production characterization (PAPERS.md) shows real
//! MLLM traffic differs on every axis that matters to a scheduler:
//!
//! * **Per-client burstiness** — chat clients alternate between bursts
//!   and silence (modeled as a 2-state MMPP: session starts arrive at
//!   `rate_on` during bursts, `rate_off` otherwise, with exponential
//!   phase lengths).
//! * **Closed loops** — agent clients hold one session in flight and
//!   only start the next after the previous finishes plus a think time,
//!   so their offered load *reacts* to serving latency.
//! * **Diurnal swings** — aggregate intensity follows a piecewise
//!   [`DiurnalCurve`] in virtual time (closed-loop clients are
//!   self-clocked and ignore it).
//! * **Sessions, not requests** — each arrival is a multi-turn
//!   [`session`](crate::workload::session) whose context grows and whose
//!   attachment re-sends every turn.
//! * **Categories** — chat / agent / batch clients map onto
//!   [`SloClass`] tiers (critical / standard / best-effort).
//!
//! Everything is virtual-time and seeded: a [`PopulationGen`] yields a
//! bit-identical trace for a given (profile, spec, seed), regenerated
//! from scratch on every call. Request ids are assigned 0..n in global
//! arrival order *after* merging all client streams, so a population
//! trace drops into every existing consumer of `WorkloadGen` output.

use crate::config::WorkloadConfig;
use crate::model::ModelProfile;
use crate::request::{Modality, Request, SloClass};
use crate::util::rng::Rng;
use crate::workload::generator::{DatasetParams, Mix};
use crate::workload::session::{sample_session, SessionParams};

/// How a client launches sessions.
#[derive(Debug, Clone, PartialEq)]
pub enum ArrivalProcess {
    /// Open-loop Poisson at a fixed session rate (sessions/second).
    Poisson { rate: f64 },
    /// 2-state Markov-modulated Poisson: sessions arrive at `rate_on`
    /// during bursts and `rate_off` between them; phase lengths are
    /// exponential with the given means.
    Mmpp { rate_on: f64, rate_off: f64, mean_on_s: f64, mean_off_s: f64 },
    /// Closed-loop: one session outstanding; the next starts a think
    /// time after the previous one's last turn completes.
    ClosedLoop { think_mean_s: f64 },
}

/// The on/off phase process of an MMPP client, exposed on its own so
/// the duty-cycle property test can drive phases without generating
/// requests.
#[derive(Debug, Clone)]
pub struct MmppPhases {
    pub on: bool,
    /// Absolute virtual time at which the current phase ends.
    pub phase_end_s: f64,
    pub mean_on_s: f64,
    pub mean_off_s: f64,
}

impl MmppPhases {
    /// Start in the stationary distribution (on with probability duty).
    pub fn init(rng: &mut Rng, mean_on_s: f64, mean_off_s: f64) -> MmppPhases {
        debug_assert!(mean_on_s > 0.0 && mean_off_s > 0.0);
        let duty = mean_on_s / (mean_on_s + mean_off_s);
        let on = rng.bool(duty);
        let mean = if on { mean_on_s } else { mean_off_s };
        MmppPhases { on, phase_end_s: rng.exponential(1.0 / mean), mean_on_s, mean_off_s }
    }

    /// Cross into the next phase.
    pub fn flip(&mut self, rng: &mut Rng) {
        self.on = !self.on;
        let mean = if self.on { self.mean_on_s } else { self.mean_off_s };
        self.phase_end_s += rng.exponential(1.0 / mean);
    }
}

/// Piecewise-constant diurnal rate curve: multiplier `m_i` applies from
/// `start_i` until the next segment (or wrap). Deterministic in virtual
/// time — no wall clock anywhere.
#[derive(Debug, Clone, PartialEq)]
pub struct DiurnalCurve {
    /// (start_s, multiplier) segments; starts strictly increasing, the
    /// first at 0. Empty = flat 1.0.
    pub points: Vec<(f64, f64)>,
    /// Wrap period (seconds); 0 = no wrap, the last segment holds.
    pub period_s: f64,
}

impl DiurnalCurve {
    pub fn flat() -> DiurnalCurve {
        DiurnalCurve { points: Vec::new(), period_s: 0.0 }
    }

    pub fn is_flat(&self) -> bool {
        self.points.is_empty()
    }

    fn local(&self, t: f64) -> f64 {
        if self.period_s > 0.0 {
            t % self.period_s
        } else {
            t
        }
    }

    /// The multiplier in effect at virtual time `t`.
    pub fn multiplier(&self, t: f64) -> f64 {
        let mut m = 1.0;
        let lt = self.local(t);
        for &(start, mult) in &self.points {
            if start <= lt {
                m = mult;
            } else {
                break;
            }
        }
        m
    }

    /// The next time strictly after `t` at which the multiplier may
    /// change; infinity when the curve is flat from `t` onward.
    pub fn next_boundary(&self, t: f64) -> f64 {
        if self.points.is_empty() {
            return f64::INFINITY;
        }
        let lt = self.local(t);
        for &(start, _) in &self.points {
            if start > lt {
                return t + (start - lt);
            }
        }
        if self.period_s > 0.0 {
            t + (self.period_s - lt)
        } else {
            f64::INFINITY
        }
    }
}

/// Client category — the ServeGen traffic taxonomy, mapped onto the
/// serving tiers: chat is bursty + latency-critical, agent is
/// closed-loop + standard, batch is open-loop + best-effort.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Category {
    Chat,
    Agent,
    Batch,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Chat => "chat",
            Category::Agent => "agent",
            Category::Batch => "batch",
        }
    }

    pub const ALL: [Category; 3] = [Category::Chat, Category::Agent, Category::Batch];
}

impl std::fmt::Display for Category {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Per-category behavior: arrival process, session shape, SLO tier.
#[derive(Debug, Clone, PartialEq)]
pub struct CategoryParams {
    pub arrival: ArrivalProcess,
    pub session: SessionParams,
    pub slo_class: SloClass,
}

/// Full specification of a client population. Build one directly for
/// tests/benches, or from the `[workload]` config section via
/// [`WorkloadSpec::from_config`].
#[derive(Debug, Clone, PartialEq)]
pub struct WorkloadSpec {
    /// Base modality mix for session starts.
    pub mix: Mix,
    /// Mid-run traffic flip: sessions starting at/after the given
    /// virtual time draw modality from the second mix instead.
    pub mix_flip: Option<(f64, Mix)>,
    pub clients: u32,
    /// Unnormalized [chat, agent, batch] weights; clients are assigned
    /// categories deterministically by position.
    pub category_weights: [f64; 3],
    pub chat: CategoryParams,
    pub agent: CategoryParams,
    pub batch: CategoryParams,
    /// Aggregate intensity modulation (open-loop categories only —
    /// closed-loop clients are self-clocked and ignore it).
    pub diurnal: DiurnalCurve,
    /// Aggregate request rate (req/s) the open-loop categories are
    /// calibrated to at diurnal multiplier 1.0. The closed-loop share is
    /// emergent (it depends on service times), so realized aggregate
    /// rate is approximate by design.
    pub target_rate: f64,
}

impl WorkloadSpec {
    /// Map the `[workload]` config section onto a population spec.
    /// `cfg` must have passed `ServeConfig::validate`.
    pub fn from_config(w: &WorkloadConfig, mix: Mix, rate: f64) -> WorkloadSpec {
        let weights = w.category_weights;
        let total: f64 = weights.iter().sum();
        let clients = w.clients as u32;
        // Deterministic client counts per category (largest share gets
        // the rounding remainder via the final bucket).
        let n_for = |cat: usize| -> f64 {
            let mut n = 0u32;
            for i in 0..clients {
                let x = (i as f64 + 0.5) / clients as f64;
                if category_at(x, &weights) == Category::ALL[cat] {
                    n += 1;
                }
            }
            n.max(1) as f64
        };

        let turns_chat = w.turns_mean.max(1.0);
        let turns_agent = (w.turns_mean * 2.0).max(1.0);
        let session = |turns: f64, think_scale: f64| SessionParams {
            continue_p: 1.0 - 1.0 / turns,
            think_mean_s: w.think_mean_s * think_scale,
            context_carry: w.context_carry,
            ..SessionParams::default()
        };

        // Chat MMPP calibration: the client's mean session rate is its
        // share of the aggregate divided by mean turns; the on-rate is
        // `burst_boost` times that, the off-rate absorbs the remainder
        // so the long-run mean is preserved. If the boost exceeds what
        // the duty cycle can balance, the off phase goes fully silent.
        let duty = w.burst_duty;
        let r_mean_chat = rate * (weights[0] / total) / (n_for(0) * turns_chat);
        let (rate_on, rate_off) = if w.burst_boost * duty >= 1.0 {
            (r_mean_chat / duty, 0.0)
        } else {
            (w.burst_boost * r_mean_chat, r_mean_chat * (1.0 - duty * w.burst_boost) / (1.0 - duty))
        };
        let mean_on_s = w.burst_len_s;
        let mean_off_s = w.burst_len_s * (1.0 - duty) / duty;

        let r_batch = rate * (weights[2] / total) / n_for(2);

        let mut points = Vec::new();
        for pair in w.diurnal.chunks(2) {
            if pair.len() == 2 {
                points.push((pair[0], pair[1]));
            }
        }
        let diurnal = DiurnalCurve { points, period_s: w.diurnal_period_s };

        let mix_flip = match Mix::by_name(&w.mix_flip_to) {
            Some(to) if !w.mix_flip_to.is_empty() => Some((w.mix_flip_at_s, to)),
            _ => None,
        };

        WorkloadSpec {
            mix,
            mix_flip,
            clients,
            category_weights: weights,
            chat: CategoryParams {
                arrival: ArrivalProcess::Mmpp { rate_on, rate_off, mean_on_s, mean_off_s },
                session: session(turns_chat, 1.0),
                slo_class: SloClass::Critical,
            },
            agent: CategoryParams {
                arrival: ArrivalProcess::ClosedLoop { think_mean_s: w.think_mean_s },
                session: session(turns_agent, 0.25),
                slo_class: SloClass::Standard,
            },
            batch: CategoryParams {
                arrival: ArrivalProcess::Poisson { rate: r_batch },
                session: SessionParams {
                    continue_p: 0.0,
                    max_turns: 1,
                    context_carry: w.context_carry,
                    ..SessionParams::default()
                },
                slo_class: SloClass::BestEffort,
            },
            diurnal,
            target_rate: rate,
        }
    }

    pub fn params_for(&self, cat: Category) -> &CategoryParams {
        match cat {
            Category::Chat => &self.chat,
            Category::Agent => &self.agent,
            Category::Batch => &self.batch,
        }
    }

    fn mix_at(&self, t: f64) -> Mix {
        match self.mix_flip {
            Some((at, to)) if t >= at => to,
            _ => self.mix,
        }
    }
}

/// Deterministic category assignment by client position: client i maps
/// to the category whose cumulative weight band contains (i + 0.5)/n.
fn category_at(x: f64, weights: &[f64; 3]) -> Category {
    let total: f64 = weights.iter().sum();
    let mut cum = 0.0;
    for (i, w) in weights.iter().enumerate() {
        cum += w / total;
        if x < cum {
            return Category::ALL[i];
        }
    }
    Category::Batch
}

/// Provenance of one generated request: which client/category/session
/// produced it and at which turn. Parallel to the request vector from
/// [`PopulationGen::generate_with_meta`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ReqMeta {
    pub client: u32,
    pub category: Category,
    pub session: u32,
    pub turn: u32,
}

/// Seeded population generator. `generate` is a pure function of
/// (profile, spec, seed, n): it regenerates from scratch each call.
pub struct PopulationGen {
    profile: ModelProfile,
    spec: WorkloadSpec,
    params: DatasetParams,
    seed: u64,
}

impl PopulationGen {
    pub fn new(profile: &ModelProfile, spec: WorkloadSpec, seed: u64) -> PopulationGen {
        let params = if profile.name == "tiny-mllm" {
            DatasetParams::tiny()
        } else {
            DatasetParams::default()
        };
        PopulationGen { profile: profile.clone(), spec, params, seed }
    }

    /// Generate `n` requests in global arrival order, ids 0..n.
    pub fn generate(&self, n: usize) -> Vec<Request> {
        self.generate_with_meta(n).0
    }

    /// Generate `n` requests plus per-request provenance.
    ///
    /// The population is simulated over a horizon and the horizon is
    /// doubled until `n` requests arrive inside it. Because each client
    /// stream is prefix-stable in its own rng (draws happen in client
    /// virtual-time order) and whole sessions are emitted, the first `n`
    /// merged requests are identical whichever horizon finally covers
    /// them — so (seed, n) determines the output bit-for-bit, and a
    /// longer run extends a shorter one.
    pub fn generate_with_meta(&self, n: usize) -> (Vec<Request>, Vec<ReqMeta>) {
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        let mut horizon = 1.25 * n as f64 / self.spec.target_rate.max(1e-9) + 30.0;
        loop {
            let mut events = self.generate_horizon(horizon);
            events.sort_by(|a, b| {
                a.0.arrival
                    .total_cmp(&b.0.arrival)
                    .then(a.1.client.cmp(&b.1.client))
                    .then(a.1.session.cmp(&b.1.session))
                    .then(a.1.turn.cmp(&b.1.turn))
            });
            if events.len() >= n && events[n - 1].0.arrival <= horizon {
                events.truncate(n);
                let mut reqs = Vec::with_capacity(n);
                let mut meta = Vec::with_capacity(n);
                for (id, (mut r, m)) in events.into_iter().enumerate() {
                    r.id = id as u64;
                    reqs.push(r);
                    meta.push(m);
                }
                return (reqs, meta);
            }
            horizon *= 2.0;
            assert!(
                horizon < 1e9,
                "population cannot produce {n} requests (offered rate too low)"
            );
        }
    }

    /// Every request from every client whose session starts within
    /// `horizon` (turns may arrive later; the caller filters by sort).
    fn generate_horizon(&self, horizon: f64) -> Vec<(Request, ReqMeta)> {
        let mut master = Rng::new(self.seed);
        let mut out = Vec::new();
        for client in 0..self.spec.clients {
            let mut rng = master.split();
            let x = (client as f64 + 0.5) / self.spec.clients as f64;
            let cat = category_at(x, &self.spec.category_weights);
            let cp = self.spec.params_for(cat);
            self.client_stream(&mut rng, client, cat, cp, horizon, &mut out);
        }
        out
    }

    fn client_stream(
        &self,
        rng: &mut Rng,
        client: u32,
        cat: Category,
        cp: &CategoryParams,
        horizon: f64,
        out: &mut Vec<(Request, ReqMeta)>,
    ) {
        let mut session_idx: u32 = 0;
        match &cp.arrival {
            ArrivalProcess::ClosedLoop { think_mean_s } => {
                // Stagger the first session; afterwards each session
                // starts a think after the previous one would finish in
                // isolation. Self-clocked: diurnal does not apply.
                let mut t = rng.exponential(1.0 / think_mean_s.max(1e-9));
                while t <= horizon {
                    let end = self.emit_session(rng, client, cat, cp, t, session_idx, out);
                    session_idx += 1;
                    t = end
                        + crate::workload::session::lognormal_with_mean(
                            rng,
                            *think_mean_s,
                            cp.session.think_sigma,
                        );
                }
            }
            ArrivalProcess::Poisson { rate } => {
                let mut t = next_open_arrival(rng, 0.0, *rate, &self.spec.diurnal, None);
                while t <= horizon {
                    self.emit_session(rng, client, cat, cp, t, session_idx, out);
                    session_idx += 1;
                    t = next_open_arrival(rng, t, *rate, &self.spec.diurnal, None);
                }
            }
            ArrivalProcess::Mmpp { rate_on, rate_off, mean_on_s, mean_off_s } => {
                let mut phases = MmppPhases::init(rng, *mean_on_s, *mean_off_s);
                let mut t = 0.0;
                loop {
                    let base = BurstRates { on: *rate_on, off: *rate_off };
                    t = next_open_arrival(
                        rng,
                        t,
                        base.on.max(base.off),
                        &self.spec.diurnal,
                        Some((&mut phases, base)),
                    );
                    if t > horizon {
                        break;
                    }
                    self.emit_session(rng, client, cat, cp, t, session_idx, out);
                    session_idx += 1;
                }
            }
        }
    }

    /// Emit one session's turns; returns the virtual time at which its
    /// last turn would complete in isolation (closed-loop pacing).
    fn emit_session(
        &self,
        rng: &mut Rng,
        client: u32,
        cat: Category,
        cp: &CategoryParams,
        start: f64,
        session_idx: u32,
        out: &mut Vec<(Request, ReqMeta)>,
    ) -> f64 {
        let mix = self.spec.mix_at(start);
        let weights = [mix.text, mix.image, mix.video];
        let modality = Modality::ALL[rng.categorical(&weights)];
        let turns = sample_session(rng, &self.profile, &self.params, &cp.session, modality, start);
        let mut end = start;
        for t in &turns {
            let mut req = t.req.clone();
            req.slo_class = Some(cp.slo_class);
            end = req.arrival + self.profile.isolated_e2e(&req);
            out.push((req, ReqMeta { client, category: cat, session: session_idx, turn: t.turn }));
        }
        end
    }
}

/// On/off session rates of an MMPP client, bundled for the shared
/// arrival loop.
#[derive(Debug, Clone, Copy)]
struct BurstRates {
    on: f64,
    off: f64,
}

/// Draw the next arrival of a piecewise-constant-rate Poisson process
/// starting from `t`. The rate is `base × diurnal(t)` where `base` is
/// the flat rate (no phases) or the current MMPP phase rate. Exact — no
/// thinning: by memorylessness the gap is simply redrawn at every rate
/// boundary (phase flips and diurnal segment changes).
fn next_open_arrival(
    rng: &mut Rng,
    t0: f64,
    flat_rate: f64,
    diurnal: &DiurnalCurve,
    mut phases: Option<(&mut MmppPhases, BurstRates)>,
) -> f64 {
    let mut t = t0;
    // Backstop against a pathological all-zero-rate spin; validated
    // configs always make progress (some multiplier is positive).
    for _ in 0..2_000_000 {
        let (rate, window_end) = match &phases {
            Some((p, rates)) => {
                let r = if p.on { rates.on } else { rates.off };
                (r, p.phase_end_s)
            }
            None => (flat_rate, f64::INFINITY),
        };
        let r = rate * diurnal.multiplier(t);
        let boundary = window_end.min(diurnal.next_boundary(t));
        if r > 0.0 {
            let gap = rng.exponential(r);
            if t + gap <= boundary {
                return t + gap;
            }
        } else if boundary.is_infinite() {
            // Rate is zero forever: this client never fires again.
            return f64::INFINITY;
        }
        t = boundary;
        if let Some((p, _)) = &mut phases {
            if boundary >= p.phase_end_s {
                p.flip(rng);
            }
        }
    }
    f64::INFINITY
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    fn spec(mix: Mix, rate: f64) -> WorkloadSpec {
        WorkloadSpec::from_config(&WorkloadConfig::default(), mix, rate)
    }

    fn population(mix: Mix, rate: f64, seed: u64) -> PopulationGen {
        PopulationGen::new(&by_name("llava-7b").unwrap(), spec(mix, rate), seed)
    }

    #[test]
    fn generates_requested_count_in_arrival_order() {
        let (reqs, meta) = population(crate::workload::MIX_MH, 3.0, 1).generate_with_meta(300);
        assert_eq!(reqs.len(), 300);
        assert_eq!(meta.len(), 300);
        for (i, r) in reqs.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
        for w in reqs.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
    }

    #[test]
    fn prefix_stability_under_count() {
        // A longer generation extends a shorter one bit-for-bit — the
        // horizon-doubling loop may settle on different horizons, so
        // this is the non-trivial determinism property.
        let (a, _) = population(crate::workload::MIX_MH, 3.0, 7).generate_with_meta(120);
        let (b, _) = population(crate::workload::MIX_MH, 3.0, 7).generate_with_meta(480);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.modality, y.modality);
            assert_eq!(x.text_tokens, y.text_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
    }

    #[test]
    fn all_three_categories_present_with_slo_tiers() {
        let (reqs, meta) = population(crate::workload::MIX_MH, 3.0, 2).generate_with_meta(400);
        for cat in Category::ALL {
            assert!(meta.iter().any(|m| m.category == cat), "missing {cat}");
        }
        for (r, m) in reqs.iter().zip(&meta) {
            let expected = match m.category {
                Category::Chat => SloClass::Critical,
                Category::Agent => SloClass::Standard,
                Category::Batch => SloClass::BestEffort,
            };
            assert_eq!(r.slo_class, Some(expected));
        }
        // batch is single-turn by construction
        assert!(meta
            .iter()
            .filter(|m| m.category == Category::Batch)
            .all(|m| m.turn == 0));
    }

    #[test]
    fn aggregate_rate_near_target() {
        let (reqs, _) = population(crate::workload::MIX_ML, 4.0, 3).generate_with_meta(2000);
        let span = reqs.last().unwrap().arrival - reqs[0].arrival;
        let rate = reqs.len() as f64 / span;
        // The agent share is closed-loop (emergent rate), so the band
        // is deliberately wide; this guards calibration blunders, not
        // precision.
        assert!(rate > 4.0 * 0.4 && rate < 4.0 * 2.5, "rate={rate}");
    }

    #[test]
    fn mix_flip_changes_modality_composition() {
        let mut s = spec(crate::workload::MIX_VH, 3.0);
        s.mix_flip = Some((60.0, crate::workload::MIX_T0));
        let p = PopulationGen::new(&by_name("llava-7b").unwrap(), s, 11);
        let (reqs, _) = p.generate_with_meta(600);
        let video_after: Vec<&Request> = reqs
            .iter()
            .filter(|r| r.arrival > 80.0 && r.modality == Modality::Video)
            .collect();
        let after: usize = reqs.iter().filter(|r| r.arrival > 80.0).count();
        assert!(after > 0, "flip window empty");
        // Sessions that *started* before the flip may still emit video
        // turns after it, so allow a small residue.
        let frac = video_after.len() as f64 / after as f64;
        assert!(frac < 0.20, "video fraction after flip = {frac}");
    }

    #[test]
    fn diurnal_curve_multiplier_and_boundaries() {
        let c = DiurnalCurve { points: vec![(0.0, 1.0), (100.0, 3.0)], period_s: 200.0 };
        assert_eq!(c.multiplier(10.0), 1.0);
        assert_eq!(c.multiplier(150.0), 3.0);
        assert_eq!(c.multiplier(210.0), 1.0); // wrapped
        assert_eq!(c.next_boundary(10.0), 100.0);
        assert_eq!(c.next_boundary(150.0), 200.0);
        let flat = DiurnalCurve::flat();
        assert_eq!(flat.multiplier(1e6), 1.0);
        assert!(flat.next_boundary(0.0).is_infinite());
    }

    #[test]
    fn diurnal_quiet_hours_shift_open_loop_arrivals() {
        // quiet first 100 s at 0.1x, busy at 3x afterwards, no wrap
        let mut s = spec(crate::workload::MIX_T0, 4.0);
        s.diurnal = DiurnalCurve { points: vec![(0.0, 0.1), (100.0, 3.0)], period_s: 0.0 };
        let p = PopulationGen::new(&by_name("llava-7b").unwrap(), s, 5);
        let (reqs, meta) = p.generate_with_meta(800);
        // open-loop categories only (closed-loop ignores the curve)
        let open: Vec<f64> = reqs
            .iter()
            .zip(&meta)
            .filter(|(_, m)| m.category != Category::Agent)
            .map(|(r, _)| r.arrival)
            .collect();
        let quiet = open.iter().filter(|&&a| a < 100.0).count() as f64;
        let busy = open.iter().filter(|&&a| (100.0..200.0).contains(&a)).count() as f64;
        assert!(busy > 4.0 * quiet.max(1.0), "quiet={quiet} busy={busy}");
    }

    #[test]
    fn mmpp_phases_match_duty_cycle() {
        let mut rng = Rng::new(9);
        let mut p = MmppPhases::init(&mut rng, 20.0, 60.0); // duty 0.25
        let horizon = 200_000.0;
        let mut on_time = 0.0;
        let mut t = 0.0;
        while t < horizon {
            let end = p.phase_end_s.min(horizon);
            if p.on {
                on_time += end - t;
            }
            t = end;
            if p.phase_end_s <= horizon {
                p.flip(&mut rng);
            }
        }
        let frac = on_time / horizon;
        assert!((frac - 0.25).abs() < 0.02, "on fraction = {frac}");
    }

    #[test]
    fn deterministic_per_seed_and_sensitive_to_seed() {
        let (a, am) = population(crate::workload::MIX_MH, 3.0, 21).generate_with_meta(250);
        let (b, bm) = population(crate::workload::MIX_MH, 3.0, 21).generate_with_meta(250);
        assert_eq!(am, bm);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival.to_bits(), y.arrival.to_bits());
            assert_eq!(x.text_tokens, y.text_tokens);
            assert_eq!(x.mm_tokens, y.mm_tokens);
            assert_eq!(x.output_tokens, y.output_tokens);
        }
        let (c, _) = population(crate::workload::MIX_MH, 3.0, 22).generate_with_meta(250);
        assert!(a.iter().zip(&c).any(|(x, y)| x.arrival.to_bits() != y.arrival.to_bits()));
    }
}
