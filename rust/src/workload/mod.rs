//! Workload synthesis: the datasets and traffic mixes of the paper's §4.1,
//! calibrated to the Fig-2 characterization.
//!
//! * ShareGPT analogue — text prompts, log-uniform 10..10^4 tokens;
//! * LLaVA-Instruct analogue — one image per request, short question;
//! * LLaVA-Video analogue — one video per request, lognormal duration;
//! * Poisson arrivals at a configurable rate;
//! * mixes T0 (text-only), ML (light multimodal), MH (heavy multimodal),
//!   VH (video-heavy — the encoder-pool stress case).
//!
//! Two arrival engines share those marginals:
//!
//! * [`WorkloadGen`] — the original open-loop i.i.d. Poisson generator;
//! * [`PopulationGen`] — the ServeGen-grade client population
//!   ([`population`]): per-client MMPP / closed-loop / Poisson
//!   processes, diurnal curves, multi-turn [`session`]s with growing
//!   context and re-attached media, and chat/agent/batch categories
//!   mapped onto SLO tiers.
//!
//! [`trace`] persists either engine's output (format v2 carries the
//! lifecycle fields) and [`scale_trace`] replays a trace at k× rate.

pub mod generator;
pub mod population;
pub mod session;
pub mod trace;

pub use generator::{Mix, WorkloadGen, MIX_MH, MIX_ML, MIX_T0, MIX_VH};
pub use population::{
    ArrivalProcess, Category, CategoryParams, DiurnalCurve, MmppPhases, PopulationGen, ReqMeta,
    WorkloadSpec,
};
pub use session::{sample_session, SessionParams, TurnReq};
pub use trace::{load_trace, save_trace, scale_trace};
