//! Workload synthesis: the datasets and traffic mixes of the paper's §4.1,
//! calibrated to the Fig-2 characterization.
//!
//! * ShareGPT analogue — text prompts, log-uniform 10..10^4 tokens;
//! * LLaVA-Instruct analogue — one image per request, short question;
//! * LLaVA-Video analogue — one video per request, lognormal duration;
//! * Poisson arrivals at a configurable rate;
//! * mixes T0 (text-only), ML (light multimodal), MH (heavy multimodal),
//!   VH (video-heavy — the encoder-pool stress case).

pub mod generator;
pub mod trace;

pub use generator::{Mix, WorkloadGen, MIX_MH, MIX_ML, MIX_T0, MIX_VH};
pub use trace::{load_trace, save_trace};
