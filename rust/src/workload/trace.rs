//! Trace persistence: save/replay request traces as a simple line format.
//!
//! Enables (a) byte-identical comparisons between schedulers on the same
//! arrival sequence, and (b) replaying externally produced traces (e.g.
//! ServeGen-style production characterizations) through the coordinator.
//!
//! Format v2 (one request per line, `#` comments):
//!   id arrival modality text_tokens mm_tokens video_dur_s output_tokens
//!   deadline_s slo_class
//! where `deadline_s` is a float or `-` (none) and `slo_class` is
//! `critical` | `standard` | `best-effort` | `-` (none). Floats are
//! written with Rust's shortest-roundtrip `Display`, so save → load is
//! exact (`==` on every field) — the old `{:.6}`/`{:.3}` fixed-point
//! formatting truncated arrivals and durations, which broke bit-identity
//! between a generated trace and its replay.
//!
//! v1 lines (the same first 7 fields, no lifecycle columns) still load,
//! with `deadline_s`/`slo_class` defaulting to `None`. v1 *saved* traces
//! silently dropped both fields, which erased every SLO from a
//! deadline-mix trace on replay — the v2 columns fix that.
//!
//! Loaded requests pass through [`Request::sanitize`]: a trace file is an
//! untrusted input, and a hand-edited NaN arrival must degrade to a
//! servable request rather than poison virtual time.

use crate::request::{Modality, Request, SloClass};
use std::io::{BufRead, Write};
use std::path::Path;

pub fn save_trace(path: &Path, reqs: &[Request]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# tcm-trace v2")?;
    writeln!(
        f,
        "# id arrival modality text_tokens mm_tokens video_dur_s output_tokens \
         deadline_s slo_class"
    )?;
    for r in reqs {
        let deadline = match r.deadline_s {
            Some(d) => d.to_string(),
            None => "-".into(),
        };
        let slo = match r.slo_class {
            Some(c) => c.name(),
            None => "-",
        };
        writeln!(
            f,
            "{} {} {} {} {} {} {} {} {}",
            r.id,
            r.arrival,
            r.modality,
            r.text_tokens,
            r.mm_tokens,
            r.video_duration_s,
            r.output_tokens,
            deadline,
            slo
        )?;
    }
    Ok(())
}

pub fn load_trace(path: &Path) -> std::io::Result<Vec<Request>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let err = |msg: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("trace line {}: {msg}: '{line}'", lineno + 1),
            )
        };
        if fields.len() != 7 && fields.len() != 9 {
            return Err(err("expected 7 (v1) or 9 (v2) fields"));
        }
        let modality = match fields[2] {
            "text" => Modality::Text,
            "image" => Modality::Image,
            "video" => Modality::Video,
            _ => return Err(err("bad modality")),
        };
        let deadline_s = match fields.get(7) {
            None | Some(&"-") => None,
            Some(s) => Some(s.parse().map_err(|_| err("bad deadline_s"))?),
        };
        let slo_class = match fields.get(8) {
            None | Some(&"-") => None,
            Some(s) => Some(SloClass::by_name(s).ok_or_else(|| err("bad slo_class"))?),
        };
        let req = Request {
            id: fields[0].parse().map_err(|_| err("bad id"))?,
            arrival: fields[1].parse().map_err(|_| err("bad arrival"))?,
            modality,
            text_tokens: fields[3].parse().map_err(|_| err("bad text_tokens"))?,
            mm_tokens: fields[4].parse().map_err(|_| err("bad mm_tokens"))?,
            video_duration_s: fields[5].parse().map_err(|_| err("bad video_dur"))?,
            output_tokens: fields[6].parse().map_err(|_| err("bad output_tokens"))?,
            deadline_s,
            slo_class,
        };
        out.push(req.sanitize());
    }
    Ok(out)
}

/// Replay a recorded trace at `k`× rate: tile `k` time-shifted copies of
/// the trace end-to-end, then compress time by `k`. The result offers
/// `k`× the request count at `k`× the arrival rate with the *same*
/// per-copy request shapes, so modality mix and relative order within
/// each copy are preserved exactly (time compression is monotone).
///
/// Id remapping is stable: copy `c` of original id `i` becomes
/// `c * (max_id + 1) + i` — rerunning with the same inputs yields the
/// same ids, and copy 0 keeps the original ids. Copies are separated by
/// one mean inter-arrival gap so the seam does not stack arrivals.
/// `k = 1` returns the trace unchanged (modulo the global arrival sort).
pub fn scale_trace(trace: &[Request], k: usize) -> Vec<Request> {
    if trace.is_empty() {
        return Vec::new();
    }
    let max_arrival = trace.iter().map(|r| r.arrival).fold(0.0_f64, f64::max);
    let max_id = trace.iter().map(|r| r.id).max().unwrap_or(0);
    let stride = max_id + 1;
    let period = max_arrival + max_arrival / trace.len() as f64;
    let kf = k as f64;
    let mut out = Vec::with_capacity(trace.len() * k);
    for c in 0..k as u64 {
        for r in trace {
            let mut r2 = r.clone();
            r2.arrival = (r.arrival + c as f64 * period) / kf;
            r2.id = c * stride + r.id;
            out.push(r2);
        }
    }
    out.sort_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.id.cmp(&b.id)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::workload::{WorkloadGen, MIX_MH};

    fn assert_exact(a: &Request, b: &Request) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.modality, b.modality);
        assert_eq!(a.text_tokens, b.text_tokens);
        assert_eq!(a.mm_tokens, b.mm_tokens);
        assert_eq!(a.output_tokens, b.output_tokens);
        // bitwise — shortest-roundtrip formatting guarantees exactness
        assert_eq!(a.arrival.to_bits(), b.arrival.to_bits(), "id={}", a.id);
        assert_eq!(a.video_duration_s.to_bits(), b.video_duration_s.to_bits());
        assert_eq!(a.deadline_s, b.deadline_s);
        assert_eq!(a.slo_class, b.slo_class);
    }

    #[test]
    fn roundtrip_is_exact_including_lifecycle_fields() {
        let dir = std::env::temp_dir().join("tcm_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let mut reqs =
            WorkloadGen::new(&by_name("llava-7b").unwrap(), MIX_MH, 2.0, 1).generate(200);
        // decorate with the fig_lifecycle deadline/SLO mix so the
        // lifecycle columns are non-vacuous
        for r in reqs.iter_mut() {
            if r.id % 3 == 0 {
                r.slo_class = Some(SloClass::Critical);
                r.deadline_s = Some(2.5 + r.id as f64 * 0.125);
            } else if r.id % 5 == 0 {
                r.slo_class = Some(SloClass::BestEffort);
            }
        }
        save_trace(&path, &reqs).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(loaded.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&loaded) {
            assert_exact(a, b);
        }
        assert!(loaded.iter().any(|r| r.slo_class == Some(SloClass::Critical)));
        assert!(loaded.iter().any(|r| r.deadline_s.is_some()));
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn v1_seven_field_lines_still_load() {
        let dir = std::env::temp_dir().join("tcm_trace_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("v1.trace");
        std::fs::write(
            &path,
            "# id arrival modality text_tokens mm_tokens video_dur_s output_tokens\n\
             0 0.125 text 40 0 0.000 99\n\
             1 1.500 video 20 5000 60.000 17\n",
        )
        .unwrap();
        let t = load_trace(&path).unwrap();
        assert_eq!(t.len(), 2);
        assert_eq!(t[0].deadline_s, None);
        assert_eq!(t[0].slo_class, None);
        assert_eq!(t[1].modality, Modality::Video);
        assert_eq!(t[1].video_duration_s, 60.0);
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("tcm_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.trace");
        std::fs::write(&path, "1 0.0 text 10\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(&path, "1 0.0 hologram 10 0 0 5\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(&path, "1 0.0 text 10 0 0 5 - platinum\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(&path, "1 0.0 text 10 0 0 5 soon -\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn hostile_floats_pass_through_sanitize() {
        // A trace file is untrusted input: NaN/inf floats must degrade
        // per `Request::sanitize`, not leak into virtual time.
        let dir = std::env::temp_dir().join("tcm_trace_hostile");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("h.trace");
        std::fs::write(
            &path,
            "0 NaN text 10 0 0 5 - -\n\
             1 2.5 video 10 5000 inf 5 - -\n\
             2 3.0 text 10 0 0 5 -inf critical\n",
        )
        .unwrap();
        let t = load_trace(&path).unwrap();
        assert_eq!(t[0].arrival, 0.0);
        assert_eq!(t[1].video_duration_s, 0.0);
        assert_eq!(t[2].deadline_s, None);
        assert_eq!(t[2].slo_class, Some(SloClass::Critical));
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn scale_trace_preserves_order_mix_and_copy0_bits() {
        let reqs =
            WorkloadGen::new(&by_name("llava-7b").unwrap(), MIX_MH, 2.0, 3).generate(150);
        let scaled = scale_trace(&reqs, 4);
        assert_eq!(scaled.len(), reqs.len() * 4);
        // arrivals sorted, ids stable per copy
        for w in scaled.windows(2) {
            assert!(w[1].arrival >= w[0].arrival);
        }
        // copy 0 keeps original ids with arrivals exactly divided by k
        for r in &reqs {
            let copy0 = scaled.iter().find(|s| s.id == r.id).unwrap();
            assert_eq!(copy0.arrival.to_bits(), (r.arrival / 4.0).to_bits());
            assert_eq!(copy0.text_tokens, r.text_tokens);
        }
        // modality mix is exactly k× the original
        for m in crate::request::Modality::ALL {
            let orig = reqs.iter().filter(|r| r.modality == m).count();
            let got = scaled.iter().filter(|r| r.modality == m).count();
            assert_eq!(got, orig * 4, "{m}");
        }
        // ~4× the arrival rate over the same shape of time
        let span = scaled.last().unwrap().arrival;
        let orig_span = reqs.last().unwrap().arrival;
        assert!(span < orig_span * 1.3, "span={span} orig={orig_span}");
        // k = 1 is the identity (post-sort)
        let same = scale_trace(&reqs, 1);
        for (a, b) in reqs.iter().zip(&same) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.arrival.to_bits(), b.arrival.to_bits());
        }
        assert!(scale_trace(&[], 3).is_empty());
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("tcm_trace_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.trace");
        std::fs::write(&path, "# header\n\n5 1.5 video 20 5000 60.0 99\n").unwrap();
        let t = load_trace(&path).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].id, 5);
        assert_eq!(t[0].modality, Modality::Video);
        std::fs::remove_file(path).unwrap();
    }
}
