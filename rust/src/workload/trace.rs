//! Trace persistence: save/replay request traces as a simple line format.
//!
//! Enables (a) byte-identical comparisons between schedulers on the same
//! arrival sequence, and (b) replaying externally produced traces (e.g.
//! ServeGen-style production characterizations) through the coordinator.
//!
//! Format (one request per line, `#` comments):
//!   id arrival modality text_tokens mm_tokens video_dur_s output_tokens

use crate::request::{Modality, Request};
use std::io::{BufRead, Write};
use std::path::Path;

pub fn save_trace(path: &Path, reqs: &[Request]) -> std::io::Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "# id arrival modality text_tokens mm_tokens video_dur_s output_tokens")?;
    for r in reqs {
        writeln!(
            f,
            "{} {:.6} {} {} {} {:.3} {}",
            r.id, r.arrival, r.modality, r.text_tokens, r.mm_tokens, r.video_duration_s,
            r.output_tokens
        )?;
    }
    Ok(())
}

pub fn load_trace(path: &Path) -> std::io::Result<Vec<Request>> {
    let f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut out = Vec::new();
    for (lineno, line) in f.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let fields: Vec<&str> = line.split_whitespace().collect();
        let err = |msg: &str| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("trace line {}: {msg}: '{line}'", lineno + 1),
            )
        };
        if fields.len() != 7 {
            return Err(err("expected 7 fields"));
        }
        let modality = match fields[2] {
            "text" => Modality::Text,
            "image" => Modality::Image,
            "video" => Modality::Video,
            _ => return Err(err("bad modality")),
        };
        out.push(Request {
            id: fields[0].parse().map_err(|_| err("bad id"))?,
            arrival: fields[1].parse().map_err(|_| err("bad arrival"))?,
            modality,
            text_tokens: fields[3].parse().map_err(|_| err("bad text_tokens"))?,
            mm_tokens: fields[4].parse().map_err(|_| err("bad mm_tokens"))?,
            video_duration_s: fields[5].parse().map_err(|_| err("bad video_dur"))?,
            output_tokens: fields[6].parse().map_err(|_| err("bad output_tokens"))?,
            ..Request::default()
        });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::workload::{WorkloadGen, MIX_MH};

    #[test]
    fn roundtrip() {
        let dir = std::env::temp_dir().join("tcm_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let reqs =
            WorkloadGen::new(&by_name("llava-7b").unwrap(), MIX_MH, 2.0, 1).generate(200);
        save_trace(&path, &reqs).unwrap();
        let loaded = load_trace(&path).unwrap();
        assert_eq!(loaded.len(), reqs.len());
        for (a, b) in reqs.iter().zip(&loaded) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.modality, b.modality);
            assert_eq!(a.text_tokens, b.text_tokens);
            assert_eq!(a.mm_tokens, b.mm_tokens);
            assert_eq!(a.output_tokens, b.output_tokens);
            assert!((a.arrival - b.arrival).abs() < 1e-5);
        }
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn rejects_malformed() {
        let dir = std::env::temp_dir().join("tcm_trace_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.trace");
        std::fs::write(&path, "1 0.0 text 10\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::write(&path, "1 0.0 hologram 10 0 0 5\n").unwrap();
        assert!(load_trace(&path).is_err());
        std::fs::remove_file(path).unwrap();
    }

    #[test]
    fn skips_comments_and_blanks() {
        let dir = std::env::temp_dir().join("tcm_trace_test3");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("c.trace");
        std::fs::write(&path, "# header\n\n5 1.5 video 20 5000 60.0 99\n").unwrap();
        let t = load_trace(&path).unwrap();
        assert_eq!(t.len(), 1);
        assert_eq!(t[0].id, 5);
        assert_eq!(t[0].modality, Modality::Video);
        std::fs::remove_file(path).unwrap();
    }
}
