//! Multi-turn session model for the client-population engine.
//!
//! ServeGen's characterization (PAPERS.md) shows production MLLM traffic
//! is dominated by *sessions*, not independent requests: a client asks a
//! question about an image or video, reads the answer, and asks a
//! follow-up — against the same attachment, with the conversation so far
//! prepended to the prompt. Two properties matter for scheduling:
//!
//! * **Context grows turn-over-turn** — each follow-up carries the prior
//!   prompt + response as context, so `text_tokens` ratchets upward and
//!   late turns of a chat session are much heavier than its first.
//! * **The attachment is re-sent** — the same image/video (drawn once
//!   per session) re-attaches on every turn, so a video session is a
//!   *stream* of rocks, not one.
//!
//! Virtual time only; every draw comes from the caller's seeded [`Rng`].

use crate::model::ModelProfile;
use crate::request::{Modality, Request};
use crate::util::rng::Rng;
use crate::workload::generator::{self, DatasetParams};

/// Parameters of the multi-turn session model (one instance per client
/// category — chat, agent, batch).
#[derive(Debug, Clone, PartialEq)]
pub struct SessionParams {
    /// Probability the session continues after each turn (geometric
    /// session length; mean turns = 1/(1-p), truncated by `max_turns`).
    pub continue_p: f64,
    /// Hard cap on turns per session (keeps the carried context well
    /// below `context_cap`, preserving strict growth).
    pub max_turns: u32,
    /// Mean think time between a turn's completion and the follow-up, s.
    pub think_mean_s: f64,
    /// Lognormal sigma of the think-time distribution.
    pub think_sigma: f64,
    /// Fraction of (prompt + output) tokens carried into the next turn's
    /// context (1.0 = the full conversation is re-sent).
    pub context_carry: f64,
    /// Upper bound on carried context tokens.
    pub context_cap: u32,
}

impl Default for SessionParams {
    fn default() -> Self {
        SessionParams {
            continue_p: 2.0 / 3.0, // mean 3 turns
            max_turns: 12,
            think_mean_s: 4.0,
            think_sigma: 0.6,
            context_carry: 1.0,
            context_cap: 65_536,
        }
    }
}

/// One turn of a sampled session. `req.id` and `req.slo_class` are left
/// at their defaults — the population engine assigns both after the
/// global arrival sort.
#[derive(Debug, Clone)]
pub struct TurnReq {
    pub req: Request,
    pub turn: u32,
}

/// Lognormal draw parameterized by its *mean* (not the underlying
/// normal's mu): mu = ln(mean) - sigma^2/2.
pub(crate) fn lognormal_with_mean(rng: &mut Rng, mean: f64, sigma: f64) -> f64 {
    rng.lognormal(mean.max(1e-3).ln() - 0.5 * sigma * sigma, sigma)
}

/// Sample one complete session: the attachment is drawn once and
/// re-attached on every turn; each follow-up arrives after the previous
/// turn's isolated service time plus a think-time draw; carried context
/// makes `text_tokens` strictly grow across turns (for `context_carry`
/// = 1.0, since every turn adds a question and an answer).
///
/// Arrivals within the session are strictly increasing (service and
/// think draws are strictly positive).
pub fn sample_session(
    rng: &mut Rng,
    profile: &ModelProfile,
    params: &DatasetParams,
    sp: &SessionParams,
    modality: Modality,
    start: f64,
) -> Vec<TurnReq> {
    let (mm_tokens, video_duration_s) =
        generator::draw_attachment(rng, profile, params, modality);
    let mut out = Vec::new();
    let mut arrival = start;
    let mut carried: u32 = 0;
    let max_turns = sp.max_turns.max(1);
    for turn in 0..max_turns {
        let output_tokens = generator::draw_output_tokens(rng, params);
        // Turn 0 of a text session draws from the full Fig-2a prompt
        // band; every follow-up (any modality) is a short question on
        // top of the carried context.
        let question = if turn == 0 && modality == Modality::Text {
            generator::draw_text_tokens(rng, params)
        } else {
            generator::draw_question_tokens(rng, params)
        };
        let text_tokens = question.saturating_add(carried);
        let req = Request {
            arrival,
            modality,
            text_tokens,
            mm_tokens,
            video_duration_s,
            output_tokens,
            ..Request::default()
        };
        let service = profile.isolated_e2e(&req);
        out.push(TurnReq { req, turn });
        if turn + 1 >= max_turns || !rng.bool(sp.continue_p) {
            break;
        }
        carried = (((text_tokens.saturating_add(output_tokens)) as f64) * sp.context_carry)
            .min(sp.context_cap as f64) as u32;
        arrival += service + lognormal_with_mean(rng, sp.think_mean_s, sp.think_sigma);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;

    fn sample(modality: Modality, seed: u64) -> Vec<TurnReq> {
        let profile = by_name("llava-7b").unwrap();
        let mut rng = Rng::new(seed);
        sample_session(
            &mut rng,
            &profile,
            &DatasetParams::default(),
            &SessionParams::default(),
            modality,
            10.0,
        )
    }

    #[test]
    fn single_turn_possible_and_bounded() {
        for seed in 0..50 {
            let s = sample(Modality::Text, seed);
            assert!(!s.is_empty());
            assert!(s.len() <= SessionParams::default().max_turns as usize);
            for (i, t) in s.iter().enumerate() {
                assert_eq!(t.turn as usize, i);
            }
        }
    }

    #[test]
    fn arrivals_and_context_strictly_increase() {
        for seed in 0..50 {
            for m in [Modality::Text, Modality::Image, Modality::Video] {
                let s = sample(m, seed);
                for w in s.windows(2) {
                    assert!(w[1].req.arrival > w[0].req.arrival);
                    assert!(
                        w[1].req.text_tokens > w[0].req.text_tokens,
                        "context must grow: {} then {}",
                        w[0].req.text_tokens,
                        w[1].req.text_tokens
                    );
                }
            }
        }
    }

    #[test]
    fn attachment_is_reattached_every_turn() {
        let mut seen_multi = false;
        for seed in 0..80 {
            let s = sample(Modality::Video, seed);
            let first = &s[0].req;
            assert!(first.mm_tokens > 0);
            for t in &s {
                assert_eq!(t.req.mm_tokens, first.mm_tokens);
                assert_eq!(t.req.video_duration_s.to_bits(), first.video_duration_s.to_bits());
            }
            seen_multi |= s.len() >= 3;
        }
        assert!(seen_multi, "no session reached 3 turns — test is vacuous");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = sample(Modality::Image, 7);
        let b = sample(Modality::Image, 7);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.req.arrival.to_bits(), y.req.arrival.to_bits());
            assert_eq!(x.req.text_tokens, y.req.text_tokens);
            assert_eq!(x.req.output_tokens, y.req.output_tokens);
        }
    }
}
