//! Synthetic request generation calibrated to the paper's characterization.

use crate::model::ModelProfile;
use crate::request::{Modality, Request};
use crate::util::rng::Rng;

/// A workload mix: fraction of text/image/video requests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Mix {
    pub name: &'static str,
    pub text: f64,
    pub image: f64,
    pub video: f64,
}

/// Traditional text-only workload.
pub const MIX_T0: Mix = Mix { name: "T0", text: 1.0, image: 0.0, video: 0.0 };
/// Light multimodal mix: "a small fraction of image and video requests".
pub const MIX_ML: Mix = Mix { name: "ML", text: 0.90, image: 0.07, video: 0.03 };
/// Heavy multimodal mix: "significantly increases their share".
pub const MIX_MH: Mix = Mix { name: "MH", text: 0.55, image: 0.30, video: 0.15 };
/// Video-heavy mix: rocks dominate the offered work — the stress case
/// for encoder disaggregation (a per-replica encoder spends most of its
/// replica's engine time on video encodes under this mix).
pub const MIX_VH: Mix = Mix { name: "VH", text: 0.40, image: 0.20, video: 0.40 };

impl Mix {
    pub fn by_name(name: &str) -> Option<Mix> {
        match name.to_ascii_uppercase().as_str() {
            "T0" => Some(MIX_T0),
            "ML" => Some(MIX_ML),
            "MH" => Some(MIX_MH),
            "VH" => Some(MIX_VH),
            _ => None,
        }
    }
}

/// Dataset-marginal parameters (the ShareGPT / LLaVA-Instruct /
/// LLaVA-Video analogues). One instance is shared by all models; vision
/// token counts additionally depend on the model's tokenizer.
#[derive(Debug, Clone)]
pub struct DatasetParams {
    /// Text prompt tokens: log-uniform [min, max] (Fig 2a text CDF).
    pub text_tokens_min: f64,
    pub text_tokens_max: f64,
    /// Accompanying question length for image/video requests.
    pub mm_question_tokens_min: f64,
    pub mm_question_tokens_max: f64,
    /// Video duration: lognormal (mu, sigma) clipped to [min, max] secs.
    pub video_mu: f64,
    pub video_sigma: f64,
    pub video_min_s: f64,
    pub video_max_s: f64,
    /// Output tokens: lognormal (mu, sigma) clipped to [min, max].
    pub out_mu: f64,
    pub out_sigma: f64,
    pub out_min: f64,
    pub out_max: f64,
}

impl Default for DatasetParams {
    fn default() -> Self {
        DatasetParams {
            text_tokens_min: 10.0,
            text_tokens_max: 10_000.0,
            mm_question_tokens_min: 8.0,
            mm_question_tokens_max: 120.0,
            // median exp(3.8) ≈ 45 s, long tail to 10 min
            video_mu: 3.8,
            video_sigma: 0.8,
            video_min_s: 4.0,
            video_max_s: 600.0,
            // median exp(5.0) ≈ 150 output tokens
            out_mu: 5.0,
            out_sigma: 0.7,
            out_min: 8.0,
            out_max: 1024.0,
        }
    }
}

impl DatasetParams {
    /// Scaled-down marginals for the TinyMLLM real engine: prompts must
    /// fit the largest prefill bucket (512) and prompt+output must fit
    /// MAX_SEQ (640). Same distribution *shapes* as the default set.
    pub fn tiny() -> DatasetParams {
        DatasetParams {
            text_tokens_min: 8.0,
            text_tokens_max: 280.0,
            mm_question_tokens_min: 4.0,
            mm_question_tokens_max: 40.0,
            video_mu: 1.8, // median ≈ 6 s
            video_sigma: 0.5,
            video_min_s: 2.0,
            video_max_s: 12.0,
            out_mu: 3.2, // median ≈ 24 tokens
            out_sigma: 0.5,
            out_min: 4.0,
            out_max: 96.0,
        }
    }
}

/// Seeded workload generator for one (model, mix, rate) configuration.
pub struct WorkloadGen {
    rng: Rng,
    pub mix: Mix,
    pub rate: f64,
    pub params: DatasetParams,
    profile: ModelProfile,
    next_id: u64,
    clock: f64,
}

impl WorkloadGen {
    pub fn new(profile: &ModelProfile, mix: Mix, rate: f64, seed: u64) -> Self {
        let params = if profile.name == "tiny-mllm" {
            DatasetParams::tiny()
        } else {
            DatasetParams::default()
        };
        WorkloadGen {
            rng: Rng::new(seed),
            mix,
            rate,
            params,
            profile: profile.clone(),
            next_id: 0,
            clock: 0.0,
        }
    }

    /// Generate the next request with a Poisson inter-arrival gap.
    pub fn next_request(&mut self) -> Request {
        let next = self.clock + self.rng.exponential(self.rate);
        // `exponential` is strictly positive, but against a large enough
        // clock a tiny gap can still round away (clock + gap == clock);
        // bump one ulp so the strictly-increasing contract of `generate`
        // holds unconditionally.
        self.clock = if next > self.clock {
            next
        } else {
            f64::from_bits(self.clock.to_bits() + 1)
        };
        self.sample_at(self.clock)
    }

    /// Generate `n` requests (arrivals strictly increasing).
    pub fn generate(&mut self, n: usize) -> Vec<Request> {
        (0..n).map(|_| self.next_request()).collect()
    }

    /// Generate `n` requests of a fixed modality, all arriving at t=0
    /// (characterization-in-isolation workloads, §2.2).
    pub fn generate_isolated(&mut self, modality: Modality, n: usize) -> Vec<Request> {
        (0..n)
            .map(|_| {
                let mut r = self.sample_modality(modality, 0.0);
                r.arrival = 0.0;
                r
            })
            .collect()
    }

    fn sample_at(&mut self, arrival: f64) -> Request {
        let weights = [self.mix.text, self.mix.image, self.mix.video];
        let modality = Modality::ALL[self.rng.categorical(&weights)];
        self.sample_modality(modality, arrival)
    }

    fn sample_modality(&mut self, modality: Modality, arrival: f64) -> Request {
        let id = self.next_id;
        self.next_id += 1;
        let output_tokens = draw_output_tokens(&mut self.rng, &self.params);
        let (mm_tokens, video_duration_s) =
            draw_attachment(&mut self.rng, &self.profile, &self.params, modality);
        let text_tokens = match modality {
            Modality::Text => draw_text_tokens(&mut self.rng, &self.params),
            _ => draw_question_tokens(&mut self.rng, &self.params),
        };
        Request {
            id,
            arrival,
            modality,
            text_tokens,
            mm_tokens,
            video_duration_s,
            output_tokens,
            ..Request::default()
        }
    }
}

// Marginal draws shared by `WorkloadGen` and the client-population
// engine (`workload::session` / `workload::population`), factored out so
// both sample from identical distributions. The order callers invoke
// these in is load-bearing for bit-compatibility with pre-refactor
// traces: output tokens first, then the attachment, then the question.

/// Output-length marginal: clipped lognormal.
pub(crate) fn draw_output_tokens(rng: &mut Rng, p: &DatasetParams) -> u32 {
    rng.lognormal(p.out_mu, p.out_sigma).clamp(p.out_min, p.out_max) as u32
}

/// Text-prompt marginal: log-uniform over the full Fig-2a band.
pub(crate) fn draw_text_tokens(rng: &mut Rng, p: &DatasetParams) -> u32 {
    rng.log_uniform(p.text_tokens_min, p.text_tokens_max) as u32
}

/// Accompanying-question marginal for image/video requests (and
/// follow-up turns in multi-turn sessions): short log-uniform band.
pub(crate) fn draw_question_tokens(rng: &mut Rng, p: &DatasetParams) -> u32 {
    rng.log_uniform(p.mm_question_tokens_min, p.mm_question_tokens_max) as u32
}

/// Attachment marginal: `(mm_tokens, video_duration_s)` for one attached
/// image or video; `(0, 0.0)` for text, with no rng draw.
pub(crate) fn draw_attachment(
    rng: &mut Rng,
    profile: &ModelProfile,
    p: &DatasetParams,
    modality: Modality,
) -> (u32, f64) {
    match modality {
        Modality::Text => (0, 0.0),
        Modality::Image => {
            let tok = &profile.tokenizer;
            let mm = if tok.image_jitter > 0.0 {
                (tok.image_tokens * rng.lognormal(0.0, tok.image_jitter))
                    .clamp(tok.image_tokens * 0.3, tok.image_tokens * 3.5)
                    as u32
            } else {
                tok.image_tokens as u32
            };
            (mm, 0.0)
        }
        Modality::Video => {
            let dur = rng.lognormal(p.video_mu, p.video_sigma).clamp(p.video_min_s, p.video_max_s);
            (profile.tokenizer.video_tokens(dur), dur)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::by_name;
    use crate::util::stats;

    fn gen(mix: Mix, seed: u64) -> WorkloadGen {
        WorkloadGen::new(&by_name("llava-7b").unwrap(), mix, 2.0, seed)
    }

    #[test]
    fn arrivals_increase_at_poisson_rate() {
        let reqs = gen(MIX_MH, 1).generate(4000);
        for w in reqs.windows(2) {
            assert!(w[1].arrival > w[0].arrival);
        }
        let span = reqs.last().unwrap().arrival;
        let rate = reqs.len() as f64 / span;
        assert!((rate - 2.0).abs() < 0.1, "rate={rate}");
    }

    #[test]
    fn degenerate_gaps_cannot_stall_arrivals() {
        // Crafted seed: the first uniform draw is exactly 0.0 (see
        // util::rng) — the old unguarded `exponential` returned a 0.0 gap
        // here, duplicating arrival times.
        let crafted = 0u64.wrapping_sub(0x9E37_79B9_7F4A_7C15);
        let mut g = gen(MIX_T0, crafted);
        assert!(g.next_request().arrival > 0.0);
        // And even when a clamped-tiny gap rounds away against a large
        // clock (ulp(1e18) ≈ 128 s ≫ any exponential(2.0) draw), the ulp
        // bump keeps arrivals strictly increasing.
        let mut g = gen(MIX_MH, 1);
        g.clock = 1e18;
        let a = g.next_request().arrival;
        let b = g.next_request().arrival;
        assert!(a > 1e18, "a={a}");
        assert!(b > a, "a={a} b={b}");
    }

    #[test]
    fn mix_proportions_respected() {
        let reqs = gen(MIX_MH, 2).generate(20_000);
        let frac = |m: Modality| {
            reqs.iter().filter(|r| r.modality == m).count() as f64 / reqs.len() as f64
        };
        assert!((frac(Modality::Text) - 0.55).abs() < 0.02);
        assert!((frac(Modality::Image) - 0.30).abs() < 0.02);
        assert!((frac(Modality::Video) - 0.15).abs() < 0.02);
    }

    #[test]
    fn vh_mix_is_video_dominant_and_named() {
        assert_eq!(Mix::by_name("vh"), Some(MIX_VH));
        let reqs = gen(MIX_VH, 12).generate(20_000);
        let frac = |m: Modality| {
            reqs.iter().filter(|r| r.modality == m).count() as f64 / reqs.len() as f64
        };
        assert!((frac(Modality::Video) - 0.40).abs() < 0.02);
        assert!((frac(Modality::Text) - 0.40).abs() < 0.02);
    }

    #[test]
    fn t0_is_text_only() {
        let reqs = gen(MIX_T0, 3).generate(1000);
        assert!(reqs.iter().all(|r| r.modality == Modality::Text));
        assert!(reqs.iter().all(|r| r.mm_tokens == 0));
    }

    #[test]
    fn text_token_band_matches_fig2() {
        let reqs = gen(MIX_T0, 4).generate(5000);
        let toks: Vec<f64> = reqs.iter().map(|r| r.text_tokens as f64).collect();
        assert!(stats::min(&toks) >= 10.0);
        assert!(stats::max(&toks) <= 10_000.0);
        // spans ~3 orders of magnitude
        assert!(stats::percentile(&toks, 5.0) < 50.0);
        assert!(stats::percentile(&toks, 95.0) > 4_000.0);
    }

    #[test]
    fn image_tokens_near_constant_for_grid_models() {
        // "near-vertical line for image requests" (Fig 2a)
        let mut g = gen(MIX_MH, 5);
        let reqs = g.generate_isolated(Modality::Image, 1000);
        let mm: Vec<f64> = reqs.iter().map(|r| r.mm_tokens as f64).collect();
        assert_eq!(stats::min(&mm), stats::max(&mm));
        assert_eq!(stats::min(&mm), 729.0);
    }

    #[test]
    fn qwen_image_tokens_variable() {
        let p = by_name("qwen-7b").unwrap();
        let mut g = WorkloadGen::new(&p, MIX_MH, 2.0, 6);
        let reqs = g.generate_isolated(Modality::Image, 1000);
        let mm: Vec<f64> = reqs.iter().map(|r| r.mm_tokens as f64).collect();
        assert!(stats::std_dev(&mm) > 50.0);
    }

    #[test]
    fn video_tokens_orders_of_magnitude_above_text() {
        let p = by_name("qwen-7b").unwrap();
        let mut g = WorkloadGen::new(&p, MIX_MH, 2.0, 7);
        let vids = g.generate_isolated(Modality::Video, 2000);
        let mm: Vec<f64> = vids.iter().map(|r| r.mm_tokens as f64).collect();
        assert!(stats::percentile(&mm, 50.0) > 1_000.0);
        assert!(stats::max(&mm) > 100_000.0, "max={}", stats::max(&mm));
    }

    #[test]
    fn deterministic_per_seed() {
        let a = gen(MIX_MH, 9).generate(100);
        let b = gen(MIX_MH, 9).generate(100);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.modality, y.modality);
            assert_eq!(x.text_tokens, y.text_tokens);
            assert_eq!(x.mm_tokens, y.mm_tokens);
        }
        let c = gen(MIX_MH, 10).generate(100);
        assert!(a.iter().zip(&c).any(|(x, y)| x.text_tokens != y.text_tokens));
    }

    #[test]
    fn output_tokens_within_bounds() {
        let reqs = gen(MIX_MH, 11).generate(5000);
        assert!(reqs.iter().all(|r| (8..=1024).contains(&r.output_tokens)));
    }
}
