//! Self-contained utility substrates (the offline environment has no
//! rand/clap/proptest/serde, so the subsets this project needs live here).

pub mod cli;
pub mod proptest_lite;
pub mod rng;
pub mod stats;

/// Request-count knob for the examples: `TCM_EXAMPLE_REQUESTS` overrides
/// each example's default so the CI smoke job can execute every example
/// end-to-end in seconds (they are the de-facto API docs — compiling is
/// not the same as running). Unset or unparsable values keep `default`.
pub fn example_requests(default: usize) -> usize {
    std::env::var("TCM_EXAMPLE_REQUESTS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(default)
}
