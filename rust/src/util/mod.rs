//! Self-contained utility substrates (the offline environment has no
//! rand/clap/proptest/serde, so the subsets this project needs live here).

pub mod cli;
pub mod proptest_lite;
pub mod rng;
pub mod stats;
