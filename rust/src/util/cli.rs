//! Minimal command-line argument parser (clap is unavailable offline).
//!
//! Supports the subset the `tcm-serve` binary and the examples need:
//! `--flag`, `--key value`, `--key=value`, positional arguments, and
//! subcommands. Unknown options are an error (catches typos in scripts).

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    flags: Vec<String>,
    known_options: Vec<String>,
    known_flags: Vec<String>,
}

#[derive(Debug)]
pub struct CliError(pub String);

impl std::fmt::Display for CliError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

/// Declarative parser: declare the accepted options/flags, then parse.
pub struct Parser {
    options: Vec<(&'static str, &'static str)>, // (name, help)
    flags: Vec<(&'static str, &'static str)>,
    subcommands: Vec<(&'static str, &'static str)>,
    program: &'static str,
    about: &'static str,
}

impl Parser {
    pub fn new(program: &'static str, about: &'static str) -> Self {
        Parser { options: vec![], flags: vec![], subcommands: vec![], program, about }
    }

    pub fn option(mut self, name: &'static str, help: &'static str) -> Self {
        self.options.push((name, help));
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.flags.push((name, help));
        self
    }

    pub fn subcommand(mut self, name: &'static str, help: &'static str) -> Self {
        self.subcommands.push((name, help));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {} ", self.program, self.about, self.program);
        if !self.subcommands.is_empty() {
            s.push_str("<subcommand> ");
        }
        s.push_str("[options]\n");
        if !self.subcommands.is_empty() {
            s.push_str("\nSUBCOMMANDS:\n");
            for (n, h) in &self.subcommands {
                s.push_str(&format!("  {n:<18} {h}\n"));
            }
        }
        if !self.options.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for (n, h) in &self.options {
                s.push_str(&format!("  --{n} <value>   {h}\n"));
            }
        }
        if !self.flags.is_empty() {
            s.push_str("\nFLAGS:\n");
            for (n, h) in &self.flags {
                s.push_str(&format!("  --{n}   {h}\n"));
            }
        }
        s
    }

    /// Parse argv (without the program name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut out = Args {
            known_options: self.options.iter().map(|(n, _)| n.to_string()).collect(),
            known_flags: self.flags.iter().map(|(n, _)| n.to_string()).collect(),
            ..Default::default()
        };
        let mut it = argv.iter().peekable();

        if !self.subcommands.is_empty() {
            match it.peek() {
                Some(first) if !first.starts_with('-') => {
                    let name = it.next().unwrap();
                    if !self.subcommands.iter().any(|(n, _)| n == name) {
                        return Err(CliError(format!(
                            "unknown subcommand '{name}'\n\n{}",
                            self.usage()
                        )));
                    }
                    out.subcommand = Some(name.clone());
                }
                _ => {}
            }
        }

        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(body) = arg.strip_prefix("--") {
                let (key, inline_val) = match body.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                if out.known_flags.contains(&key) {
                    if inline_val.is_some() {
                        return Err(CliError(format!("flag --{key} takes no value")));
                    }
                    out.flags.push(key);
                } else if out.known_options.contains(&key) {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError(format!("--{key} requires a value")))?
                            .clone(),
                    };
                    out.options.insert(key, val);
                } else {
                    return Err(CliError(format!("unknown option --{key}\n\n{}", self.usage())));
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        Ok(out)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected a number, got '{v}'"))),
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected an integer, got '{v}'"))),
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64, CliError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError(format!("--{key}: expected an integer, got '{v}'"))),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    fn parser() -> Parser {
        Parser::new("test", "about")
            .subcommand("serve", "run server")
            .subcommand("bench", "run bench")
            .option("rate", "req/s")
            .option("model", "model name")
            .flag("verbose", "chatty")
    }

    #[test]
    fn parses_subcommand_options_flags() {
        let a = parser()
            .parse(&argv("serve --rate 2.5 --model=llava-7b --verbose pos1"))
            .unwrap();
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("rate"), Some("2.5"));
        assert_eq!(a.get_f64("rate", 0.0).unwrap(), 2.5);
        assert_eq!(a.get("model"), Some("llava-7b"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn defaults_apply() {
        let a = parser().parse(&argv("bench")).unwrap();
        assert_eq!(a.get_f64("rate", 2.0).unwrap(), 2.0);
        assert_eq!(a.get_or("model", "llava-7b"), "llava-7b");
        assert!(!a.has_flag("verbose"));
    }

    #[test]
    fn rejects_unknown_option() {
        assert!(parser().parse(&argv("serve --nope 1")).is_err());
    }

    #[test]
    fn rejects_unknown_subcommand() {
        assert!(parser().parse(&argv("explode")).is_err());
    }

    #[test]
    fn rejects_missing_value() {
        assert!(parser().parse(&argv("serve --rate")).is_err());
    }

    #[test]
    fn rejects_bad_number() {
        let a = parser().parse(&argv("serve --rate abc")).unwrap();
        assert!(a.get_f64("rate", 0.0).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(parser().parse(&argv("serve --verbose=1")).is_err());
    }

    #[test]
    fn help_is_error_with_usage() {
        let e = parser().parse(&argv("--help")).unwrap_err();
        assert!(e.0.contains("SUBCOMMANDS"));
    }
}
