//! Deterministic pseudo-random generation for workload synthesis.
//!
//! The `rand` crate family is unavailable in the offline crate set, so this
//! module provides the subset the serving simulator needs: a SplitMix64
//! core (trivially seedable and splittable) plus the distributions the
//! workload model draws from (uniform, exponential for Poisson
//! inter-arrivals, normal via Box–Muller, lognormal for token-length
//! marginals, categorical for modality mixes).
//!
//! Every generator is seeded explicitly; all experiments in EXPERIMENTS.md
//! are exactly reproducible from the seeds recorded there.

/// SplitMix64 PRNG. Small state, full 2^64 period, very fast.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed }
    }

    /// Derive an independent stream (e.g. one per request class) without
    /// correlating with the parent sequence.
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA0761D6478BD642F)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). Lemire multiply-shift.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform integer in [lo, hi] inclusive.
    #[inline]
    pub fn range_u64(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponential with rate `lambda` (mean 1/lambda). Poisson-process
    /// inter-arrival gaps. Always strictly positive: `1 - f64()` is in
    /// (0, 1], and at exactly 1.0 (`f64() == 0.0`, a 2^-53 draw) `ln()`
    /// would be 0.0 and the gap would collapse to zero — breaking the
    /// strictly-increasing arrival contract of `WorkloadGen::generate` —
    /// so the draw is clamped off the endpoint, matching `normal()`'s
    /// `max(f64::MIN_POSITIVE)` guard. Bit-identical for every other
    /// draw.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        // 1.0 - EPSILON/2 is the largest f64 below 1.0.
        let u = (1.0 - self.f64()).min(1.0 - f64::EPSILON / 2.0);
        -u.ln() / lambda
    }

    /// Standard normal via Box–Muller (no cached spare: simplicity over
    /// the 2x draw cost, which is irrelevant at our volumes).
    pub fn normal(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn normal_with(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterized by the *underlying* normal's mu/sigma.
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        self.normal_with(mu, sigma).exp()
    }

    /// Log-uniform over [lo, hi]: uniform in log space. This is the shape
    /// of the paper's Fig-2a text-token CDF (a straight line on a log-x
    /// axis from 10 to 10^4).
    pub fn log_uniform(&mut self, lo: f64, hi: f64) -> f64 {
        debug_assert!(lo > 0.0 && hi >= lo);
        (self.range_f64(lo.ln(), hi.ln())).exp()
    }

    /// Sample an index from unnormalized weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
        // all residues reachable
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let mean: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    /// Seed chosen so the very first `next_u64()` is exactly 0, hence
    /// `f64() == 0.0`: SplitMix64's finalizer is a bijection mapping
    /// 0 → 0, so the state after the gamma add must be 0 — i.e. the seed
    /// is `-GAMMA`. Regression for the duplicate-arrival bug: an
    /// unguarded `exponential()` returns exactly 0.0 on this draw.
    #[test]
    fn exponential_is_strictly_positive_on_zero_draw() {
        let crafted = 0u64.wrapping_sub(0x9E37_79B9_7F4A_7C15);
        let mut probe = Rng::new(crafted);
        assert_eq!(probe.f64(), 0.0, "seed no longer produces the zero draw");
        let mut r = Rng::new(crafted);
        let gap = r.exponential(2.0);
        assert!(gap > 0.0, "zero uniform draw must not collapse the gap, got {gap}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn log_uniform_bounds_and_median() {
        let mut r = Rng::new(17);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.log_uniform(10.0, 10_000.0)).collect();
        assert!(xs.iter().all(|&x| (10.0..=10_000.0).contains(&x)));
        let mut s = xs.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let median = s[n / 2];
        // geometric mean of bounds = 10^(2.5) ≈ 316
        assert!((median.log10() - 2.5).abs() < 0.05, "median={median}");
    }

    #[test]
    fn categorical_proportions() {
        let mut r = Rng::new(19);
        let w = [0.5, 0.3, 0.2];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[r.categorical(&w)] += 1;
        }
        for i in 0..3 {
            let p = counts[i] as f64 / n as f64;
            assert!((p - w[i]).abs() < 0.01, "p[{i}]={p}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(23);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_differ() {
        let mut parent = Rng::new(31);
        let mut child = parent.split();
        let a: Vec<u64> = (0..8).map(|_| parent.next_u64()).collect();
        let b: Vec<u64> = (0..8).map(|_| child.next_u64()).collect();
        assert_ne!(a, b);
    }
}
