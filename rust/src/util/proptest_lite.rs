//! A small property-based testing harness (proptest is unavailable
//! offline). Provides seeded case generation with automatic shrinking for
//! the coordinator invariants tests.
//!
//! Usage:
//! ```ignore
//! proptest_lite::run(200, |g| {
//!     let xs: Vec<u32> = g.vec(0..64, |g| g.u64_in(0, 100) as u32);
//!     // ... assert invariant, return Err(msg) to fail ...
//!     Ok(())
//! });
//! ```
//!
//! On failure the harness re-runs the failing seed with progressively
//! simpler size hints (a pragmatic shrink: smaller collections, smaller
//! magnitudes) and reports the smallest seed/size that still fails.

use crate::util::rng::Rng;

/// Generator handed to each property case.
pub struct Gen {
    pub rng: Rng,
    /// Size budget in [0.0, 1.0]; generators scale collection lengths and
    /// magnitudes by this to enable shrinking.
    pub size: f64,
    pub case: usize,
}

impl Gen {
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        let hi_scaled = lo + (((hi - lo) as f64) * self.size).round() as u64;
        self.rng.range_u64(lo, hi_scaled.max(lo))
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.u64_in(lo as u64, hi as u64) as usize
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        let hi_scaled = lo + (hi - lo) * self.size;
        self.rng.range_f64(lo, hi_scaled.max(lo))
    }

    pub fn bool(&mut self) -> bool {
        self.rng.bool(0.5)
    }

    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `cases` random cases of the property. Panics with a reproduction
/// line on failure.
pub fn run(cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    run_seeded(0x7C3_5EED, cases, prop)
}

pub fn run_seeded(seed: u64, cases: usize, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut meta = Rng::new(seed);
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen { rng: Rng::new(case_seed), size: 1.0, case };
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the same seed at smaller sizes; keep the
            // smallest size that still fails.
            let mut best: Option<(f64, String)> = None;
            for step in 1..=8 {
                let size = 1.0 - step as f64 / 9.0;
                let mut g = Gen { rng: Rng::new(case_seed), size, case };
                if let Err(m) = prop(&mut g) {
                    best = Some((size, m));
                }
            }
            let (size, final_msg) = best.unwrap_or((1.0, msg));
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, size {size:.2}): {final_msg}\n\
                 reproduce with proptest_lite::run_case({case_seed:#x}, {size:.2}, prop)"
            );
        }
    }
}

/// Re-run a single failing case (for debugging).
pub fn run_case(case_seed: u64, size: f64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    let mut g = Gen { rng: Rng::new(case_seed), size, case: 0 };
    if let Err(msg) = prop(&mut g) {
        panic!("case failed: {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run(100, |g| {
            let x = g.u64_in(0, 100);
            if x <= 100 {
                Ok(())
            } else {
                Err(format!("x={x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_reports() {
        run(100, |g| {
            let xs = g.vec(16, |g| g.u64_in(0, 100));
            if xs.iter().sum::<u64>() < 400 {
                Ok(())
            } else {
                Err("sum too large".into())
            }
        });
    }

    #[test]
    fn generators_respect_bounds() {
        run(200, |g| {
            let a = g.u64_in(5, 10);
            let b = g.f64_in(-1.0, 1.0);
            if (5..=10).contains(&a) && (-1.0..=1.0).contains(&b) {
                Ok(())
            } else {
                Err(format!("a={a} b={b}"))
            }
        });
    }
}
