//! Statistical primitives for the profiler, estimator and classifier.
//!
//! Everything the paper's learning components need, self-contained:
//!   * summary statistics and percentiles (metrics reporting),
//!   * ordinary least squares (the text prefill estimator, §3.3),
//!   * quantile regression at τ=0.9 (the image/video prefill estimator,
//!     fitted by iterated subgradient descent on the pinball loss),
//!   * k-means (the smart classifier's clustering backend, §3.4).

/// Arithmetic mean. 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile with linear interpolation, q in [0, 100]. NaN-free input
/// required. O(n log n); fine at our sample sizes.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    percentile_sorted(&s, q)
}

/// Percentile over an already-sorted slice.
pub fn percentile_sorted(s: &[f64], q: f64) -> f64 {
    if s.is_empty() {
        return 0.0;
    }
    let rank = (q / 100.0) * (s.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        s[lo]
    } else {
        let w = rank - lo as f64;
        s[lo] * (1.0 - w) + s[hi] * w
    }
}

pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().cloned().fold(f64::INFINITY, f64::min)
}

/// Empirical CDF evaluation points: returns (sorted_xs, cum_prob).
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    let n = s.len() as f64;
    let probs = (1..=s.len()).map(|i| i as f64 / n).collect();
    (s, probs)
}

// ---------------------------------------------------------------------
// Ordinary least squares: y ≈ a + b·x
// ---------------------------------------------------------------------

/// Closed-form simple linear regression.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearFit {
    pub intercept: f64,
    pub slope: f64,
}

impl LinearFit {
    pub fn fit(xs: &[f64], ys: &[f64]) -> LinearFit {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        let mx = mean(xs);
        let my = mean(ys);
        let mut sxx = 0.0;
        let mut sxy = 0.0;
        for (&x, &y) in xs.iter().zip(ys) {
            sxx += (x - mx) * (x - mx);
            sxy += (x - mx) * (y - my);
        }
        let slope = if sxx > 0.0 { sxy / sxx } else { 0.0 };
        LinearFit { intercept: my - slope * mx, slope }
    }

    #[inline]
    pub fn predict(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }

    /// Coefficient of determination on a dataset.
    pub fn r2(&self, xs: &[f64], ys: &[f64]) -> f64 {
        let my = mean(ys);
        let ss_tot: f64 = ys.iter().map(|y| (y - my).powi(2)).sum();
        let ss_res: f64 = xs
            .iter()
            .zip(ys)
            .map(|(&x, &y)| (y - self.predict(x)).powi(2))
            .sum();
        if ss_tot == 0.0 {
            1.0
        } else {
            1.0 - ss_res / ss_tot
        }
    }
}

// ---------------------------------------------------------------------
// Quantile regression: y ≈ a + b·x at quantile τ (pinball loss)
// ---------------------------------------------------------------------

/// Linear quantile regression fitted by subgradient descent on the pinball
/// loss, warm-started from OLS. The paper (§3.3) uses τ = 0.9 for image
/// and video prefill estimates "to avoid underestimation and protect SLO
/// compliance".
#[derive(Debug, Clone, Copy)]
pub struct QuantileFit {
    pub intercept: f64,
    pub slope: f64,
    pub tau: f64,
}

impl QuantileFit {
    pub fn fit(xs: &[f64], ys: &[f64], tau: f64) -> QuantileFit {
        assert_eq!(xs.len(), ys.len());
        assert!(!xs.is_empty());
        assert!((0.0..1.0).contains(&tau) || tau == 1.0);
        let ols = LinearFit::fit(xs, ys);
        let (mut a, mut b) = (ols.intercept, ols.slope);
        // Normalize x for conditioning.
        let mx = mean(xs);
        let sx = std_dev(xs).max(1e-12);
        let sy = std_dev(ys).max(1e-12);
        let n = xs.len() as f64;
        // Subgradient of pinball loss: -tau if residual>0 else (1-tau).
        let mut lr = 0.5 * sy;
        for epoch in 0..400 {
            let mut ga = 0.0;
            let mut gb = 0.0;
            for (&x, &y) in xs.iter().zip(ys) {
                // Updates happen in normalized-x coordinates for stable
                // conditioning; the fit is denormalized once at the end.
                let xn = (x - mx) / sx;
                let res = y - (a + b * xn);
                let g = if res > 0.0 { -tau } else { 1.0 - tau };
                ga += g;
                gb += g * xn;
            }
            a -= lr * ga / n;
            b -= lr * gb / n;
            if epoch % 40 == 39 {
                lr *= 0.5;
            }
        }
        // Denormalize: pred = a + b*(x - mx)/sx = (a - b*mx/sx) + (b/sx)*x
        QuantileFit { intercept: a - b * mx / sx, slope: b / sx, tau }
    }

    #[inline]
    pub fn predict(&self, x: f64) -> f64 {
        self.intercept + self.slope * x
    }

    /// Fraction of points at or below the fitted line (should be ≈ tau).
    pub fn coverage(&self, xs: &[f64], ys: &[f64]) -> f64 {
        let below = xs
            .iter()
            .zip(ys)
            .filter(|(&x, &y)| y <= self.predict(x))
            .count();
        below as f64 / xs.len() as f64
    }
}

// ---------------------------------------------------------------------
// K-means (the smart classifier backend)
// ---------------------------------------------------------------------

/// K-means with k-means++ seeding over points in R^d.
#[derive(Debug, Clone)]
pub struct KMeans {
    pub centroids: Vec<Vec<f64>>,
}

impl KMeans {
    /// Fit on `points` (each a d-vector) with deterministic seeding.
    pub fn fit(points: &[Vec<f64>], k: usize, seed: u64) -> KMeans {
        assert!(!points.is_empty());
        assert!(k >= 1);
        let d = points[0].len();
        let mut rng = crate::util::rng::Rng::new(seed);
        let k = k.min(points.len());

        // k-means++ seeding.
        let mut centroids: Vec<Vec<f64>> = Vec::with_capacity(k);
        centroids.push(points[rng.below(points.len() as u64) as usize].clone());
        while centroids.len() < k {
            let d2: Vec<f64> = points
                .iter()
                .map(|p| {
                    centroids
                        .iter()
                        .map(|c| sq_dist(p, c))
                        .fold(f64::INFINITY, f64::min)
                })
                .collect();
            let total: f64 = d2.iter().sum();
            if total == 0.0 {
                // all points identical to some centroid; duplicate one
                centroids.push(centroids[0].clone());
                continue;
            }
            let idx = rng.categorical(&d2);
            centroids.push(points[idx].clone());
        }

        // Lloyd iterations.
        let mut assign = vec![0usize; points.len()];
        for _ in 0..100 {
            let mut changed = false;
            for (i, p) in points.iter().enumerate() {
                let best = nearest(&centroids, p).0;
                if assign[i] != best {
                    assign[i] = best;
                    changed = true;
                }
            }
            let mut sums = vec![vec![0.0; d]; k];
            let mut counts = vec![0usize; k];
            for (i, p) in points.iter().enumerate() {
                counts[assign[i]] += 1;
                for (j, &v) in p.iter().enumerate() {
                    sums[assign[i]][j] += v;
                }
            }
            for c in 0..k {
                if counts[c] > 0 {
                    for j in 0..d {
                        centroids[c][j] = sums[c][j] / counts[c] as f64;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        KMeans { centroids }
    }

    /// Index of the nearest centroid.
    pub fn assign(&self, p: &[f64]) -> usize {
        nearest(&self.centroids, p).0
    }

    /// Centroid magnitudes (L2 norm): used to order clusters into
    /// motorcycles < cars < trucks by resource intensity.
    pub fn centroid_norms(&self) -> Vec<f64> {
        self.centroids
            .iter()
            .map(|c| c.iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect()
    }
}

fn sq_dist(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum()
}

fn nearest(cs: &[Vec<f64>], p: &[f64]) -> (usize, f64) {
    let mut best = (0, f64::INFINITY);
    for (i, c) in cs.iter().enumerate() {
        let d = sq_dist(p, c);
        if d < best.1 {
            best = (i, d);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn percentile_basics() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert!((percentile(&xs, 90.0) - 4.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_empty_and_single() {
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(percentile(&[7.0], 99.0), 7.0);
    }

    #[test]
    fn linear_fit_exact() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let f = LinearFit::fit(&xs, &ys);
        assert!((f.intercept - 3.0).abs() < 1e-9);
        assert!((f.slope - 2.0).abs() < 1e-9);
        assert!((f.r2(&xs, &ys) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn linear_fit_noisy_recovers_slope() {
        let mut rng = Rng::new(5);
        let xs: Vec<f64> = (0..2000).map(|_| rng.range_f64(0.0, 100.0)).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 1.0 + 0.5 * x + rng.normal()).collect();
        let f = LinearFit::fit(&xs, &ys);
        assert!((f.slope - 0.5).abs() < 0.01, "slope={}", f.slope);
        assert!(f.r2(&xs, &ys) > 0.95);
    }

    #[test]
    fn quantile_fit_coverage_near_tau() {
        let mut rng = Rng::new(6);
        let xs: Vec<f64> = (0..3000).map(|_| rng.range_f64(0.0, 10.0)).collect();
        // heteroscedastic noise like real prefill latency
        let ys: Vec<f64> = xs
            .iter()
            .map(|x| 2.0 * x + rng.normal().abs() * (0.5 + 0.2 * x))
            .collect();
        let f = QuantileFit::fit(&xs, &ys, 0.9);
        let cov = f.coverage(&xs, &ys);
        assert!((cov - 0.9).abs() < 0.05, "coverage={cov}");
        // P90 line must sit above the OLS line on average
        let ols = LinearFit::fit(&xs, &ys);
        assert!(f.predict(5.0) > ols.predict(5.0));
    }

    #[test]
    fn quantile_fit_tau_one_majorizes() {
        let xs = vec![1.0, 2.0, 3.0, 4.0];
        let ys = vec![1.0, 2.5, 2.8, 4.2];
        let f = QuantileFit::fit(&xs, &ys, 0.99);
        let cov = f.coverage(&xs, &ys);
        assert!(cov >= 0.75, "cov={cov}");
    }

    #[test]
    fn kmeans_separates_three_scales() {
        // three log-scale blobs like motorcycles / cars / trucks
        let mut rng = Rng::new(7);
        let mut pts = Vec::new();
        let mut labels = Vec::new();
        for (label, center) in [(0, 1.5), (1, 2.8), (2, 4.5)] {
            for _ in 0..200 {
                pts.push(vec![
                    center + rng.normal() * 0.2,
                    center + rng.normal() * 0.2,
                ]);
                labels.push(label);
            }
        }
        let km = KMeans::fit(&pts, 3, 42);
        // order clusters by norm -> should recover the three blobs
        let norms = km.centroid_norms();
        let mut order: Vec<usize> = (0..3).collect();
        order.sort_by(|&a, &b| norms[a].total_cmp(&norms[b]));
        let rank = |c: usize| order.iter().position(|&o| o == c).unwrap();
        let correct = pts
            .iter()
            .zip(&labels)
            .filter(|(p, &l)| rank(km.assign(p)) == l)
            .count();
        assert!(correct as f64 / pts.len() as f64 > 0.97);
    }

    #[test]
    fn kmeans_k_larger_than_points() {
        let pts = vec![vec![0.0, 0.0], vec![1.0, 1.0]];
        let km = KMeans::fit(&pts, 5, 1);
        assert!(km.centroids.len() <= 5);
        assert!(km.assign(&[0.1, 0.1]) < km.centroids.len());
    }

    #[test]
    fn ecdf_monotone() {
        let (xs, ps) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(xs, vec![1.0, 2.0, 3.0]);
        assert_eq!(ps, vec![1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }
}
