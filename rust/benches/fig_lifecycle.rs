//! fig_lifecycle — the request-lifecycle API under load: a cancel-heavy
//! scenario (abandonment-heavy traffic, ServeGen-style) and a
//! deadline-mix scenario (latency-critical vs best-effort tiers sharing
//! a fleet).
//!
//! Cancel-heavy: 30% of requests are abandoned a fixed number of
//! scheduler steps after injection. The interesting quantities are the
//! makespan (cancelled work must *shrink* the schedule — freed KV and
//! encoder slots go back to surviving requests) and conservation
//! (`finished + cancelled == submitted`, bit-deterministic).
//!
//! Deadline-mix: the same trace with every third request Critical and a
//! tight explicit deadline, every fifth BestEffort. The critical tier's
//! SLO attainment must beat the undeclared baseline's on the same trace.
//!
//! With `BENCH_JSON=path` set, each scenario lands in the JSONL sink;
//! `lifecycle/cancel-heavy/makespan` is the hot-gated headline (virtual
//! time → machine-independent and bit-deterministic, so the >25% CI gate
//! cannot flake).

use tcm_serve::backend::{self, ServeBackend};
use tcm_serve::bench_harness::record_named;
use tcm_serve::config::ServeConfig;
use tcm_serve::coordinator::StepOutcome;
use tcm_serve::experiments::make_trace;
use tcm_serve::metrics::Report;
use tcm_serve::request::{Request, SloClass};

fn cfg() -> ServeConfig {
    let mut c = ServeConfig::default();
    c.policy = "tcm".into();
    c.mix = "MH".into();
    c.rate = 3.0;
    c.num_requests = 300;
    c.seed = 71;
    c.cluster.replicas = 2;
    c.cluster.router = "least-work".into();
    c.pool.enabled = true;
    c.pool.slots = 2;
    c
}

/// Drive a backend with a deterministic cancellation schedule: request
/// `id` is cancelled `delay` steps after the run starts when
/// `id % 10 < 3` (a 30% abandonment rate). Returns (report, makespan).
fn run_with_cancels(c: &ServeConfig, trace: Vec<Request>, delay: u64) -> (Report, f64) {
    let mut b = backend::build(c);
    let cancel_ids: Vec<u64> = trace.iter().map(|r| r.id).filter(|id| id % 10 < 3).collect();
    for req in trace {
        b.inject(req);
    }
    let mut collected = Report::default();
    let mut steps = 0u64;
    loop {
        match b.step() {
            StepOutcome::Executed { .. } => {}
            StepOutcome::Idle { next_event } => b.advance_to(next_event),
            StepOutcome::Blocked { next_event: Some(t) } => b.advance_to(t),
            StepOutcome::Blocked { next_event: None } => b.drop_blocked(),
            StepOutcome::Drained => break,
        }
        if steps == delay {
            for &id in &cancel_ids {
                b.cancel(id);
            }
        }
        b.take_events();
        collected.merge(b.take_finished());
        steps += 1;
        assert!(steps < 5_000_000, "did not drain");
    }
    b.take_events();
    collected.merge(b.take_finished());
    collected.sort_by_id();
    (collected, b.now())
}

fn main() {
    let base = cfg();
    let profile = tcm_serve::model::by_name(&base.model).unwrap();
    let trace = make_trace(&base, &profile);
    let n = trace.len();

    println!("=== fig_lifecycle — 2 replicas + pool, MH mix, tcm, 3 req/s, llava-7b ===");

    // ------------------------------------------------------------------
    // cancel-heavy: no cancels vs 30% abandoned after 200 steps
    // ------------------------------------------------------------------
    println!("\n--- cancel-heavy (30% of ids abandoned) ---");
    let (clean, clean_makespan) = run_with_cancels(&base, trace.clone(), u64::MAX);
    let (abandoned, ab_makespan) = run_with_cancels(&base, trace.clone(), 200);
    println!(
        "{:<22} finished={:<5} cancelled={:<5} makespan={:>8.1}s slo={:>5.1}%",
        "no-cancels",
        clean.outcomes.len(),
        clean.cancelled.len(),
        clean_makespan,
        clean.slo_attainment() * 100.0
    );
    println!(
        "{:<22} finished={:<5} cancelled={:<5} makespan={:>8.1}s slo={:>5.1}%",
        "30%-abandoned",
        abandoned.outcomes.len(),
        abandoned.cancelled.len(),
        ab_makespan,
        abandoned.slo_attainment() * 100.0
    );
    assert_eq!(clean.total(), n, "conservation without cancels");
    assert_eq!(abandoned.total(), n, "finished + failed + cancelled == submitted");
    assert!(!abandoned.cancelled.is_empty(), "the scenario must exercise cancellation");
    println!(
        "abandonment reclaimed {:.1}% of the schedule ({})",
        100.0 * (1.0 - ab_makespan / clean_makespan),
        if ab_makespan < clean_makespan { "freed capacity reused" } else { "NO — regression" }
    );
    // virtual-time gate metrics: bit-deterministic per seed
    record_named("lifecycle/cancel-heavy/makespan", ab_makespan * 1e9, None, true);
    record_named("lifecycle/no-cancels/makespan", clean_makespan * 1e9, None, false);

    // ------------------------------------------------------------------
    // deadline-mix: declared tiers vs the undeclared baseline
    // ------------------------------------------------------------------
    println!("\n--- deadline-mix (every 3rd Critical w/ tight deadline, every 5th BestEffort) ---");
    let tiered: Vec<Request> = trace
        .iter()
        .cloned()
        .map(|mut r| {
            if r.id % 3 == 0 {
                r.slo_class = Some(SloClass::Critical);
                r.deadline_s = Some(2.5 * profile.isolated_e2e(&r));
            } else if r.id % 5 == 0 {
                r.slo_class = Some(SloClass::BestEffort);
            }
            r
        })
        .collect();
    let (mixed, mixed_makespan) = run_with_cancels(&base, tiered, u64::MAX);
    let tier_slo = |rep: &Report, pred: &dyn Fn(u64) -> bool| {
        let outs: Vec<_> = rep.outcomes.iter().filter(|o| pred(o.id)).collect();
        let ok = outs.iter().filter(|o| !o.violates_slo()).count();
        (ok as f64 / outs.len().max(1) as f64, outs.len())
    };
    let (crit_att, crit_n) = tier_slo(&mixed, &|id| id % 3 == 0);
    let (base_att, base_n) = tier_slo(&clean, &|id| id % 3 == 0);
    println!(
        "critical tier: n={crit_n} attainment={:.1}% (tight 2.5x deadlines) vs undeclared \
         n={base_n} {:.1}% (lax 5x default)",
        crit_att * 100.0,
        base_att * 100.0
    );
    println!("mixed makespan={mixed_makespan:.1}s (same work, reordered by tier)");
    record_named("lifecycle/deadline-mix/makespan", mixed_makespan * 1e9, None, false);

    println!("\nExpected shape: abandonment shortens the schedule (cancel frees KV and");
    println!("encoder slots mid-flight); the critical tier holds high attainment even");
    println!("against deadlines half as forgiving as the default.");
}
