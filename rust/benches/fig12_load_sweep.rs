//! Fig 12 — Scalability under increasing load: overall normalized
//! latency, average TTFT and P90 TTFT as the request rate grows.
//!
//! Paper shape: vLLM degrades sharply; EDF holds longer but its tail
//! (P90) approaches vLLM at high load; TCM sustains the lowest latency
//! and sharply reduces tail latency at peak rates.

use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::run_sim;

fn main() {
    println!("Fig 12 — load sweep (MH, llava-7b)");
    println!(
        "{:>6} {:>16} {:>12} {:>12} {:>12}",
        "req/s", "policy", "norm(s/tok)", "ttft_avg(s)", "ttft_p90(s)"
    );
    for rate in [0.5, 1.0, 2.0, 3.0, 4.0, 6.0] {
        for policy in ["fcfs", "edf", "tcm"] {
            let mut cfg = ServeConfig::default();
            cfg.policy = policy.into();
            cfg.rate = rate;
            cfg.num_requests = 500;
            cfg.seed = 12;
            let r = run_sim(&cfg);
            let o = r.report.overall();
            println!(
                "{rate:>6.1} {policy:>16} {:>12.4} {:>12.3} {:>12.3}",
                o.avg_norm_latency, o.avg_ttft, o.p90_ttft
            );
        }
    }
}
