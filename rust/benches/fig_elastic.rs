//! fig_elastic — the elastic control plane against every static
//! configuration on the PR-9 mix-flip trace.
//!
//! Scenario: a client population floods the fleet with pure text
//! (`T0`) and flips video-heavy (`VH`) at t=25s, at a rate that
//! overloads any single replica. Every static arm is wrong in one of
//! the two regimes:
//!
//!   * the static modality-partition split (1/1/2 at n=4) pins sand to
//!     one replica, so the text flood queues unboundedly before the
//!     flip;
//!   * round-robin and least-work survive the flood (all four replicas
//!     take text) but mix videos into every queue after the flip, so
//!     late sand requests wait behind multi-second video prefills;
//!   * the elastic controller starts at 1/1/2, reads the text queue at
//!     the first epoch, drains an idle rock into sand (2/1/1) within
//!     seconds, then gives the replica back to the rocks after the
//!     flip — low sand tails in both regimes.
//!
//! All arms run fcfs so the comparison isolates the partition dimension
//! (policy-level mitigation is fig_servegen's subject). Sand p99 TTFT
//! is hard-asserted: elastic strictly beats every static arm, and the
//! elastic run is bit-deterministic. A second section grows the encoder
//! pool under the post-flip video backlog.
//!
//! With `BENCH_JSON=path` set each arm lands in the JSONL sink;
//! `elastic/flip/elastic/sand-p99-ttft` is the hot-gated headline.

use tcm_serve::bench_harness::record_named;
use tcm_serve::cluster::Cluster;
use tcm_serve::config::ServeConfig;
use tcm_serve::experiments::make_trace;
use tcm_serve::model::by_name;
use tcm_serve::request::Modality;

const FLIP_AT_S: f64 = 25.0;

fn cfg() -> ServeConfig {
    let mut c = ServeConfig::default();
    c.model = "llava-7b".into();
    c.policy = "fcfs".into();
    c.mix = "T0".into();
    c.rate = 10.0;
    c.num_requests = 500;
    c.seed = 17;
    c.cluster.replicas = 4;
    c.cluster.router = "modality-partition".into();
    c.workload.engine = "population".into();
    c.workload.mix_flip_at_s = FLIP_AT_S;
    c.workload.mix_flip_to = "VH".into();
    c
}

fn elastic_cfg() -> ServeConfig {
    let mut c = cfg();
    c.elastic.enabled = true;
    c.elastic.epoch_s = 1.0;
    c.elastic.hysteresis = 0.25;
    c.elastic.cooldown_epochs = 0;
    c
}

/// Run one arm and return (sand p99 TTFT, sand mean, rock mean, report).
fn run_arm(c: &ServeConfig, trace: &[tcm_serve::request::Request]) -> (f64, f64, f64, Cluster) {
    let mut cluster = Cluster::new(c);
    let cr = cluster.run(trace.to_vec());
    assert_eq!(cr.report.total(), trace.len(), "conservation");
    let sand = cr.report.by_modality(Modality::Text);
    let rocks = cr.report.by_modality(Modality::Video);
    (sand.p99_ttft, sand.avg_ttft, rocks.avg_ttft, cluster)
}

fn main() {
    let base = cfg();
    let profile = by_name(&base.model).unwrap();
    let trace = make_trace(&base, &profile);
    let n = trace.len();

    println!(
        "=== fig_elastic — T0→VH flip @ {FLIP_AT_S}s, {} req/s, 4 replicas ===",
        base.rate
    );

    // trace shape: the flip must move video share from ~zero to heavy
    let vfrac = |lo: f64, hi: f64| {
        let mut total = 0usize;
        let mut videos = 0usize;
        for r in &trace {
            if r.arrival >= lo && r.arrival < hi {
                total += 1;
                if r.modality == Modality::Video {
                    videos += 1;
                }
            }
        }
        (videos as f64 / total.max(1) as f64, total)
    };
    let last = trace.iter().map(|r| r.arrival).fold(0.0_f64, f64::max);
    let (v_before, n_before) = vfrac(0.0, FLIP_AT_S);
    let (v_after, n_after) = vfrac(FLIP_AT_S, last + 1.0);
    println!(
        "video fraction: {:.1}% of {n_before} before the flip → {:.1}% of {n_after} after",
        v_before * 100.0,
        v_after * 100.0
    );
    assert!(n_before > 0 && n_after > 0, "flip must split the run");
    assert!(v_after > v_before, "the flip must raise video share");

    // ------------------------------------------------------------------
    // elastic vs every static arm on sand p99 TTFT
    // ------------------------------------------------------------------
    println!("\n--- sand p99 TTFT, elastic vs static (fcfs) ---");
    let mut static_p99 = Vec::new();
    for router in ["round-robin", "least-work", "modality-partition"] {
        let mut c = base.clone();
        c.cluster.router = router.into();
        let (p99, mean, rock_mean, _) = run_arm(&c, &trace);
        println!(
            "static {:<18} sand p99-ttft={:>8.3}s mean={:>8.3}s | rocks mean={:>8.3}s",
            router, p99, mean, rock_mean
        );
        record_named(&format!("elastic/flip/{router}/sand-p99-ttft"), p99 * 1e9, None, false);
        static_p99.push((router, p99));
    }

    let ec = elastic_cfg();
    let (e_p99, e_mean, e_rock_mean, cluster) = run_arm(&ec, &trace);
    let snap = cluster.elastic_snapshot().expect("controller attached");
    println!(
        "elastic {:<17} sand p99-ttft={:>8.3}s mean={:>8.3}s | rocks mean={:>8.3}s",
        "(partition)", e_p99, e_mean, e_rock_mean
    );
    println!(
        "controller: epochs={} drains={} repartitions={} groups={}/{}/{} (sand/pebble/rock)",
        snap.stats.epochs,
        snap.stats.drains_started,
        snap.stats.repartitions,
        snap.sand.len(),
        snap.pebble.len(),
        snap.rock.len()
    );
    record_named("elastic/flip/elastic/sand-p99-ttft", e_p99 * 1e9, None, true);

    assert!(snap.stats.repartitions >= 1, "controller never repartitioned: {:?}", snap.stats);
    assert_eq!(snap.stats.max_active_at_flip, 0, "replica flipped groups while occupied");
    for (router, p99) in &static_p99 {
        assert!(
            e_p99 < *p99,
            "elastic sand p99 {e_p99:.3}s does not beat static {router} ({p99:.3}s)"
        );
    }
    println!("elastic beats every static arm on sand p99: yes");

    // bit-determinism: the controller's decisions rerun identically
    {
        let (p99b, _, _, cluster2) = run_arm(&ec, &trace);
        let snap2 = cluster2.elastic_snapshot().expect("controller attached");
        assert_eq!(e_p99.to_bits(), p99b.to_bits(), "elastic rerun diverged");
        assert_eq!(snap.stats, snap2.stats, "controller decisions diverged");
        assert_eq!(
            (&snap.sand, &snap.pebble, &snap.rock),
            (&snap2.sand, &snap2.pebble, &snap2.rock)
        );
        println!("rerun bit-identity: ok (stats {:?})", snap.stats);
    }

    // ------------------------------------------------------------------
    // encoder-pool elasticity under the post-flip video backlog
    // ------------------------------------------------------------------
    println!("\n--- encoder pool: 1 slot, elastic up to 4 ---");
    let mut pc = elastic_cfg();
    pc.pool.enabled = true;
    pc.pool.slots = 1;
    pc.elastic.slots_min = 1;
    pc.elastic.slots_max = 4;
    let mut cluster = Cluster::new(&pc);
    let cr = cluster.run(trace.clone());
    assert_eq!(cr.report.total(), n, "pool arm: conservation");
    let p = cr.pool.as_ref().expect("pool enabled");
    let e = cr.elastic.as_ref().expect("controller attached");
    println!(
        "slots: start=1 peak={} now={} | grow_events={} shrink_events={} (controller grows={})",
        p.max_concurrent_slots,
        p.slots,
        p.slot_grow_events,
        p.slot_shrink_events,
        e.stats.slot_grows
    );
    assert!(
        p.slot_grow_events >= 1 && p.max_concurrent_slots >= 2,
        "post-flip video backlog never grew the pool: {:?}",
        p.stats
    );

    println!("\nExpected shape: the text flood overloads the static 1/1/2 split's single");
    println!("sand replica while round-robin/least-work mix post-flip videos into every");
    println!("queue; the controller re-partitions within seconds of each regime and grows");
    println!("the encoder pool once the video backlog queues behind one slot.");
}
